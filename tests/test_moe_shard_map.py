"""shard_map MoE ≡ dense MoE (dropless), on trivial and 2×2 meshes."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.registry import reduced_config
from repro.models.moe import moe_apply, moe_init
from repro.models.moe_shard_map import moe_apply_shard_map

cfg = dataclasses.replace(reduced_config("qwen3-moe-235b-a22b"), capacity_factor=8.0)
key = jax.random.PRNGKey(0)
p = moe_init(key, cfg, jnp.float32)
x = jax.random.normal(key, (2, 16, cfg.d_model))
y_d, aux_d = moe_apply(p, x, cfg)
rules = {"batch": ("data",), "seq_res": None}

mesh1 = jax.make_mesh((1, 1), ("data", "model"))
with mesh1:
    y1, a1 = jax.jit(lambda p_, x_: moe_apply_shard_map(p_, x_, cfg, mesh1, rules))(p, x)
assert float(jnp.abs(y1 - y_d).max()) < 1e-5, "1x1 mismatch"
assert abs(float(a1) - float(aux_d)) < 1e-5

mesh = jax.make_mesh((2, 2), ("data", "model"))
xs = jax.device_put(x, NamedSharding(mesh, P("data", None, None)))
with mesh:
    y2, a2 = jax.jit(lambda p_, x_: moe_apply_shard_map(p_, x_, cfg, mesh, rules))(p, xs)
assert float(jnp.abs(y2 - y_d).max()) < 1e-5, "2x2 mismatch"

# gradients flow through the all_to_all exchange
g = jax.grad(lambda p_: jnp.sum(jnp.tanh(
    moe_apply_shard_map(p_, xs, cfg, mesh, rules)[0])))(p)
import numpy as np
with mesh:
    pass
for leaf in jax.tree.leaves(g):
    assert bool(jnp.isfinite(leaf).all()), "NaN grads through shard_map MoE"
print("SHARD_MAP_MOE_OK")
"""


def test_shard_map_moe_subprocess():
    """Needs 4 host devices → subprocess (XLA_FLAGS before jax init)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], cwd=REPO, env=env,
        capture_output=True, text=True, timeout=480,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARD_MAP_MOE_OK" in out.stdout
