"""The three AR workloads (paper §2.2–2.3, Fig. 2, Table 1).

TDG structures follow the paper's description: Audio has 15 tasks and the
highest task-level parallelism; CAVA is a serial ISP pipeline (TaLP = 1);
Edge Detection has 6 tasks, modest TaLP (4) and the highest LLP / data
movement. Per-task Gables numbers are spread deterministically around the
Table-1 per-task averages (the paper's appendix task tables are not in the
text) so that every Table-1 average is matched exactly.

Budgets: Table 4a gives 21/34/34 ms latencies with 8.737 mW / 17.475 mm²
system budgets at 5 nm. Those power numbers are not reachable under *any*
physical pJ/op constant given Table 1's own op counts (CAVA alone runs
~170 Gops per 34 ms frame → ≥1 W at 5 nm-class 0.3 pJ/op; the paper's internal
AccelSeeker database evidently counts "ops" differently). We therefore keep
the paper's latency budgets and latency *ratios*, and calibrate power/area
budgets against our own database (``calibrated_budget``) so that convergence
experiments are demanding but feasible — see EXPERIMENTS.md §Deviations.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List

from .budgets import Budget
from .database import HardwareDatabase
from .tdg import Task, TaskGraph, merge_graphs

MOPS = 1e6
MB = 1e6


def _spread(center: float, names: List[str], lo: float = 0.5, hi: float = 1.5) -> Dict[str, float]:
    """Deterministic per-task factors in [lo, hi], rescaled to preserve the
    mean exactly (Table-1 values are per-task averages)."""
    raw = {}
    for n in names:
        h = int.from_bytes(hashlib.sha256(n.encode()).digest()[:8], "big") / 2**64
        raw[n] = lo + (hi - lo) * h
    mean = sum(raw.values()) / len(raw)
    return {n: center * v / mean for n, v in raw.items()}


def audio() -> TaskGraph:
    """Audio decoder: pose-driven soundfield rotation/zoom + speaker mapping.
    15 tasks: source-decode → 8 parallel ambisonic channel encoders → combine
    → 4 parallel band rotate/zoom stages → binaural mix (high TaLP)."""
    g = TaskGraph("audio")
    names = (
        ["src_decode"]
        + [f"enc_ch{i}" for i in range(8)]
        + ["combine"]
        + [f"rotzoom_b{i}" for i in range(4)]
        + ["binaural_mix"]
    )
    f = _spread(13 * MOPS, names)
    llp = _spread(2392.0, names)
    for n in names:
        g.add_task(
            Task(n, work_ops=f[n], i_read=8.0, i_write=12.0, llp=llp[n], burst_bytes=256)
        )
    edge = 0.19 * MB  # Table-1 average data movement per task
    for i in range(8):
        g.add_edge("src_decode", f"enc_ch{i}", edge)
        g.add_edge(f"enc_ch{i}", "combine", edge)
    for i in range(4):
        g.add_edge("combine", f"rotzoom_b{i}", edge)
        g.add_edge(f"rotzoom_b{i}", "binaural_mix", edge)
    g.validate()
    return g


def cava() -> TaskGraph:
    """CAVA camera-vision ISP pipeline (Nikon-D7000-modelled kernel): a strict
    serial chain — TaLP = 1, only loop-level parallelism (Table 1)."""
    g = TaskGraph("cava")
    names = ["scale", "demosaic", "denoise", "wbalance", "cspace", "gamut", "tonemap"]
    f = _spread(24_252 * MOPS, names)
    llp = _spread(151.0, names)
    for n in names:
        g.add_task(
            Task(n, work_ops=f[n], i_read=67e3, i_write=74e3, llp=llp[n], burst_bytes=1024)
        )
    for a, b in zip(names, names[1:]):
        g.add_edge(a, b, 0.33 * MB)
    g.validate()
    return g


def edge_detection() -> TaskGraph:
    """Edge detection: 6 tasks, gradient operators run in parallel (TaLP = 4),
    massive LLP (per-pixel independence) and the highest data movement."""
    g = TaskGraph("ed")
    names = ["grayscale", "gauss_blur", "grad_x", "grad_y", "laplacian", "magnitude"]
    f = _spread(1_098 * MOPS, names)
    llp = _spread(1_365_376.0, names)
    for n in names:
        g.add_task(
            Task(n, work_ops=f[n], i_read=126.0, i_write=1.23e6, llp=llp[n], burst_bytes=4096)
        )
    g.add_edge("grayscale", "gauss_blur", 7.01 * MB)
    for n in ("grad_x", "grad_y", "laplacian"):
        g.add_edge("gauss_blur", n, 7.01 * MB)
        g.add_edge(n, "magnitude", 7.01 * MB)
    g.validate()
    return g


def all_workloads() -> Dict[str, TaskGraph]:
    return {"audio": audio(), "cava": cava(), "ed": edge_detection()}


def ar_complex() -> TaskGraph:
    """The §5 SoC scenario: all three workloads running together."""
    return merge_graphs(all_workloads().values(), name="ar_complex")


PAPER_LATENCY_S = {"audio": 21e-3, "cava": 34e-3, "ed": 34e-3}


def paper_budget() -> Budget:
    """Table 4a verbatim (see module docstring for why power/area are not
    directly usable with our stand-in database)."""
    return Budget(latency_s=dict(PAPER_LATENCY_S), power_w=8.737e-3, area_mm2=17.475)


def ideal_latency_s(g: TaskGraph, db: HardwareDatabase) -> float:
    """Critical-path latency with every task on its own maxed accelerator and
    infinite bandwidth — the analytic floor used for budget calibration."""
    best: Dict[str, float] = {}
    for name, t in g.tasks.items():
        p = db.gpp_ops_per_cycle * 800e6 * db.a_peak(name, t.llp, 1024)
        best[name] = t.work_ops / p

    memo: Dict[str, float] = {}

    def finish(n: str) -> float:
        if n not in memo:
            memo[n] = best[n] + max((finish(p) for p in g.parents[n]), default=0.0)
        return memo[n]

    return max(finish(n) for n in g.tasks)


def calibrated_budget(
    db: HardwareDatabase,
    latency_slack: float = 8.0,
    power_slack: float = 1.2,
    area_slack: float = 1.15,
) -> Budget:
    """Budgets derived from analytic floors × slack so they are demanding but
    feasible under our stand-in PPA database (see module docstring):

      latency — per-workload critical-path floor × slack (at least the
                paper's Table-4a value, preserving the 21:34:34 ratio)
      power   — best-case dynamic energy (all-accelerator, all-SRAM) spread
                over the slowest latency budget, plus a base leakage
      area    — one hardened IP per task + modest NoC/Mem overhead
    """
    lats = {}
    for name, g in all_workloads().items():
        floor = ideal_latency_s(g, db)
        lats[name] = max(PAPER_LATENCY_S[name], floor * latency_slack)

    e_floor = 0.0
    n_tasks = 0
    for g in all_workloads().values():
        for t in g.tasks.values():
            e_floor += t.work_ops * db.energy.acc_pj_per_op * 1e-12
            e_floor += t.data_bytes * db.energy.sram_pj_per_byte * 1e-12
            n_tasks += 1
    base_leak_w = n_tasks * db.energy.acc_leak_w + 10e-3
    power = power_slack * (e_floor / max(lats.values()) + base_leak_w)

    area = area_slack * (
        n_tasks * db.area.acc_mm2 + 2 * db.area.dram_phy_mm2 + 2.0
    )
    return Budget(latency_s=lats, power_w=power, area_mm2=area)
