"""HLO-text parsing: collective operand bytes per category.

``cost_analysis()`` does not report collective traffic, so we parse the
compiled module text and sum the result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute instruction.

Collectives inside a while body (lax.scan over layer cycles) appear once in
the text; the roofline analysis multiplies per-computation totals by the
known trip counts compositionally (roofline/analysis.py) — the whole-graph
numbers returned here are the raw, single-visit sums.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %all-reduce.7 = f32[16,512]{1,0} all-reduce(f32[16,512]{1,0} %x), ...
_INSTR_RE = re.compile(
    r"=\s*(?:\(?)([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective category over the whole module text.
    ``*-done`` ops are skipped (the ``*-start`` carries the shape)."""
    out: Dict[str, int] = {c: 0 for c in COLLECTIVES}
    out["count"] = 0
    for m in _INSTR_RE.finditer(hlo_text):
        dtype, dims, kind = m.groups()
        if "-done" in m.group(0):
            continue
        out[kind] += _shape_bytes(dtype, dims)
        out["count"] += 1
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def collective_bytes_per_computation(hlo_text: str) -> Dict[str, Dict[str, int]]:
    """Same sums, but grouped by HLO computation name (lets the caller apply
    while-loop trip counts to loop bodies)."""
    comps: Dict[str, Dict[str, int]] = {}
    cur = "<module>"
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*{$", stripped)
        if stripped.endswith("{") and ("(" in stripped and "->" in stripped):
            name = stripped.split()[0].lstrip("%")
            cur = name
            continue
        im = _INSTR_RE.search(line)
        if im:
            dtype, dims, kind = im.groups()
            d = comps.setdefault(cur, {c: 0 for c in COLLECTIVES})
            d[kind] += _shape_bytes(dtype, dims)
    for d in comps.values():
        d["total"] = sum(d[c] for c in COLLECTIVES)
    return comps
