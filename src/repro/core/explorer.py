"""Exploration heuristic (paper §3.3–3.4, Algorithm 1).

Simulated annealing is the base search; FARSI augments its neighbour
generation with architectural reasoning. A neighbour is produced by choosing
the 5-tuple (Metric, Direction, Task, Block, Move):

  metric    — the one farthest from budget (co-design: changes per iteration)
  direction — +1 buy performance / −1 return it
  task      — highest distance contribution (critical-path duration for
              latency, dynamic energy for power)
  block     — the task's bottleneck block (Eq. 5 attribution)
  move      — Algorithm 1 reasoning + development-cost precedence
              (join > migrate > fork > swap > fork_swap), sampled
              probabilistically by precedence weight

Awareness ladder (paper Fig. 9b): ``sa`` picks all five at random;
``task`` adds bottleneck-driven task selection; ``task_block`` adds block
selection; ``farsi`` adds Algorithm-1 move selection + precedence.

If no neighbour improves, the failed (task, block) target goes on a short
taboo list so the next iteration targets "the task/block with the next
highest distance" (§3.4), and classic SA temperature occasionally accepts a
worse design.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Dict, Generator, List, Optional, Tuple

from .backend import Candidate, SimHandle, SimulatorBackend, make_backend
from .blocks import BlockKind
from .budgets import Budget, Distance, distance
from .codesign import CodesignLedger, FocusRecord
from .database import HardwareDatabase
from .design import Design
from .moves import MOVE_KINDS, MOVE_PRECEDENCE, MoveDelta, MoveSpec, apply_move
from .phase_sim import SimResult
from .tdg import TaskGraph, workload_of

AWARENESS_LEVELS = ("sa", "task", "task_block", "farsi")


@dataclasses.dataclass
class ExplorerConfig:
    awareness: str = "farsi"
    neighbors_per_iter: int = 4
    max_iterations: int = 1500
    seed: int = 0
    temperature0: float = 0.05
    temp_decay: float = 0.997
    alpha_met: float = 0.05
    dev_cost_aware: bool = True
    codesign: bool = True  # False => fixate focus until the focused metric is met
    taboo_ttl: int = 5
    backend: str = "python"  # SimulatorBackend registry name (backend.BACKENDS)


@dataclasses.dataclass
class ExplorationResult:
    best_design: Design
    best_result: SimResult
    best_distance: Distance
    converged: bool
    iterations: int
    n_sims: int
    wall_s: float
    history: List[dict]
    ledger: CodesignLedger
    backend_name: str = "python"
    sim_wall_s: float = 0.0  # time inside backend.evaluate for this run


def _task_duration(result: SimResult, tdg: TaskGraph, t: str) -> float:
    start = max((result.task_finish_s[p] for p in tdg.parents[t]), default=0.0)
    return result.task_finish_s[t] - start


def _block_has_parallel_tasks(design: Design, tdg: TaskGraph, block: str) -> bool:
    kind = design.blocks[block].kind
    if kind == BlockKind.PE:
        hosted = design.tasks_on_pe(block)
    elif kind == BlockKind.MEM:
        hosted = design.buffers_on_mem(block)
    else:
        hosted = design.tasks_via_noc(block)
    for i, a in enumerate(hosted):
        par = set(tdg.parallel_tasks_of(a))
        if par & set(hosted[i + 1:]):
            return True
    return False


def _task_parallel_other_blocks(design: Design, tdg: TaskGraph, t: str) -> bool:
    mine = design.task_pe[t]
    return any(design.task_pe[p] != mine for p in tdg.parallel_tasks_of(t))


class Explorer:
    def __init__(
        self,
        tdg: TaskGraph,
        db: HardwareDatabase,
        budget: Budget,
        config: ExplorerConfig = ExplorerConfig(),
        backend: Optional[SimulatorBackend] = None,
    ) -> None:
        self.tdg = tdg
        self.db = db
        self.budget = budget
        self.cfg = config
        assert config.awareness in AWARENESS_LEVELS
        self.rng = random.Random(config.seed)
        self.backend = backend or make_backend(config.backend, tdg, db)
        self.n_sims = 0  # designs this run submitted (backend stats aggregate
        # across sharers; this stays per-exploration under Campaign)
        self._taboo: Dict[Tuple[str, str], int] = {}
        self._sticky_focus: Optional[str] = None  # codesign-off fixation

    # ---- 5-tuple selection ----------------------------------------------
    def _select_metric(self, dist: Distance) -> str:
        if self.cfg.awareness == "sa":
            return self.rng.choice(("latency", "power", "area"))
        if not self.cfg.codesign:
            # fixation ablation: stick to one metric until it meets budget
            if self._sticky_focus and dist.per_metric[self._sticky_focus] > 0:
                return self._sticky_focus
            unmet = [m for m, d in dist.per_metric.items() if d > 0]
            self._sticky_focus = unmet[0] if unmet else "latency"
            return self._sticky_focus
        return dist.farthest_metric()

    def _select_task(
        self, design: Design, metric: str, dist: Distance, result: SimResult
    ) -> str:
        tasks = list(self.tdg.tasks)
        if self.cfg.awareness == "sa":
            return self.rng.choice(tasks)
        # domain/architecture awareness: rank by contribution to the metric
        if metric == "latency":
            wl = max(
                dist.per_workload_latency,
                key=lambda w: dist.per_workload_latency[w],
            )
            pool = [t for t in tasks if workload_of(t) == wl] or tasks
            ranked = sorted(
                pool, key=lambda t: _task_duration(result, self.tdg, t), reverse=True
            )
        elif metric == "power":
            ranked = sorted(
                tasks, key=lambda t: result.task_energy_j.get(t, 0.0), reverse=True
            )
        else:  # area: tasks whose buffers sit on the largest memories first
            # (capacity is keyed by *memory* name — resolve through the task's
            # mapped memory; own write bytes break ties within one memory)
            ranked = sorted(
                tasks,
                key=lambda t: (
                    result.mem_capacity_bytes.get(design.task_mem.get(t, ""), 0.0),
                    self.tdg.tasks[t].write_bytes,
                ),
                reverse=True,
            )
        for t in ranked:
            if not any(k[0] == t for k in self._taboo):
                return t
        return ranked[0]

    def _select_block(self, design: Design, metric: str, task: str, result: SimResult) -> str:
        if self.cfg.awareness in ("sa", "task"):
            return self.rng.choice(list(design.blocks))
        if metric in ("power", "area"):
            # dead hardware first: an idle block is pure leakage/area, and
            # join removes it for free (the cheapest possible move)
            for n, b in design.blocks.items():
                if b.kind == BlockKind.PE and not design.tasks_on_pe(n):
                    return n
                if b.kind == BlockKind.MEM and not design.buffers_on_mem(n):
                    return n
        if metric == "area":
            return max(design.blocks, key=lambda b: self.db.block_area_mm2(design.blocks[b]))
        blk = result.task_bottleneck_block.get(task)
        if blk in design.blocks:
            return blk
        return design.task_pe[task]

    def _select_moves(self, design: Design, metric: str, task: str, block: str) -> List[str]:
        """Algorithm 1, steps I + II."""
        if self.cfg.awareness != "farsi":
            moves = list(MOVE_KINDS)
            self.rng.shuffle(moves)
            return moves
        if metric == "latency":
            if _block_has_parallel_tasks(design, self.tdg, block):
                allowed = ["migrate", "fork"]
            else:
                allowed = ["swap", "fork_swap"]
        elif metric == "power":
            if _task_parallel_other_blocks(design, self.tdg, task):
                if not _block_has_parallel_tasks(design, self.tdg, block):
                    allowed = ["migrate"]
                else:
                    allowed = ["join"]
            else:
                allowed = ["swap", "fork_swap"]
        else:  # area
            if design.blocks[block].kind == BlockKind.PE:
                allowed = ["join", "swap"]
            else:
                allowed = ["migrate", "join", "swap"]
        # step II/III: precedence-weighted probabilistic ordering
        if self.cfg.dev_cost_aware:
            weights = [MOVE_PRECEDENCE[m] for m in allowed]
        else:
            weights = [1.0] * len(allowed)
        ordered: List[str] = []
        pool, w = list(allowed), list(weights)
        while pool:
            pick = self.rng.choices(range(len(pool)), weights=w)[0]
            ordered.append(pool.pop(pick))
            w.pop(pick)
        # graceful fallback to the rest of the move set
        ordered += [m for m in MOVE_KINDS if m not in ordered]
        return ordered

    # ---- neighbour generation --------------------------------------------
    def _make_neighbors(
        self, design: Design, metric: str, task: str, block: str, moves: List[str],
        bottleneck: str, n: int,
    ) -> List[Candidate]:
        """Up to ``n`` *distinct* neighbours: one per move of the precedence-
        ordered list (candidate generation in SA, §3.4).

        Clone-free: each move is trialled in place on ``design`` (checkpoint
        → apply, recording its encoding delta → rollback), and the neighbour
        is shipped to the backend as a lightweight :class:`Candidate` — the
        paper's Fig.-8b design-duplication hot-spot never runs. Only the
        accepted candidate is ever materialized (``Candidate.accept``)."""
        direction = +1 if metric == "latency" else -1
        out: List[Candidate] = []
        ck = design.checkpoint()
        for move in moves:
            if len(out) >= n:
                break
            delta = MoveDelta()
            ok = apply_move(
                design, self.tdg, move, block, task, direction, bottleneck,
                metric, self.rng, delta,
            )
            design.restore(ck)
            if ok:
                spec = MoveSpec(move, block, task, direction, bottleneck, metric)
                out.append(
                    Candidate(
                        base=design, spec=spec, delta=delta,
                        budget=self.budget, alpha=self.cfg.alpha_met,
                    )
                )
        return out

    # ---- main loop ---------------------------------------------------------
    def run_steps(
        self, initial: Optional[Design] = None
    ) -> Generator[List[Candidate], List[SimHandle], ExplorationResult]:
        """Coroutine form of the search: yields each iteration's candidate
        batch (lightweight :class:`Candidate` records sharing the current
        design — no clones) and is resumed (``gen.send``) with the matching
        :class:`SimHandle` list. The winner is picked from the handles'
        fitness column (device-computed on the JAX backend); only that one
        handle is decoded into a full ``SimResult``, and only on acceptance
        is its move materialized onto the current design. ``run()`` drives
        it against ``self.backend``; `Campaign` drives many explorers'
        generators in lockstep so one dispatch prices the pending neighbours
        of *all* live explorations. The ``StopIteration`` value is the
        :class:`ExplorationResult`."""
        t0 = time.perf_counter()
        cur = initial or Design.base(self.tdg)
        self.n_sims += 1
        (h0,) = yield [Candidate.of_design(cur, self.budget, self.cfg.alpha_met)]
        cur_res = h0.result()
        cur_dist = distance(cur_res, self.budget)
        # best keeps a stable-name snapshot: cur mutates in place hereafter
        best = (cur.clone(rename=False), cur_res, cur_dist)
        history: List[dict] = []
        ledger = CodesignLedger()

        for it in range(self.cfg.max_iterations):
            if cur_dist.converged():
                break
            self._taboo = {k: v - 1 for k, v in self._taboo.items() if v > 1}

            metric = self._select_metric(cur_dist)
            task = self._select_task(cur, metric, cur_dist, cur_res)
            block = self._select_block(cur, metric, task, cur_res)
            bneck = cur_res.task_bottleneck.get(task, "pe")
            moves = self._select_moves(cur, metric, task, block)

            neighbors = self._make_neighbors(
                cur, metric, task, block, moves, bneck, self.cfg.neighbors_per_iter
            )
            if not neighbors:
                self._taboo[(task, block)] = self.cfg.taboo_ttl
                continue
            # one evaluation request per iteration: the whole neighbour set
            self.n_sims += len(neighbors)
            handles = yield neighbors
            assert len(handles) == len(neighbors)
            # rank from the batch's (B,) fitness column — no decode; stable
            # argmin preserves the precedence order on ties like the old sort
            fits = [h.fitness for h in handles]
            j = min(range(len(fits)), key=fits.__getitem__)
            cand, move = neighbors[j], neighbors[j].spec.move
            res = handles[j].result()  # lazy: only the winner pays decode
            dist_after = distance(res, self.budget)
            d_before = cur_dist.fitness(self.cfg.alpha_met)
            d_after = dist_after.fitness(self.cfg.alpha_met)
            temp = self.cfg.temperature0 * self.cfg.temp_decay**it
            accept = d_after < d_before or (
                temp > 0
                and self.rng.random() < math.exp(-(d_after - d_before) / max(temp, 1e-9))
            )
            ledger.log(
                FocusRecord(
                    iteration=it,
                    metric=metric,
                    workload=workload_of(task),
                    comm_comp="comp" if bneck == "pe" else "comm",
                    move=move,
                    distance_before=cur_dist.city_block(),
                    distance_after=dist_after.city_block() if accept else cur_dist.city_block(),
                )
            )
            if accept:
                cand.accept(self.tdg)  # materialize the move onto cur
                cur_res, cur_dist = res, dist_after
                if cur_dist.city_block() < best[2].city_block():
                    best = (cur.clone(rename=False), cur_res, cur_dist)
            else:
                self._taboo[(task, block)] = self.cfg.taboo_ttl

            history.append(
                {
                    "iteration": it,
                    "n_sims": self.n_sims,
                    "distance": best[2].city_block(),
                    "fitness": best[2].fitness(self.cfg.alpha_met),
                    "metric": metric,
                    "move": move,
                    "accepted": accept,
                    "wall_s": time.perf_counter() - t0,
                }
            )

        return ExplorationResult(
            best_design=best[0],
            best_result=best[1],
            best_distance=best[2],
            converged=best[2].converged(),
            iterations=len(history),
            n_sims=self.n_sims,
            wall_s=time.perf_counter() - t0,
            history=history,
            ledger=ledger,
            backend_name=self.backend.name,
        )

    def run(self, initial: Optional[Design] = None) -> ExplorationResult:
        """Drive :meth:`run_steps` against ``self.backend`` — exactly one
        ``backend.evaluate_candidates`` call per search iteration (plus one
        for the initial design)."""
        gen = self.run_steps(initial)
        sim_wall = 0.0
        try:
            pending = next(gen)
            while True:
                t0 = time.perf_counter()
                handles = self.backend.evaluate_candidates(pending)
                sim_wall += time.perf_counter() - t0
                pending = gen.send(handles)
        except StopIteration as stop:
            result: ExplorationResult = stop.value
            result.sim_wall_s = sim_wall
            return result
