"""Array-native multi-NoC regime: forked/joined chain designs price
identically through the scalar Python simulator and the batched JAX backend
(XLA and Pallas-kernel paths), topology-move-enabled explorations never hit
the scalar fallback, and the development-cost policy lands the §5.3
complexity-reduction comparison through ``Campaign.aggregate``."""
import random

import numpy as np
import pytest

from repro.core import (
    Campaign,
    Design,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    JaxBatchedBackend,
    ar_complex,
    audio,
    calibrated_budget,
    synthetic_family,
)
from repro.core.backend import Candidate
from repro.core.blocks import make_accelerator, make_mem
from repro.core.moves import MoveDelta, apply_fork, apply_join
from repro.core.phase_sim import simulate

PARITY_REL_TOL = 1e-5  # acceptance bar: multi-NoC backends agree ≤ 1e-5


def chain_designs(g, n_noc: int, count: int, seed: int = 0):
    """``count`` designs with an ``n_noc``-deep chain, built the way the
    explorer builds them: real NoC forks (which re-home half the attached
    blocks per fork) on top of a randomized single-NoC design, then random
    remapping so routes span the chain. Link counts stay at the default 1 —
    the regime NoC forks explore (relief via more buses, not more links)."""
    rng = random.Random(seed)
    tasks = sorted(g.tasks)
    out = []
    for _ in range(count):
        d = Design.base(g)
        noc0 = d.noc_chain[0]
        for _ in range(rng.randint(2, 4)):
            if rng.random() < 0.5:
                t = rng.choice(tasks)
                b = d.add_block(make_accelerator(t, rng.choice((100, 400))),
                                attach_to=noc0)
                d.task_pe[t] = b.name
            else:
                d.add_block(make_mem(rng.choice(("dram", "sram")),
                                     rng.choice((100, 800)), 32),
                            attach_to=noc0)
        while len(d.noc_chain) < n_noc:
            assert apply_fork(d, g, rng.choice(d.noc_chain))
        pes, mems = d.pes(), d.mems()
        for t in tasks:
            d.task_pe[t] = rng.choice(pes)
            d.task_mem[t] = rng.choice(mems)
        assert len(d.noc_chain) == n_noc
        out.append(d)
    return out


@pytest.mark.parametrize("n_noc", [2, 3])
@pytest.mark.parametrize(
    "batch", [1, 8, pytest.param(64, marks=pytest.mark.slow)]
)
@pytest.mark.parametrize("use_kernel", [False, True])
def test_multi_noc_parity_python_vs_jax(n_noc, batch, use_kernel):
    """Forked chains (N ∈ {2, 3}) priced by PythonBackend vs
    JaxBatchedBackend — XLA and Pallas, B ∈ {1, 8, 64} — agree ≤ 1e-5 on
    latency, per-task finish times, PPA, fitness, and the Algorithm-1
    bottleneck attribution."""
    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    designs = chain_designs(g, n_noc, batch, seed=10 * n_noc + batch)
    jb = JaxBatchedBackend(g, db, use_kernel=use_kernel)
    cands = [Candidate.of_design(d, bud) for d in designs]
    handles = jb.evaluate_candidates(cands)
    assert jb.stats().n_fallback == 0 and jb.stats().n_batched == batch
    for i, (d, h) in enumerate(zip(designs, handles)):
        ref = simulate(d, g, db)
        got = h.result()
        rel = lambda a, b: abs(a - b) / max(abs(a), 1e-12)
        assert rel(ref.latency_s, got.latency_s) <= PARITY_REL_TOL, i
        for t, f in ref.task_finish_s.items():
            assert rel(f, got.task_finish_s[t]) <= PARITY_REL_TOL, (i, t)
        assert rel(ref.energy_j, got.energy_j) <= 1e-4, i
        assert rel(ref.area_mm2, got.area_mm2) <= 1e-4, i
        from repro.core.budgets import distance

        assert rel(distance(ref, bud).fitness(0.05), h.fitness) <= 1e-4, i
        # multi-hop routing shows up in the bottleneck attribution too
        assert got.task_bottleneck == ref.task_bottleneck, i
        assert got.task_bottleneck_block == ref.task_bottleneck_block, i
        for name, s in ref.block_bottleneck_s.items():
            tol = PARITY_REL_TOL * max(ref.latency_s, 1e-12) * len(g.tasks)
            assert abs(got.block_bottleneck_s[name] - s) <= tol, (i, name)


def test_all_join_batch_buckets_to_base_shape():
    """Regression (caught driving the DSE campaign): a batch whose every
    candidate REMOVES a NoC/block must still bucket to the BASE design's
    shape — the group fill broadcasts the base row before applying diffs,
    so a bucket sized off the (smaller) candidate encodings overflows."""
    from repro.core.moves import MoveSpec

    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    (d,) = chain_designs(g, 3, 1, seed=11)
    ck = d.checkpoint()
    cands = []
    for noc in (d.noc_chain[1], d.noc_chain[2]):
        delta = MoveDelta()
        assert apply_join(d, g, noc, delta=delta)
        d.restore(ck)
        cands.append(Candidate(
            base=d, spec=MoveSpec("join", noc, None, -1, "noc", "area"),
            delta=delta, budget=bud,
        ))
    jb = JaxBatchedBackend(g, db)
    handles = jb.evaluate_candidates(cands)
    assert jb.stats().n_fallback == 0
    for c, h in zip(cands, handles):
        with c.materialized(g) as joined:
            ref = simulate(joined, g, db)
        got = h.result()
        assert abs(got.latency_s - ref.latency_s) / ref.latency_s <= 1e-4


def test_joined_chain_parity_after_noc_join():
    """A chain that grew and then shrank (fork → join) prices identically —
    the join's removed-NoC + re-attachment delta compacts the encoding the
    same way a from-scratch encode sees the design."""
    db = HardwareDatabase()
    g = audio()
    (d,) = chain_designs(g, 3, 1, seed=5)
    delta = MoveDelta()
    assert apply_join(d, g, d.noc_chain[1], delta=delta)
    assert len(d.noc_chain) == 2 and delta.removed and not delta.topology
    ref = simulate(d, g, db)
    got = JaxBatchedBackend(g, db).evaluate([d])[0]
    assert abs(got.latency_s - ref.latency_s) / ref.latency_s <= PARITY_REL_TOL


def test_topology_exploration_never_falls_back():
    """Acceptance bar: a topology-move-enabled exploration on the JAX
    backend — seeded from a multi-NoC design so NoC fork/join candidates
    are generated and accepted — completes with ``n_fallback == 0``."""
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db).scaled(0.5)  # tight: keeps the search moving
    (initial,) = chain_designs(g, 3, 1, seed=2)
    jb = JaxBatchedBackend(g, db)
    res = Explorer(
        g, db, bud, ExplorerConfig(max_iterations=120, seed=3), backend=jb
    ).run(initial=initial)
    s = jb.stats()
    assert s.n_fallback == 0, s
    assert s.n_batched > 0
    assert res.iterations > 0
    # the topology candidates really were priced (chain length varied) and
    # the final design still decodes cleanly against its own blocks
    assert set(res.best_result.task_bottleneck_block.values()) <= set(
        res.best_design.blocks
    )


def test_accepted_noc_fork_adopts_row_encoding():
    """Accepting a NoC fork promotes the winner's delta-encoding as the
    base's cached encoding — and it must equal a from-scratch encode of the
    mutated design (chain order, attachments, slots)."""
    from repro.core.phase_sim_jax import EncodedDesign

    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    (d,) = chain_designs(g, 2, 1, seed=7)
    jb = JaxBatchedBackend(g, db)
    ck = d.checkpoint()
    delta = MoveDelta()
    assert apply_fork(d, g, d.noc_chain[0], delta=delta)
    d.restore(ck)
    from repro.core.moves import MoveSpec

    spec = MoveSpec("fork", d.noc_chain[0], None, +1, "noc", "latency")
    cand = Candidate(base=d, spec=spec, delta=delta, budget=bud)
    (h,) = jb.evaluate_candidates([cand])
    assert np.isfinite(h.fitness)
    cand.accept(g)
    jb.adopt_encoding(h)
    adopted = jb._adopted[id(d)][1]
    fresh = EncodedDesign.of(d, g, db, jb._enc)
    assert adopted.noc_slot == fresh.noc_slot
    assert np.array_equal(adopted.pe_noc, fresh.pe_noc)
    assert np.array_equal(adopted.mem_noc, fresh.mem_noc)
    assert np.array_equal(adopted.noc_bw, fresh.noc_bw)
    # and the adopted encoding prices the mutated design correctly
    (h2,) = jb.evaluate_candidates([Candidate.of_design(d, bud)])
    ref = simulate(d, g, db)
    assert abs(h2.result().latency_s - ref.latency_s) / ref.latency_s <= 1e-4


# ---------------------------------------------------------------------------
# §5.3 development-cost comparison through Campaign.aggregate
# ---------------------------------------------------------------------------
def test_dev_cost_policy_reduces_complexity_vs_farsi():
    """Acceptance bar: a dev_cost-vs-farsi policy sweep over the generated
    scenario family converges on both policies, never falls back, and
    ``Campaign.aggregate`` reports component-count/variation reductions
    (strict on ≥ 2 scenarios) — the §5.3 development-cost result."""
    db = HardwareDatabase()
    scens = synthetic_family(seed=0, n=4, db=db)
    camp = Campaign.policy_sweep(
        db, scens, policies=("farsi", "dev_cost"), seeds=(0,),
        backend="jax", max_iterations=150,
    )
    res = camp.run()
    for stats in res.backend_stats.values():
        assert stats.n_fallback == 0, stats
    pc = res.policy_complexity()
    assert set(pc) == {"farsi", "dev_cost"}
    for k in ("components", "noc_components", "variation"):
        assert pc["dev_cost"][k] <= pc["farsi"][k], (k, pc)
        assert f"complexity_{k}_mean" in res.aggregate
        assert res.aggregate[f"dev_cost_{k}_reduction"] >= 0.0, k
    # strictly simpler (fewer components and/or less variation) on ≥ 2
    # scenarios, and no scenario got MORE complex under dev_cost
    strict = 0
    for s in scens:
        mf = res.runs[f"{s.name}.farsi.s0"].best_design.complexity_metrics()
        md = res.runs[f"{s.name}.dev_cost.s0"].best_design.complexity_metrics()
        assert md["components"] <= mf["components"], s.name
        assert md["variation"] <= mf["variation"] + 1e-9, s.name
        strict += (md["components"] < mf["components"]) or (
            md["variation"] < mf["variation"] - 1e-9
        )
    assert strict >= 2, res.policy_complexity()
    # development-cost awareness must not wreck convergence: dev_cost still
    # reaches budget on every scenario
    assert all(
        res.runs[f"{s.name}.dev_cost.s0"].converged for s in scens
    ), res.aggregate


def test_dev_cost_penalty_shape():
    """The penalty is exact and signed: growing moves pay, simplifying
    moves are subsidised, knob swaps on uniform blocks are free."""
    import random as _random

    from repro.core import make_policy

    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    pol = make_policy("dev_cost")
    pol.bind(g, db, bud, ExplorerConfig(), _random.Random(0))
    d = Design.base(g)
    base_pe = d.pes()[0]

    def cand_for(move, block, task=None):
        ck = d.checkpoint()
        delta = MoveDelta()
        from repro.core.moves import MoveSpec, apply_move

        ok = apply_move(d, g, move, block, task, +1, "pe", "latency",
                        _random.Random(0), delta)
        d.restore(ck)
        assert ok, move
        return Candidate(base=d, spec=MoveSpec(move, block, task, +1, "pe",
                                               "latency"), delta=delta)

    grow = pol.move_penalty(d, cand_for("fork", base_pe))
    assert grow > 0.0
    swap = pol.move_penalty(d, cand_for("swap", base_pe))
    assert abs(swap) < grow
    # an unmoved candidate (initial design pricing) costs nothing
    assert pol.move_penalty(d, Candidate.of_design(d)) == 0.0
