"""Pure-jnp oracle for the fused phase-sim kernel.

The oracle *is* the production XLA path — ``vmap`` of
``repro.core.phase_sim_jax.simulate_one`` — re-exported here so the kernel
package follows the repo's ``{kernel,ops,ref}`` convention without forking
the simulator physics into a second copy. ``simulate_one`` is already
asserted equivalent to the scalar Python simulator
(tests/test_phase_sim_jax.py, tests/test_backend_campaign.py); the Pallas
kernel is asserted ≤ 1e-5 against *this* function, so the chain

    phase_sim (Pallas) ≡ phase_sim_ref ≡ simulate_batch ≡ phase_sim.simulate

is closed by tests at every link.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.phase_sim_jax import EncodedWorkload, simulate_one


def phase_sim_ref(
    enc: EncodedWorkload, rows: Dict[str, jnp.ndarray]
) -> Dict[str, jnp.ndarray]:
    """Batched phase simulation + Eq.-7 scoring: the vmap'd reference."""
    return jax.vmap(lambda row: simulate_one(enc, row))(rows)
