"""FARSI on the pod: the paper's simulator + explorer applied to the
distributed-execution design space (DESIGN.md §2 mapping).

*Workload*: one training/serving step, as a TDG whose tasks are the step-graph
ops (roofline/analytic.py per-device costs). Compute ops carry FLOPs as Gables
work `f` and HBM traffic as `D`; collectives become communication-only tasks
whose bytes ride the ICI "NoC".

*Design*: one representative chip (SPMD symmetry) — a PE at 197 TFLOP/s, an
HBM "memory" at 819 GB/s (1024 B × 800 MHz), and an ICI "NoC" at 50 GB/s/link
(64 B × 800 MHz) — priced through the same Block/Database interfaces as the
SoC designs, with ladder knobs intact.

*Estimate*: the phase-driven simulator runs the step TDG with Eqs. 1–6 —
giving a step-time estimate that models compute/HBM/ICI *overlap* through
task-level parallelism, where the bare 3-term roofline only gives
max(t_c, t_h, t_i). The autotuner (launch/autotune.py) uses this as its agile
cost oracle; the compiled dry-run plays Platform Architect's validation role.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from ..configs.base import ModelConfig, ShapeConfig
from ..roofline.analytic import (
    HBM_BW,
    ICI_BW_PER_LINK,
    PEAK_FLOPS,
    MeshShape,
    OpCost,
    roofline_terms,
    step_costs,
)
from ..sharding.rules import DistConfig
from .blocks import Block, BlockKind
from .database import TPUDatabase
from .design import Design
from .phase_sim import SimResult, simulate
from .tdg import Task, TaskGraph


class PodDatabase(TPUDatabase):
    """TPU constants expressed through the HardwareDatabase interface."""

    def pe_peak_ops(self, block: Block) -> float:
        return PEAK_FLOPS


def step_tdg(ops: List[OpCost]) -> TaskGraph:
    """Step-graph ops → FARSI TDG. A compute op's communication component is
    its HBM traffic (split evenly read/write for I_read/I_write); a
    collective op is all-communication routed over the ICI NoC (expressed as
    a task whose 'memory' is the remote pod — its D rides the NoC route)."""
    g = TaskGraph("tpu_step")
    for op in ops:
        if op.ici_bytes > 0 and op.flops == 0:
            # communication-only task: tiny compute, bytes over ICI
            g.add_task(
                Task(
                    op.name,
                    work_ops=1.0,
                    i_read=1.0 / max(op.ici_bytes / 2, 1e-9),
                    i_write=1.0 / max(op.ici_bytes / 2, 1e-9),
                    llp=1.0,
                    burst_bytes=65536,
                )
            )
        else:
            rd = max(op.hbm_bytes / 2, 1.0)
            wr = max(op.hbm_bytes / 2, 1.0)
            g.add_task(
                Task(
                    op.name,
                    work_ops=max(op.flops, 1.0),
                    i_read=max(op.flops, 1.0) / rd,
                    i_write=max(op.flops, 1.0) / wr,
                    llp=1e6,  # MXU ops are fully data-parallel
                    burst_bytes=65536,
                )
            )
    for op in ops:
        for dep in op.deps:
            if dep in g.tasks:
                g.add_edge(dep, op.name, 0.0)
    g.validate()
    return g


def pod_design(g: TaskGraph, db: PodDatabase) -> Design:
    """One chip + HBM + ICI. Compute tasks map to (chip, HBM); collective
    tasks map their 'buffer' to the ICI-attached remote memory so their
    traffic rides the NoC chain (multi-hop = inter-pod)."""
    d = Design()
    ici = d.add_block(
        Block(kind=BlockKind.NOC, subtype="noc", freq_mhz=800, width_bytes=64, n_links=1)
    )
    hbm_noc = d.add_block(
        Block(kind=BlockKind.NOC, subtype="noc", freq_mhz=800, width_bytes=1024, n_links=4)
    )
    chip = d.add_block(
        Block(kind=BlockKind.PE, subtype="acc", freq_mhz=800, hardened_for=None),
        attach_to=hbm_noc.name,
    )
    hbm = d.add_block(
        Block(kind=BlockKind.MEM, subtype="dram", freq_mhz=800, width_bytes=1024),
        attach_to=hbm_noc.name,
    )
    # the remote endpoint must never be the binding pipe — the ICI NoC is the
    # collective bandwidth model (so link-schedule knobs act on the NoC)
    remote = d.add_block(
        Block(kind=BlockKind.MEM, subtype="dram", freq_mhz=800, width_bytes=1024),
        attach_to=ici.name,
    )
    collective_markers = ("_tp", "a2a", "sync")
    for t in g.tasks:
        d.task_pe[t] = chip.name
        is_coll = any(m in t for m in collective_markers)
        d.task_mem[t] = remote.name if is_coll else hbm.name
    return d


def simulate_step(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: MeshShape,
    dist: Optional[DistConfig] = None,
) -> Dict[str, float]:
    """FARSI phase-sim step-time estimate + the three roofline terms."""
    ops = step_costs(cfg, shape, mesh, dist)
    links = dist.ici_links if dist else 1
    terms = roofline_terms(ops, ici_links=links)
    g = step_tdg(ops)
    db = PodDatabase()
    design = pod_design(g, db)
    # a multi-direction ring serves a SINGLE collective with all links —
    # model as wider ICI (n_links stripes *different* tasks, not this)
    ici = design.blocks[design.noc_chain[0]]
    ici.width_bytes = ici.width_bytes * links
    res: SimResult = simulate(design, g, db)
    terms["t_phase_sim_s"] = res.latency_s
    terms["sim_bottleneck_s"] = dict(res.bottleneck_s)
    # overlap efficiency: roofline max() vs dependency-aware estimate
    terms["overlap_ratio"] = (
        terms["t_roofline_s"] / res.latency_s if res.latency_s > 0 else 1.0
    )
    return terms
