"""Shared finding record for the static-analysis passes.

Every pass (``contracts`` / ``lint`` / ``jaxpr``) reports the same
:class:`Finding` shape so the CLI, the baseline file, and the tests all
speak one format. A finding is frozen — passes build them, consumers only
read; ``baselined``/``suppressed`` annotations come back as *new* records
via :func:`dataclasses.replace` so a list of findings is safely shareable.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

__all__ = ["Finding", "format_findings"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One defect reported by a pass.

    ``path`` is the primary file (repo-relative where possible) and
    ``related`` names the other side(s) of a cross-file contract — the
    contract checker always fills it, so a report names BOTH files that
    must move together. ``source`` holds the stripped source-line text for
    lint findings: the baseline keys on it instead of the line number, so
    frozen debt survives unrelated edits shifting lines."""

    pass_name: str  # "contracts" | "lint" | "jaxpr"
    rule: str
    message: str
    path: str = ""
    line: int = 0
    related: Tuple[str, ...] = ()
    source: str = ""
    suppressed: bool = False  # via `# repro: noqa[rule]`
    baselined: bool = False  # frozen in the checked-in baseline

    def key(self) -> str:
        """Baseline identity: file + rule + normalized source text (line
        numbers drift; the offending line's text does not)."""
        return f"{self.path}::{self.rule}::{self.source}"

    @property
    def live(self) -> bool:
        """Counts against ``--strict``: neither suppressed nor baselined."""
        return not (self.suppressed or self.baselined)

    def render(self) -> str:
        loc = self.path or "<global>"
        if self.line:
            loc += f":{self.line}"
        tags = "".join(
            t for t, on in ((" [noqa]", self.suppressed),
                            (" [baseline]", self.baselined)) if on
        )
        rel = f" (with {', '.join(self.related)})" if self.related else ""
        return f"{loc}: {self.pass_name}/{self.rule}{tags}: {self.message}{rel}"


def format_findings(findings: List[Finding]) -> str:
    return "\n".join(f.render() for f in findings)
