"""Serve layer: multi-tenant continuous-batching DSE on one shared backend.

FARSI's value proposition is *agile* exploration; the production north star
is a service, not a script. This package hosts many concurrent exploration
**sessions** (each a policy-driven :class:`~repro.core.explorer.Explorer`
coroutine) on top of one shared :class:`~repro.core.backend.JaxBatchedBackend`
per workload:

  ``DesignStore``               — content-addressed evaluation cache keyed on
                                  ``hash(EncodedDesign leaves, workload,
                                  budget)`` so identical evaluations resolve
                                  to memoized device rows without a dispatch.
  ``Session`` / ``SessionRequest`` — one exploration request wrapped around
                                  the ``Explorer.run_steps`` coroutine, with
                                  streamed best-design events.
  ``ContinuousBatchScheduler``  — generalizes ``Campaign``'s lockstep
                                  cross-batching: sessions join and leave
                                  mid-flight; every tick packs all ready
                                  candidates into the shape-bucketed device
                                  batches.
  ``DseService``                — the front door: submit sessions, drive
                                  ticks, read streamed events and final
                                  results, and aggregate service stats.

The fault-tolerance layer (``faults``) adds a seeded chaos harness
(``FaultInjector``), session-level isolation (a ``FAILED`` lifecycle state,
bisect-and-redispatch of poisoned shared batches), retry/backoff +
per-session deadlines, and graceful per-session degradation to the scalar
backend; see the "Fault tolerance" section of docs/SERVING.md.

See docs/SERVING.md for the architecture and the streaming/caching
contracts.
"""
from .faults import (
    DeadlineExceeded,
    DispatchFailed,
    FaultInjector,
    InjectedDispatchError,
    InjectedSessionCrash,
    InjectedFault,
    RetryPolicy,
    SessionFailed,
)
from .scheduler import ContinuousBatchScheduler
from .service import DseService, ServiceStats, SessionHandle
from .session import BestEvent, Session, SessionRequest
from .store import DesignStore, StoreStats

__all__ = [
    "BestEvent",
    "ContinuousBatchScheduler",
    "DeadlineExceeded",
    "DesignStore",
    "DispatchFailed",
    "DseService",
    "FaultInjector",
    "InjectedDispatchError",
    "InjectedFault",
    "InjectedSessionCrash",
    "RetryPolicy",
    "ServiceStats",
    "Session",
    "SessionFailed",
    "SessionHandle",
    "SessionRequest",
    "StoreStats",
]
