"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-235B-A22B; family hf:Qwen/Qwen3-30B-A3B].

Fine-grained MoE: 94L, d_model=4096, 64 q / 4 kv heads (head_dim 128,
qk-norm), 128 experts top-8 with per-expert d_ff=1536, vocab=151936.
The expert-parallel stress cell (128 experts over the model axis).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94,
    d_model=4096,
    vocab_size=151936,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    qk_norm=True,
    d_ff=1536,
    mlp_kind="swiglu",
    n_experts=128,
    top_k=8,
    moe_d_ff=1536,
    rope_kind="rope",
    rope_theta=1e6,
    block_kinds=("attn",),
    mlp_kinds=("moe",),
)
