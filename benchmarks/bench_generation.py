"""Paper Fig. 8: DSE time breakdown. The paper profiles design duplication at
79.9% of generation time (naive deepcopy); we measure our structured clone vs
the deepcopy reference, and the end-to-end split between simulation and
generation — the motivation for the vmap'd batched evaluator
(core/phase_sim_jax.py)."""
from __future__ import annotations

from typing import List

from repro.core import Design, Explorer, ExplorerConfig, HardwareDatabase, ar_complex, calibrated_budget, simulate

from .common import Row, timeit


def run() -> List[Row]:
    db = HardwareDatabase()
    g = ar_complex()
    # a moderately complex design from a short exploration
    res = Explorer(g, db, calibrated_budget(db), ExplorerConfig(max_iterations=150, seed=6)).run()
    d = res.best_design

    t_clone = timeit(d.clone, n=20)
    t_deep = timeit(d.deep_clone_reference, n=20)
    t_sim = timeit(lambda: simulate(d, g, db), n=10)

    rows = [
        ("fig8.design_clone", t_clone, f"structured_clone; deepcopy={t_deep:.0f}us speedup={t_deep/max(t_clone,1e-9):.1f}x"),
        ("fig8.simulate", t_sim, f"blocks={sum(d.block_counts().values())} phases={simulate(d, g, db).n_phases}"),
        (
            "fig8.clone_share",
            0.0,
            f"clone_share_ours={t_clone/(t_clone+t_sim)*100:.0f}% "
            f"clone_share_deepcopy={t_deep/(t_deep+t_sim)*100:.0f}% (paper: 79.9%)",
        ),
    ]

    # beyond-paper: vmap'd batched neighbour evaluation (single-NoC regime)
    import jax

    from repro.core import random_single_noc_designs
    from repro.core.phase_sim_jax import EncodedWorkload, encode_batch, simulate_batch

    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, 64, seed=5)
    batch = encode_batch(designs, g, db, enc)
    fn = jax.jit(lambda rows: simulate_batch(enc, rows))
    jax.block_until_ready(fn(batch)["latency_s"])  # compile once
    t_batch = timeit(lambda: jax.block_until_ready(fn(batch)["latency_s"]), n=5)
    t_python = timeit(lambda: [simulate(dd, g, db) for dd in designs], n=3)
    rows.append(
        (
            "fig8.vmap_batch64",
            t_batch,
            f"python_loop={t_python:.0f}us speedup={t_python/max(t_batch,1e-9):.1f}x "
            f"per_design={t_batch/64:.1f}us (batched SA neighbour evaluation)",
        )
    )
    return rows
