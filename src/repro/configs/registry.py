"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .base import ModelConfig
from . import (  # noqa: F401 — imported for registration side effect below
    gemma_7b,
    grok_1_314b,
    jamba_v0_1_52b,
    mamba2_370m,
    mistral_large_123b,
    musicgen_large,
    qwen2_vl_2b,
    qwen3_1_7b,
    qwen3_moe_235b_a22b,
    starcoder2_7b,
)

ARCHS: Dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        jamba_v0_1_52b,
        qwen3_1_7b,
        mistral_large_123b,
        starcoder2_7b,
        gemma_7b,
        qwen3_moe_235b_a22b,
        grok_1_314b,
        qwen2_vl_2b,
        musicgen_large,
        mamba2_370m,
    )
}


def arch_names() -> List[str]:
    return list(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def reduced_config(name: str) -> ModelConfig:
    """Same family, tiny dims: one cycle of layers, d_model 64, 4 heads,
    vocab 512, ≤4 experts — per-arch smoke tests run this on CPU. All
    family-defining features (qk-norm, GeGLU, M-RoPE, MoE, SSD, hybrid
    interleave) are preserved."""
    cfg = get_config(name)
    n_experts = min(cfg.n_experts, 4) if cfg.n_experts else 0
    head_dim = (
        (32 if cfg.head_dim > cfg.d_model // max(cfg.n_heads, 1) else 16)
        if cfg.n_heads
        else 0
    )
    half = head_dim // 2
    t_sec = max(half // 4, 1)
    h_sec = (half - t_sec) // 2
    return dataclasses.replace(
        cfg,
        name=f"{cfg.name}-reduced",
        n_layers=cfg.cycle_len,
        d_model=64,
        vocab_size=512,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=(4 if cfg.n_kv_heads == cfg.n_heads else 2) if cfg.n_heads else 0,
        head_dim=head_dim,
        d_ff=128 if cfg.d_ff else 0,
        moe_d_ff=64 if cfg.moe_d_ff else 0,
        n_experts=n_experts,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        mrope_sections=(t_sec, h_sec, half - t_sec - h_sec) if half else cfg.mrope_sections,
    )
