"""Exploration engine (paper §3.3–3.4, Algorithm 1).

Simulated annealing is the base search; FARSI augments its neighbour
generation with architectural reasoning. A neighbour is produced by choosing
the 5-tuple (Metric, Direction, Task, Block, Move):

  metric    — the one farthest from budget (co-design: changes per iteration)
  direction — +1 buy performance / −1 return it
  task      — highest distance contribution (critical-path duration for
              latency, dynamic energy for power)
  block     — the task's bottleneck block (Eq. 5 attribution)
  move      — Algorithm 1 reasoning + development-cost precedence
              (join > migrate > fork > swap > fork_swap), sampled
              probabilistically by precedence weight

All of that reasoning lives in the pluggable **policy layer**
(`repro.core.policy`): the Explorer owns the mechanics — neighbour
materialization, the speculative dispatch pipeline, bookkeeping — and
delegates every selection and accept decision to the
:class:`~repro.core.policy.HeuristicPolicy` named by
``ExplorerConfig.policy`` (default: derived from the historical
``awareness`` ladder — ``sa``/``task``/``task_block``/``farsi``, paper
Fig. 9b). Policies reason over :class:`~repro.core.backend.SimTelemetry`
views fed from the device-side bottleneck telemetry columns, so the
winner's full ``SimResult`` decode is paid ONCE per exploration (for the
returned best design), not per accepted move.

If no neighbour improves, the failed (task, block) target goes on the
policy's short taboo list so the next iteration targets "the task/block
with the next highest distance" (§3.4), and classic SA temperature
occasionally accepts a worse design.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Generator, List, Optional

import random

from .backend import Candidate, SimHandle, SimTelemetry, SimulatorBackend, make_backend
from .budgets import Budget, Distance
from .codesign import CodesignLedger, FocusRecord
from .database import HardwareDatabase
from .design import Design
from .moves import MoveDelta, MoveSpec, apply_move
from .phase_sim import SimResult
from .policy import AWARENESS_POLICY, Focus, HeuristicPolicy, make_policy
from .tdg import TaskGraph, workload_of

AWARENESS_LEVELS = ("sa", "task", "task_block", "farsi")

# adaptive-pipeline speculation window: if the first SPEC_WINDOW speculative
# batches all miss (zero spec hits), auto-disable speculation for the rest
# of the run — a speculative batch costs real encode + device time, and a
# 0%-hit-rate pipeline is pure overhead (the BENCH_simbackend regression
# this guards: pipelined audio ran *slower* than non-pipelined with
# n_spec_hits == 0)
SPEC_WINDOW = 8


@dataclasses.dataclass
class _Sel:
    """One dispatched iteration's selection context (the focus and the
    candidates a resolution needs back after its batch was scored — possibly
    one full iteration later, when the batch was dispatched speculatively)."""

    it: int
    focus: Focus
    neighbors: List["Candidate"]


@dataclasses.dataclass
class ExplorerConfig:
    awareness: str = "farsi"
    # HeuristicPolicy registry name (policy.POLICIES). Empty string — the
    # default — derives the policy from ``awareness`` (sa → naive_sa, … ,
    # farsi → farsi) so the historical knob keeps working; naming a policy
    # explicitly overrides the ladder (e.g. "bottleneck", "locality").
    policy: str = ""
    neighbors_per_iter: int = 4
    max_iterations: int = 1500
    seed: int = 0
    temperature0: float = 0.05
    temp_decay: float = 0.997
    alpha_met: float = 0.05
    dev_cost_aware: bool = True
    codesign: bool = True  # False => fixate focus until the focused metric is met
    taboo_ttl: int = 5
    backend: str = "python"  # SimulatorBackend registry name (backend.BACKENDS)
    # two-deep speculative dispatch pipeline: generate + encode batch i+1
    # (assuming batch i is rejected) while the device scores batch i.
    #   None  — auto: on async backends, speculate ADAPTIVELY (only while a
    #           running estimate says rejection is the likely outcome — in
    #           accept-heavy phases a speculative batch is almost always
    #           thrown away, so speculating there is pure overhead);
    #   True  — always speculate (the stall-guard / identity-test mode);
    #   False — off.
    # Every mode produces the same accepted-move sequence under a fixed
    # seed — speculation rolls its rng/policy state back on a miss.
    pipeline: Optional[bool] = None


@dataclasses.dataclass
class ExplorationResult:
    best_design: Design
    best_result: SimResult
    best_distance: Distance
    converged: bool
    iterations: int
    n_sims: int  # committed evaluations (mis-speculated batches excluded)
    wall_s: float
    history: List[dict]
    ledger: CodesignLedger
    backend_name: str = "python"
    policy_name: str = "farsi"
    sim_wall_s: float = 0.0  # time inside backend.evaluate for this run
    pipelined: bool = False  # ran with the speculative dispatch pipeline
    n_spec_hits: int = 0  # speculative batches that became the next iteration
    n_sims_wasted: int = 0  # speculated evaluations discarded on accept
    # the adaptive pipeline observed zero spec hits over its first
    # SPEC_WINDOW speculative batches and shut speculation off for the rest
    # of the run (pipeline=None only; forced pipeline=True never disables)
    spec_auto_disabled: bool = False

    def iterations_to_budget(self, cap: Optional[int] = None) -> float:
        """Iterations this run needed to reach budget — the policy-comparison
        metric (paper Fig. 9b): the iteration count when converged, else
        ``cap`` (default: the iterations actually run) as a censored floor."""
        if self.converged:
            return float(self.iterations)
        return float(cap if cap is not None else self.iterations)


class Explorer:
    def __init__(
        self,
        tdg: TaskGraph,
        db: HardwareDatabase,
        budget: Budget,
        config: ExplorerConfig = ExplorerConfig(),
        backend: Optional[SimulatorBackend] = None,
    ) -> None:
        self.tdg = tdg
        self.db = db
        self.budget = budget
        self.cfg = config
        assert config.awareness in AWARENESS_LEVELS
        self.rng = random.Random(config.seed)
        self.backend = backend or make_backend(config.backend, tdg, db)
        self.policy: HeuristicPolicy = make_policy(
            config.policy or AWARENESS_POLICY[config.awareness]
        )
        self.policy.bind(tdg, db, budget, config, self.rng)
        self.n_sims = 0  # committed designs this run submitted (backend stats
        # aggregate across sharers AND count mis-speculated batches; this
        # stays per-exploration — and per-commit — under Campaign)
        self.n_sims_wasted = 0  # speculated evaluations discarded on accept
        self.n_spec_hits = 0
        if config.pipeline is None:  # auto: needs an asynchronous dispatch
            self._pipeline = (
                "adaptive" if getattr(self.backend, "async_dispatch", False) else "off"
            )
        else:
            self._pipeline = "always" if config.pipeline else "off"
        self._p_rej = 0.0  # EW estimate of the rejection rate (adaptive gate)
        self._spec_tries = 0  # speculative batches actually dispatched
        self._spec_dead = False  # adaptive auto-disable latched (0-hit window)
        self.n_nonfinite = 0  # candidate rows rejected for NaN/Inf fitness
        # crash-restart support (serve layer): when enabled, each committed
        # loop top snapshots (rng state, policy checkpoint, iteration) so a
        # dead coroutine can be rebuilt from its last committed accept
        self.track_restart = False
        self._restart_ck: Optional[tuple] = None
        # session-yield point (serve.Session): called whenever an accepted
        # move improves the best-so-far design, with a small event dict —
        # accept-path state is never rolled back by speculation, so every
        # event is a committed improvement
        self.on_improve: Optional[Callable[[dict], None]] = None

    # ---- neighbour generation --------------------------------------------
    def _make_neighbors(
        self, design: Design, focus: Focus, moves: List[str], n: int
    ) -> List[Candidate]:
        """Up to ``n`` *distinct* neighbours: one per move of the policy's
        ordered list (candidate generation in SA, §3.4).

        Clone-free: each move is trialled in place on ``design`` (checkpoint
        → apply, recording its encoding delta → rollback), and the neighbour
        is shipped to the backend as a lightweight :class:`Candidate` — the
        paper's Fig.-8b design-duplication hot-spot never runs. Only the
        accepted candidate is ever materialized (``Candidate.accept``)."""
        direction = +1 if focus.metric == "latency" else -1
        out: List[Candidate] = []
        ck = design.checkpoint()
        for move in moves:
            if len(out) >= n:
                break
            task = focus.task
            delta = MoveDelta()
            ok = apply_move(
                design, self.tdg, move, focus.block, task, direction,
                focus.bneck, focus.metric, self.rng, delta,
            )
            design.restore(ck)
            if not ok and move in ("fork", "fork_swap") and task:
                # a targeted fork is inapplicable when the focus task is the
                # block's anchor (it must stay — apply_fork refuses rather
                # than silently migrating a different task). The untargeted
                # fork — split half the hosted load — is the legitimate
                # relief move for that same congestion, so offer it instead.
                task = None
                delta = MoveDelta()
                ok = apply_move(
                    design, self.tdg, move, focus.block, None, direction,
                    focus.bneck, focus.metric, self.rng, delta,
                )
                design.restore(ck)
            if ok:
                spec = MoveSpec(
                    move, focus.block, task, direction, focus.bneck,
                    focus.metric,
                )
                out.append(
                    Candidate(
                        base=design, spec=spec, delta=delta,
                        budget=self.budget, alpha=self.cfg.alpha_met,
                    )
                )
        return out

    # ---- main loop ---------------------------------------------------------
    def run_steps(
        self, initial: Optional[Design] = None
    ) -> Generator[List[Candidate], List[SimHandle], ExplorationResult]:
        """Coroutine form of the search: yields each iteration's candidate
        batch (lightweight :class:`Candidate` records sharing the current
        design — no clones) and is resumed (``gen.send``) with the matching
        :class:`SimHandle` list. The winner is picked from the handles'
        fitness column (device-computed on the JAX backend); an accepted
        winner yields only a :class:`SimTelemetry` view (device bottleneck
        columns + host-exact scalars) for the policy's next selection — the
        full ``SimResult`` decode is paid once, at exploration end, for the
        returned best design.

        With ``pipeline`` on (auto-enabled on async backends) the coroutine
        runs a TWO-DEEP SPECULATIVE PIPELINE: after receiving batch *i*'s
        (lazy) handles it does NOT touch them — it first speculates that
        batch *i* will be *rejected* (the steady-state outcome of a cooling
        anneal), generates + yields batch *i+1* under that assumption, and
        only then forces batch *i*'s one ``(B,)`` fitness pull. The driver
        encodes and dispatches batch *i+1* while the device is still scoring
        batch *i*, so host work hides behind device compute. On a miss (the
        move was accepted) the speculated rng/policy state is rolled back
        and batch *i+1* is regenerated from the true state — the
        accepted-move sequence is therefore IDENTICAL to the unpipelined
        coroutine under a fixed seed (asserted in tests); the only cost is
        the discarded device batch, accounted in ``n_sims_wasted``.

        ``run()`` drives it against ``self.backend``; `Campaign` drives many
        explorers' generators in lockstep so one dispatch prices the pending
        neighbours of *all* live explorations (speculative or not). The
        ``StopIteration`` value is the :class:`ExplorationResult`."""
        t0 = time.perf_counter()
        cur = initial or Design.base(self.tdg)
        pol = self.policy
        self._cur = cur  # committed design (mutated in place on accept only)
        if self.track_restart:
            self._restart_ck = (self.rng.getstate(), pol.checkpoint(), 0)
        adopt = getattr(self.backend, "adopt_encoding", None)
        self.n_sims += 1
        (h0,) = yield [Candidate.of_design(cur, self.budget, self.cfg.alpha_met)]
        cur_view: SimTelemetry = h0.telemetry()
        cur_dist = cur_view.dist(self.budget)
        if adopt is not None:
            adopt(h0)
        # best keeps (handle, stable-name design snapshot): cur mutates in
        # place hereafter. The snapshot CLONE is deferred (best_stale) until
        # right after the next dispatch is submitted, so its dict-copy cost
        # hides behind the device scoring that batch — cur cannot mutate
        # again before then. The handle is decoded into the best SimResult
        # only at exploration end (the one decode the search pays).
        best_design, best_handle, best_dist = cur.clone(rename=False), h0, cur_dist
        best_stale = False
        history: List[dict] = []
        max_it = self.cfg.max_iterations

        def select_from(it: int) -> Optional[_Sel]:
            """The head of one serial iteration, from the CURRENT search
            state: policy taboo decay → focus selection → move proposal →
            neighbour generation; iterations yielding no neighbours are
            taboo'd and skipped exactly as the serial loop's ``continue``
            did. Returns None once the iteration budget is spent or the
            search converged (convergence only moves on accept, so a
            reject-speculated call sees the truth)."""
            while it < max_it and not cur_dist.converged():
                pol.tick()
                focus = pol.select_focus(cur, cur_dist, cur_view)
                moves = pol.propose_moves(cur, focus)
                neighbors = self._make_neighbors(
                    cur, focus, moves, self.cfg.neighbors_per_iter
                )
                if neighbors:
                    return _Sel(it, focus, neighbors)
                pol.mark_failed(focus.task, focus.block)
                it += 1
            return None

        def resolve(sel: _Sel, handles: List[SimHandle], u: float) -> bool:
            """Rank batch ``sel`` from its fitness column (the one host pull
            that forces the dispatch) and run the policy's accept test with
            the pre-drawn uniform ``u`` — directly on that column: the
            backend's fitness IS Eq.-7 (device-computed on JAX,
            `budgets.distance` on Python), so a rejected iteration never
            reads anything else. Only an accepted winner yields its
            telemetry view for the next selection. Commits the accept-path
            state change; the reject-path taboo add is the caller's (it is
            part of the speculated continuation)."""
            nonlocal cur_view, cur_dist, best_design, best_handle, best_dist, best_stale
            assert len(handles) == len(sel.neighbors)
            # stable argmin preserves the precedence order on ties; the
            # policy's move_penalty rides on the fitness column (0.0 — and
            # bit-neutral — for every policy but dev_cost, so the guard below
            # fires on the backend's fitness, not the penalty), so a system-
            # growing move must buy more PPA than its development cost.
            # Non-finite rows (a poisoned device row, a NaN that leaked
            # through the scal pull) are clamped to +inf so they lose every
            # ranking — argmin over NaN is undefined — and can never be
            # accepted even when the whole batch is poisoned
            fits = []
            for h, c in zip(handles, sel.neighbors):
                f = h.fitness + pol.move_penalty(cur, c)
                if not math.isfinite(f):
                    self.n_nonfinite += 1
                    f = float("inf")
                fits.append(f)
            j = min(range(len(fits)), key=fits.__getitem__)
            cand, move = sel.neighbors[j], sel.neighbors[j].spec.move
            d_before = cur_dist.fitness(self.cfg.alpha_met)
            accept = math.isfinite(fits[j]) and pol.accept(sel.it, d_before, fits[j], u)
            dist_after = None
            if accept:
                # telemetry view, not a decode: device bottleneck columns +
                # the host-exact scalar rollup the next selection needs
                if pol.needs_result:
                    view = SimTelemetry.of_result(
                        handles[j].result(), self.tdg, cand.base
                    )
                else:
                    view = handles[j].telemetry()
                dist_after = view.dist(self.budget)
            pol.record(
                FocusRecord(
                    iteration=sel.it,
                    metric=sel.focus.metric,
                    workload=workload_of(sel.focus.task),
                    comm_comp="comp" if sel.focus.bneck == "pe" else "comm",
                    move=move,
                    distance_before=cur_dist.city_block(),
                    distance_after=dist_after.city_block() if accept else cur_dist.city_block(),
                )
            )
            if accept:
                cand.accept(self.tdg)  # materialize the move onto cur
                if adopt is not None:
                    adopt(handles[j])  # cur's encoding == the winner's row
                cur_view, cur_dist = view, dist_after
                if cur_dist.city_block() < best_dist.city_block():
                    best_handle, best_dist, best_stale = handles[j], cur_dist, True
                    if self.on_improve is not None:
                        # streamed best-design-so-far event: scalars only
                        # (the batch is already forced by the fitness read;
                        # no decode) — the full design decode stays deferred
                        # to exploration end
                        self.on_improve(
                            {
                                "iteration": sel.it,
                                "distance": best_dist.city_block(),
                                "fitness": best_dist.fitness(self.cfg.alpha_met),
                                "move": move,
                                "converged": best_dist.converged(),
                                **handles[j].scalars(),
                            }
                        )
            history.append(
                {
                    "iteration": sel.it,
                    "n_sims": self.n_sims,
                    "distance": best_dist.city_block(),
                    "fitness": best_dist.fitness(self.cfg.alpha_met),
                    "metric": sel.focus.metric,
                    "move": move,
                    "accepted": accept,
                    "wall_s": time.perf_counter() - t0,
                }
            )
            return accept

        mode = self._pipeline
        sel = select_from(0)
        if sel is not None:
            self.n_sims += len(sel.neighbors)
            handles = yield sel.neighbors
        while sel is not None:
            # loop-top state is always the committed truth: cur only mutates
            # on accept, and both speculation continuations land here with
            # rng/policy either rolled back (miss) or confirmed real (hit) —
            # the one safe point to snapshot for crash-restart
            if self.track_restart:
                self._restart_ck = (self.rng.getstate(), pol.checkpoint(), sel.it)
            # the SA accept draw: consumed unconditionally and BEFORE the
            # next iteration's selection draws, so the rng stream is the
            # same whether that selection happens now (speculation) or
            # after resolution (serial)
            u = self.rng.random()

            # ---- speculate REJECT: select + dispatch batch i+1 while the
            # device is still scoring batch i. The adaptive gate only
            # speculates when rejection is the likely outcome — a wasted
            # speculative batch costs real encode + device time, so in
            # accept-heavy (early, improving) phases the serial path wins.
            # the zero-value guard: an adaptive pipeline whose first
            # SPEC_WINDOW speculative batches all missed latches _spec_dead
            # and stops speculating — rejection-rate alone said "speculate"
            # while the observed hit rate said the batches were pure waste
            speculate = mode == "always" or (
                mode == "adaptive" and not self._spec_dead and self._p_rej >= 0.5
            )
            spec = spec_handles = None
            if speculate:
                ck = (self.rng.getstate(), pol.checkpoint())
                pol.mark_failed(sel.focus.task, sel.focus.block)
                spec = select_from(sel.it + 1)
                if spec is not None:
                    self._spec_tries += 1
                    spec_handles = yield spec.neighbors  # in flight behind batch i

            accepted = resolve(sel, handles, u)  # first host pull forces batch i
            self._p_rej = 0.75 * self._p_rej + (0.0 if accepted else 0.25)
            if speculate and not accepted:
                # hit: batch i+1 was encoded while batch i was scored and is
                # (likely) already scored itself — commit the speculation
                if spec is None:
                    break
                self.n_spec_hits += 1
                self.n_sims += len(spec.neighbors)
                sel, handles = spec, spec_handles
                continue
            if speculate:
                # miss: the accepted move invalidated the speculated state —
                # roll back rng/policy state and regenerate from the truth
                self.rng.setstate(ck[0])
                pol.restore(ck[1])
                if spec is not None:
                    self.n_sims_wasted += len(spec.neighbors)
            elif not accepted:
                pol.mark_failed(sel.focus.task, sel.focus.block)
            if (
                mode == "adaptive" and not self._spec_dead
                and self.n_spec_hits == 0 and self._spec_tries >= SPEC_WINDOW
            ):
                self._spec_dead = True
            sel = select_from(sel.it + 1)
            if sel is None:
                break
            self.n_sims += len(sel.neighbors)
            handles = yield sel.neighbors
            if best_stale:  # deferred snapshot: hides behind the dispatch
                best_design, best_stale = cur.clone(rename=False), False

        if best_stale:
            best_design = cur.clone(rename=False)
        # the exploration's ONE full decode: the returned best result, read
        # against the stable best-design snapshot (the winner's own base has
        # long since mutated past the priced state)
        best_res = best_handle.result_for(best_design)
        return ExplorationResult(
            best_design=best_design,
            best_result=best_res,
            best_distance=best_dist,
            converged=best_dist.converged(),
            iterations=len(history),
            n_sims=self.n_sims,
            wall_s=time.perf_counter() - t0,
            history=history,
            ledger=pol.ledger,
            backend_name=self.backend.name,
            policy_name=pol.name,
            pipelined=self._pipeline != "off",
            n_spec_hits=self.n_spec_hits,
            n_sims_wasted=self.n_sims_wasted,
            spec_auto_disabled=self._spec_dead,
        )

    def restart_state(self) -> Optional[dict]:
        """Crash-restart snapshot (serve layer; ``track_restart`` must have
        been on). Returns the last committed accept's ``design`` clone, the
        ``rng``/``policy`` state to restore onto a fresh Explorer, and the
        ``iteration`` the search had reached — or None if the coroutine died
        before the tracking was primed."""
        ck = self._restart_ck
        cur = getattr(self, "_cur", None)
        if ck is None or cur is None:
            return None
        rng_state, pol_ck, it = ck
        return {
            "design": cur.clone(rename=False),
            "rng": rng_state,
            "policy": pol_ck,
            "iteration": it,
        }

    def run(self, initial: Optional[Design] = None) -> ExplorationResult:
        """Drive :meth:`run_steps` against ``self.backend`` — exactly one
        ``backend.evaluate_candidates`` call per search iteration (plus one
        for the initial design, plus any mis-speculated batches when the
        pipeline is on). Drains abandoned speculative dispatches on exit."""
        gen = self.run_steps(initial)
        sim_wall = 0.0
        try:
            pending = next(gen)
            while True:
                t0 = time.perf_counter()
                handles = self.backend.evaluate_candidates(pending)
                sim_wall += time.perf_counter() - t0
                pending = gen.send(handles)
        except StopIteration as stop:
            flush = getattr(self.backend, "flush", None)
            if flush is not None:
                flush()
            result: ExplorationResult = stop.value
            result.sim_wall_s = sim_wall
            return result
