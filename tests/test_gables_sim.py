"""Extended-Gables analytical models (Eqs. 1–6) + phase-driven simulator on
hand-solvable systems, and phase-vs-event fidelity properties."""
import math

import pytest
from _optional_hypothesis import given, settings, st

from repro.core import (
    Design,
    HardwareDatabase,
    Task,
    TaskGraph,
    simulate,
    simulate_events,
)
from repro.core.gables import completion_time, phase_rates


def _single_task_graph(f=8e8, i_r=10.0, i_w=20.0, llp=1.0):
    g = TaskGraph("g")
    g.add_task(Task("t0", work_ops=f, i_read=i_r, i_write=i_w, llp=llp))
    return g


def test_eq1_eq5_single_task():
    """One task on a 100 MHz GPP (2 ops/cycle): C_T = max(f/P, D_r/B, D_w/B)."""
    db = HardwareDatabase()
    g = _single_task_graph()
    d = Design.base(g)
    res = simulate(d, g, db)
    p_peak = 100e6 * 2
    b_peak = 100e6 * 32  # mem 100 MHz × 32 B
    t = g.tasks["t0"]
    expected = max(t.work_ops / p_peak, t.read_bytes / b_peak, t.write_bytes / b_peak)
    assert math.isclose(res.latency_s, expected, rel_tol=1e-9)
    assert res.n_phases == 1


def test_eq1_preemptive_sharing():
    """Two independent compute-bound tasks on one PE finish in 2× the time
    (Eq. 1: P/|T|) but identical total (equal share, same completion)."""
    db = HardwareDatabase()
    g = TaskGraph("g")
    g.add_task(Task("a", work_ops=4e8, i_read=1e9, i_write=1e9))
    g.add_task(Task("b", work_ops=4e8, i_read=1e9, i_write=1e9))
    d = Design.base(g)
    res = simulate(d, g, db)
    single = 4e8 / (100e6 * 2)
    assert math.isclose(res.latency_s, 2 * single, rel_tol=1e-6)


def test_eq4_burst_proportional_memory():
    """Memory bandwidth divides by burst ratio: a task with 3× burst gets 3×
    bandwidth (Eq. 4), so the two finish together when data scales 3:1."""
    db = HardwareDatabase()
    g = TaskGraph("g")
    # communication-bound tasks (tiny compute): data ∝ burst
    g.add_task(Task("big", work_ops=1.0, i_read=1.0 / 3e6, i_write=1e30, burst_bytes=192))
    g.add_task(Task("small", work_ops=1.0, i_read=1.0 / 1e6, i_write=1e30, burst_bytes=64))
    d = Design.base(g)
    rates = phase_rates(d, g, ["big", "small"], db)
    assert math.isclose(rates["big"].read_bw / rates["small"].read_bw, 3.0, rel_tol=1e-9)
    c_big = completion_time(g.tasks["big"], rates["big"])
    c_small = completion_time(g.tasks["small"], rates["small"])
    assert math.isclose(c_big, c_small, rel_tol=1e-6)


def test_eq6_phase_boundaries_on_dependencies():
    """A chain of n tasks ⇒ n phases (each completion shifts the bottleneck)."""
    db = HardwareDatabase()
    g = TaskGraph("g")
    prev = None
    for i in range(4):
        g.add_task(Task(f"t{i}", work_ops=2e8, i_read=50, i_write=50))
        if prev:
            g.add_edge(prev, f"t{i}", 1e5)
        prev = f"t{i}"
    d = Design.base(g)
    res = simulate(d, g, db)
    assert res.n_phases == 4
    assert math.isclose(res.latency_s, 4 * (2e8 / 2e8), rel_tol=1e-6)


def test_accelerator_speedup_eq2():
    db = HardwareDatabase()
    g = _single_task_graph(f=8e8, i_r=1e9, i_w=1e9, llp=64.0)
    d = Design.base(g)
    base = simulate(d, g, db).latency_s
    # harden: swap the GPP into an accelerator for t0 with unroll 8
    pe = d.blocks[d.task_pe["t0"]]
    pe.subtype = "acc"
    pe.hardened_for = "t0"
    pe.unroll = 8
    acc = simulate(d, g, db).latency_s
    expected_speedup = db.a_peak("t0", llp=64.0, unroll=8)
    assert math.isclose(base / acc, expected_speedup, rel_tol=1e-6)
    # unroll beyond LLP is capped (Table 3: "according to the task")
    pe.unroll = 1024
    capped = simulate(d, g, db).latency_s
    assert math.isclose(base / capped, db.a_peak_base("t0") * 64.0, rel_tol=1e-6)


def test_noc_multi_hop_route():
    """A buffer two NoCs away is bottlenecked by the slowest link and counts
    hops in energy (locality reasoning substrate)."""
    from repro.core.blocks import make_gpp, make_mem, make_noc

    db = HardwareDatabase()
    g = _single_task_graph(f=1.0, i_r=1.0 / 3.2e6, i_w=1e30)
    d = Design()
    n0 = d.add_block(make_noc(freq_mhz=800, width_bytes=32))
    n1 = d.add_block(make_noc(freq_mhz=100, width_bytes=4))  # slow far link
    pe = d.add_block(make_gpp(800), attach_to=n0.name)
    m = d.add_block(make_mem("dram", 800, 256), attach_to=n1.name)
    d.task_pe["t0"] = pe.name
    d.task_mem["t0"] = m.name
    assert d.hops("t0") == 2
    res = simulate(d, g, db)
    slow_bw = 100e6 * 4
    assert math.isclose(res.latency_s, 3.2e6 / slow_bw, rel_tol=1e-6)


# ---------------------------------------------------------------------------
# phase-sim vs event-sim (the §4 fidelity claim, as properties)
# ---------------------------------------------------------------------------
@st.composite
def random_workload(draw):
    n = draw(st.integers(2, 6))
    g = TaskGraph("rand")
    for i in range(n):
        g.add_task(
            Task(
                f"t{i}",
                work_ops=draw(st.floats(1e6, 1e9)),
                i_read=draw(st.floats(1.0, 1e4)),
                i_write=draw(st.floats(1.0, 1e4)),
                llp=draw(st.floats(1.0, 1e4)),
                burst_bytes=draw(st.sampled_from([64, 256, 1024])),
            )
        )
    for i in range(1, n):
        if draw(st.booleans()):
            j = draw(st.integers(0, i - 1))
            g.add_edge(f"t{j}", f"t{i}", 1e5)
    return g


@given(random_workload(), st.integers(0, 3))
@settings(max_examples=25, deadline=None)
def test_event_sim_close_to_phase_sim(g, n_extra_pe):
    """The event-driven reference (burst-pipelined, per-event re-arbitration)
    must stay close to the phase estimate — the paper's 98.5% claim shape."""
    from repro.core.blocks import make_gpp

    db = HardwareDatabase()
    d = Design.base(g)
    # spread tasks over a few PEs to create contention variety
    for k in range(n_extra_pe):
        d.add_block(make_gpp(200), attach_to=d.noc_chain[0])
    pes = d.pes()
    for i, t in enumerate(sorted(g.tasks)):
        d.task_pe[t] = pes[i % len(pes)]
    r_p = simulate(d, g, db)
    r_e = simulate_events(d, g, db, max_chunks=64)
    rel = abs(r_p.latency_s - r_e.latency_s) / r_e.latency_s
    assert rel < 0.15, (r_p.latency_s, r_e.latency_s)
    assert r_p.n_phases <= r_e.n_phases  # agility: far fewer phases than events


def test_monotonicity_faster_pe():
    db = HardwareDatabase()
    g = _single_task_graph()
    d = Design.base(g)
    lat1 = simulate(d, g, db).latency_s
    d.blocks[d.task_pe["t0"]].freq_mhz = 800
    lat2 = simulate(d, g, db).latency_s
    assert lat2 < lat1
