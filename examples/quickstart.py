"""Quickstart: FARSI DSE on the AR workload complex (the paper's core loop).

Builds the Audio/CAVA/Edge-Detection task graphs, calibrates budgets, runs
the architecture-aware explorer from the 1-GPP base design, and prints the
convergence trajectory + final SoC.

  PYTHONPATH=src python examples/quickstart.py [--iterations 500] [--awareness farsi]
"""
import argparse

from repro.core import (
    AWARENESS_LEVELS,
    Design,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    ar_complex,
    calibrated_budget,
    simulate,
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=500)
    ap.add_argument("--awareness", choices=AWARENESS_LEVELS, default="farsi")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument(
        "--backend", choices=("python", "jax"), default="python",
        help="simulation backend: scalar Python reference or vmap-batched JAX "
             "(each iteration's neighbour set priced in one XLA dispatch)",
    )
    args = ap.parse_args()

    db = HardwareDatabase()
    graph = ar_complex()
    budget = calibrated_budget(db)
    print(f"workloads: {sorted(set(t.split('.')[0] for t in graph.tasks))}")
    print(f"latency budgets (ms): "
          f"{ {k: round(v*1e3,1) for k,v in budget.latency_s.items()} }")
    print(f"power budget: {budget.power_w*1e3:.0f} mW   area budget: {budget.area_mm2:.1f} mm²")

    base = Design.base(graph)
    r0 = simulate(base, graph, db)
    print(f"\nbase design (1 GPP + 1 NoC + 1 DRAM): latency={r0.latency_s:.2f}s "
          f"power={r0.power_w*1e3:.1f}mW area={r0.area_mm2:.1f}mm²")

    ex = Explorer(
        graph, db, budget,
        ExplorerConfig(awareness=args.awareness, max_iterations=args.iterations,
                       seed=args.seed, backend=args.backend),
    )
    res = ex.run()

    stats = ex.backend.stats()
    print(f"\nexplored {res.n_sims} designs in {res.wall_s:.1f}s "
          f"({res.n_sims/max(res.wall_s,1e-9):.0f} sims/s) "
          f"[backend={res.backend_name}: {stats.n_dispatches} dispatches, "
          f"sim_wall={res.sim_wall_s:.1f}s]")
    print(f"converged={res.converged} after {res.iterations} iterations")
    for h in res.history[:: max(len(res.history) // 10, 1)]:
        print(f"  iter {h['iteration']:4d}  distance={h['distance']:10.3f}  "
              f"metric={h['metric']:8s} move={h['move']}")

    d, r = res.best_design, res.best_result
    print(f"\nfinal SoC: {d.block_counts()}  "
          f"latency/workload(ms)={ {k: round(v*1e3,1) for k,v in r.workload_latency_s.items()} }")
    print(f"power={r.power_w*1e3:.1f}mW area={r.area_mm2:.1f}mm²")
    print("co-design summary:", res.ledger.summary())


if __name__ == "__main__":
    main()
