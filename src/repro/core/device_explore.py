"""Device-resident exploration: fused accept loop + vmapped chain populations.

The host-driven accept loop caps the explorer at ~1.2k it/s while the
batched evaluator sustains ~19k evals/s (BENCH_simbackend.json): every SA
iteration pays a dispatch, a device→host fitness transfer, and a Python
accept/taboo update before the next candidate can even be proposed. This
module moves the whole explore step onto the device:

  * :class:`MoveTable` — ``propose_moves`` in packed array form. Every
    candidate move is enumerated up front as three flat int32 columns
    (``kind``/``arg``/``dest``); the loop *samples* an index from this
    table on device instead of materializing `MoveDelta` objects on host.
    Beyond the PR-8 mapping moves (task → PE/MEM slot migrates), the
    ``alloc`` table adds FARSI's allocation moves as shape-preserving
    array operations over *capacity-padded slot inventories*: PE/MEM
    fork (clone a slot's coefficient columns into an inactive slot and
    re-home one task), join (deactivate an emptied slot — its leak/area
    stop pricing via the active masks), swap (step the slot's frequency
    rung, scaling the closed-form coefficient columns by static ladder
    ratios), and NoC attach (re-home a slot to another chain position).
    Validity is masked dynamically per chain: join only when the slot is
    empty, fork only into an inactive slot and only off a slot hosting
    ≥ 2 tasks, swap only inside the ladder — so the table is samplable
    inside a jitted loop even though each chain's platform differs.
  * A ``lax.scan`` accept loop: K iterations of propose → mutate carry
    → re-simulate → SA accept/reject run entirely on device. The carry
    (:class:`ChainCarry`) holds the full per-chain platform state:
    task→slot maps, active-slot masks, per-slot coefficient columns
    (the allocation moves' mutable state), frequency rungs, fork
    provenance, the (T, cap) acceleration table, fitness, PRNG key,
    per-move taboo TTLs, and the incumbent bottleneck telemetry.
  * Chain populations: the R chains ARE the batch axis of the simulator —
    each scan step prices an (R,)-rows dict through the usual batched
    path (Pallas kernel or XLA reference; ``kernels.phase_sim.chain``).
    Per-chain PRNG keys are ``fold_in(base_key, chain_index)``, so chain
    i's stream — and therefore its accepted-move sequence — is identical
    at R=16 and R=256 (population size never perturbs a chain).

Menus: ``naive_sa`` samples uniformly over the valid rows; ``telemetry``
weights rows by the bottleneck seconds of the move's focus slot (FARSI's
bottleneck-directed neighbour selection); ``farsi`` further multiplies in
the Algorithm-1 move-kind precedence (join > migrate ≈ attach > fork >
swap), making the full FARSI move ordering device-eligible.

One dispatch prices an (R, K) exploration block. The host calls
:meth:`DeviceChainRunner.run_chains` once per block and reconciles the
winning chain onto the live design — :func:`reconcile_mapping` for
mapping-only blocks, :func:`reconcile_alloc` (fork/join/retune/attach
replayed through ``moves.py``'s allocation bridge) for mixed blocks.
:meth:`DeviceChainRunner.run_chains_host` is the same compiled step
driven one iteration per dispatch — the classic host-loop regime — which
makes it both the parity oracle (bit-identical accepted-move sequences,
same threefry draws, same f32 accept math) and the speedup baseline the
bench reports against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import FREQ_LADDER_MHZ
from .budgets import Budget
from .database import HardwareDatabase
from .design import Design
from .moves import MoveDelta, apply_mapping, mapping_delta
from .phase_sim_jax import (
    BIG,
    EncodedDesign,
    EncodedWorkload,
    alloc_rows,
    fill_budget,
    fill_row,
)
from .tdg import TaskGraph

__all__ = [
    "MENUS",
    "MoveTable",
    "ChainCarry",
    "ChainRequest",
    "ChainBlockResult",
    "DeviceChainRunner",
    "copy_carry",
    "reconcile_mapping",
    "reconcile_alloc",
]

MENUS = ("naive_sa", "telemetry", "farsi")

# packed move-kind codes (MoveTable.kind). Even codes act on the PE class,
# odd on the MEM class; ``arg`` is a task index for migrate/fork and a slot
# index for join/swap/attach; ``dest`` is a slot index (migrate/fork), a
# ladder direction 0/1 (swap), or a NoC chain index (attach).
MV_MIG_PE, MV_MIG_MEM = 0, 1
MV_FORK_PE, MV_FORK_MEM = 2, 3
MV_JOIN_PE, MV_JOIN_MEM = 4, 5
MV_SWAP_PE, MV_SWAP_MEM = 6, 7
MV_ATT_PE, MV_ATT_MEM = 8, 9

# Algorithm-1 move precedence (moves.MOVE_PRECEDENCE), indexed by kind code:
# join 5 > migrate/attach 4 > fork 3 > swap 2 — the ``farsi`` menu folds
# log(precedence) into the sampling logits
_KIND_PRECEDENCE = np.asarray(
    [4.0, 4.0, 3.0, 3.0, 5.0, 5.0, 2.0, 2.0, 4.0, 4.0], np.float32
)

# frequency-rung ratio tables for the device swap move: stepping slot s from
# rung i to i±1 multiplies its closed-form coefficient columns in place —
# peak ops, mem bandwidth and leakage all scale linearly with f
# (db.pe_peak_ops / Block.peak_bandwidth / db.leakage_w), PE area scales
# with the timing-closure factor 0.6 + 0.4·f/800 (db.block_area_mm2); MEM
# area terms are frequency-independent in the encoding (DRAM PHY is fixed,
# SRAM per-MB carries no f-scale) and are left untouched.
_F = np.asarray(FREQ_LADDER_MHZ, np.float64)
_AREA_FS = 0.6 + 0.4 * (_F / 800.0)


def _ratio_table(vals: np.ndarray) -> np.ndarray:
    """(8, 2) f32: [i, 0] = vals[i-1]/vals[i] (step down), [i, 1] =
    vals[i+1]/vals[i] (step up); ladder ends hold 1.0 (masked invalid)."""
    r = np.ones((len(vals), 2), np.float32)
    r[1:, 0] = (vals[:-1] / vals[1:]).astype(np.float32)
    r[:-1, 1] = (vals[1:] / vals[:-1]).astype(np.float32)
    return r


_RATIO_F = _ratio_table(_F)
_RATIO_AREA = _ratio_table(_AREA_FS)
_N_RUNG = len(FREQ_LADDER_MHZ)


def _rung_of(freq_mhz: int) -> int:
    return int(np.argmin(np.abs(_F - float(freq_mhz))))


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


class ChainCarry(NamedTuple):
    """The full per-chain device state of an (R, K) block. The first seven
    leaves are the PR-8 mapping-only carry (order preserved — checkpoints
    and parity tests iterate leaves positionally); the rest is the
    allocation state: active-slot masks over the capacity-padded slot
    inventories, the per-slot coefficient columns allocation moves mutate
    (fork copies, swap scales, join strands), frequency rungs, fork
    provenance (the *base-encoding* slot each slot was transitively cloned
    from — what :func:`reconcile_alloc` replays on the host design), and
    the per-chain (T, cap_pe) acceleration table."""

    task_pe: jnp.ndarray  # (R, T) i32
    task_mem: jnp.ndarray  # (R, T) i32
    fitness: jnp.ndarray  # (R,) f32
    key: jnp.ndarray  # (R, 2) u32 per-chain PRNG key
    taboo: jnp.ndarray  # (R, M) i32 per-move taboo TTL
    pe_bneck: jnp.ndarray  # (R, cap_pe) f32 incumbent telemetry
    mem_bneck: jnp.ndarray  # (R, cap_mem) f32
    pe_active: jnp.ndarray  # (R, cap_pe) f32 active-slot mask
    mem_active: jnp.ndarray  # (R, cap_mem) f32
    pe_peak: jnp.ndarray  # (R, cap_pe) f32 coefficient columns …
    pe_pj: jnp.ndarray
    pe_leak: jnp.ndarray
    pe_area: jnp.ndarray
    pe_noc: jnp.ndarray  # (R, cap_pe) i32 NoC chain attachment
    pe_rung: jnp.ndarray  # (R, cap_pe) i32 frequency-ladder rung
    pe_src: jnp.ndarray  # (R, cap_pe) i32 fork provenance (base slot)
    mem_bw: jnp.ndarray  # (R, cap_mem) f32 …
    mem_pj: jnp.ndarray
    mem_leak: jnp.ndarray
    mem_area_fixed: jnp.ndarray
    mem_area_per_mb: jnp.ndarray
    mem_noc: jnp.ndarray  # (R, cap_mem) i32
    mem_rung: jnp.ndarray  # (R, cap_mem) i32
    mem_src: jnp.ndarray  # (R, cap_mem) i32
    accel: jnp.ndarray  # (R, T, cap_pe) f32 per-slot task acceleration


def reconcile_mapping(
    design: Design,
    res: "ChainBlockResult",
    g: TaskGraph,
    db: HardwareDatabase,
    enc: EncodedWorkload,
    ed: Optional[EncodedDesign] = None,
    delta: Optional[MoveDelta] = None,
) -> Dict[str, Dict[str, str]]:
    """Apply the winning chain's final mapping onto ``design`` in place
    (slot indices → block names via the encoding's slot dicts). Returns the
    changed assignments — empty dicts mean the block improved nothing over
    the incumbent mapping. Mapping-only: allocation state in the carry (if
    any) is ignored; mixed blocks reconcile via :func:`reconcile_alloc`."""
    if ed is None:
        ed = EncodedDesign.of(design, g, db, enc)
    inv_pe = {s: n for n, s in ed.pe_slot.items()}
    inv_mem = {s: n for n, s in ed.mem_slot.items()}
    w = res.winner
    ch_pe: Dict[str, str] = {}
    ch_mem: Dict[str, str] = {}
    for i, name in enumerate(enc.names):
        s = int(res.task_pe[w, i])
        if s != int(ed.task_pe[i]):
            ch_pe[name] = inv_pe[s]
        s = int(res.task_mem[w, i])
        if s != int(ed.task_mem[i]):
            ch_mem[name] = inv_mem[s]
    if ch_pe or ch_mem:
        apply_mapping(design, ch_pe, ch_mem, delta)
    return {"task_pe": ch_pe, "task_mem": ch_mem}


def _reconcile_class(
    design: Design,
    inv: Dict[int, str],
    active: np.ndarray,
    src: np.ndarray,
    rung: np.ndarray,
    noc: np.ndarray,
    base_noc: np.ndarray,
    out: Dict[str, object],
) -> Dict[int, str]:
    """One slot class (PE or MEM) of :func:`reconcile_alloc`: returns the
    carry-slot → block-name map after creating clones for forked slots and
    retuning/re-homing preserved originals. Removals are deferred to the
    caller (tasks must be re-mapped off doomed originals first)."""
    from .moves import attach_block, fork_block, retune_block

    s_base = len(inv)
    slot_name: Dict[int, str] = {}
    for j in range(active.shape[0]):
        if active[j] <= 0.5:
            continue
        f = int(FREQ_LADDER_MHZ[int(rung[j])])
        noc_name = design.noc_chain[int(noc[j])]
        if j < s_base and int(src[j]) == j:
            name = inv[j]
            slot_name[j] = name
            if design.blocks[name].freq_mhz != f:
                retune_block(design, name, f)
                out["retuned"][name] = f
            if j < len(base_noc) and int(noc[j]) != int(base_noc[j]):
                attach_block(design, name, noc_name)
                out["attached"][name] = noc_name
        else:
            origin = inv[int(src[j])]
            name = fork_block(design, origin, freq_mhz=f, noc=noc_name)
            slot_name[j] = name
            out["forked"].append(name)
    return slot_name


def reconcile_alloc(
    design: Design,
    res: "ChainBlockResult",
    g: TaskGraph,
    db: HardwareDatabase,
    enc: EncodedWorkload,
    ed: Optional[EncodedDesign] = None,
) -> Dict[str, object]:
    """Replay the winning chain's *platform* onto ``design`` in place: the
    mixed-move inverse of :func:`reconcile_mapping`. Uses the carry's fork
    provenance (``pe_src``/``mem_src`` point at the base-encoding slot each
    active slot was transitively cloned from) to rebuild the winner through
    ``moves.py``'s allocation bridge — clones for forked slots
    (:func:`~repro.core.moves.fork_block`), frequency retunes for stepped
    rungs, NoC re-homes for attaches, then the task mapping, then removal
    of originals the winner joined away. ``design`` must be the same design
    that seeded the block's fresh carry (provenance indexes its encoding)."""
    if ed is None:
        ed = EncodedDesign.of(design, g, db, enc)
    from .moves import join_block

    cc = ChainCarry(*res.carry)
    w = res.winner
    out: Dict[str, object] = {
        "task_pe": {}, "task_mem": {}, "forked": [], "removed": [],
        "retuned": {}, "attached": {},
    }
    inv_pe = {s: n for n, s in ed.pe_slot.items()}
    inv_mem = {s: n for n, s in ed.mem_slot.items()}
    pe_names = _reconcile_class(
        design, inv_pe, np.asarray(cc.pe_active[w]), np.asarray(cc.pe_src[w]),
        np.asarray(cc.pe_rung[w]), np.asarray(cc.pe_noc[w]), ed.pe_noc, out,
    )
    mem_names = _reconcile_class(
        design, inv_mem, np.asarray(cc.mem_active[w]),
        np.asarray(cc.mem_src[w]), np.asarray(cc.mem_rung[w]),
        np.asarray(cc.mem_noc[w]), ed.mem_noc, out,
    )
    # task re-mapping (after clones exist, before doomed originals go)
    for i, name in enumerate(enc.names):
        p = pe_names[int(res.task_pe[w, i])]
        if design.task_pe[name] != p:
            design.task_pe[name] = p
            out["task_pe"][name] = p
        m = mem_names[int(res.task_mem[w, i])]
        if design.task_mem[name] != m:
            design.task_mem[name] = m
            out["task_mem"][name] = m
    # originals the winner joined away (or re-populated with a clone)
    for inv, act, src in (
        (inv_pe, np.asarray(cc.pe_active[w]), np.asarray(cc.pe_src[w])),
        (inv_mem, np.asarray(cc.mem_active[w]), np.asarray(cc.mem_src[w])),
    ):
        for j, name in inv.items():
            if act[j] <= 0.5 or int(src[j]) != j:
                join_block(design, name)
                out["removed"].append(name)
    return out


def copy_carry(carry: Optional[tuple]) -> Optional[tuple]:
    """Deep-copy a chain-block carry so policy checkpoints round-trip
    bit-exactly even if the live carry advances. Preserves the carry's
    tuple type (:class:`ChainCarry` stays a ChainCarry)."""
    if carry is None:
        return None
    return type(carry)(*(np.array(x, copy=True) for x in carry))


@dataclasses.dataclass(frozen=True)
class MoveTable:
    """``propose_moves`` as packed arrays: row m is one candidate move
    (``kind[m]`` ∈ the ``MV_*`` codes) with operand columns ``arg`` (task
    index for migrate/fork, slot index for join/swap/attach) and ``dest``
    (destination slot / ladder direction / NoC chain index). Every row is
    shape-preserving over the capacity-padded inventories, so the whole
    table is samplable inside a jitted loop; validity (no-op destinations,
    inactive slots, full capacity, ladder ends, taboo) is masked
    dynamically per chain from the carry."""

    kind: np.ndarray  # (M,) int32 MV_* code
    task: np.ndarray  # (M,) int32 operand (task or slot index — see class)
    dest: np.ndarray  # (M,) int32 destination operand

    @property
    def n_moves(self) -> int:
        return int(self.kind.shape[0])

    @staticmethod
    def of(
        ed: EncodedDesign,
        enc: EncodedWorkload,
        *,
        alloc: bool = False,
        cap_pe: Optional[int] = None,
        cap_mem: Optional[int] = None,
    ) -> "MoveTable":
        """Enumerate the move rows of ``ed``. Mapping-only (default): all
        T·(S_pe + S_mem) single-task migrates, bit-compatible with the
        PR-8 table. ``alloc=True`` additionally enumerates fork/join/swap/
        NoC-attach rows over ``cap_pe``/``cap_mem`` padded slot inventories
        (default: pow2 ≥ real + 1, so at least one fork slot is free)."""
        t = len(enc.names)
        s_pe = int(ed.pe_peak.shape[0])
        s_mem = int(ed.mem_bw.shape[0])
        n_noc = int(ed.noc_bw.shape[0])
        if not alloc:
            cap_pe, cap_mem = s_pe, s_mem
        else:
            cap_pe = cap_pe or _pow2_at_least(s_pe + 1)
            cap_mem = cap_mem or _pow2_at_least(s_mem + 1)
        kinds: List[np.ndarray] = []
        args: List[np.ndarray] = []
        dests: List[np.ndarray] = []
        ti = np.arange(t, dtype=np.int32)

        def rows(kind: int, arg: np.ndarray, dest: np.ndarray) -> None:
            kinds.append(np.full(arg.shape[0], kind, np.int32))
            args.append(arg.astype(np.int32))
            dests.append(dest.astype(np.int32))

        def cross(kind: int, a: np.ndarray, d: np.ndarray) -> None:
            rows(kind, np.repeat(a, d.shape[0]), np.tile(d, a.shape[0]))

        cross(MV_MIG_PE, ti, np.arange(cap_pe))
        cross(MV_MIG_MEM, ti, np.arange(cap_mem))
        if alloc:
            si_pe = np.arange(cap_pe, dtype=np.int32)
            si_mem = np.arange(cap_mem, dtype=np.int32)
            updn = np.arange(2, dtype=np.int32)
            cross(MV_FORK_PE, ti, si_pe)
            cross(MV_FORK_MEM, ti, si_mem)
            rows(MV_JOIN_PE, si_pe, np.zeros(cap_pe))
            rows(MV_JOIN_MEM, si_mem, np.zeros(cap_mem))
            cross(MV_SWAP_PE, si_pe, updn)
            cross(MV_SWAP_MEM, si_mem, updn)
            if n_noc > 1:
                cross(MV_ATT_PE, si_pe, np.arange(n_noc))
                cross(MV_ATT_MEM, si_mem, np.arange(n_noc))
        return MoveTable(
            kind=np.concatenate(kinds),
            task=np.concatenate(args),
            dest=np.concatenate(dests),
        )

    def delta_of(
        self, m: int, enc: EncodedWorkload, ed: EncodedDesign
    ) -> MoveDelta:
        """Unpack a *migrate* row ``m`` into an ordinary :class:`MoveDelta`
        (absolute task→block-name mapping) — the bridge back to the host
        move system. Allocation rows have no single-delta form; whole
        blocks reconcile through :func:`reconcile_alloc` instead."""
        k = int(self.kind[m])
        if k not in (MV_MIG_PE, MV_MIG_MEM):
            raise ValueError(f"row {m} (kind {k}) is not a migrate move")
        tname = enc.names[int(self.task[m])]
        d = int(self.dest[m])
        if k == MV_MIG_PE:
            inv = {s: n for n, s in ed.pe_slot.items()}
            return mapping_delta({tname: inv[d]}, {})
        inv = {s: n for n, s in ed.mem_slot.items()}
        return mapping_delta({}, {tname: inv[d]})


@dataclasses.dataclass
class ChainRequest:
    """One (R, K) exploration block the explorer asks its backend to price.

    Yielded by ``Explorer.run_chain_steps`` in place of a candidate list;
    the serve scheduler (or ``Explorer.run_chains``) answers it with the
    :class:`ChainBlockResult` of ``backend.run_chains``. ``carry`` resumes
    the chain population from a previous block (or a ``device_sa`` policy
    checkpoint); ``it0`` keeps the SA temperature schedule global across
    blocks. ``alloc`` widens the move table to the mixed
    mapping+allocation menu over ``cap_pe``/``cap_mem`` padded slot
    inventories (pinned by the first block of a run so resumed carries
    stay shape-compatible; None derives pow2 capacities from the design)."""

    design: Design
    budget: Budget
    r: int
    k: int
    seed: int = 0
    it0: int = 0
    menu: str = "naive_sa"
    alpha: float = 0.05
    temperature0: float = 0.05
    temp_decay: float = 0.997
    taboo_ttl: int = 5
    carry: Optional[tuple] = None
    alloc: bool = False
    cap_pe: Optional[int] = None
    cap_mem: Optional[int] = None


@dataclasses.dataclass
class ChainBlockResult:
    """Host-side view of one priced (R, K) block. ``carry`` is the full
    device state pulled back as numpy (the checkpointable object); the
    per-step traces cover every chain so parity/trajectory tests can replay
    any of them."""

    task_pe: np.ndarray  # (R, T) final task→PE-slot map per chain
    task_mem: np.ndarray  # (R, T) final task→MEM-slot map per chain
    fitness: np.ndarray  # (R,) final Eq.-7 fitness per chain
    move_idx: np.ndarray  # (R, K) sampled MoveTable row per step
    accepted: np.ndarray  # (R, K) bool accept/reject per step
    fit_trace: np.ndarray  # (R, K) incumbent fitness after each step
    carry: tuple  # numpy ChainCarry (resume / checkpoint)
    winner: int  # argmin-fitness chain index
    wall_s: float  # dispatch wall-clock (including device sync)
    n_moves: int  # MoveTable rows (M)

    def seq(self, chain: int = 0) -> List[Tuple[int, int]]:
        """(move_idx, accepted) sequence of one chain — the parity object."""
        return [
            (int(m), int(a))
            for m, a in zip(self.move_idx[chain], self.accepted[chain])
        ]


class DeviceChainRunner:
    """Owns the jitted (R, K) chain blocks for one workload.

    The jit cache is keyed on everything that changes the traced program:
    (R, K, slot capacities, chain length, menu, alloc flag, SA constants).
    ``n_compiles`` counts distinct cache entries — the smoke guard asserts
    the whole bench run stays within a handful. There is no fallback path:
    a design the flat encoding cannot host (``UnsupportedDesignError``)
    fails loudly instead of silently degrading to a host loop, so
    ``n_fallback`` is 0 by construction and asserted in the bench."""

    def __init__(
        self,
        g: TaskGraph,
        db: HardwareDatabase,
        enc: Optional[EncodedWorkload] = None,
        *,
        use_kernel: bool = False,
        interpret: bool = False,
    ):
        self.g = g
        self.db = db
        self.enc = enc if enc is not None else EncodedWorkload.of(g)
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._blocks: Dict[tuple, object] = {}
        self.n_compiles = 0
        self.n_fallback = 0
        self.n_dispatches = 0
        self.n_chain_steps = 0

    # -- host-side staging -------------------------------------------------
    def _row0(self, ed: EncodedDesign, budget: Budget, alpha: float):
        t = len(self.enc.names)
        rows = alloc_rows(
            1, t, int(ed.pe_peak.shape[0]), int(ed.mem_bw.shape[0]),
            len(self.enc.wl_names), int(ed.noc_bw.shape[0]),
        )
        fill_row(rows, 0, ed)
        fill_budget(
            rows, 0, self.enc,
            budget.latency_s, budget.power_w, budget.area_mm2, alpha,
        )
        return {k: v[0] for k, v in rows.items()}

    def _accel_table(
        self, design: Design, ed: EncodedDesign, cap_pe: Optional[int] = None
    ) -> np.ndarray:
        """(T, cap_pe) effective acceleration of task t if mapped to PE slot
        p — ``pe_accel`` is a per-task column, so a device migrate re-gathers
        it from this table instead of asking the hardware DB mid-loop.
        Padded slots accelerate nothing (1.0); a device fork copies its
        source slot's column, so clones inherit the hardened profile."""
        t = len(self.enc.names)
        cap = cap_pe or int(ed.pe_peak.shape[0])
        tab = np.ones((t, cap), np.float32)
        tasks = self.g.tasks
        for name, s in ed.pe_slot.items():
            b = design.blocks[name]
            if b.subtype == "acc" and b.hardened_for in self.enc.index:
                k = self.enc.index[b.hardened_for]
                tab[k, s] = self.db.a_peak(
                    b.hardened_for, tasks[b.hardened_for].llp, b.unroll
                )
        return tab

    @staticmethod
    def _pad_cols(col: np.ndarray, cap: int, pad: float, dtype) -> np.ndarray:
        out = np.full(cap, pad, dtype)
        out[: col.shape[0]] = col
        return out

    def fresh_carry(
        self,
        design: Design,
        ed: EncodedDesign,
        r: int,
        seed: int,
        *,
        cap_pe: Optional[int] = None,
        cap_mem: Optional[int] = None,
        alloc: Optional[bool] = None,
    ) -> ChainCarry:
        """Initial chain-population carry: every chain starts from the live
        design with fitness BIG (the first finite candidate is accepted,
        exactly like the host explorer pricing its seed), zero taboo, zero
        telemetry, all real slots active / padded slots inactive, rungs
        read off the blocks' frequency knobs, provenance = own slot, and
        key ``fold_in(PRNGKey(seed), chain_index)`` — the per-chain stream
        is a function of (seed, chain) only, never of R."""
        t = len(self.enc.names)
        s_pe = int(ed.pe_peak.shape[0])
        s_mem = int(ed.mem_bw.shape[0])
        cap_pe = cap_pe or s_pe
        cap_mem = cap_mem or s_mem
        if alloc is None:
            alloc = cap_pe > s_pe or cap_mem > s_mem
        m = MoveTable.of(
            ed, self.enc, alloc=alloc, cap_pe=cap_pe, cap_mem=cap_mem
        ).n_moves
        base = jax.random.PRNGKey(seed)
        keys = np.asarray(
            jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(r))
        )
        inv_pe = {s: n for n, s in ed.pe_slot.items()}
        inv_mem = {s: n for n, s in ed.mem_slot.items()}
        pe_rung = np.zeros(cap_pe, np.int32)
        for s in range(s_pe):
            pe_rung[s] = _rung_of(design.blocks[inv_pe[s]].freq_mhz)
        mem_rung = np.zeros(cap_mem, np.int32)
        for s in range(s_mem):
            mem_rung[s] = _rung_of(design.blocks[inv_mem[s]].freq_mhz)
        pad = self._pad_cols
        bc = lambda a: np.broadcast_to(a, (r,) + a.shape).copy()
        accel = np.ones((t, cap_pe), np.float32)
        accel[:, :s_pe] = self._accel_table(design, ed)[:, :s_pe]
        return ChainCarry(
            task_pe=np.broadcast_to(ed.task_pe, (r, t)).copy(),
            task_mem=np.broadcast_to(ed.task_mem, (r, t)).copy(),
            fitness=np.full((r,), BIG, np.float32),
            key=keys,
            taboo=np.zeros((r, m), np.int32),
            pe_bneck=np.zeros((r, cap_pe), np.float32),
            mem_bneck=np.zeros((r, cap_mem), np.float32),
            pe_active=bc(pad(ed.pe_active, cap_pe, 0.0, np.float32)),
            mem_active=bc(pad(ed.mem_active, cap_mem, 0.0, np.float32)),
            pe_peak=bc(pad(ed.pe_peak, cap_pe, 1.0, np.float32)),
            pe_pj=bc(pad(ed.pe_pj, cap_pe, 0.0, np.float32)),
            pe_leak=bc(pad(ed.pe_leak, cap_pe, 0.0, np.float32)),
            pe_area=bc(pad(ed.pe_area, cap_pe, 0.0, np.float32)),
            pe_noc=bc(pad(ed.pe_noc, cap_pe, 0, np.int32)),
            pe_rung=bc(pe_rung),
            pe_src=bc(np.arange(cap_pe, dtype=np.int32)),
            mem_bw=bc(pad(ed.mem_bw, cap_mem, 1.0, np.float32)),
            mem_pj=bc(pad(ed.mem_pj, cap_mem, 0.0, np.float32)),
            mem_leak=bc(pad(ed.mem_leak, cap_mem, 0.0, np.float32)),
            mem_area_fixed=bc(pad(ed.mem_area_fixed, cap_mem, 0.0, np.float32)),
            mem_area_per_mb=bc(pad(ed.mem_area_per_mb, cap_mem, 0.0, np.float32)),
            mem_noc=bc(pad(ed.mem_noc, cap_mem, 0, np.int32)),
            mem_rung=bc(mem_rung),
            mem_src=bc(np.arange(cap_mem, dtype=np.int32)),
            accel=bc(accel),
        )

    # -- the fused block ---------------------------------------------------
    def _block(
        self, r: int, k: int, ed: EncodedDesign, menu: str,
        t0: float, decay: float, ttl: int, alloc: bool,
        cap_pe: int, cap_mem: int,
    ):
        key = (
            r, k, cap_pe, cap_mem,
            int(ed.noc_bw.shape[0]), menu, float(t0), float(decay), int(ttl),
            alloc,
        )
        fn = self._blocks.get(key)
        if fn is None:
            fn = self._build_block(
                r, k, menu, float(t0), float(decay), int(ttl),
                cap_pe, cap_mem,
            )
            self._blocks[key] = fn
            self.n_compiles += 1
        return fn

    def _build_block(
        self, r: int, k: int, menu: str, t0: float, decay: float, ttl: int,
        cap_pe: int, cap_mem: int,
    ):
        # deferred: core must stay importable before kernels.phase_sim
        # finishes initializing (chain.py itself imports core.phase_sim_jax,
        # so a module-level import here closes an import cycle whenever the
        # kernels package is imported first)
        from ..kernels.phase_sim.chain import resimulate_chains

        enc = self.enc
        use_kernel, interpret = self.use_kernel, self.interpret
        t = len(enc.names)
        tidx = jnp.arange(t)
        ridx = jnp.arange(r)
        t0f, decayf = jnp.float32(t0), jnp.float32(decay)
        prec_log = jnp.log(jnp.asarray(_KIND_PRECEDENCE))
        ratio_f = jnp.asarray(_RATIO_F)
        ratio_a = jnp.asarray(_RATIO_AREA)
        # carry leaves the accept step swaps wholesale on accept/reject
        # (everything mutable except fitness/key/taboo/telemetry)
        _STATE = (
            "task_pe", "task_mem", "pe_active", "mem_active",
            "pe_peak", "pe_pj", "pe_leak", "pe_area", "pe_noc", "pe_rung",
            "pe_src",
            "mem_bw", "mem_pj", "mem_leak", "mem_area_fixed",
            "mem_area_per_mb", "mem_noc", "mem_rung", "mem_src", "accel",
        )

        def apply_move(c: ChainCarry, kd, a, d) -> ChainCarry:
            """Apply each chain's sampled row (kind ``kd``, operands ``a``,
            ``d``; all (R,)) to its platform state. Every move class writes
            through a sentinel-gated scatter (``mode="drop"``): rows of
            another class point the update at an out-of-range index, so the
            write vanishes — one fused graph, no per-kind branches."""
            s = {f: getattr(c, f) for f in _STATE}
            a_task = jnp.clip(a, 0, t - 1)
            dsw = jnp.clip(d, 0, 1)  # swap rows: dest is the direction bit
            step = 2 * dsw - 1
            for cls, cap, mig, frk, jn, sw, att in (
                ("pe", cap_pe, MV_MIG_PE, MV_FORK_PE, MV_JOIN_PE,
                 MV_SWAP_PE, MV_ATT_PE),
                ("mem", cap_mem, MV_MIG_MEM, MV_FORK_MEM, MV_JOIN_MEM,
                 MV_SWAP_MEM, MV_ATT_MEM),
            ):
                tm = s["task_pe"] if cls == "pe" else s["task_mem"]
                act = s[f"{cls}_active"]
                rung = s[f"{cls}_rung"]
                cols_f = (
                    ("pe_peak", "pe_pj", "pe_leak", "pe_area")
                    if cls == "pe"
                    else ("mem_bw", "mem_pj", "mem_leak", "mem_area_fixed",
                          "mem_area_per_mb")
                )
                # rung-ratio columns: rates/leak scale with f, PE area with
                # the timing-closure factor; MEM area is f-independent
                sw_cols = (
                    (("pe_peak", ratio_f), ("pe_leak", ratio_f),
                     ("pe_area", ratio_a))
                    if cls == "pe"
                    else (("mem_bw", ratio_f), ("mem_leak", ratio_f))
                )
                misc = (f"{cls}_noc", f"{cls}_rung", f"{cls}_src")
                # mapping write (migrate/fork re-home task ``a`` to ``d``)
                ti = jnp.where((kd == mig) | (kd == frk), a, t)
                tm = tm.at[ridx, ti].set(d, mode="drop")
                s["task_pe" if cls == "pe" else "task_mem"] = tm
                # fork: clone the forked task's pre-move slot into slot
                # ``d`` (gather via the OLD map — the mapping write above
                # already re-pointed the task at d)
                old_tm = getattr(c, "task_pe" if cls == "pe" else "task_mem")
                src_slot = jnp.clip(old_tm[ridx, a_task], 0, cap - 1)
                fi = jnp.where(kd == frk, d, cap)
                for f in cols_f + misc:
                    s[f] = s[f].at[ridx, fi].set(
                        s[f][ridx, src_slot], mode="drop"
                    )
                s[f"{cls}_active"] = s[f"{cls}_active"].at[ridx, fi].set(
                    1.0, mode="drop"
                )
                if cls == "pe":
                    s["accel"] = s["accel"].at[
                        ridx[:, None], tidx[None, :], fi[:, None]
                    ].set(
                        s["accel"][ridx[:, None], tidx[None, :],
                                   src_slot[:, None]],
                        mode="drop",
                    )
                # join: deactivate the (empty) slot ``a``
                ji = jnp.where(kd == jn, a, cap)
                s[f"{cls}_active"] = s[f"{cls}_active"].at[ridx, ji].set(
                    0.0, mode="drop"
                )
                # swap: step slot ``a`` one frequency rung, scaling the
                # closed-form columns by the static ladder ratios
                si = jnp.where(kd == sw, a, cap)
                r_cur = jnp.clip(rung[ridx, jnp.clip(a, 0, cap - 1)],
                                 0, _N_RUNG - 1)
                for f, tab in sw_cols:
                    s[f] = s[f].at[ridx, si].multiply(
                        tab[r_cur, dsw], mode="drop"
                    )
                s[f"{cls}_rung"] = s[f"{cls}_rung"].at[ridx, si].add(
                    step, mode="drop"
                )
                # attach: re-home slot ``a`` to NoC chain position ``d``
                ai = jnp.where(kd == att, a, cap)
                s[f"{cls}_noc"] = s[f"{cls}_noc"].at[ridx, ai].set(
                    d, mode="drop"
                )
            return c._replace(**s)

        def block(carry, it0, row0, kind, arg, dest):
            # static per-block columns: the NoC chain + budget rows
            # broadcast once; the carry supplies every PE/MEM column
            rows_static = {
                n: jnp.broadcast_to(v, (r,) + jnp.shape(v))
                for n, v in row0.items()
                if n.startswith("noc_") or n in (
                    "wl_budget", "power_budget", "area_budget", "alpha",
                )
            }

            def step(c: ChainCarry, it):
                taboo = jnp.maximum(c.taboo - 1, 0)
                keys = jax.vmap(lambda kk: jax.random.split(kk, 3))(c.key)
                key, k_move, k_acc = keys[:, 0], keys[:, 1], keys[:, 2]
                c = c._replace(key=key, taboo=taboo)
                # ---- dynamic validity over the packed table -------------
                a_task = jnp.clip(arg, 0, t - 1)
                cur_pe = c.task_pe[:, a_task]  # (R, M)
                cur_mem = c.task_mem[:, a_task]
                a_pe = jnp.clip(arg, 0, cap_pe - 1)
                a_mem = jnp.clip(arg, 0, cap_mem - 1)
                d_pe = jnp.clip(dest, 0, cap_pe - 1)
                d_mem = jnp.clip(dest, 0, cap_mem - 1)
                load_pe = jnp.sum(
                    c.task_pe[:, :, None]
                    == jnp.arange(cap_pe)[None, None, :],
                    axis=1,
                )  # (R, cap_pe) tasks per slot
                load_mem = jnp.sum(
                    c.task_mem[:, :, None]
                    == jnp.arange(cap_mem)[None, None, :],
                    axis=1,
                )
                act_pe_d = c.pe_active[:, d_pe] > 0
                act_mem_d = c.mem_active[:, d_mem] > 0
                act_pe_a = c.pe_active[:, a_pe] > 0
                act_mem_a = c.mem_active[:, a_mem] > 0
                step_r = 2 * jnp.clip(dest, 0, 1) - 1
                rung_pe = c.pe_rung[:, a_pe] + step_r
                rung_mem = c.mem_rung[:, a_mem] + step_r
                in_lad = lambda x: (x >= 0) & (x < _N_RUNG)
                kd = kind[None, :]
                valid = (
                    ((kd == MV_MIG_PE) & (dest[None, :] != cur_pe) & act_pe_d)
                    | ((kd == MV_MIG_MEM)
                       & (dest[None, :] != cur_mem) & act_mem_d)
                    | ((kd == MV_FORK_PE) & ~act_pe_d
                       & (jnp.take_along_axis(load_pe, cur_pe, axis=1) >= 2))
                    | ((kd == MV_FORK_MEM) & ~act_mem_d
                       & (jnp.take_along_axis(load_mem, cur_mem, axis=1) >= 2))
                    | ((kd == MV_JOIN_PE) & act_pe_a
                       & (load_pe[:, a_pe] == 0))
                    | ((kd == MV_JOIN_MEM) & act_mem_a
                       & (load_mem[:, a_mem] == 0))
                    | ((kd == MV_SWAP_PE) & act_pe_a & in_lad(rung_pe))
                    | ((kd == MV_SWAP_MEM) & act_mem_a & in_lad(rung_mem))
                    | ((kd == MV_ATT_PE) & act_pe_a
                       & (dest[None, :] != c.pe_noc[:, a_pe]))
                    | ((kd == MV_ATT_MEM) & act_mem_a
                       & (dest[None, :] != c.mem_noc[:, a_mem]))
                ) & (taboo == 0)
                any_valid = jnp.any(valid, axis=1)  # (R,)
                # ---- menu logits ----------------------------------------
                if menu in ("telemetry", "farsi"):
                    is_pe_cls = (kd % 2) == 0
                    is_task_arg = kd <= MV_FORK_MEM
                    w_task = jnp.where(
                        is_pe_cls,
                        jnp.take_along_axis(c.pe_bneck, cur_pe, axis=1),
                        jnp.take_along_axis(c.mem_bneck, cur_mem, axis=1),
                    )
                    w_slot = jnp.where(
                        is_pe_cls, c.pe_bneck[:, a_pe], c.mem_bneck[:, a_mem]
                    )
                    w = jnp.where(is_task_arg, w_task, w_slot) + jnp.float32(
                        1e-6
                    )
                    logw = jnp.log(w)
                    if menu == "farsi":
                        logw = logw + prec_log[kind][None, :]
                else:
                    logw = jnp.zeros((r, kind.shape[0]), jnp.float32)
                logits = jnp.where(valid, logw, jnp.float32(-1e30))
                m = jax.vmap(jax.random.categorical)(k_move, logits)
                # ---- apply + price the candidate platform ---------------
                cand = apply_move(c, kind[m], arg[m], dest[m])
                rows = dict(rows_static)
                rows["task_pe"] = cand.task_pe
                rows["task_mem"] = cand.task_mem
                rows["pe_accel"] = jnp.take_along_axis(
                    cand.accel, cand.task_pe[:, :, None], axis=2
                )[:, :, 0]
                for f in (
                    "pe_peak", "pe_pj", "pe_leak", "pe_area", "pe_noc",
                    "pe_active", "mem_bw", "mem_pj", "mem_leak",
                    "mem_area_fixed", "mem_area_per_mb", "mem_noc",
                    "mem_active",
                ):
                    rows[f] = getattr(cand, f)
                res = resimulate_chains(
                    enc, rows, use_kernel=use_kernel, interpret=interpret
                )
                f_new = res["fitness"].astype(jnp.float32)
                # SA accept, f32 mirror of PolicyBase.accept; chains whose
                # whole menu was masked (all-taboo / degenerate platform)
                # force-reject and leave every state leaf untouched
                temp = t0f * decayf ** it.astype(jnp.float32)
                u = jax.vmap(
                    lambda kk: jax.random.uniform(kk, dtype=jnp.float32)
                )(k_acc)
                ok = jnp.isfinite(f_new) & (
                    (f_new < c.fitness)
                    | (
                        (temp > 0)
                        & (
                            u
                            < jnp.exp(
                                -(f_new - c.fitness)
                                / jnp.maximum(temp, jnp.float32(1e-9))
                            )
                        )
                    )
                )
                ok = ok & any_valid
                sel = lambda n, o: jnp.where(
                    ok.reshape((r,) + (1,) * (o.ndim - 1)), n, o
                )
                merged = {
                    f: sel(getattr(cand, f), getattr(c, f)) for f in _STATE
                }
                fit = jnp.where(ok, f_new, c.fitness)
                tab_wr = taboo.at[ridx, m].set(jnp.int32(ttl))
                taboo2 = jnp.where(
                    (ok | ~any_valid)[:, None], taboo, tab_wr
                )
                pe_b = jnp.where(
                    ok[:, None], res["pe_bneck_s"].astype(jnp.float32),
                    c.pe_bneck,
                )
                mem_b = jnp.where(
                    ok[:, None], res["mem_bneck_s"].astype(jnp.float32),
                    c.mem_bneck,
                )
                c = c._replace(
                    fitness=fit, taboo=taboo2, pe_bneck=pe_b, mem_bneck=mem_b,
                    **merged,
                )
                return c, (m.astype(jnp.int32), ok, fit)

            its = it0 + jnp.arange(k, dtype=jnp.int32)
            carry, (mv, acc, ft) = jax.lax.scan(step, carry, its)
            return carry, (mv.T, acc.T, ft.T)

        return jax.jit(block)

    def _capacities(
        self, ed: EncodedDesign, alloc: bool,
        cap_pe: Optional[int], cap_mem: Optional[int],
        carry: Optional[tuple],
    ) -> Tuple[int, int]:
        """Resolve the padded slot capacities of a block: an explicit
        override wins, then a resumed carry's shape (capacity is pinned for
        a whole exploration), then pow2 ≥ real+1 (alloc) / real (mapping)."""
        if carry is not None:
            cc = ChainCarry(*carry)
            return int(cc.pe_active.shape[1]), int(cc.mem_active.shape[1])
        s_pe = int(ed.pe_peak.shape[0])
        s_mem = int(ed.mem_bw.shape[0])
        if not alloc:
            return s_pe, s_mem
        return (
            cap_pe or _pow2_at_least(s_pe + 1),
            cap_mem or _pow2_at_least(s_mem + 1),
        )

    # -- entry points ------------------------------------------------------
    def run_chains(
        self,
        design: Design,
        budget: Budget,
        *,
        r: int,
        k: int,
        seed: int = 0,
        it0: int = 0,
        menu: str = "naive_sa",
        alpha: float = 0.05,
        temperature0: float = 0.05,
        temp_decay: float = 0.997,
        taboo_ttl: int = 5,
        carry: Optional[tuple] = None,
        alloc: bool = False,
        cap_pe: Optional[int] = None,
        cap_mem: Optional[int] = None,
    ) -> ChainBlockResult:
        """Price one fused (R, K) exploration block in a single dispatch.
        ``alloc=True`` samples the mixed mapping+allocation menu over
        capacity-padded slot inventories; the default is the PR-8
        mapping-only table (bit-compatible sequences)."""
        if menu not in MENUS:
            raise ValueError(f"unknown device move menu: {menu!r}")
        ed = EncodedDesign.of(design, self.g, self.db, self.enc)
        cap_pe, cap_mem = self._capacities(ed, alloc, cap_pe, cap_mem, carry)
        s_pe = int(ed.pe_peak.shape[0])
        s_mem = int(ed.mem_bw.shape[0])
        alloc = alloc or cap_pe > s_pe or cap_mem > s_mem
        table = MoveTable.of(
            ed, self.enc, alloc=alloc, cap_pe=cap_pe, cap_mem=cap_mem
        )
        row0 = self._row0(ed, budget, alpha)
        fn = self._block(
            r, k, ed, menu, temperature0, temp_decay, taboo_ttl, alloc,
            cap_pe, cap_mem,
        )
        if carry is None:
            carry = self.fresh_carry(
                design, ed, r, seed, cap_pe=cap_pe, cap_mem=cap_mem,
                alloc=alloc,
            )
        elif not isinstance(carry, ChainCarry):
            carry = ChainCarry(*carry)
        t_start = time.perf_counter()
        out_carry, (mv, acc, ft) = fn(
            carry, jnp.int32(it0), row0,
            table.kind, table.task, table.dest,
        )
        out_carry = ChainCarry(*(np.asarray(x) for x in out_carry))
        mv, acc, ft = np.asarray(mv), np.asarray(acc), np.asarray(ft)
        wall = time.perf_counter() - t_start
        self.n_dispatches += 1
        self.n_chain_steps += r * k
        return ChainBlockResult(
            task_pe=out_carry.task_pe,
            task_mem=out_carry.task_mem,
            fitness=out_carry.fitness,
            move_idx=mv,
            accepted=acc,
            fit_trace=ft,
            carry=out_carry,
            winner=int(np.argmin(out_carry.fitness)),
            wall_s=wall,
            n_moves=table.n_moves,
        )

    def run_chains_host(
        self,
        design: Design,
        budget: Budget,
        *,
        r: int = 1,
        n_steps: int,
        seed: int = 0,
        it0: int = 0,
        menu: str = "naive_sa",
        alpha: float = 0.05,
        temperature0: float = 0.05,
        temp_decay: float = 0.997,
        taboo_ttl: int = 5,
        carry: Optional[tuple] = None,
        alloc: bool = False,
        cap_pe: Optional[int] = None,
        cap_mem: Optional[int] = None,
    ) -> ChainBlockResult:
        """The host-driven reference accept loop: the SAME compiled chain
        step, dispatched K=1 at a time with the carry pulled back to host
        between iterations — one dispatch + one round trip per SA step,
        the regime of the classic host explorer. Because it shares the
        block body (same threefry draws, same f32 accept math — for the
        mixed mapping+allocation menu too), a fused K-step block must
        replay it bit-for-bit; this is the parity oracle and the speedup
        baseline."""
        t_start = time.perf_counter()
        mvs, accs, fts = [], [], []
        res = None
        for i in range(n_steps):
            res = self.run_chains(
                design, budget, r=r, k=1, seed=seed, it0=it0 + i, menu=menu,
                alpha=alpha, temperature0=temperature0, temp_decay=temp_decay,
                taboo_ttl=taboo_ttl, carry=carry, alloc=alloc,
                cap_pe=cap_pe, cap_mem=cap_mem,
            )
            carry = res.carry  # numpy — the per-iteration host round trip
            mvs.append(res.move_idx)
            accs.append(res.accepted)
            fts.append(res.fit_trace)
        wall = time.perf_counter() - t_start
        return ChainBlockResult(
            task_pe=res.task_pe,
            task_mem=res.task_mem,
            fitness=res.fitness,
            move_idx=np.concatenate(mvs, axis=1),
            accepted=np.concatenate(accs, axis=1),
            fit_trace=np.concatenate(fts, axis=1),
            carry=res.carry,
            winner=res.winner,
            wall_s=wall,
            n_moves=res.n_moves,
        )

    def reconcile(
        self,
        design: Design,
        res: ChainBlockResult,
        ed: Optional[EncodedDesign] = None,
        delta: Optional[MoveDelta] = None,
    ) -> Dict[str, Dict[str, str]]:
        """:func:`reconcile_mapping` against this runner's workload."""
        return reconcile_mapping(
            design, res, self.g, self.db, self.enc, ed=ed, delta=delta
        )

    def reconcile_alloc(
        self,
        design: Design,
        res: ChainBlockResult,
        ed: Optional[EncodedDesign] = None,
    ) -> Dict[str, object]:
        """:func:`reconcile_alloc` against this runner's workload."""
        return reconcile_alloc(
            design, res, self.g, self.db, self.enc, ed=ed
        )
