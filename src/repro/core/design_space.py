"""Randomized design sampling.

One generator shared by the simulator-equivalence tests, the backend
benchmarks, and anything seeding exploration populations — so every consumer
exercises the same design distribution: base design plus a random mix of
task-hardened accelerators and extra memories on a single NoC, random
buffer placement, random link count.
"""
from __future__ import annotations

import random
from typing import List

from .blocks import make_accelerator, make_mem
from .design import Design
from .tdg import TaskGraph


def random_single_noc_designs(
    g: TaskGraph, n: int, seed: int = 0, vary_links: bool = True
) -> List[Design]:
    """``n`` random single-NoC designs shaped like SA neighbourhoods."""
    rng = random.Random(seed)
    designs = []
    for _ in range(n):
        d = Design.base(g)
        noc = d.noc_chain[0]
        tasks = sorted(g.tasks)
        for _ in range(rng.randint(0, 6)):
            if rng.random() < 0.6:
                t = rng.choice(tasks)
                b = d.add_block(
                    make_accelerator(t, rng.choice((100, 400, 800))), attach_to=noc
                )
                b.unroll = rng.choice((1, 8, 64))
                d.task_pe[t] = b.name
            else:
                d.add_block(
                    make_mem(
                        rng.choice(("dram", "sram")),
                        rng.choice((100, 800)),
                        rng.choice((32, 256)),
                    ),
                    attach_to=noc,
                )
        mems = d.mems()
        for t in tasks:
            d.task_mem[t] = rng.choice(mems)
        if vary_links:
            d.blocks[noc].n_links = rng.choice((1, 2, 4))
        designs.append(d)
    return designs
