"""Mamba-2 block [arXiv:2405.21060]: in_proj → short causal depthwise conv →
SSD sequence transform → gated RMSNorm → out_proj.

Sequence path uses the chunked SSD (``kernels/ssd``: Pallas on TPU, pure-jnp
reference elsewhere); decode path keeps a recurrent (conv window, SSM state)
cache per layer — O(1) per token, which is why the SSM archs run long_500k.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels.ssd.ref import ssd_decode_step, ssd_reference
from .layers import rms_norm


def _dims(cfg: ModelConfig):
    d_in = cfg.ssm_d_inner
    nh = cfg.ssm_n_heads
    n = cfg.ssm_state
    conv_dim = d_in + 2 * n  # x, B, C all pass through the conv
    return d_in, nh, n, conv_dim


def mamba2_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d = cfg.d_model
    d_in, nh, n, conv_dim = _dims(cfg)
    keys = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    # in_proj emits [z (d_in), xBC (conv_dim), dt (nh)]
    return {
        "in_proj": (
            jax.random.normal(keys[0], (d, 2 * d_in + 2 * n + nh)) * s_in
        ).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (cfg.ssm_conv_width, conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_in,), dtype),
        "out_proj": (
            jax.random.normal(keys[3], (d_in, d)) / math.sqrt(d_in)
        ).astype(dtype),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W: y_t = Σ_w x_{t-W+1+w} · w_w + b.
    Expressed as W shifted adds (no conv primitive needed — fuses trivially).
    xbc: (B, S, C)."""
    width = w.shape[0]
    out = jnp.zeros_like(xbc)
    for i in range(width):
        shift = width - 1 - i
        if shift == 0:
            out = out + xbc * w[i]
        else:
            out = out + jnp.pad(xbc, ((0, 0), (shift, 0), (0, 0)))[:, : xbc.shape[1]] * w[i]
    return out + b


def _split(cfg: ModelConfig, zxbcdt: jax.Array):
    d_in, nh, n, conv_dim = _dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim :]
    return z, xbc, dt


def mamba2_apply(
    params: dict,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    ssd_fn=None,
) -> jax.Array:
    d_in, nh, n, conv_dim = _dims(cfg)
    b, s, _ = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = _split(cfg, zxbcdt)
    xbc = jax.nn.silu(_causal_conv(xbc, params["conv_w"], params["conv_b"]).astype(jnp.float32)).astype(x.dtype)
    xs = xbc[..., :d_in].reshape(b, s, nh, cfg.ssm_head_dim)
    b_mat = xbc[..., d_in : d_in + n]
    c_mat = xbc[..., d_in + n :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,nh)
    a = -jnp.exp(params["a_log"])  # (nh,) < 0
    ssd = ssd_fn or ssd_reference
    y, _ = ssd(xs, dt, a, b_mat, c_mat)
    y = y + params["d_skip"][None, None, :, None] * xs  # D skip connection
    y = y.reshape(b, s, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"])


# ---------------------------------------------------------------------------
# decode path: recurrent cache = (conv window, ssm state)
# ---------------------------------------------------------------------------
def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, nh, n, conv_dim = _dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, cfg.ssm_head_dim, n), jnp.float32),
    }


def mamba2_decode(
    params: dict,
    x: jax.Array,  # (B, 1, D)
    cache: dict,
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict]:
    d_in, nh, n, conv_dim = _dims(cfg)
    b = x.shape[0]
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["in_proj"])
    z, xbc, dt = _split(cfg, zxbcdt)  # xbc: (B, 1, conv_dim)

    window = jnp.concatenate([cache["conv"], xbc], axis=1)  # (B, W, conv_dim)
    conv_out = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    xbc_t = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)  # (B, conv_dim)

    xs = xbc_t[:, :d_in].reshape(b, nh, cfg.ssm_head_dim)
    b_vec = xbc_t[:, d_in : d_in + n]
    c_vec = xbc_t[:, d_in + n :]
    dt_t = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + params["dt_bias"])  # (B, nh)
    a = -jnp.exp(params["a_log"])

    y, h_new = ssd_decode_step(cache["ssm"], xs, dt_t, a, b_vec, c_vec)
    y = y + params["d_skip"][None, :, None] * xs
    y = y.reshape(b, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"])
    new_cache = {"conv": window[:, 1:], "ssm": h_new}
    return out, new_cache
