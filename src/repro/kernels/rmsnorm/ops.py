"""jit'd wrapper: (..., d) RMSNorm via the fused Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import rmsnorm_2d


@partial(jax.jit, static_argnames=("eps", "row_block", "interpret"))
def rmsnorm(
    x: jax.Array, w: jax.Array, eps: float = 1e-6, row_block: int = 256, interpret: bool = False
) -> jax.Array:
    shape = x.shape
    out = rmsnorm_2d(
        x.reshape(-1, shape[-1]), w, eps=eps, row_block=row_block, interpret=interpret
    )
    return out.reshape(shape)
