"""Pluggable heuristic policies (paper §3.3–3.4, Algorithm 1, §5.2–5.3).

FARSI's headline result is not the simulator but the *navigation heuristic*:
simulated annealing augmented with architectural reasoning converges up to
16X faster than naive SA (§5.2), and co-design focus rotation adds another
32% (§5.3). This module makes that reasoning an explicit, swappable layer:
a :class:`HeuristicPolicy` owns the per-iteration 5-tuple selection
(metric → task → block → moves), the SA accept rule, the taboo list, and
the co-design ledger — the `Explorer` is reduced to the dispatch loop that
drives whichever policy `ExplorerConfig.policy` names.

Policies select from a :class:`~repro.core.backend.SimTelemetry` view —
device-side bottleneck telemetry columns (per-block binding-bottleneck
seconds, top-bottleneck block, comp-vs-comm split) plus host-exact scalar
accessors — so a policy-driven search never forces the winner's full
``SimResult`` decode.

Registered policies (``POLICIES`` / ``make_policy``):

  ``naive_sa``    — every choice uniformly random (the §5.2 baseline; also
                    what ``awareness="sa"`` maps to)
  ``task``        — + bottleneck-driven task selection (awareness ladder)
  ``task_block``  — + bottleneck-driven block selection (awareness ladder)
  ``bottleneck``  — relaxation guided purely by the DEVICE telemetry: the
                    comp-vs-comm split picks the resource class, the
                    top-bottleneck column picks the block, the longest
                    hosted task is targeted; moves stay random
  ``locality``    — Algorithm-1 parallelism/locality move reasoning on top
                    of bottleneck-driven selection, without development-cost
                    precedence or co-design rotation
  ``farsi``       — the full composition (bottleneck relaxation + locality
                    exploitation + dev-cost precedence + co-design focus
                    rotation): replays the recorded golden accepted-move
                    sequences bit-for-bit under a fixed seed (fixtures are
                    regenerated only on deliberate behaviour changes —
                    tests/gen_golden_policy_seqs.py)
  ``dev_cost``    — ``farsi`` plus an explicit development-cost penalty on
                    every candidate's fitness (component count + variation,
                    NoCs double-weighted): the §5.3 NoC-simplification
                    policy, compared against ``farsi`` via the complexity
                    metrics ``Campaign.aggregate`` reports
  ``device_sa``   — ``naive_sa`` on the host path, and the DEVICE-ELIGIBLE
                    policy for the fused chain blocks
                    (`repro.core.device_explore`): its checkpoint/restore
                    additionally round-trips the chain-population carry
                    bit-exactly, so a crash-restarted session resumes
                    mid-population

A policy is stateful (taboo list, sticky focus, ledger) and must support
``checkpoint()``/``restore()`` so the serve layer can rebuild a crashed
session from its last committed state; the rng is the *explorer's* —
shared so the accept-draw/selection interleaving is reproducible from a
seed alone.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Protocol, Tuple, runtime_checkable

from .backend import SimTelemetry
from .blocks import BlockKind
from .budgets import Budget, Distance
from .codesign import CodesignLedger, FocusRecord
from .database import HardwareDatabase
from .design import Design
from .device_explore import copy_carry
from .moves import MOVE_KINDS, MOVE_PRECEDENCE
from .tdg import TaskGraph, workload_of


@dataclasses.dataclass(frozen=True)
class Focus:
    """One iteration's selection target: the (metric, task, block) triple of
    Algorithm 1 plus the task's binding-resource class."""

    metric: str
    task: str
    block: str
    bneck: str  # "pe" | "mem" | "noc"


@runtime_checkable
class HeuristicPolicy(Protocol):
    """The navigation heuristic the Explorer delegates to."""

    name: str
    needs_result: bool  # True → feed decoded SimResults instead of telemetry
    ledger: CodesignLedger

    def bind(self, tdg: TaskGraph, db: HardwareDatabase, budget: Budget,
             cfg, rng: random.Random) -> None:
        """Attach the search context. Called once by the Explorer."""
        ...

    def tick(self) -> None:
        """Start-of-iteration bookkeeping (taboo decay)."""
        ...

    def select_focus(self, design: Design, dist: Distance,
                     view: SimTelemetry) -> Focus:
        """Pick the next (metric, task, block, bneck) from the current
        design, its Eq.-7 distance, and the bottleneck telemetry."""
        ...

    def propose_moves(self, design: Design, focus: Focus) -> List[str]:
        """Ordered move kinds to try for ``focus`` (Algorithm 1 steps I–III)."""
        ...

    def accept(self, it: int, d_before: float, d_after: float, u: float) -> bool:
        """The SA accept rule on the device fitness column (``u`` is the
        pre-drawn uniform, one per resolved iteration — the same draw
        discipline the device accept loop mirrors in f32)."""
        ...

    def record(self, rec: FocusRecord) -> None:
        """Log one committed iteration's focus into the co-design ledger."""
        ...

    def mark_failed(self, task: str, block: str) -> None:
        """Taboo a (task, block) target that produced no acceptable move."""
        ...

    def is_taboo_task(self, task: str) -> bool:
        ...

    def move_penalty(self, design: Design, cand) -> float:
        """Additive fitness penalty for one priced candidate (Eq.-7 units).
        The explorer ranks and accept-tests on ``fitness + penalty``, so a
        non-zero penalty makes a candidate win only when its PPA gain
        outweighs its development cost. The default is 0.0 — bit-neutral."""
        ...

    def checkpoint(self) -> object:
        """Snapshot mutable policy state (crash restart / session resume)."""
        ...

    def restore(self, ck: object) -> None:
        ...


# ---------------------------------------------------------------------------
# shared structural predicates (Algorithm 1's parallelism/locality tests)
# ---------------------------------------------------------------------------
def block_has_parallel_tasks(design: Design, tdg: TaskGraph, block: str) -> bool:
    """Does ``block`` host two tasks that could run concurrently? Runs on the
    memoized ``tdg.parallel_set_of`` frozensets — no per-call set builds."""
    kind = design.blocks[block].kind
    if kind == BlockKind.PE:
        hosted = design.tasks_on_pe(block)
    elif kind == BlockKind.MEM:
        hosted = design.buffers_on_mem(block)
    else:
        hosted = design.tasks_via_noc(block)
    for i, a in enumerate(hosted):
        if tdg.parallel_set_of(a).intersection(hosted[i + 1:]):
            return True
    return False


def task_parallel_other_blocks(design: Design, tdg: TaskGraph, t: str) -> bool:
    """Does ``t`` have a concurrent peer mapped to a different PE?"""
    mine = design.task_pe[t]
    return any(design.task_pe[p] != mine for p in tdg.parallel_set_of(t))


# ---------------------------------------------------------------------------
# base: shared state + SA accept rule
# ---------------------------------------------------------------------------
class PolicyBase:
    """Common policy state: taboo list, sticky focus, co-design ledger, and
    the classic SA temperature accept test. Subclasses implement the
    selection reasoning."""

    name = "base"
    needs_result = False
    # the on-device move menu this policy corresponds to when the explorer
    # runs chain-batched (device_explore.MENUS). Any policy can carry the
    # chain-population state between blocks (``device_carry``), and every
    # checkpoint round-trips it bit-exactly; subclasses with a device-
    # eligible selection heuristic override the menu name.
    device_menu = "naive_sa"

    def __init__(self) -> None:
        self.ledger = CodesignLedger()
        self._taboo: Dict[Tuple[str, str], int] = {}
        self._sticky: Optional[str] = None  # codesign-off focus fixation
        self.device_carry: Optional[tuple] = None

    def bind(self, tdg, db, budget, cfg, rng) -> None:
        self.tdg = tdg
        self.db = db
        self.budget = budget
        self.cfg = cfg
        self.rng = rng

    # ---- bookkeeping -----------------------------------------------------
    def tick(self) -> None:
        self._taboo = {k: v - 1 for k, v in self._taboo.items() if v > 1}

    def mark_failed(self, task: str, block: str) -> None:
        self._taboo[(task, block)] = self.cfg.taboo_ttl

    def is_taboo_task(self, task: str) -> bool:
        return any(k[0] == task for k in self._taboo)

    def record(self, rec: FocusRecord) -> None:
        self.ledger.log(rec)

    def checkpoint(self) -> object:
        return (dict(self._taboo), self._sticky, copy_carry(self.device_carry))

    def restore(self, ck: object) -> None:
        self._taboo, self._sticky = dict(ck[0]), ck[1]
        self.device_carry = copy_carry(ck[2]) if len(ck) > 2 else None

    def move_penalty(self, design: Design, cand) -> float:
        """Development-cost scoring hook — 0.0 for every stock policy, so
        ranking and accept stay bit-identical to the raw fitness column
        (x + 0.0 is exact). :class:`DevCostPolicy` overrides it."""
        return 0.0

    # ---- SA accept (Eq.-7 fitness on the device column) ------------------
    def accept(self, it: int, d_before: float, d_after: float, u: float) -> bool:
        temp = self.cfg.temperature0 * self.cfg.temp_decay ** it
        return d_after < d_before or (
            temp > 0 and u < math.exp(-(d_after - d_before) / max(temp, 1e-9))
        )

    # ---- shared selection fragments --------------------------------------
    rotate = True  # False → always fixate, regardless of cfg.codesign

    def _metric_farthest(self, dist: Distance) -> str:
        """Focus rotation: re-pick the farthest metric every iteration when
        co-design is on (§5.3); fixate on one unmet metric when it is off
        (the paper's ablation) or when the policy opts out of rotation
        (``rotate = False`` — the locality ablation)."""
        if not self.cfg.codesign or not self.rotate:
            if self._sticky and dist.per_metric[self._sticky] > 0:
                return self._sticky
            unmet = [m for m, d in dist.per_metric.items() if d > 0]
            self._sticky = unmet[0] if unmet else "latency"
            return self._sticky
        return dist.farthest_metric()

    def _rank_tasks(self, design: Design, metric: str, dist: Distance,
                    view: SimTelemetry) -> List[str]:
        """Distance-contribution ranking per metric (§3.3): critical-path
        duration for latency (worst workload first), dynamic energy for
        power, resident memory footprint for area."""
        tasks = list(self.tdg.tasks)
        if metric == "latency":
            wl = max(
                dist.per_workload_latency,
                key=lambda w: dist.per_workload_latency[w],
            )
            pool = [t for t in tasks if workload_of(t) == wl] or tasks
            return sorted(pool, key=view.task_duration, reverse=True)
        if metric == "power":
            return sorted(tasks, key=view.task_energy_j, reverse=True)
        # area: tasks whose buffers sit on the largest memories first
        # (capacity is keyed by *memory* name — resolve through the task's
        # mapped memory; own write bytes break ties within one memory)
        return sorted(
            tasks,
            key=lambda t: (
                view.mem_capacity(design.task_mem.get(t, "")),
                self.tdg.tasks[t].write_bytes,
            ),
            reverse=True,
        )

    def _first_untabooed(self, ranked: List[str]) -> str:
        for t in ranked:
            if not self.is_taboo_task(t):
                return t
        return ranked[0]

    def _idle_block(self, design: Design) -> Optional[str]:
        """Dead hardware first: an idle block is pure leakage/area, and join
        removes it for free (the cheapest possible move)."""
        for n, b in design.blocks.items():
            if b.kind == BlockKind.PE and not design.tasks_on_pe(n):
                return n
            if b.kind == BlockKind.MEM and not design.buffers_on_mem(n):
                return n
        return None

    def _algorithm1_moves(self, design: Design, focus: Focus) -> List[str]:
        """Algorithm 1 step I: the move classes the parallelism/locality
        structure of the focus admits."""
        if focus.metric == "latency":
            if block_has_parallel_tasks(design, self.tdg, focus.block):
                return ["migrate", "fork"]
            return ["swap", "fork_swap"]
        if focus.metric == "power":
            if task_parallel_other_blocks(design, self.tdg, focus.task):
                if not block_has_parallel_tasks(design, self.tdg, focus.block):
                    return ["migrate"]
                return ["join"]
            return ["swap", "fork_swap"]
        # area
        if design.blocks[focus.block].kind == BlockKind.PE:
            return ["join", "swap"]
        return ["migrate", "join", "swap"]

    def _weighted_order(self, allowed: List[str], weights: List[float]) -> List[str]:
        """Algorithm 1 steps II/III: precedence-weighted probabilistic
        ordering, then graceful fallback to the rest of the move set."""
        ordered: List[str] = []
        pool, w = list(allowed), list(weights)
        while pool:
            pick = self.rng.choices(range(len(pool)), weights=w)[0]
            ordered.append(pool.pop(pick))
            w.pop(pick)
        ordered += [m for m in MOVE_KINDS if m not in ordered]
        return ordered


# ---------------------------------------------------------------------------
# the awareness ladder (paper Fig. 9b) as concrete policies
# ---------------------------------------------------------------------------
class NaiveSA(PolicyBase):
    """Pure simulated annealing: metric, task, block, and move order all
    uniformly random (the §5.2 baseline FARSI beats by up to 16X)."""

    name = "naive_sa"

    def select_focus(self, design, dist, view) -> Focus:
        metric = self.rng.choice(("latency", "power", "area"))
        task = self.rng.choice(list(self.tdg.tasks))
        block = self.rng.choice(list(design.blocks))
        return Focus(metric, task, block, view.task_bneck(task))

    def propose_moves(self, design, focus) -> List[str]:
        moves = list(MOVE_KINDS)
        self.rng.shuffle(moves)
        return moves


class DeviceSA(NaiveSA):
    """`naive_sa` + device-eligibility: the policy the fused chain blocks
    (`repro.core.device_explore`) run under. On the host path it behaves
    exactly like ``naive_sa`` (same draws, same accept rule); when the
    explorer runs chain-batched (``ExplorerConfig.chain_r > 0``) the device
    carry — per-chain task maps, fitness, PRNG keys, taboo TTLs, telemetry
    columns — is stored here between blocks, and ``checkpoint``/``restore``
    round-trip it bit-exactly so a crash-restarted session resumes
    mid-population instead of re-annealing from scratch.

    ``device_menu`` names the on-device move menu the policy corresponds
    to: ``naive_sa`` samples the packed move table uniformly — the menu the
    R=1/K=1 parity contract is stated against. (The carry storage and its
    bit-exact checkpoint round-trip live on :class:`PolicyBase` now, so
    every policy can drive chain blocks; this class survives as the
    canonical registry name for the uniform-menu device search.)"""

    name = "device_sa"
    device_menu = "naive_sa"


class TaskAware(NaiveSA):
    """+ bottleneck-driven task selection (awareness level ``task``)."""

    name = "task"

    def select_focus(self, design, dist, view) -> Focus:
        metric = self._metric_farthest(dist)
        task = self._first_untabooed(self._rank_tasks(design, metric, dist, view))
        block = self.rng.choice(list(design.blocks))
        return Focus(metric, task, block, view.task_bneck(task))


class TaskBlockAware(TaskAware):
    """+ bottleneck-driven block selection (awareness level ``task_block``)."""

    name = "task_block"

    def _select_block(self, design, metric, task, view) -> str:
        if metric in ("power", "area"):
            idle = self._idle_block(design)
            if idle is not None:
                return idle
        if metric == "area":
            return max(
                design.blocks,
                key=lambda b: self.db.block_area_mm2(design.blocks[b]),
            )
        blk = view.task_bneck_block(task)
        if blk in design.blocks:
            return blk
        return design.task_pe[task]

    def select_focus(self, design, dist, view) -> Focus:
        metric = self._metric_farthest(dist)
        task = self._first_untabooed(self._rank_tasks(design, metric, dist, view))
        block = self._select_block(design, metric, task, view)
        return Focus(metric, task, block, view.task_bneck(task))


class FarsiPolicy(TaskBlockAware):
    """The full FARSI heuristic: bottleneck relaxation + Algorithm-1
    locality reasoning + development-cost move precedence + co-design focus
    rotation. Replays the recorded golden accepted-move sequences
    bit-for-bit under a fixed seed (tests/test_policy.py fixtures;
    regenerated via tests/gen_golden_policy_seqs.py only when search
    behaviour changes deliberately).

    Device-eligible: the ``farsi`` chain menu weights the packed move table
    by bottleneck telemetry AND folds in the Algorithm-1 move-kind
    precedence (join > migrate ≈ attach > fork > swap) — the on-device
    counterpart of ``propose_moves``'s dev-cost-weighted ordering."""

    name = "farsi"
    device_menu = "farsi"

    def propose_moves(self, design, focus) -> List[str]:
        allowed = self._algorithm1_moves(design, focus)
        if self.cfg.dev_cost_aware:
            weights = [float(MOVE_PRECEDENCE[m]) for m in allowed]
        else:
            weights = [1.0] * len(allowed)
        return self._weighted_order(allowed, weights)


# ---------------------------------------------------------------------------
# telemetry-native policies (select straight from the device columns)
# ---------------------------------------------------------------------------
class BottleneckRelaxation(PolicyBase):
    """Pure bottleneck relaxation, driven by the device telemetry columns:
    the comp-vs-comm split picks the resource class to relax, the
    top-bottleneck column picks the block, and the longest task hosted on it
    is targeted. Move order stays random — this isolates *where to aim* (the
    telemetry's contribution) from *what to do* (Algorithm 1, see
    :class:`LocalityExploitation` / :class:`FarsiPolicy`).

    Device-eligible: the ``telemetry`` chain menu is this policy's
    on-device counterpart — move rows are weighted by the bottleneck
    seconds of their focus slot, straight from the carry's telemetry
    columns."""

    name = "bottleneck"
    device_menu = "telemetry"

    def select_focus(self, design, dist, view) -> Focus:
        metric = self._metric_farthest(dist)
        if metric == "area":
            idle = self._idle_block(design)
            block = idle or max(
                design.blocks,
                key=lambda b: self.db.block_area_mm2(design.blocks[b]),
            )
        elif view.comp_s >= view.comm_s:
            block = view.top_bneck_pe() or design.noc_chain[0]
        else:
            block = view.top_bneck_mem() or design.noc_chain[0]
        kind = design.blocks[block].kind
        if kind == BlockKind.PE:
            hosted = design.tasks_on_pe(block)
        elif kind == BlockKind.MEM:
            hosted = design.buffers_on_mem(block)
        else:
            hosted = list(self.tdg.tasks)
        pool = [t for t in hosted if not self.is_taboo_task(t)] or hosted \
            or list(self.tdg.tasks)
        task = max(pool, key=view.task_duration)
        return Focus(metric, task, block, view.task_bneck(task))

    def propose_moves(self, design, focus) -> List[str]:
        moves = list(MOVE_KINDS)
        self.rng.shuffle(moves)
        return moves


class LocalityExploitation(TaskBlockAware):
    """Algorithm-1 parallelism/locality move reasoning on top of
    bottleneck-driven selection, but WITHOUT development-cost precedence or
    co-design rotation: the structural reasoning alone, for ablating how
    much of FARSI's gain comes from *which move* vs *which target*."""

    name = "locality"
    rotate = False  # fixate until the focused metric meets budget

    def propose_moves(self, design, focus) -> List[str]:
        allowed = self._algorithm1_moves(design, focus)
        return self._weighted_order(allowed, [1.0] * len(allowed))


class DevCostPolicy(FarsiPolicy):
    """Development-cost-aware navigation (paper §5.3): FARSI's full
    heuristic plus an explicit component-count / variation penalty on every
    candidate's fitness. A move that grows the system (fork, fork_swap) or
    makes it more heterogeneous must buy a PPA improvement larger than its
    penalty to win a batch or pass the accept test; moves that simplify
    (join) are subsidised symmetrically. This is what lands the paper's
    NoC-simplification result: under equal budgets the dev_cost policy
    converges to designs with fewer and more uniform components —
    especially NoCs, whose forks are pure congestion relief and are easiest
    to over-provision — measured by ``Design.complexity_metrics`` and
    reported per policy by ``Campaign.aggregate``.

    The penalty is EXACT, not a proxy: the candidate's recorded move is
    replayed onto the base (checkpoint → metrics → rollback, O(blocks))
    and the complexity deltas are scored as
    ``lam_component · Δcomponents + lam_variation · Δvariation``, with NoC
    components double-weighted (``lam_noc`` rides on top of
    ``lam_component`` for them)."""

    name = "dev_cost"
    lam_component = 0.02  # Eq.-7 distance units per added block
    lam_noc = 0.04  # additional weight per added NoC (the §5.3 focus)
    lam_variation = 0.10  # per unit of mean heterogeneity-CV increase

    def move_penalty(self, design: Design, cand) -> float:
        if cand.spec is None:
            return 0.0
        delta = cand.delta
        if delta is not None and not (
            delta.added or delta.removed or delta.touched
        ):
            # pure mapping moves (migrate, join-less remaps) change no block
            # set and no knob — complexity is invariant, skip the replay.
            # Migrates dominate long anneals, so the exact-penalty path below
            # only runs for the few allocation/customization candidates.
            return 0.0
        before = design.complexity_metrics()
        with cand.materialized(self.tdg) as mutated:
            after = mutated.complexity_metrics()
        return (
            self.lam_component * (after["components"] - before["components"])
            + self.lam_noc * (after["noc_components"] - before["noc_components"])
            + self.lam_variation * (after["variation"] - before["variation"])
        )


POLICIES = {
    "naive_sa": NaiveSA,
    "device_sa": DeviceSA,
    "task": TaskAware,
    "task_block": TaskBlockAware,
    "bottleneck": BottleneckRelaxation,
    "locality": LocalityExploitation,
    "farsi": FarsiPolicy,
    "dev_cost": DevCostPolicy,
}

# awareness ladder → policy (ExplorerConfig.policy="" keeps the historical
# awareness knob working; both tests and benches sweep it)
AWARENESS_POLICY = {
    "sa": "naive_sa",
    "task": "task",
    "task_block": "task_block",
    "farsi": "farsi",
}


def make_policy(name: str) -> HeuristicPolicy:
    """Instantiate a registered policy by name (`ExplorerConfig.policy`)."""
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(
            f"unknown policy {name!r}; known: {sorted(POLICIES)}"
        ) from None
    return cls()
