"""shard_map MoE dispatch: per-shard local capacity + expert all-to-all.

Why this exists: the pjit/dense dispatch (models/moe.py) scatters tokens into
the grouped buffer with data-dependent indices over a *global* flat axis —
XLA's SPMD partitioner cannot shard that scatter/gather and falls back to
all-gathering the (T·k, d_model) dispatch tensors (measured: 34 GB/device at
jamba's 1M-token prefill). Here every device dispatches only its own tokens
(local cumsum → local scatter into an (E, C_local) slice), then one
``all_to_all`` over the model axis exchanges expert ownership for token
ownership — the textbook EP exchange, and the only collective in the path.

Semantics difference vs the dense path: capacity is **per data×SP shard**
(C_local = ceil(T_local·k·cf/E)) rather than global — per-shard capacity is
what large MoE systems actually deploy (it bounds the a2a payload
deterministically). With axis sizes of 1 the two paths agree exactly (tested).

Applicability: EP only (n_experts divisible by the model axis); grok-1
(8 experts) keeps the dense expert-TP path.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig


def _shard_map(f, *, mesh, in_specs, out_specs):
    """Version-tolerant shard_map: the API moved from
    ``jax.experimental.shard_map`` to top-level ``jax.shard_map``, and its
    replication-check kwarg was renamed ``check_rep`` → ``check_vma`` along
    the way. We disable the check under whichever spelling exists."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    for check_kw in ("check_vma", "check_rep"):
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{check_kw: False})
        except TypeError:
            continue
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _local_dispatch(xf, probs, k: int, c_loc: int, e: int):
    """Local capacity dispatch over this shard's tokens.
    xf: (T_loc, D); probs: (T_loc, E) → (grouped (E, C_loc, D), slot, keep, gates)."""
    t, d = xf.shape
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    flat_e = expert_idx.reshape(t * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = ((jnp.cumsum(onehot, axis=0) - 1) * onehot).sum(-1)
    keep = pos_in_e < c_loc
    slot = jnp.where(keep, flat_e * c_loc + pos_in_e, 0)
    x_rep = jnp.broadcast_to(xf[:, None, :], (t, k, d)).reshape(t * k, d)
    x_rep = x_rep * keep[:, None].astype(xf.dtype)
    grouped = jnp.zeros((e * c_loc, d), xf.dtype).at[slot].add(x_rep)
    return grouped.reshape(e, c_loc, d), slot, keep, gate_vals


def moe_apply_shard_map(
    params: dict,
    x: jax.Array,  # (B, S, D) sharded (batch→data axes, seq→model [SP])
    cfg: ModelConfig,
    mesh,
    rules,
) -> Tuple[jax.Array, jax.Array]:
    e, k = cfg.n_experts, cfg.top_k
    model_n = mesh.shape["model"]
    assert e % model_n == 0, "shard_map MoE requires EP divisibility"
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    batch_axes = rules.get("batch") or data_axes
    seq_axes = rules.get("seq_res")
    sp = model_n if seq_axes else 1

    b, s, d = x.shape
    n_data = 1
    for a in batch_axes:
        n_data *= mesh.shape[a]
    if b % n_data != 0 or s % sp != 0:
        batch_axes, n_data = (), 1  # fall back to replicated-batch blocks
    t_loc = (b // n_data) * (s // sp)
    c_loc = max(k, int(math.ceil(t_loc * k * cfg.capacity_factor / e)))

    x_spec = P(batch_axes if batch_axes else None, "model" if seq_axes else None, None)
    w_in_spec = P("model", None, None)  # (E, D, F) EP
    w_out_spec = P("model", None, None)  # (E, F, D)

    def block(xb, router, wi_g, wi_u, wo):
        bl, sl, _ = xb.shape
        xf = xb.reshape(bl * sl, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
        probs = jax.nn.softmax(logits, axis=-1)
        grouped, slot, keep, gates = _local_dispatch(xf, probs, k, c_loc, e)

        # aux loss (Switch): local fractions, averaged over every shard
        me = probs.mean(axis=0)
        ce_cnt = jnp.zeros((e,), jnp.float32).at[slot // c_loc].add(
            keep.astype(jnp.float32)
        ) / (bl * sl * k)
        aux = e * jnp.sum(me * ce_cnt)
        axes = tuple(batch_axes) + (("model",) if seq_axes else ())
        if axes:
            aux = jax.lax.pmean(aux, axes)

        # EP exchange: expert ownership ↔ token ownership over 'model'
        grouped = jax.lax.all_to_all(
            grouped, "model", split_axis=0, concat_axis=1, tiled=True
        )  # (E_loc, C_loc·model_n, D)

        gate = jnp.einsum("ecd,edf->ecf", grouped, wi_g)
        up = jnp.einsum("ecd,edf->ecf", grouped, wi_u)
        if cfg.mlp_kind == "geglu":
            act = jax.nn.gelu(gate.astype(jnp.float32), approximate=True).astype(xb.dtype)
        else:
            act = jax.nn.silu(gate.astype(jnp.float32)).astype(xb.dtype)
        h = jnp.einsum("ecf,efd->ecd", act * up, wo)  # (E_loc, C_loc·model_n, D)

        h = jax.lax.all_to_all(
            h, "model", split_axis=1, concat_axis=0, tiled=True
        )  # (E, C_loc, D)

        y_rep = h.reshape(e * c_loc, d)[slot] * (
            gates.reshape(-1, 1) * keep[:, None]
        ).astype(h.dtype)
        y = y_rep.reshape(bl * sl, k, d).sum(axis=1)
        return y.reshape(bl, sl, d), aux

    y, aux = _shard_map(
        block,
        mesh=mesh,
        in_specs=(x_spec, P(None, None), w_in_spec, w_in_spec, w_out_spec),
        out_specs=(x_spec, P()),
    )(x, params["router"], params["wi_gate"], params["wi_up"], params["wo"])
    return y, aux
