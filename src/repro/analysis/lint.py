"""JAX lint: AST rules flagging host-sync and retracing hazards in traced
scopes.

The repo's hot paths are jitted scan/vmap bodies; a stray ``float()`` or
``np.asarray`` inside one silently drags the whole value back to host every
dispatch (or fails only at trace time on an obscure input), and a Python
``if`` on a tracer raises a ConcretizationTypeError three layers away from
the actual mistake. This pass finds those *lexically*, before anything
runs.

Scope detection — a function is considered **traced** when any of:

  * it is decorated with ``jax.jit`` / ``functools.partial(jax.jit, …)`` /
    ``jax.checkpoint`` / ``jax.remat``;
  * it (or a ``partial(fn, …)`` / plain alias of it) is passed to a JAX
    tracing entry point: ``jit``, ``vmap``, ``pmap``, ``grad``,
    ``value_and_grad``, ``lax.scan``, ``fori_loop``, ``while_loop``,
    ``cond``, ``switch``, ``associative_scan``, ``lax.map``,
    ``pallas_call``, ``shard_map``, ``eval_shape``, ``make_jaxpr``;
  * it is lexically nested inside a traced function (scan bodies, helper
    closures);
  * it is referenced by name from inside a traced function in the same
    module (one-module call-graph closure — catches ``simulate_one``
    called by the vmap lambda in ``simulate_batch``);
  * its ``def`` line carries the explicit marker comment
    ``# repro: traced`` — for functions whose tracing caller lives in a
    *different* module (``ops.phase_sim`` is jitted by the backend), where
    no static analysis of this file can see the jit.

Rules (ids are what ``# repro: noqa[<rule>]`` must name):

  ``host-sync``        ``float()``/``int()``/``bool()`` on a non-literal,
                       ``.item()``, ``np.asarray``/``np.array``,
                       ``jax.device_get``, ``.block_until_ready()`` inside
                       a traced scope — each forces a device→host transfer
                       per call (or a trace error).
  ``tracer-branch``    Python ``if``/``while``/``assert``/ternary whose
                       test involves a ``jnp.``/``lax.`` expression or an
                       ``.any()``/``.all()`` reduction — control flow on a
                       tracer concretizes; use ``jnp.where``/``lax.cond``.
  ``f64-promote``      ``math.*`` calls, ``np.float64``, or a ``float64``
                       dtype inside a traced scope — ``math`` concretizes
                       the tracer and returns a Python float; np.float64
                       operands promote f32 pipelines to f64.
  ``mutable-closure``  mutating a free (closed-over) variable inside a
                       traced scope — ``xs.append(…)``, ``cache[k] = v``,
                       ``x += …`` on names the function does not bind, and
                       ``global``/``nonlocal`` — the mutation runs once at
                       trace time, not per call, and is invisible to the
                       jit cache key.
  ``noqa-reason``      a ``# repro: noqa[…]`` with no justification text —
                       suppressions must say why.

Suppression: append ``# repro: noqa[rule]: reason`` to the offending line.
Existing debt is frozen (not hidden) in the checked-in baseline
(``src/repro/analysis/baseline.json``, keyed on file+rule+line-text so it
survives line drift); ``python -m repro.analysis --update-baseline``
regenerates it, ``--strict`` fails on anything new.
"""
from __future__ import annotations

import ast
import json
import os
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .findings import Finding

__all__ = [
    "RULES",
    "lint_source",
    "lint_paths",
    "run_lint",
    "load_baseline",
    "apply_baseline",
    "write_baseline",
    "default_baseline_path",
    "default_lint_root",
]

RULES = {
    "host-sync": "device→host transfer inside a traced scope",
    "tracer-branch": "Python control flow on a traced boolean",
    "f64-promote": "f64-promoting host math inside a traced scope",
    "mutable-closure": "closed-over mutable state mutated in a traced scope",
    "noqa-reason": "suppression without a justification string",
}

# names that take a function and trace it (matched on the LAST attribute
# segment, so jax.jit / jax.lax.scan / pl.pallas_call all hit)
_TRACE_ENTRY_NAMES = {
    "jit", "vmap", "pmap", "grad", "value_and_grad", "scan", "fori_loop",
    "while_loop", "cond", "switch", "associative_scan", "pallas_call",
    "shard_map", "eval_shape", "make_jaxpr", "checkpoint", "remat",
}
# lax.map is tracing too, but bare "map" would catch the builtin — require
# an attribute access for it
_TRACE_ATTR_ONLY = {"map"}

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa\[([A-Za-z0-9_,\- ]+)\]\s*:?\s*(.*)$"
)
_TRACED_MARK_RE = re.compile(r"#\s*repro:\s*traced\b")

_FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _last_name(node: ast.expr) -> Optional[str]:
    """Trailing identifier of a Name/Attribute chain (``jax.lax.scan`` →
    ``scan``), or None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _root_name(node: ast.expr) -> Optional[str]:
    """Leading identifier of an Attribute chain (``np.asarray`` → ``np``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


class _Scopes:
    """Lexical function-scope index of one module: parents, name tables,
    and simple aliases (``f = g`` / ``f = partial(g, …)``)."""

    def __init__(self, tree: ast.Module) -> None:
        self.parent: Dict[ast.AST, Optional[ast.AST]] = {tree: None}
        self.defs: List[ast.AST] = []
        # (enclosing scope node, name) -> def node
        self.by_name: Dict[Tuple[ast.AST, str], ast.AST] = {}
        self.tree = tree
        stack: List[ast.AST] = [tree]

        def walk(node: ast.AST) -> None:
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
                if isinstance(child, _FuncNode):
                    self.defs.append(child)
                    if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        self.by_name[(stack[-1], child.name)] = child
                    stack.append(child)
                    walk(child)
                    stack.pop()
                else:
                    if isinstance(child, ast.Assign) and len(child.targets) == 1:
                        t = child.targets[0]
                        v = child.value
                        alias = None
                        if isinstance(v, ast.Name):
                            alias = v.id
                        elif (
                            isinstance(v, ast.Call)
                            and _last_name(v.func) == "partial"
                            and v.args
                            and isinstance(v.args[0], ast.Name)
                        ):
                            alias = v.args[0].id
                        if alias is not None and isinstance(t, ast.Name):
                            self.by_name.setdefault((stack[-1], t.name
                                                     if hasattr(t, "name")
                                                     else t.id), None)
                            # map the alias target name onto the aliased def
                            # lazily: store the *name* and resolve later
                            self.by_name[(stack[-1], t.id)] = self.by_name.get(
                                (stack[-1], alias)
                            ) or self._resolve_from(stack[-1], alias)
                    walk(child)

        walk(tree)

    def scope_of(self, node: ast.AST) -> ast.AST:
        """Nearest enclosing function scope (or the module)."""
        cur = self.parent.get(node)
        while cur is not None and not isinstance(cur, _FuncNode):
            cur = self.parent.get(cur)
        return cur if cur is not None else self.tree

    def _resolve_from(self, scope: ast.AST, name: str) -> Optional[ast.AST]:
        cur: Optional[ast.AST] = scope
        while cur is not None:
            hit = self.by_name.get((cur, name))
            if hit is not None:
                return hit
            cur = self.parent.get(cur)
            while cur is not None and not isinstance(
                cur, _FuncNode + (ast.Module,)
            ):
                cur = self.parent.get(cur)
        return None

    def resolve(self, at: ast.AST, name: str) -> Optional[ast.AST]:
        """Find the def a Name load refers to, walking scopes outward."""
        return self._resolve_from(self.scope_of(at), name)


def _traced_defs(tree: ast.Module, scopes: _Scopes,
                 lines: List[str]) -> Set[ast.AST]:
    traced: Set[ast.AST] = set()

    # 1. decorator-marked + explicit `# repro: traced` marker
    for d in scopes.defs:
        if isinstance(d, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in d.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                name = _last_name(target)
                if name == "partial" and isinstance(dec, ast.Call) and dec.args:
                    name = _last_name(dec.args[0])
                if name in _TRACE_ENTRY_NAMES:
                    traced.add(d)
            ln = d.lineno - 1
            if 0 <= ln < len(lines) and _TRACED_MARK_RE.search(lines[ln]):
                traced.add(d)

    # 2. functions handed to tracing entry points
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _last_name(node.func)
        is_entry = name in _TRACE_ENTRY_NAMES or (
            name in _TRACE_ATTR_ONLY and isinstance(node.func, ast.Attribute)
        )
        if not is_entry:
            continue
        for arg in node.args:
            if isinstance(arg, ast.Lambda):
                traced.add(arg)
            elif isinstance(arg, ast.Name):
                hit = scopes.resolve(node, arg.id)
                if hit is not None:
                    traced.add(hit)
            elif (
                isinstance(arg, ast.Call)
                and _last_name(arg.func) == "partial"
                and arg.args
                and isinstance(arg.args[0], ast.Name)
            ):
                hit = scopes.resolve(node, arg.args[0].id)
                if hit is not None:
                    traced.add(hit)

    # 3. closure: lexical nesting + same-module references from traced code
    changed = True
    while changed:
        changed = False
        for d in scopes.defs:
            if d in traced:
                continue
            cur = scopes.parent.get(d)
            while cur is not None:
                if cur in traced:
                    traced.add(d)
                    changed = True
                    break
                cur = scopes.parent.get(cur)
        for d in list(traced):
            for node in ast.walk(d):
                if isinstance(node, ast.Name) and isinstance(
                    node.ctx, ast.Load
                ):
                    hit = scopes.resolve(node, node.id)
                    if hit is not None and hit not in traced:
                        # don't re-enter through the def currently walked
                        traced.add(hit)
                        changed = True
    return traced


def _local_bindings(fn: ast.AST) -> Set[str]:
    """Names a function binds itself: params + any Store/target inside it
    (excluding nested function bodies — those have their own scopes)."""
    names: Set[str] = set()
    args = fn.args
    for a in (
        list(args.posonlyargs) + list(args.args) + list(args.kwonlyargs)
    ):
        names.add(a.arg)
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)

    def walk(node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, _FuncNode):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    names.add(child.name)
                continue
            if isinstance(child, ast.Name) and isinstance(
                child.ctx, (ast.Store, ast.Del)
            ):
                names.add(child.id)
            walk(child)

    if isinstance(fn, ast.Lambda):
        return names
    for stmt in fn.body:
        walk(stmt)
        if isinstance(stmt, ast.Name) and isinstance(stmt.ctx, ast.Store):
            names.add(stmt.id)
    return names


_MUTATOR_METHODS = {
    "append", "extend", "insert", "update", "add", "setdefault", "pop",
    "popitem", "remove", "discard", "clear", "appendleft", "extendleft",
}
_NP_HOST_FNS = {"asarray", "array", "copy", "save", "savez"}
_NP_ROOTS = {"np", "numpy", "onp"}


def _contains_tracerish(node: ast.expr) -> bool:
    """Does an expression subtree smell like a traced array? Narrow on
    purpose: `jnp.`/`lax.`-rooted CALLS and `.any()`/`.all()` reductions.
    Static-config branches (`if menu == "farsi"`, `if n_noc == 1`) and
    dtype comparisons against `jnp.float32` must stay legal inside traced
    functions — a bare jnp attribute is a constant, only invoking one
    produces an array."""
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Call):
            continue
        if _root_name(sub.func) in ("jnp", "lax"):
            return True
        if (
            isinstance(sub.func, ast.Attribute)
            and sub.func.attr in ("any", "all")
            and not sub.args
        ):
            return True
    return False


def _is_f64_dtype(node: ast.expr) -> bool:
    if isinstance(node, ast.Constant) and node.value in (
        "float64", "double"
    ):
        return True
    return _last_name(node) in ("float64", "double")


def _lint_traced_fn(
    fn: ast.AST, path: str, lines: List[str], out: List[Finding]
) -> None:
    free_guard = _local_bindings(fn)

    def emit(rule: str, node: ast.AST, msg: str) -> None:
        line = getattr(node, "lineno", 0)
        src = lines[line - 1].strip() if 0 < line <= len(lines) else ""
        out.append(Finding(
            pass_name="lint", rule=rule, message=msg, path=path,
            line=line, source=src,
        ))

    body = fn.body if not isinstance(fn, ast.Lambda) else [fn.body]
    for stmt in body if isinstance(body, list) else [body]:
        for node in ast.walk(stmt):
            if isinstance(node, _FuncNode):
                # nested defs are linted as their own traced scopes
                continue
            if isinstance(node, ast.Call):
                fname = _last_name(node.func)
                root = (
                    _root_name(node.func)
                    if isinstance(node.func, ast.Attribute) else None
                )
                if (
                    isinstance(node.func, ast.Name)
                    and node.func.id in ("float", "int", "bool")
                    and node.args
                    and not isinstance(node.args[0], ast.Constant)
                ):
                    emit("host-sync", node,
                         f"`{node.func.id}()` on a traced value forces a "
                         "device→host sync (use jnp casts / keep it "
                         "device-side)")
                elif fname == "item" and not node.args and isinstance(
                    node.func, ast.Attribute
                ):
                    emit("host-sync", node,
                         "`.item()` pulls the value to host inside a "
                         "traced scope")
                elif fname == "block_until_ready" and isinstance(
                    node.func, ast.Attribute
                ):
                    emit("host-sync", node,
                         "`.block_until_ready()` is a host sync — it has "
                         "no place inside a traced scope")
                elif root in _NP_ROOTS and fname in _NP_HOST_FNS:
                    emit("host-sync", node,
                         f"`{root}.{fname}` materializes the tracer on "
                         "host — use jnp inside traced code")
                elif root == "jax" and fname == "device_get":
                    emit("host-sync", node,
                         "`jax.device_get` inside a traced scope is a "
                         "per-call host transfer")
                elif root == "math":
                    emit("f64-promote", node,
                         f"`math.{fname}` concretizes the tracer and "
                         "returns a Python float (f64) — use jnp")
                elif root in _NP_ROOTS and fname == "float64":
                    emit("f64-promote", node,
                         "np.float64 operands promote the f32 pipeline "
                         "to f64")
                elif fname in _MUTATOR_METHODS and isinstance(
                    node.func, ast.Attribute
                ) and isinstance(node.func.value, ast.Name):
                    target = node.func.value.id
                    if target not in free_guard:
                        emit("mutable-closure", node,
                             f"`{target}.{fname}(…)` mutates closed-over "
                             "state at trace time — it will NOT re-run "
                             "per call and is invisible to the jit cache "
                             "key")
                for kw in node.keywords:
                    if kw.arg == "dtype" and _is_f64_dtype(kw.value):
                        emit("f64-promote", node,
                             "explicit float64 dtype inside a traced "
                             "scope")
            elif isinstance(node, (ast.If, ast.While, ast.IfExp, ast.Assert)):
                test = node.test
                if _contains_tracerish(test):
                    emit("tracer-branch", node,
                         "Python control flow on a traced boolean "
                         "concretizes — use jnp.where / lax.cond / "
                         "lax.select")
            elif isinstance(node, (ast.Global, ast.Nonlocal)):
                emit("mutable-closure", node,
                     f"`{'global' if isinstance(node, ast.Global) else 'nonlocal'}`"
                     " write-through inside a traced scope runs at trace "
                     "time only")
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (
                    node.targets if isinstance(node, ast.Assign)
                    else [node.target]
                )
                for t in targets:
                    if isinstance(t, ast.Subscript) and isinstance(
                        t.value, ast.Name
                    ):
                        target = t.value.id
                        # `o_ref[...] = acc` on a closed-over Pallas Ref is
                        # THE kernel output idiom, not trace-time leakage —
                        # Refs are mutable on device by design
                        if target not in free_guard and not target.endswith(
                            "_ref"
                        ):
                            emit("mutable-closure", node,
                                 f"subscript store into closed-over "
                                 f"`{target}` runs once at trace time, "
                                 "not per call")


def _noqa_filter(
    findings: List[Finding], lines: List[str], path: str
) -> List[Finding]:
    """Apply per-line `# repro: noqa[rule]` suppressions; a suppression
    with no reason text surfaces as its own ``noqa-reason`` finding."""
    out: List[Finding] = []
    reason_flagged: Set[int] = set()
    for f in findings:
        line_txt = lines[f.line - 1] if 0 < f.line <= len(lines) else ""
        m = _NOQA_RE.search(line_txt)
        if m:
            named = {r.strip() for r in m.group(1).split(",")}
            if f.rule in named or "*" in named:
                f = Finding(**{**f.__dict__, "suppressed": True})
                if not m.group(2).strip() and f.line not in reason_flagged:
                    reason_flagged.add(f.line)
                    out.append(Finding(
                        pass_name="lint", rule="noqa-reason",
                        message="suppression has no justification — add "
                        "`# repro: noqa[rule]: <why>`",
                        path=path, line=f.line, source=line_txt.strip(),
                    ))
        out.append(f)
    return out


def lint_source(src: str, path: str = "<memory>") -> List[Finding]:
    """Lint one module's source text. The unit tests drive this directly
    with fixture snippets; :func:`lint_paths` feeds it files."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(
            pass_name="lint", rule="host-sync",
            message=f"unparseable module: {e}", path=path,
            line=e.lineno or 0,
        )]
    lines = src.splitlines()
    scopes = _Scopes(tree)
    traced = _traced_defs(tree, scopes, lines)
    findings: List[Finding] = []
    for fn in traced:
        _lint_traced_fn(fn, path, lines, findings)
    # a (line, rule) can be reached through several traced parents after
    # the call-graph closure — report it once
    seen: Set[Tuple[int, str, str]] = set()
    deduped = []
    for f in sorted(findings, key=lambda f: (f.line, f.rule)):
        k = (f.line, f.rule, f.message)
        if k in seen:
            continue
        seen.add(k)
        deduped.append(f)
    return _noqa_filter(deduped, lines, path)


def default_lint_root() -> str:
    """``src/repro`` as shipped: the parent of this package."""
    return os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def default_baseline_path() -> str:
    return os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "baseline.json")


def _rel(path: str, root: str) -> str:
    # stable repo-relative keys: src/repro/… regardless of install layout
    rp = os.path.relpath(path, os.path.dirname(os.path.dirname(root)))
    return rp.replace(os.sep, "/")


def lint_paths(paths: Iterable[str], root: Optional[str] = None) -> List[Finding]:
    root = root or default_lint_root()
    out: List[Finding] = []
    for p in paths:
        with open(p, "r", encoding="utf-8") as fh:
            src = fh.read()
        out.extend(lint_source(src, path=_rel(p, root)))
    return out


def run_lint(root: Optional[str] = None) -> List[Finding]:
    """Lint every ``.py`` under ``src/repro/`` (excluding this package —
    the analyzer's own fixtures would trip the rules)."""
    root = root or default_lint_root()
    files: List[str] = []
    skip_dir = os.path.join(root, "analysis")
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        if os.path.abspath(dirpath).startswith(os.path.abspath(skip_dir)):
            continue
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                files.append(os.path.join(dirpath, fn))
    return lint_paths(sorted(files), root=root)


# ---------------------------------------------------------------------------
# baseline: freeze existing debt without hiding it
# ---------------------------------------------------------------------------
def load_baseline(path: Optional[str] = None) -> Dict[str, int]:
    path = path or default_baseline_path()
    if not os.path.exists(path):
        return {}
    with open(path, "r", encoding="utf-8") as fh:
        data = json.load(fh)
    return {str(k): int(v) for k, v in data.get("findings", {}).items()}


def apply_baseline(
    findings: List[Finding], baseline: Dict[str, int]
) -> List[Finding]:
    """Mark up to ``baseline[key]`` occurrences of each key as baselined
    (never suppressed ones — those are already accounted for)."""
    budget = dict(baseline)
    out: List[Finding] = []
    for f in findings:
        if not f.suppressed and budget.get(f.key(), 0) > 0:
            budget[f.key()] -= 1
            f = Finding(**{**f.__dict__, "baselined": True})
        out.append(f)
    return out


def write_baseline(
    findings: List[Finding], path: Optional[str] = None
) -> str:
    path = path or default_baseline_path()
    counts: Dict[str, int] = {}
    for f in findings:
        if f.suppressed or f.rule == "noqa-reason":
            continue
        counts[f.key()] = counts.get(f.key(), 0) + 1
    with open(path, "w", encoding="utf-8") as fh:
        json.dump(
            {"comment": "frozen lint debt — python -m repro.analysis "
             "--update-baseline regenerates; tier-1 asserts this stays "
             "EMPTY for src/repro/core/",
             "findings": dict(sorted(counts.items()))},
            fh, indent=1, sort_keys=False,
        )
        fh.write("\n")
    return path
