"""Paper Fig. 10: co-design deployment rates per vector (10b) and their
convergence contribution (10c); plus the co-design ON/OFF ablation (§5.3:
'embedding the same co-design capabilities in regular SA does not necessarily
translate to design improvements')."""
from __future__ import annotations

import statistics
from typing import List

from repro.core import Explorer, ExplorerConfig, HardwareDatabase, ar_complex, calibrated_budget
from repro.core.codesign import VECTORS

from .common import Row

SEEDS = (1, 2, 3)


def run() -> List[Row]:
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    rows: List[Row] = []

    summaries = []
    for seed in SEEDS:
        res = Explorer(g, db, bud, ExplorerConfig(max_iterations=500, seed=seed)).run()
        summaries.append(res.ledger.summary())
    for v in VECTORS:
        sw = statistics.mean(s[v]["switch_rate"] for s in summaries)
        cc = statistics.mean(s[v]["convergence_contribution"] for s in summaries)
        rows.append((f"fig10.{v}", 0.0, f"switch_rate={sw:.2f} convergence_contrib={cc*100:.1f}%"))

    # ON/OFF ablation at fixed iteration budget
    for label, codesign, awareness in (
        ("farsi_codesign_on", True, "farsi"),
        ("farsi_codesign_off", False, "farsi"),
        ("sa_codesign_on", True, "sa"),
    ):
        iters, dists = [], []
        for seed in SEEDS:
            res = Explorer(
                g, db, bud,
                ExplorerConfig(awareness=awareness, codesign=codesign, max_iterations=400, seed=seed),
            ).run()
            iters.append(res.iterations if res.converged else 400)
            dists.append(res.best_distance.city_block())
        rows.append(
            (
                f"fig10c.{label}",
                0.0,
                f"iters_avg={statistics.mean(iters):.0f} dist_avg={statistics.mean(dists):.3f}",
            )
        )
    return rows
