"""Optimization moves (paper §3.3, Fig. 6, Table 3).

Three primitives plus a composite, all *incremental* (one knob, one step, one
task at a time — the development-cost policy) and *symmetric* (every move has
an inverse, enabling backtracking):

  swap      — customization: step one knob one rung, or GPP↔Acc conversion
  fork      — allocation: duplicate a block, migrate some load over
  join      — allocation⁻¹: merge a block into a sibling, delete it
  migrate   — mapping: move one task (or its buffer) to another block
  fork_swap — fork followed by swap ("introduced to accelerate navigation")

Every function mutates ``design`` in place (the explorer clones first) and
returns True on success / False when the move is inapplicable (ladder end
stop, last block of a kind, ...). Failed moves cost nothing and let the
explorer fall through its precedence list.
"""
from __future__ import annotations

import copy
import dataclasses
import random
from typing import Dict, List, Optional

from .blocks import Block, BlockKind, make_noc
from .design import Design
from .tdg import TaskGraph

MOVE_KINDS = ("swap", "fork", "join", "migrate", "fork_swap")


@dataclasses.dataclass(frozen=True)
class MoveSpec:
    """The 5-tuple a move application needs — a candidate neighbour is
    (base design, spec), replayable deterministically via :func:`apply_move`
    (moves never consume the RNG), so the full ``Design`` object is only
    materialized for the candidate the explorer accepts."""

    move: str
    block: Optional[str]
    task: Optional[str]
    direction: int
    bottleneck: str
    objective: str


@dataclasses.dataclass
class MoveDelta:
    """Encoding delta emitted by a move: exactly what changed, in terms the
    flat-array design encoding understands (``phase_sim_jax.apply_delta``
    turns one into an :class:`~repro.core.phase_sim_jax.EncodedDesign`
    without re-encoding the whole design).

    ``touched`` holds post-move knob *snapshots* (shallow copies) because the
    design itself is rolled back after the trial; ``added`` holds the new
    Block objects themselves — rollback detaches them from the design, after
    which nothing mutates them. ``attached`` records NoC-attachment edits
    (block → NoC name) for both newly added PE/MEM blocks and blocks a NoC
    fork/join re-homed; ``noc_after`` records where an added NoC was inserted
    in the chain (the predecessor's name). Together with ``removed`` they make
    topology moves fully replayable against the flat encoding — NoC fork/join
    emit ordinary deltas and ride the vectorized path. ``topology`` remains
    as the escape hatch for edits the encoding cannot host (no built-in move
    sets it anymore; a True value forces the scalar Python fallback)."""

    task_pe: Dict[str, str] = dataclasses.field(default_factory=dict)
    task_mem: Dict[str, str] = dataclasses.field(default_factory=dict)
    touched: Dict[str, Block] = dataclasses.field(default_factory=dict)
    added: List[Block] = dataclasses.field(default_factory=list)
    removed: List[str] = dataclasses.field(default_factory=list)
    attached: Dict[str, str] = dataclasses.field(default_factory=dict)
    noc_after: Optional[str] = None
    topology: bool = False

    def touch(self, block: Block) -> None:
        self.touched[block.name] = copy.copy(block)
# Development-cost precedence (paper Algorithm 1, step II):
#   join > migrate > fork > swap > fork_swap
MOVE_PRECEDENCE = {"join": 5, "migrate": 4, "fork": 3, "swap": 2, "fork_swap": 1}
# software-to-hardware mapping & allocation are "high-level" optimizations,
# knob tuning is "low-level" (paper §5.3 co-design vectors)
HIGH_LEVEL = {"migrate", "fork", "join", "fork_swap"}


# ---------------------------------------------------------------------------
# swap
# ---------------------------------------------------------------------------
def _knob_candidates(block: Block, task, direction: int) -> List[str]:
    """Which knobs a swap may step on this block, in preference order."""
    if block.kind == BlockKind.PE:
        if block.subtype == "acc":
            # prefer unrolling while the task still has LLP headroom
            if task is not None and direction > 0 and block.unroll < task.llp:
                return ["unroll", "freq_mhz"]
            return ["freq_mhz", "unroll"]
        return ["freq_mhz"]
    if block.kind == BlockKind.NOC:
        return ["width_bytes", "freq_mhz", "n_links"]
    return ["width_bytes", "freq_mhz"]  # MEM


def apply_swap(
    design: Design,
    tdg: TaskGraph,
    block_name: str,
    direction: int,
    task_name: Optional[str] = None,
    rng: Optional[random.Random] = None,
    delta: Optional[MoveDelta] = None,
) -> bool:
    """Step one knob one rung (incremental customization). ``direction=+1``
    buys performance, ``-1`` returns it (power/area). GPP→Acc hardening
    happens when the PE hosts exactly the target task (otherwise the explorer
    reaches hardening via fork_swap); Acc→GPP is the symmetric inverse.
    Mem swap also flips DRAM↔SRAM: SRAM saves energy/byte, DRAM saves area."""
    rng = rng or random.Random(0)
    block = design.blocks[block_name]
    task = tdg.tasks.get(task_name) if task_name else None

    def done() -> bool:
        if delta is not None:
            delta.touch(block)
        return True

    # subtype conversions first (the "real" customization)
    if block.kind == BlockKind.PE and direction > 0 and block.subtype == "gpp":
        hosted = design.tasks_on_pe(block_name)
        if task_name and hosted == [task_name]:
            block.subtype = "acc"
            block.hardened_for = task_name
            return done()
    if block.kind == BlockKind.PE and direction < 0 and block.subtype == "acc":
        # soften: cheaper to develop, slower (symmetric inverse of hardening)
        if block.unroll > 1:
            return block.step_knob("unroll", -1) and done()
        block.subtype = "gpp"
        block.hardened_for = None
        return done()
    if block.kind == BlockKind.MEM:
        # energy pressure → SRAM; area pressure → DRAM (§6.1 memory study)
        if direction < 0 and block.subtype == "dram":
            block.subtype = "sram"
            return done()

    knobs = _knob_candidates(block, task, direction)
    for knob in knobs:
        if block.step_knob(knob, direction):
            return done()
    if block.kind == BlockKind.MEM and direction > 0 and block.subtype == "sram":
        block.subtype = "dram"  # ladder exhausted: trade energy for capacity
        return done()
    return False


# ---------------------------------------------------------------------------
# fork / join
# ---------------------------------------------------------------------------
def apply_fork(
    design: Design,
    tdg: TaskGraph,
    block_name: str,
    task_name: Optional[str] = None,
    rng: Optional[random.Random] = None,
    delta: Optional[MoveDelta] = None,
) -> bool:
    """Duplicate ``block`` and migrate load over: the target task (if given)
    or every other task/buffer. For NoCs the new router is inserted next in
    the chain and takes half the attached PEs/Mems (congestion relief)."""
    rng = rng or random.Random(0)
    block = design.blocks[block_name]

    if block.kind == BlockKind.NOC:
        attached = design.attached(block_name)
        if len(attached) < 2:
            return False
        new = make_noc(block.freq_mhz, block.width_bytes, block.n_links)
        design.add_block(new, after_noc=block_name)
        for b in attached[1::2]:
            design.attached_noc[b] = new.name
        if delta is not None:
            delta.added.append(new)
            delta.noc_after = block_name  # chain insertion point
            for b in attached[1::2]:
                delta.attached[b] = new.name
        return True

    hosted = (
        design.tasks_on_pe(block_name)
        if block.kind == BlockKind.PE
        else design.buffers_on_mem(block_name)
    )
    if len(hosted) < 2:
        return False  # duplication must *split* load, never orphan the source
    if task_name == hosted[0]:
        # the anchor task must stay: an explicit request to migrate it is
        # inapplicable — refuse rather than silently moving a different task
        return False
    movers = [task_name] if (task_name in hosted) else hosted[1::2]
    clone = block.clone()
    if clone.subtype == "acc" and task_name and task_name != block.hardened_for:
        clone.hardened_for = task_name  # duplicated IP hardened for the mover
    design.add_block(clone, attach_to=design.attached_noc[block_name])
    target_map = design.task_pe if block.kind == BlockKind.PE else design.task_mem
    for t in movers:
        target_map[t] = clone.name
    if delta is not None:
        delta.added.append(clone)
        delta.attached[clone.name] = design.attached_noc[block_name]
        moved = delta.task_pe if block.kind == BlockKind.PE else delta.task_mem
        for t in movers:
            moved[t] = clone.name
    return True


def apply_join(
    design: Design,
    tdg: TaskGraph,
    block_name: str,
    rng: Optional[random.Random] = None,
    delta: Optional[MoveDelta] = None,
) -> bool:
    """Merge ``block`` into a sibling and delete it (the inverse of fork;
    the highest-precedence move because it *removes* hardware)."""
    rng = rng or random.Random(0)
    block = design.blocks.get(block_name)
    if block is None:
        return False

    if block.kind == BlockKind.NOC:
        if len(design.noc_chain) < 2:
            return False
        idx = design.noc_chain.index(block_name)
        target = design.noc_chain[idx - 1] if idx > 0 else design.noc_chain[1]
        for b in design.attached(block_name):
            design.attached_noc[b] = target
            if delta is not None:
                delta.attached[b] = target
        design.remove_block(block_name)
        if delta is not None:
            delta.removed.append(block_name)
        return True

    siblings = [
        n
        for n, b in design.blocks.items()
        if n != block_name and b.kind == block.kind
    ]
    if not siblings:
        return False
    # prefer a sibling on the same NoC (locality), then a GPP for PE joins
    same_noc = [s for s in siblings if design.attached_noc[s] == design.attached_noc[block_name]]
    pool = same_noc or siblings
    if block.kind == BlockKind.PE:
        gpps = [s for s in pool if design.blocks[s].subtype == "gpp"]
        target = (gpps or pool)[0]
        for t in design.tasks_on_pe(block_name):
            design.task_pe[t] = target
            if delta is not None:
                delta.task_pe[t] = target
    else:
        target = pool[0]
        for t in design.buffers_on_mem(block_name):
            design.task_mem[t] = target
            if delta is not None:
                delta.task_mem[t] = target
    design.remove_block(block_name)
    if delta is not None:
        delta.removed.append(block_name)
    return True


# ---------------------------------------------------------------------------
# migrate
# ---------------------------------------------------------------------------
def apply_migrate(
    design: Design,
    tdg: TaskGraph,
    task_name: str,
    bottleneck: str = "pe",
    rng: Optional[random.Random] = None,
    objective: str = "latency",
    delta: Optional[MoveDelta] = None,
) -> bool:
    """Move one task (compute-bound → new PE) or its buffer (comm-bound →
    new MEM) — mapping change. Destination is chosen with architectural
    reasoning: load balancing for latency (least-loaded candidate), spatial
    locality (same-NoC placement, fewer hops), consolidation for power/area
    (paper §3.3 'Using Architectural Reasoning for Move Selection')."""
    rng = rng or random.Random(0)

    if bottleneck in ("mem", "noc"):
        cur = design.task_mem[task_name]
        cands = [m for m in design.mems() if m != cur]
        if not cands:
            return False
        pe_noc = design.attached_noc[design.task_pe[task_name]]
        if objective == "latency":
            # locality: fewest hops to the task's PE, then least congested
            def key(m):
                i = design.noc_chain.index(design.attached_noc[m])
                j = design.noc_chain.index(pe_noc)
                return (abs(i - j), len(design.buffers_on_mem(m)))
        else:
            # consolidation: the busiest memory (lets joins follow)
            def key(m):
                return -len(design.buffers_on_mem(m))
        design.task_mem[task_name] = min(cands, key=key)
        if delta is not None:
            delta.task_mem[task_name] = design.task_mem[task_name]
        return True

    cur = design.task_pe[task_name]
    cands = [p for p in design.pes() if p != cur]
    # an accelerator hardened for another task would run this task at a=1;
    # still legal (paper migrates freely) but de-prioritized by the key below
    if not cands:
        return False
    mem_noc = design.attached_noc[design.task_mem[task_name]]

    def pe_key(p):
        b = design.blocks[p]
        hardened = b.subtype == "acc" and b.hardened_for == task_name
        i = design.noc_chain.index(design.attached_noc[p])
        j = design.noc_chain.index(mem_noc)
        if objective == "latency":
            return (not hardened, len(design.tasks_on_pe(p)), abs(i - j))
        return (-len(design.tasks_on_pe(p)), not hardened)

    design.task_pe[task_name] = min(cands, key=pe_key)
    if delta is not None:
        delta.task_pe[task_name] = design.task_pe[task_name]
    return True


# ---------------------------------------------------------------------------
# composite
# ---------------------------------------------------------------------------
def apply_fork_swap(
    design: Design,
    tdg: TaskGraph,
    block_name: str,
    task_name: Optional[str],
    direction: int,
    rng: Optional[random.Random] = None,
    delta: Optional[MoveDelta] = None,
) -> bool:
    """Fork then swap the forked block up — the paper's shortcut for
    'dedicate new hardware to this task and customize it'."""
    rng = rng or random.Random(0)
    before = set(design.blocks)
    if not apply_fork(design, tdg, block_name, task_name, rng, delta):
        return False
    new_block = next(iter(set(design.blocks) - before), None)
    if new_block is None:
        return False
    # the swap's touch snapshot is redundant for a just-added block (the
    # delta's `added` ref is the same live object) but harmless
    apply_swap(design, tdg, new_block, direction, task_name, rng, delta)
    return True


def apply_move(
    design: Design,
    tdg: TaskGraph,
    move: str,
    block_name: Optional[str],
    task_name: Optional[str],
    direction: int,
    bottleneck: str,
    objective: str,
    rng: random.Random,
    delta: Optional[MoveDelta] = None,
) -> bool:
    if move == "swap":
        return apply_swap(design, tdg, block_name, direction, task_name, rng, delta)
    if move == "fork":
        return apply_fork(design, tdg, block_name, task_name, rng, delta)
    if move == "join":
        return apply_join(design, tdg, block_name, rng, delta)
    if move == "migrate":
        return apply_migrate(design, tdg, task_name, bottleneck, rng, objective, delta)
    if move == "fork_swap":
        return apply_fork_swap(design, tdg, block_name, task_name, direction, rng, delta)
    raise KeyError(move)


def apply_spec(
    design: Design,
    tdg: TaskGraph,
    spec: MoveSpec,
    rng: Optional[random.Random] = None,
    delta: Optional[MoveDelta] = None,
) -> bool:
    """Replay a recorded move 5-tuple (moves are deterministic given the
    design state, so a spec applied to the same base reproduces the same
    neighbour bit-for-bit)."""
    return apply_move(
        design, tdg, spec.move, spec.block, spec.task, spec.direction,
        spec.bottleneck, spec.objective, rng or random.Random(0), delta,
    )


# ---------------------------------------------------------------------------
# array-packable deltas (device-resident exploration)
# ---------------------------------------------------------------------------
def mapping_delta(
    task_pe: Dict[str, str], task_mem: Dict[str, str]
) -> MoveDelta:
    """A :class:`MoveDelta` for a pure mapping change with *absolute*
    destinations — the form a packed device move table stores. A relative
    migrate (:func:`apply_migrate`) reasons about the current design to pick
    a destination; the device loop instead enumerates every
    (task, destination-slot) pair up front as packed int32 arrays
    (``device_explore.MoveTable``) and samples among them on device, so an
    accepted move comes back as concrete (task → block-name) assignments.
    Shape-preserving by construction: no blocks added, removed, or touched,
    so the delta always rides the vectorized encoding path."""
    d = MoveDelta()
    d.task_pe.update(task_pe)
    d.task_mem.update(task_mem)
    return d


def apply_mapping(
    design: Design,
    task_pe: Dict[str, str],
    task_mem: Dict[str, str],
    delta: Optional[MoveDelta] = None,
) -> bool:
    """Apply absolute task→block assignments onto ``design`` in place — the
    host-side reconcile primitive for device-accepted packed moves (the
    winning chain's final mapping is a batch of these). Returns False
    without mutating anything if any named task or block is unknown."""
    for t, p in task_pe.items():
        if t not in design.task_pe or p not in design.blocks:
            return False
    for t, m in task_mem.items():
        if t not in design.task_mem or m not in design.blocks:
            return False
    for t, p in task_pe.items():
        design.task_pe[t] = p
        if delta is not None:
            delta.task_pe[t] = p
    for t, m in task_mem.items():
        design.task_mem[t] = m
        if delta is not None:
            delta.task_mem[t] = m
    return True


# ---------------------------------------------------------------------------
# Allocation bridge: host-side reconcile primitives for device-accepted
# allocation moves. A device chain block mutates padded slot inventories
# (active masks + per-slot coefficient columns, ``device_explore.ChainCarry``)
# instead of the Design's dict shape; when the explorer adopts a winning
# chain, ``device_explore.reconcile_alloc`` replays that platform onto the
# live Design through these four primitives — clone-and-attach for forked
# slots, removal for joined slots, a frequency retune for stepped rungs, and
# a NoC re-home for attach moves. Each is shape-changing on the HOST design
# (that is the point: the shape change happens once per adopted block, not
# once per SA iteration).


def fork_block(
    design: Design, origin: str, *, freq_mhz: int, noc: str
) -> str:
    """Clone ``origin`` (same subtype/width/unroll/hardening — the device
    fork copies the source slot's coefficient columns, so the host clone
    must inherit the same knobs), retune it to ``freq_mhz``, attach it to
    ``noc``, and return the new block's (fresh, uid-suffixed) name."""
    b = design.blocks[origin].clone()
    b.freq_mhz = freq_mhz
    design.add_block(b, attach_to=noc)
    return b.name


def join_block(design: Design, name: str) -> None:
    """Remove a block the device loop joined away (or whose slot the winner
    re-populated with a clone). The caller must have re-mapped every task
    off it first — device join validity guarantees the slot was empty."""
    assert name not in design.task_pe.values(), f"{name} still hosts tasks"
    assert name not in design.task_mem.values(), f"{name} still hosts buffers"
    design.remove_block(name)


def retune_block(design: Design, name: str, freq_mhz: int) -> None:
    """Set a block's frequency knob to the ladder value the device swap
    moves walked it to (``FREQ_LADDER_MHZ[rung]``)."""
    assert freq_mhz in design.blocks[name].ladder("freq_mhz"), freq_mhz
    design.blocks[name].freq_mhz = freq_mhz


def attach_block(design: Design, name: str, noc: str) -> None:
    """Re-home a PE/MEM block to another NoC chain position (the device
    NoC-attach move)."""
    assert noc in design.noc_chain, noc
    design.attached_noc[name] = noc
