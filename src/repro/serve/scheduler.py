"""Continuous batching over shared shape-bucketed device dispatches.

``Campaign`` cross-batches a *fixed* grid of explorations in lockstep; the
scheduler generalizes that to a serve loop: sessions **join and leave
mid-flight**, and each :meth:`tick` packs the pending candidate batches of
every currently-live session — grouped per shared backend (one per distinct
task graph, exactly like Campaign) — into one ``evaluate_candidates``
dispatch per group. The dispatch is non-blocking, per-session handle slices
go back through ``Session.resume``, sessions that finish retire immediately,
and whatever was admitted between ticks rides the next pack.

Per-row results are independent of batch composition (each candidate owns
its device row), so co-batching never changes any session's search — the
determinism that lets a mid-flight joiner converge exactly as if it ran
alone, and lets ``Campaign`` route its lockstep sweeps through this
scheduler without changing a single aggregate.

An attached :class:`~repro.serve.store.DesignStore` turns the pack into a
dedupe point as well: identical candidates across sessions resolve to one
device row (same tick) or to a memoized row (earlier tick — even from a
session that already left).
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Union

from ..core.backend import BackendStats, Candidate, SimulatorBackend, make_backend
from ..core.database import HardwareDatabase
from ..core.tdg import TaskGraph
from .session import RUNNING, Session
from .store import DesignStore

BackendSpec = Union[str, Callable[[TaskGraph, HardwareDatabase], SimulatorBackend]]


class ContinuousBatchScheduler:
    """Owns the shared backends and the live-session set; drives ticks."""

    def __init__(
        self,
        db: HardwareDatabase,
        backend: BackendSpec = "jax",
        store: Optional[DesignStore] = None,
    ) -> None:
        self.db = db
        self.store = store
        self._backend_spec = backend
        self._backends: Dict[int, SimulatorBackend] = {}  # id(tdg) -> backend
        self._live: List[Session] = []  # admission order = packing order
        self.n_ticks = 0

    # ---- backends --------------------------------------------------------
    def backend_for(self, tdg: TaskGraph) -> SimulatorBackend:
        """One shared backend per distinct task-graph object (the encoding
        is workload-specific). A store, when configured, is attached to
        every backend that supports it — the store itself is shared, so
        dedupe crosses workload boundaries by digest namespace only."""
        key = id(tdg)
        if key not in self._backends:
            if callable(self._backend_spec):
                backend = self._backend_spec(tdg, self.db)
            else:
                backend = make_backend(self._backend_spec, tdg, self.db)
            attach = getattr(backend, "attach_store", None)
            if self.store is not None and attach is not None:
                attach(self.store)
            self._backends[key] = backend
        return self._backends[key]

    def backends(self) -> Dict[int, SimulatorBackend]:
        return self._backends

    def backend_stats(self) -> Dict[int, BackendStats]:
        return {k: b.stats() for k, b in self._backends.items()}

    # ---- session lifecycle ----------------------------------------------
    @property
    def n_live(self) -> int:
        return len(self._live)

    def admit(self, session: Session) -> None:
        """Start a session and enroll it for the next tick — the mid-flight
        join point. Safe at any moment between ticks."""
        session.start()
        if session.state == RUNNING:
            self._live.append(session)

    def tick(self) -> List[Session]:
        """One scheduler round: pack all live sessions' pending candidates
        per backend group, dispatch once per group, resume every member with
        its handle slice. Returns the sessions that completed this tick.

        The shared-dispatch wall is attributed to sessions proportionally to
        their candidate counts (the same accounting the lockstep Campaign
        loop reported as ``sim_wall_s``)."""
        completed: List[Session] = []
        if not self._live:
            return completed
        self.n_ticks += 1
        groups: Dict[int, List[Session]] = {}
        for s in self._live:
            groups.setdefault(id(s.request.tdg), []).append(s)
        for members in groups.values():
            backend = self.backend_for(members[0].request.tdg)
            cands: List[Candidate] = [c for s in members for c in s.pending]
            t0 = time.perf_counter()
            handles = backend.evaluate_candidates(cands)
            dispatch_s = time.perf_counter() - t0
            offset = 0
            for s in members:
                k = len(s.pending)
                sub = handles[offset:offset + k]
                offset += k
                s.sim_wall_s += dispatch_s * k / max(len(cands), 1)
                if s.resume(sub):
                    completed.append(s)
                    self._live.remove(s)
        return completed

    def run_until_idle(self, max_ticks: Optional[int] = None) -> List[Session]:
        """Tick until no session is live (or ``max_ticks`` elapsed);
        returns everything that completed along the way."""
        done: List[Session] = []
        while self._live and (max_ticks is None or self.n_ticks < max_ticks):
            done.extend(self.tick())
        return done

    def flush(self) -> None:
        """Drain every shared backend's in-flight dispatches (abandoned
        speculative batches must not outlive the serve loop)."""
        for backend in self._backends.values():
            flush = getattr(backend, "flush", None)
            if flush is not None:
                flush()
