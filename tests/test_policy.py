"""Heuristic-policy layer: golden-sequence equivalence with the pre-refactor
Explorer, registry plumbing, device bottleneck-telemetry parity, and the
telemetry-driven policies' behaviour."""
import json
import os

import pytest

from repro.core import (
    POLICIES,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    JaxBatchedBackend,
    PythonBackend,
    SimTelemetry,
    ar_complex,
    audio,
    calibrated_budget,
    edge_detection,
    make_policy,
    random_single_noc_designs,
    simulate,
)
from repro.core.backend import Candidate
from repro.core.blocks import BlockKind
from repro.core.policy import AWARENESS_POLICY, FarsiPolicy, NaiveSA

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden_policy_seqs.json")
GRAPHS = {"audio": audio, "ar_complex": ar_complex, "ed": edge_detection}


def _seq(res):
    return [[h["iteration"], h["move"], int(h["accepted"])] for h in res.history]


# ---------------------------------------------------------------------------
# golden-sequence regression: the policy refactor replays the pre-refactor
# Explorer bit-for-bit (fixtures captured at the PR-3 tree under fixed seeds)
# ---------------------------------------------------------------------------
with open(GOLDEN) as f:
    _GOLD = json.load(f)


@pytest.mark.parametrize("key", sorted(_GOLD))
def test_policy_replays_pre_refactor_golden(key):
    ref = _GOLD[key]
    gname, aware, s, it = key.split("@")[0].split(".")
    seed, iters = int(s[1:]), int(it[2:])
    g = GRAPHS[gname]()
    db = HardwareDatabase()
    bud = calibrated_budget(db)
    for backend in ref["backends"]:
        res = Explorer(
            g, db, bud,
            ExplorerConfig(awareness=aware, max_iterations=iters, seed=seed,
                           backend=backend),
        ).run()
        assert _seq(res) == ref["seq"], (key, backend)
        assert res.n_sims == ref["n_sims"], (key, backend)


def test_farsi_policy_identical_pipelined_and_serial():
    """The acceptance bar, policy edition: FarsiPolicy replays the identical
    accepted-move sequence serial vs speculative-pipelined (and the policy
    state — taboo/sticky/ledger — rolls back cleanly on mis-speculation)."""
    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    seqs, ledgers = [], []
    for pipe in (False, True):
        res = Explorer(
            g, db, bud,
            ExplorerConfig(policy="farsi", max_iterations=60, seed=7,
                           pipeline=pipe),
            backend=JaxBatchedBackend(g, db),
        ).run()
        seqs.append(_seq(res))
        ledgers.append([(r.iteration, r.metric, r.move) for r in res.ledger.records])
    assert seqs[0] == seqs[1]
    assert ledgers[0] == ledgers[1]


# ---------------------------------------------------------------------------
# registry + config plumbing
# ---------------------------------------------------------------------------
def test_policy_registry_and_config_selection():
    assert len(POLICIES) >= 4
    assert set(AWARENESS_POLICY.values()) <= set(POLICIES)
    db = HardwareDatabase()
    g = edge_detection()
    bud = calibrated_budget(db)
    for name in POLICIES:
        res = Explorer(
            g, db, bud, ExplorerConfig(policy=name, max_iterations=8, seed=1)
        ).run()
        assert res.policy_name == name
        assert res.iterations >= 1
    with pytest.raises(ValueError):
        make_policy("nope")
    # the awareness ladder still maps onto policies
    res = Explorer(g, db, bud, ExplorerConfig(awareness="sa", max_iterations=5)).run()
    assert res.policy_name == "naive_sa"
    assert isinstance(make_policy("farsi"), FarsiPolicy)
    assert isinstance(make_policy("naive_sa"), NaiveSA)


# ---------------------------------------------------------------------------
# telemetry parity: device columns vs host SimResult attribution
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("graph_fn,seed", [(audio, 3), (ar_complex, 5)])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_bottleneck_telemetry_matches_host_attribution(graph_fn, seed, use_kernel):
    """Acceptance bar: the device-side per-block bottleneck telemetry agrees
    with the Python simulator's host attribution to ≤ 1e-5 (relative to the
    design's latency), on the XLA and the Pallas-kernel path alike; the
    top-bottleneck argmax column resolves to the same block."""
    db = HardwareDatabase()
    g = graph_fn()
    jb = JaxBatchedBackend(g, db, use_kernel=use_kernel)
    designs = random_single_noc_designs(g, 6, seed=seed)
    handles = jb.evaluate_candidates([Candidate.of_design(d) for d in designs])
    for d, h in zip(designs, handles):
        ref = simulate(d, g, db)
        got = h.result()
        tol = 1e-5 * max(ref.latency_s, 1e-12) * len(g.tasks)
        assert set(got.block_bottleneck_s) == set(ref.block_bottleneck_s)
        for name, s in ref.block_bottleneck_s.items():
            assert abs(got.block_bottleneck_s[name] - s) <= tol, (name, s)
        # kind sums tie the per-block split to the class attribution
        for kind, blocks in (
            ("pe", [n for n, b in d.blocks.items() if b.kind == BlockKind.PE]),
            ("mem", [n for n, b in d.blocks.items() if b.kind == BlockKind.MEM]),
        ):
            assert abs(
                sum(got.block_bottleneck_s[n] for n in blocks)
                - ref.bottleneck_s[kind]
            ) <= tol
        tel = h.telemetry()
        ref_tel = SimTelemetry.of_result(ref, g, d)
        assert tel.top_bneck_pe() == ref_tel.top_bneck_pe()
        assert tel.top_bneck_mem() == ref_tel.top_bneck_mem()
        assert abs(tel.comp_s - ref_tel.comp_s) <= tol
        assert abs(tel.comm_s - ref_tel.comm_s) <= tol


def test_telemetry_view_matches_decode_bitwise():
    """A row-backed telemetry view must produce the exact floats the lazy
    decode produces (shared scalar helpers) — this is what makes the
    telemetry-driven FarsiPolicy bit-identical to the decode-driven one."""
    from repro.core.budgets import distance

    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    jb = JaxBatchedBackend(g, db)
    d = random_single_noc_designs(g, 1, seed=2)[0]
    (h,) = jb.evaluate_candidates([Candidate.of_design(d, bud)])
    tel = h.telemetry()
    res = h.result()
    assert tel.dist(bud).per_metric == distance(res, bud).per_metric
    assert tel.dist(bud).per_workload_latency == distance(res, bud).per_workload_latency
    for t in g.tasks:
        assert tel.task_finish_s(t) == res.task_finish_s[t]
        assert tel.task_energy_j(t) == res.task_energy_j[t]
        assert tel.task_bneck(t) == res.task_bottleneck[t]
        assert tel.task_bneck_block(t) == res.task_bottleneck_block[t]
    for m in d.mems():
        assert tel.mem_capacity(m) == res.mem_capacity_bytes[m]
    assert tel.block_bneck_s() == res.block_bottleneck_s


# ---------------------------------------------------------------------------
# telemetry-driven policies
# ---------------------------------------------------------------------------
def test_bottleneck_policy_targets_top_bottleneck_block():
    """BottleneckRelaxation must aim at the device's top-bottleneck column:
    on a fresh base design every task shares one PE, so the first focus is
    that PE (comp-bound) with the longest-duration hosted task."""
    from repro.core import Design

    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    d = Design.base(g)
    py = PythonBackend(g, db)
    (h,) = py.evaluate_candidates([Candidate.of_design(d, bud)])
    tel = h.telemetry()
    pol = make_policy("bottleneck")
    import random

    pol.bind(g, db, bud, ExplorerConfig(), random.Random(0))
    focus = pol.select_focus(d, tel.dist(bud), tel)
    assert focus.block == tel.top_bneck_pe()
    assert focus.task in d.tasks_on_pe(focus.block)
    assert focus.task == max(d.tasks_on_pe(focus.block), key=tel.task_duration)


def test_policy_convergence_ordering_on_ed():
    """Paper §5.2 qualitative ordering at a fixed iteration budget: the
    architecture-aware policies must land at least as close to budget as
    naive SA, with FarsiPolicy converging."""
    db = HardwareDatabase()
    g = edge_detection()
    bud = calibrated_budget(db)
    dist = {}
    for name in ("naive_sa", "bottleneck", "locality", "farsi"):
        res = Explorer(
            g, db, bud, ExplorerConfig(policy=name, max_iterations=60, seed=3)
        ).run()
        dist[name] = res.best_distance.city_block()
    assert dist["farsi"] == 0.0
    assert max(dist["bottleneck"], dist["locality"], dist["farsi"]) <= dist["naive_sa"]
