"""Paper Table 4b: phase-driven simulator fidelity + speedup vs the
event-driven reference (our Platform-Architect stand-in).

Methodology mirrors §4: collect designs of varying complexity from an
exploration trajectory (1..13+ PEs, 1..8 mems, 1..3+ NoCs in the paper),
simulate each with both simulators, report accuracy = 100·(1−mean rel err),
error std, and the wall-time speedup distribution.
"""
from __future__ import annotations

import statistics
import time
from typing import List

from repro.core import (
    Design,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    ar_complex,
    calibrated_budget,
    simulate,
    simulate_events,
)

from .common import Row


def _collect_designs(n: int = 40) -> List[Design]:
    """Snapshot designs along FARSI *and* naive-SA explorations — the SA ones
    keep messy many-tasks-per-block mappings whose contention transients are
    exactly where the two simulators can disagree (§4: buses show the highest
    sensitivity)."""
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    designs = [Design.base(g)]

    for level, seed in (("farsi", 11), ("sa", 12), ("sa", 13)):
        ex = Explorer(
            g, db, bud, ExplorerConfig(awareness=level, max_iterations=120, seed=seed)
        )
        orig = ex.backend.evaluate
        quota = n // 3 + 1

        def spy(batch, orig=orig, box=[0, quota], seen=[0]):
            for design in batch:
                seen[0] += 1
                if box[0] < box[1] and seen[0] % 7 == 3:
                    designs.append(design.clone())
                    box[0] += 1
            return orig(batch)

        ex.backend.evaluate = spy
        ex.run()
    return designs[:n]


def run() -> List[Row]:
    db = HardwareDatabase()
    g = ar_complex()
    designs = _collect_designs(40)
    errs, speedups, t_phase_all, t_event_all = [], [], [], []
    for d in designs:
        t0 = time.perf_counter()
        rp = simulate(d, g, db)
        t1 = time.perf_counter()
        re = simulate_events(d, g, db, max_chunks=128)
        t2 = time.perf_counter()
        # per-workload latency + power errors (the paper's metric set)
        for wl in rp.workload_latency_s:
            errs.append(
                abs(rp.workload_latency_s[wl] - re.workload_latency_s[wl])
                / re.workload_latency_s[wl]
                * 100
            )
        errs.append(abs(rp.power_w - re.power_w) / re.power_w * 100)
        speedups.append((t2 - t1) / max(t1 - t0, 1e-9))
        t_phase_all.append(t1 - t0)
        t_event_all.append(t2 - t1)

    acc = 100 - statistics.mean(errs)
    rows = [
        (
            "table4b.accuracy_pct",
            statistics.mean(t_phase_all) * 1e6,
            f"accuracy={acc:.4f}% err_avg={statistics.mean(errs):.4f}% "
            f"err_max={max(errs):.4f}% err_std={statistics.pstdev(errs):.4f}% "
            f"n={len(designs)} (reference shares the Gables rate model; "
            f"paper's 98.5% is vs the richer Synopsys PA)",
        ),
        (
            "table4b.speedup",
            statistics.mean(t_event_all) * 1e6,
            f"speedup_avg={statistics.mean(speedups):.0f}x "
            f"speedup_max={max(speedups):.0f}x "
            f"phase_avg={statistics.mean(t_phase_all)*1e3:.2f}ms "
            f"event_avg={statistics.mean(t_event_all)*1e3:.1f}ms",
        ),
    ]
    return rows
