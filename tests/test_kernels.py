"""Per-kernel validation (deliverable c): shape/dtype sweeps in
``interpret=True`` against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_naive, ssd_reference
from repro.models.layers import rms_norm

KEY = jax.random.PRNGKey(42)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
FLASH_CASES = [
    # (B, S, H, KH, Dh, qb, kb, dtype)
    (2, 256, 8, 4, 64, 64, 128, jnp.float32),
    (1, 512, 4, 4, 128, 128, 128, jnp.float32),
    (2, 128, 8, 2, 32, 64, 64, jnp.float32),
    (1, 256, 16, 1, 64, 128, 64, jnp.float32),  # MQA
    (2, 256, 8, 4, 64, 64, 128, jnp.bfloat16),
    (1, 128, 4, 4, 256, 64, 64, jnp.bfloat16),  # gemma-style head_dim
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_kernel_vs_oracle(case, causal):
    b, s, h, kh, dh, qb, kb, dtype = case
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, dh)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, dh)).astype(dtype)
    out = flash_attention(q, k, v, causal, qb, kb, interpret=True)
    ref = attention_reference(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        causal=causal,
    ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )


def test_flash_blockwise_ref_grads_match_dense():
    """The training path's custom-VJP blockwise attention: grads vs dense."""
    from repro.models.flash_ref import flash_attention_ref
    from repro.models.layers import attention_full

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 128, 8, 32))
    k = jax.random.normal(ks[1], (2, 128, 4, 32))
    v = jax.random.normal(ks[2], (2, 128, 4, 32))

    g1 = jax.grad(lambda q, k, v: jnp.sum(jnp.tanh(attention_full(q, k, v, causal=True))), (0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda q, k, v: jnp.sum(jnp.tanh(flash_attention_ref(q, k, v, True, 32, 64))), (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, atol=2e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
SSD_CASES = [
    # (B, S, H, P, N, chunk, dtype)
    (2, 128, 4, 16, 8, 32, jnp.float32),
    (1, 256, 2, 64, 128, 128, jnp.float32),
    (2, 64, 8, 32, 16, 16, jnp.float32),
    (1, 128, 4, 64, 32, 64, jnp.bfloat16),
]


def _ssd_inputs(b, s, h, p, n, dtype):
    ks = jax.random.split(KEY, 5)
    x = jax.random.normal(ks[0], (b, s, h, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    return x, dt, a, bm, cm


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_kernel_vs_naive(case):
    b, s, h, p, n, chunk, dtype = case
    x, dt, a, bm, cm = _ssd_inputs(b, s, h, p, n, dtype)
    y_k, h_k = ssd(x, dt, a, bm, cm, chunk=chunk, interpret=True)
    y_r, h_r = ssd_naive(x, dt, a, bm, cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(
        y_k.astype(jnp.float32), y_r.astype(jnp.float32), atol=tol, rtol=tol
    )
    np.testing.assert_allclose(h_k, h_r, atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("chunk", [16, 32, 64, 128])
def test_ssd_ref_chunk_invariance(chunk):
    """Chunked SSD == naive recurrence for every chunk size (oracle property)."""
    x, dt, a, bm, cm = _ssd_inputs(2, 128, 4, 16, 8, jnp.float32)
    y_c, h_c = ssd_reference(x, dt, a, bm, cm, chunk=chunk)
    y_n, h_n = ssd_naive(x, dt, a, bm, cm)
    np.testing.assert_allclose(y_c, y_n, atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(h_c, h_n, atol=5e-5, rtol=1e-3)


def test_ssd_initial_state_handoff():
    """Splitting a sequence in two and carrying h across == one pass
    (prefill→decode contract)."""
    x, dt, a, bm, cm = _ssd_inputs(1, 64, 2, 8, 4, jnp.float32)
    y_full, h_full = ssd_reference(x, dt, a, bm, cm, chunk=16)
    y1, h1 = ssd_reference(x[:, :32], dt[:, :32], a, bm[:, :32], cm[:, :32], chunk=16)
    y2, h2 = ssd_reference(x[:, 32:], dt[:, 32:], a, bm[:, 32:], cm[:, 32:], chunk=16, h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(h2, h_full, atol=1e-5, rtol=1e-3)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape,rb", [((64, 128), 32), ((2, 32, 64), 16), ((256, 512), 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(shape, rb, dtype):
    x = jax.random.normal(KEY, shape).astype(dtype)
    w = (jax.random.normal(jax.random.PRNGKey(7), (shape[-1],)) * 0.1).astype(dtype)
    out = rmsnorm(x, w, row_block=rb, interpret=True)
    ref = rms_norm(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        out.astype(jnp.float32), ref.astype(jnp.float32), atol=tol, rtol=tol
    )
