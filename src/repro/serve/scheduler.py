"""Continuous batching over shared shape-bucketed device dispatches.

``Campaign`` cross-batches a *fixed* grid of explorations in lockstep; the
scheduler generalizes that to a serve loop: sessions **join and leave
mid-flight**, and each :meth:`tick` packs the pending candidate batches of
every currently-live session — grouped per shared backend (one per distinct
task graph, exactly like Campaign) — into one ``evaluate_candidates``
dispatch per group. The dispatch is non-blocking, per-session handle slices
go back through ``Session.resume``, sessions that finish retire immediately,
and whatever was admitted between ticks rides the next pack.

Per-row results are independent of batch composition (each candidate owns
its device row), so co-batching never changes any session's search — the
determinism that lets a mid-flight joiner converge exactly as if it ran
alone, and lets ``Campaign`` route its lockstep sweeps through this
scheduler without changing a single aggregate.

An attached :class:`~repro.serve.store.DesignStore` turns the pack into a
dedupe point as well: identical candidates across sessions resolve to one
device row (same tick) or to a memoized row (earlier tick — even from a
session that already left).

**Fault isolation.** A fault inside the tick costs its owning session —
never the tick, never the service:

* a shared group dispatch that raises (an injected fault, a mid-batch
  ``UnsupportedDesignError`` that escaped the backend's own fallback) is
  **bisected**: every member session redispatches its own slice alone, so
  the poison is pinned to its owner and the survivors' rows stay
  bit-identical (per-row independence again);
* a per-session dispatch retries with capped exponential backoff
  (:class:`~repro.serve.faults.RetryPolicy`); ``degrade_after`` consecutive
  primary-backend failures pin that one session to a scalar
  ``PythonBackend`` fallback (the service keeps serving); a session whose
  fallback also fails is quarantined to ``FAILED`` with the error recorded
  on it;
* chain-batched (``device_sa``) sessions ride the same ladder: their fused
  (R, K) block re-dispatch is deterministic so retries price an identical
  block, and the degraded regime is the host-driven loop (K dispatches of
  the same compiled step at K=1 — bit-identical results by the parity
  contract, at host-loop cost) rather than the scalar fallback, which
  cannot price a device block;
* an exception escaping a session *coroutine* fails (or, with restarts
  budgeted, rebuilds from the explorer's last committed accept via the
  policy checkpoint machinery) that one session;
* per-session ``deadline_s`` SLOs are enforced at the top of every tick;
* an attached :class:`~repro.serve.faults.FaultInjector` exercises all of
  the above deterministically, and a ``runtime.health.StepTimeMonitor``
  EMA-flags straggler ticks.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Union

from ..core.backend import (
    BackendStats,
    Candidate,
    PythonBackend,
    SimHandle,
    SimulatorBackend,
    make_backend,
)
from ..core.database import HardwareDatabase
from ..core.device_explore import ChainRequest
from ..core.explorer import Explorer
from ..core.tdg import TaskGraph
from ..runtime.health import StepTimeMonitor
from .faults import (
    DeadlineExceeded,
    DispatchFailed,
    FaultInjector,
    InjectedDispatchError,
    InjectedSessionCrash,
    RetryPolicy,
)
from .session import RUNNING, Session
from .store import DesignStore

BackendSpec = Union[str, Callable[[TaskGraph, HardwareDatabase], SimulatorBackend]]


class ContinuousBatchScheduler:
    """Owns the shared backends and the live-session set; drives ticks."""

    def __init__(
        self,
        db: HardwareDatabase,
        backend: BackendSpec = "jax",
        store: Optional[DesignStore] = None,
        faults: Optional[FaultInjector] = None,
        retry: Optional[RetryPolicy] = None,
    ) -> None:
        self.db = db
        self.store = store
        self.faults = faults
        self.retry = retry or RetryPolicy()
        self.monitor = StepTimeMonitor()  # EMA straggler flagging per tick
        self._backend_spec = backend
        self._backends: Dict[int, SimulatorBackend] = {}  # id(tdg) -> backend
        self._fallbacks: Dict[int, PythonBackend] = {}  # degraded-mode backends
        self._live: List[Session] = []  # admission order = packing order
        self.n_ticks = 0
        # fault-tolerance counters (surfaced through ServiceStats)
        self.n_dispatch_faults = 0  # dispatch attempts that raised
        self.n_retries = 0  # backed-off per-session re-attempts
        self.n_bisects = 0  # shared dispatches split after a fault
        self.n_degraded = 0  # sessions pinned to the python fallback
        self.n_failed = 0  # sessions quarantined to FAILED
        self.n_restarts = 0  # crash-restarts performed
        self.n_deadline_exceeded = 0  # sessions failed by their SLO
        self.n_straggler_ticks = 0  # ticks the StepTimeMonitor flagged

    # ---- backends --------------------------------------------------------
    def backend_for(self, tdg: TaskGraph) -> SimulatorBackend:
        """One shared backend per distinct task-graph object (the encoding
        is workload-specific). A store, when configured, is attached to
        every backend that supports it — the store itself is shared, so
        dedupe crosses workload boundaries by digest namespace only."""
        key = id(tdg)
        if key not in self._backends:
            if callable(self._backend_spec):
                backend = self._backend_spec(tdg, self.db)
            else:
                backend = make_backend(self._backend_spec, tdg, self.db)
            attach = getattr(backend, "attach_store", None)
            if self.store is not None and attach is not None:
                attach(self.store)
            self._backends[key] = backend
        return self._backends[key]

    def fallback_for(self, tdg: TaskGraph) -> PythonBackend:
        """The degraded-mode scalar backend for this graph, built lazily on
        first degradation (a fault-free service never pays for one)."""
        key = id(tdg)
        if key not in self._fallbacks:
            self._fallbacks[key] = PythonBackend(tdg, self.db)
        return self._fallbacks[key]

    def backends(self) -> Dict[int, SimulatorBackend]:
        return self._backends

    def fallback_backends(self) -> Dict[int, PythonBackend]:
        return self._fallbacks

    def backend_stats(self) -> Dict[int, BackendStats]:
        return {k: b.stats() for k, b in self._backends.items()}

    # ---- session lifecycle ----------------------------------------------
    @property
    def n_live(self) -> int:
        return len(self._live)

    def admit(self, session: Session) -> None:
        """Start a session and enroll it for the next tick — the mid-flight
        join point. Safe at any moment between ticks."""
        session.start()
        if session.state == RUNNING:
            self._live.append(session)

    # ---- fault paths -----------------------------------------------------
    def _fail(self, session: Session, exc: BaseException) -> None:
        session.fail(exc)
        self.n_failed += 1
        if session in self._live:
            self._live.remove(session)

    def _restart(self, session: Session) -> bool:
        """Rebuild a crashed session's coroutine from its explorer's last
        committed accept: fresh Explorer (budget shrunk to the remaining
        iterations), rng/policy restored through the checkpoint machinery,
        generator re-primed from the last accepted design. Returns False if
        no committed snapshot exists (the scheduler then fails the session)."""
        old = session.explorer
        st = old.restart_state()
        if st is None:
            return False
        remaining = max(1, old.cfg.max_iterations - st["iteration"])
        cfg = dataclasses.replace(old.cfg, max_iterations=remaining)
        ex = Explorer(
            session.request.tdg, self.db, session.request.budget, cfg,
            backend=old.backend,
        )
        ex.rng.setstate(st["rng"])
        ex.policy.restore(st["policy"])
        session.resurrect(ex, st["design"])
        self.n_restarts += 1
        return True

    def _recover(self, session: Session, exc: BaseException, completed: List[Session]) -> None:
        """An exception escaped the session coroutine: crash-restart if the
        request budgeted restarts (and a committed snapshot exists),
        otherwise quarantine to FAILED. Either way the tick — and every
        other session — proceeds untouched."""
        if session.restarts_left > 0 and self._restart(session):
            if session.done:  # pragma: no cover — resurrect hit StopIteration
                completed.append(session)
                self._live.remove(session)
            return
        self._fail(session, exc)

    def _attempt(
        self, backend: SimulatorBackend, cands: List[Candidate], target: str,
        inject: bool,
    ) -> List[SimHandle]:
        """One dispatch attempt, with the injector consulted *before* the
        backend call — a vetoed attempt raises without submitting anything,
        so a retry of the same rows is bit-identical by construction."""
        fi = self.faults
        if fi is not None and inject:
            if fi.draw_dispatch_fault(target):
                raise InjectedDispatchError(f"injected dispatch fault: {target}")
            delay = fi.draw_straggler(target)
            if delay > 0.0:
                time.sleep(delay)  # artificial latency: the monitor's outlier
        return backend.evaluate_candidates(cands)

    def _price_session(self, session: Session) -> Optional[List[SimHandle]]:
        """Price one session's pending batch alone: retry with capped
        exponential backoff on the primary backend, degrade to the scalar
        fallback after ``degrade_after`` consecutive failures (counted
        across ticks, reset on success), FAIL the session only when the
        fallback path fails too. Returns None iff the session was failed."""
        rp = self.retry
        tdg = session.request.tdg
        if not session.degraded:
            backend = self.backend_for(tdg)
            delay = rp.backoff_s
            last: Optional[BaseException] = None
            for attempt in range(rp.max_attempts):
                if session.n_consec_dispatch_failures >= rp.degrade_after:
                    break  # ladder exhausted: degrade instead of retrying
                if attempt > 0:
                    self.n_retries += 1
                    if delay > 0.0:
                        time.sleep(delay)
                    delay = min(delay * 2.0, rp.backoff_cap_s)
                try:
                    handles = self._attempt(
                        backend, session.pending, session.name, inject=True
                    )
                    session.n_consec_dispatch_failures = 0
                    return handles
                except Exception as exc:
                    self.n_dispatch_faults += 1
                    session.n_consec_dispatch_failures += 1
                    last = exc
            if session.n_consec_dispatch_failures < rp.degrade_after:
                self._fail(session, DispatchFailed(
                    f"session {session.name!r}: {rp.max_attempts} dispatch "
                    f"attempts failed (last: {last!r})"
                ))
                return None
            # graceful degradation: pin this one session to the scalar
            # backend; the service keeps serving everyone else on the device
            session.degraded = True
            self.n_degraded += 1
        # degraded path — the known-good backend; the injector never vetoes
        # it (degradation models recovery, not a second failure domain)
        try:
            return self._attempt(
                self.fallback_for(tdg), session.pending,
                session.name + "~degraded", inject=False,
            )
        except Exception as exc:
            self.n_dispatch_faults += 1
            self._fail(session, DispatchFailed(
                f"session {session.name!r}: degraded-mode dispatch failed "
                f"({exc!r})"
            ))
            return None

    def _price_chain_session(self, session: Session):
        """The retry/degrade ladder for a chain-batched session (its pending
        object is a fused (R, K) :class:`ChainRequest`, not a candidate
        list). Same shape as :meth:`_price_session` — retry with capped
        exponential backoff, degrade after ``degrade_after`` consecutive
        failures, FAIL only when the degraded path fails too — with one
        difference: the scalar fallback cannot price a fused device block,
        so the degraded regime is the *host-driven loop* instead — the same
        compiled chain step dispatched K=1 at a time with the carry pulled
        back between iterations. By the R=1-parity contract that replays
        the fused block bit-for-bit, so degradation changes dispatch
        granularity (and cost), never the search. The injector is consulted
        before every primary attempt (a vetoed attempt raises without
        submitting, and a ``ChainRequest`` re-dispatch is deterministic, so
        the retry prices an identical block); the degraded loop is never
        vetoed — degradation models recovery, not a second failure domain.
        Returns None iff the session was failed."""
        rp = self.retry
        fi = self.faults
        req: ChainRequest = session.pending
        backend = self.backend_for(session.request.tdg)
        if not hasattr(backend, "run_chains"):
            self._fail(session, DispatchFailed(
                f"session {session.name!r}: backend {backend.name!r} does "
                "not support device chain blocks"
            ))
            return None
        if not session.degraded:
            delay = rp.backoff_s
            last: Optional[BaseException] = None
            for attempt in range(rp.max_attempts):
                if session.n_consec_dispatch_failures >= rp.degrade_after:
                    break  # ladder exhausted: degrade instead of retrying
                if attempt > 0:
                    self.n_retries += 1
                    if delay > 0.0:
                        time.sleep(delay)
                    delay = min(delay * 2.0, rp.backoff_cap_s)
                try:
                    if fi is not None and fi.draw_dispatch_fault(session.name):
                        raise InjectedDispatchError(
                            f"injected dispatch fault: {session.name}"
                        )
                    block = backend.run_chains(req)
                    session.n_consec_dispatch_failures = 0
                    return block
                except Exception as exc:
                    self.n_dispatch_faults += 1
                    session.n_consec_dispatch_failures += 1
                    last = exc
            if session.n_consec_dispatch_failures < rp.degrade_after:
                self._fail(session, DispatchFailed(
                    f"session {session.name!r}: {rp.max_attempts} chain-"
                    f"block dispatch attempts failed (last: {last!r})"
                ))
                return None
            session.degraded = True
            self.n_degraded += 1
        # degraded regime: the host-loop schedule — K dispatches of the same
        # compiled step at k=1, carry round-tripped through host numpy
        # between iterations (the parity oracle's exact access pattern)
        try:
            import numpy as _np

            carry = req.carry
            block = None
            mvs, accs, fts = [], [], []
            for i in range(req.k):
                block = backend.run_chains(dataclasses.replace(
                    req, k=1, it0=req.it0 + i, carry=carry,
                ))
                carry = block.carry
                mvs.append(block.move_idx)
                accs.append(block.accepted)
                fts.append(block.fit_trace)
            block = dataclasses.replace(
                block,
                move_idx=_np.concatenate(mvs, axis=1),
                accepted=_np.concatenate(accs, axis=1),
                fit_trace=_np.concatenate(fts, axis=1),
            )
            return block
        except Exception as exc:
            self.n_dispatch_faults += 1
            self._fail(session, DispatchFailed(
                f"session {session.name!r}: degraded host-loop chain "
                f"dispatch failed ({exc!r})"
            ))
            return None

    # ---- the tick --------------------------------------------------------
    def tick(self) -> List[Session]:
        """One scheduler round: pack all live sessions' pending candidates
        per backend group, dispatch once per group, resume every member with
        its handle slice. Returns the sessions that completed this tick.

        The shared-dispatch wall is attributed to sessions proportionally to
        their candidate counts (the same accounting the lockstep Campaign
        loop reported as ``sim_wall_s``). Faults — injected or real — are
        quarantined per session; see the module docstring for the ladder."""
        completed: List[Session] = []
        if not self._live:
            return completed
        self.n_ticks += 1
        t_tick = time.perf_counter()
        fi = self.faults
        if fi is not None:
            fi.begin_tick(self.n_ticks)

        # deadline SLOs first: a session past its budget fails before it can
        # consume another dispatch
        for s in list(self._live):
            if s.past_deadline():
                self.n_deadline_exceeded += 1
                self._fail(s, DeadlineExceeded(
                    f"session {s.name!r} exceeded deadline_s="
                    f"{s.request.deadline_s}"
                ))

        # injected coroutine crashes (the chaos harness's process-death
        # stand-in) — thrown into the generator so the real unwind runs
        if fi is not None:
            for s in list(self._live):
                if fi.draw_crash(s.name):
                    escaped = s.crash(InjectedSessionCrash(
                        f"injected crash: session {s.name!r}"
                    ))
                    if escaped is not None:
                        self._recover(s, escaped, completed)
                    elif s.done:  # pragma: no cover — graceful wind-down
                        completed.append(s)
                        self._live.remove(s)

        # chain-batched sessions (config.chain_r > 0) carry a ChainRequest
        # instead of a candidate list: each is one fused (R, K) device block
        # already — there is nothing to pack, so they dispatch individually
        # through the SAME retry/degrade ladder as ordinary sessions
        # (_price_chain_session: backoff-capped retries, then the host-loop
        # regime as the degraded backend) and rejoin the ordinary pack only
        # for their final winner decode
        for s in list(self._live):
            if not isinstance(s.pending, ChainRequest):
                continue
            t0 = time.perf_counter()
            block = self._price_chain_session(s)
            if block is None:  # failed through the whole ladder
                continue
            s.sim_wall_s += time.perf_counter() - t0
            try:
                finished = s.resume([block])
            except Exception as exc:
                self._recover(s, exc, completed)
                continue
            if finished:  # pragma: no cover — final yield is a decode batch
                completed.append(s)
                self._live.remove(s)

        groups: Dict[int, List[Session]] = {}
        for s in self._live:
            if isinstance(s.pending, ChainRequest):
                continue  # failed resume above left no pack-able batch
            groups.setdefault(id(s.request.tdg), []).append(s)
        for members in groups.values():
            # degraded sessions price on the scalar fallback individually;
            # everyone else shares one device dispatch
            shared = [s for s in members if not s.degraded]
            priced: Dict[str, Optional[List[SimHandle]]] = {}
            if shared:
                backend = self.backend_for(shared[0].request.tdg)
                cands: List[Candidate] = [c for s in shared for c in s.pending]
                target = "shared:" + getattr(
                    shared[0].request.tdg, "name", str(id(shared[0].request.tdg))
                )
                t0 = time.perf_counter()
                try:
                    handles: Optional[List[SimHandle]] = self._attempt(
                        backend, cands, target, inject=True
                    )
                except Exception:
                    handles = None
                    self.n_dispatch_faults += 1
                    self.n_bisects += 1
                dispatch_s = time.perf_counter() - t0
                if handles is not None:
                    offset = 0
                    for s in shared:
                        k = len(s.pending)
                        priced[s.name] = handles[offset:offset + k]
                        offset += k
                        s.sim_wall_s += dispatch_s * k / max(len(cands), 1)
                        s.n_consec_dispatch_failures = 0
                else:
                    # bisect-and-redispatch: the poison (injected or a real
                    # mid-batch failure) is quarantined to whichever session
                    # owns it; survivors' redispatched rows are bit-identical
                    # to the shared rows (per-row independence)
                    for s in shared:
                        t1 = time.perf_counter()
                        priced[s.name] = self._price_session(s)
                        s.sim_wall_s += time.perf_counter() - t1
            for s in members:
                # degraded before this tick (mid-bisect degraders are
                # already in ``priced`` via their fallback redispatch)
                if s.degraded and s.state == RUNNING and s.name not in priced:
                    t1 = time.perf_counter()
                    priced[s.name] = self._price_session(s)
                    s.sim_wall_s += time.perf_counter() - t1

            for s in members:
                if s.state != RUNNING:
                    continue  # failed while pricing this very group
                handles = priced.get(s.name)
                if handles is None:
                    continue
                if fi is not None:
                    handles = fi.poison_rows(s.name, handles)
                try:
                    finished = s.resume(handles)
                except Exception as exc:
                    # satellite fix: a coroutine death no longer aborts the
                    # tick — quarantine (or crash-restart) that one session
                    self._recover(s, exc, completed)
                    continue
                if finished:
                    completed.append(s)
                    self._live.remove(s)

        st = self.monitor.record(self.n_ticks, time.perf_counter() - t_tick)
        if st.is_straggler:
            self.n_straggler_ticks += 1
        return completed

    def run_until_idle(self, max_ticks: Optional[int] = None) -> List[Session]:
        """Tick until no session is live (or ``max_ticks`` elapsed);
        returns everything that completed along the way."""
        done: List[Session] = []
        while self._live and (max_ticks is None or self.n_ticks < max_ticks):
            done.extend(self.tick())
        return done

    def flush(self) -> None:
        """Drain every shared backend's in-flight dispatches (batches a
        failed or finished session never consumed must not outlive the
        serve loop)."""
        for backend in self._backends.values():
            flush = getattr(backend, "flush", None)
            if flush is not None:
                flush()
