"""Fault-tolerance demo: inject a step failure mid-training; the supervisor
restores the last atomic checkpoint, rewinds the data pipeline, and the run
completes with the SAME final parameters as an uninterrupted run.

  PYTHONPATH=src python examples/fault_tolerance.py
"""
import shutil
import tempfile

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import reduced_config
from repro.data.pipeline import for_model
from repro.models.model import RunFlags
from repro.optim.adamw import AdamWConfig
from repro.runtime.health import Supervisor
from repro.train.step import init_train_state, make_train_step

STEPS, SAVE_EVERY, FAIL_AT = 24, 6, 15


def run(workdir: str, inject_failure: bool):
    cfg = reduced_config("qwen3-1.7b")
    data = for_model(cfg, seq_len=32, global_batch=4, seed=0)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, RunFlags(attn_impl="full"),
                                   AdamWConfig(peak_lr=1e-3, warmup_steps=2)))
    calls = {"n": 0}

    def maybe_flaky(s, b):
        calls["n"] += 1
        if inject_failure and calls["n"] == FAIL_AT:
            print("  !! injected device failure at call", calls["n"])
            raise RuntimeError("simulated ICI link failure")
        return step(s, b)

    ckpt = CheckpointManager(workdir, keep_n=3, async_save=False)
    sup = Supervisor(ckpt, data, save_every=SAVE_EVERY)
    out = sup.run(state, maybe_flaky, STEPS,
                  restore_fn=lambda: ckpt.restore(state),
                  on_metrics=lambda s, m: print(f"  step {s:3d} loss={float(m['loss']):.4f}")
                  if s % 6 == 0 else None)
    return out, sup.recoveries


def main() -> None:
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        print("reference run (no failure):")
        ref, _ = run(d1, inject_failure=False)
        print("\nfaulty run (failure at call 15 → restore from step 12):")
        out, recoveries = run(d2, inject_failure=True)
        same = all(
            np.allclose(a, b, atol=1e-6)
            for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(out["params"]))
        )
        print(f"\nrecoveries={recoveries}; final params identical to uninterrupted run: {same}")
        assert same and recoveries == 1
    finally:
        shutil.rmtree(d1, ignore_errors=True)
        shutil.rmtree(d2, ignore_errors=True)


if __name__ == "__main__":
    main()
