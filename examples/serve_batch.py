"""Batched serving: prefill a prompt batch, then decode tokens with the
KV/SSM cache, reporting per-phase throughput.

  PYTHONPATH=src python examples/serve_batch.py --arch qwen3-1.7b --tokens 32
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import arch_names, reduced_config
from repro.launch.serve import generate
from repro.models.model import RunFlags, init_params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=arch_names(), default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = reduced_config(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    key = jax.random.PRNGKey(1)
    if cfg.input_mode == "tokens":
        prompt = {"tokens": jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab_size)}
    else:
        prompt = {"embeds": jax.random.normal(key, (args.batch, args.prompt_len, cfg.d_model), jnp.bfloat16)}

    flags = RunFlags(attn_impl="full", ssd_chunk=8)
    t0 = time.perf_counter()
    out, _ = generate(params, cfg, prompt, n_tokens=args.tokens, flags=flags)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    print(f"{args.arch} (reduced): batch={args.batch} prompt={args.prompt_len} "
          f"generated={args.tokens}")
    print(f"sample tokens: {out[0, :10].tolist()}")
    print(f"wall={dt:.2f}s  decode throughput ≈ {args.batch*args.tokens/dt:,.1f} tok/s "
          f"(CPU, reduced config; jit compile included)")


if __name__ == "__main__":
    main()
