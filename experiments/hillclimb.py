import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: hypothesis → change → measure → validate cycles on
the three selected cells (see EXPERIMENTS.md §Perf for the selection
rationale):

  1. qwen3-moe-235b-a22b × train_4k   — worst roofline fraction, most
     collective-bound (EP all-to-all dominated)
  2. qwen3-1.7b × train_4k            — worst MODEL/executed-FLOPs ratio;
     the "small model over-TP'd on a big mesh" pathology
  3. gemma-7b × decode_32k            — memory-bound serving cell whose
     baseline cache did not fit HBM (19.6 GB temps vs 16 GB)

Each iteration records: hypothesis (napkin math), the knob changed, the
analytic/phase-sim terms before/after, and a verdict. Moves that change the
*lowered program* (sharding rules, remat, kv-quant) are additionally
compile-validated: the cell is re-lowered on the production mesh and the
compiled memory analysis + HLO collective parse are recorded next to the
baseline dry-run record.

  PYTHONPATH=src python experiments/hillclimb.py
"""
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

from repro.configs.base import SHAPES  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402
from repro.launch.autotune import apply_move, estimate  # noqa: E402
from repro.launch.dryrun import run_cell  # noqa: E402
from repro.roofline.analytic import MeshShape, model_flops  # noqa: E402
from repro.sharding.rules import DistConfig  # noqa: E402

MESH = MeshShape(16, 16)
OUT_DIR = os.path.join(os.path.dirname(__file__), "perf")
os.makedirs(OUT_DIR, exist_ok=True)


def tp_rules():
    return {
        "qkv": ("model",), "kv_qkv": ("model",), "mlp": ("model",),
        "ssm_inner": ("model",), "ssm_conv": ("model",), "expert_mlp": ("model",),
        "seq_res": ("model",), "embed": ("data",),
    }


def dp_rules():
    """TP-off lowering rules: the model axis becomes extra data parallelism."""
    return {
        "qkv": None, "kv_qkv": None, "mlp": None, "ssm_inner": None,
        "ssm_conv": None, "expert_mlp": None, "seq_res": None,
        "act_heads": None, "act_kv_heads": None,
        "batch": ("pod", "data", "model"), "exp_capacity": ("pod", "data", "model"),
        "embed": ("data",),
    }


CELLS = {
    "qwen3-moe-235b-a22b_train_4k": {
        "arch": "qwen3-moe-235b-a22b",
        "shape": "train_4k",
        "micro": 8,
        "moves": [
            ("a2a_int8", "EP all-to-all dominates t_ici (~75%): int8 dispatch payload halves it"),
            ("remat_none", "collective replay: full remat re-runs every fwd collective (mult 4→3) "
                           "→ −25% ici; hypothesis: SP keeps the larger stack affordable. "
                           "COMPILE-REFUTED: real lowering shows 214.6 GB/device temps "
                           "(remat-off saves ALL intermediates — dispatch buffers + expert "
                           "activations, not just the residual stack the napkin math counted). "
                           "Reverted; see EXPERIMENTS.md §Perf."),
            ("cf_down", "capacity factor 1.25→1.0: dispatch volume ×0.8 on both a2a bytes "
                        "and expert FLOPs (dropped-token rate rises ~3%→8% on balanced load)"),
            ("grad_int8", "remaining grad_sync is fp32 reduce-scatter: EF-int8 quarters it"),
        ],
        "compile_refuted": {"remat_none"},
        "real_dist": lambda: DistConfig(
            rules=tp_rules(), microbatches=8, capacity_factor=1.0, moe_impl="shard_map"
        ),
        "real_note": "compile-validated: shard_map MoE dispatch + capacity_factor=1.0 — "
                     "temps 18.1→16.0 GB, HLO collectives 10.2→3.5 GB/visit (the dense "
                     "dispatch at cf=1.0 regressed to 97.9 GB: SPMD's scatter heuristics "
                     "flip at the power-of-two capacity — one more reason the explicit "
                     "all-to-all path is the production one). a2a_int8/grad_int8 are "
                     "payload-dtype changes modeled analytically (EF-int8 implemented in "
                     "optim/compress.py); remat=none was compile-refuted (214 GB temps)",
    },
    "qwen3-1.7b_train_4k": {
        "arch": "qwen3-1.7b",
        "shape": "train_4k",
        "micro": 4,
        "moves": [
            ("tp_off", "1.7B over 256 chips at TP=16 is boundary-collective bound "
                       "(t_ici 10× t_comp) AND re-computes kv ×16 (kv 1024-dim < 16 heads): "
                       "replicate weights, use the model axis as extra DP"),
            ("kernel_attn", "with collectives gone, compute dominates; the Pallas kernel "
                            "skips fully-masked causal blocks: attention core FLOPs ÷2"),
            ("grad_int8", "grad all-reduce is now the only collective: EF-int8 ÷4"),
        ],
        "real_dist": lambda: DistConfig(rules=dp_rules(), microbatches=4),
        "real_note": "compile-validated: TP-off rules (kernel runs on TPU only; "
                     "its flop counts are exercised in tests/test_kernels.py)",
    },
    "mistral-large-123b_train_4k": {
        "arch": "mistral-large-123b",
        "shape": "train_4k",
        "micro": 8,
        "moves": [
            ("tp_off", "kill TP boundary collectives like cell (b)? Napkin math says NO "
                       "before trying: 123B fp32+opt replicated over the model axis = "
                       "92 GB/device state — the state model rejects it (infeasible), "
                       "the knob must not fire"),
            ("ring_bidir", "TP is mandatory here, so attack the collective *schedule*: "
                           "bidirectional ring uses both torus directions → boundary "
                           "collective time ÷2"),
            ("kernel_attn", "with ici halved, compute is near-binding: causal block-skip "
                            "cuts the 88-layer attention core ÷2"),
            ("grad_int8", "FSDP grad reduce-scatter in fp32 → EF-int8 ÷4"),
        ],
        "real_dist": lambda: DistConfig(rules=tp_rules(), microbatches=8),
        "real_note": "compile-validated baseline only (ring schedule and payload dtypes "
                     "are XLA/collective-config choices, modeled analytically; the "
                     "tp_off rejection is the state-model guardrail working)",
    },
    "jamba-v0.1-52b_prefill_32k": {
        "arch": "jamba-v0.1-52b",
        "shape": "prefill_32k",
        "micro": 8,
        "moves": [],  # this cell's iterations are compile-measured (memory term)
        "real_dist": lambda: DistConfig(rules=tp_rules(), moe_impl="shard_map"),
        "real_note": (
            "memory-capacity hillclimb, compile-measured: "
            "(1) hypothesis 'SSD decay tensor (∝ chunk) dominates the 75.6 GB "
            "temps' → ssd_chunk 64→32→16 measured 75.6/77.0/79.6 GB — REFUTED; "
            "(2) buffer dump showed fp32[2.1M, 4096] MoE dispatch tensors "
            "all-gathered by SPMD's unpartitionable scatter → shard_map "
            "local-dispatch MoE (per-shard capacity + expert all-to-all) — "
            "CONFIRMED: 75.6 → 14.4 GB/device (5.3×), compile 45 s → 10 s; "
            "dense-path equivalence tested (tests/test_moe_shard_map.py)"
        ),
    },
    "gemma-7b_decode_32k": {
        "arch": "gemma-7b",
        "shape": "decode_32k",
        "micro": 1,
        "moves": [
            ("kv_int8", "decode = KV-cache-read roofline (cache 7.5 GB/dev of 10.5 ms "
                        "t_hbm): int8+scale cache ÷1.9 bytes — also fixes the >16 GB "
                        "HBM overflow of the baseline"),
        ],
        "real_dist": lambda: DistConfig(rules=tp_rules(), kv_quant="int8"),
        "real_note": "compile-validated: int8 cache halves compiled argument+temp bytes "
                     "(accuracy: ≤1.3% logit error, tests/test_train_serve-adjacent check)",
    },
}


def run_one(tag: str, spec: dict) -> dict:
    cfg = get_config(spec["arch"])
    shape = SHAPES[spec["shape"]]
    dist = DistConfig(rules=tp_rules(), microbatches=spec["micro"])
    base = estimate(cfg, shape, MESH, dist)
    mf = model_flops(cfg, shape)
    frac0 = mf / MESH.chips / 197e12 / base["t_phase_sim_s"] * 100

    record = {
        "cell": tag,
        "baseline": {k: base[k] for k in (
            "t_compute_s", "t_memory_s", "t_collective_s", "t_phase_sim_s",
            "hbm_state_bytes", "dominant")},
        "baseline_roofline_frac_pct": frac0,
        "iterations": [],
    }
    print(f"\n=== {tag} ===")
    print(f"baseline: comp={base['t_compute_s']:.3e} hbm={base['t_memory_s']:.3e} "
          f"ici={base['t_collective_s']:.3e} sim={base['t_phase_sim_s']:.3e} "
          f"dom={base['dominant']} frac={frac0:.1f}%")

    cur, cur_t = dist, base
    refuted = spec.get("compile_refuted", set())
    for knob, hypothesis in spec["moves"]:
        applied = apply_move(cur, knob)
        if applied is None:
            print(f"  [skip] {knob} inapplicable")
            continue
        cand, auto_hyp = applied
        cand_t = estimate(cfg, shape, MESH, cand)
        improved = cand_t["t_phase_sim_s"] < cur_t["t_phase_sim_s"] * 0.999
        verdict = "confirmed" if improved else "refuted"
        if cand_t["hbm_state_bytes"] > 16e9 and cand_t["hbm_state_bytes"] > cur_t["hbm_state_bytes"] * 1.5:
            verdict = (
                f"rejected (HBM wall: {cand_t['hbm_state_bytes']/1e9:.0f} GB/device state)"
            )
            improved = False
        if knob in refuted:
            verdict = "compile-refuted"  # analytic win overturned by real lowering
            improved = False
        frac = mf / MESH.chips / 197e12 / cand_t["t_phase_sim_s"] * 100
        it = {
            "knob": knob,
            "hypothesis": hypothesis,
            "before": {k: cur_t[k] for k in ("t_compute_s", "t_memory_s", "t_collective_s", "t_phase_sim_s")},
            "after": {k: cand_t[k] for k in ("t_compute_s", "t_memory_s", "t_collective_s", "t_phase_sim_s")},
            "dominant_after": cand_t["dominant"],
            "roofline_frac_pct": frac,
            "verdict": verdict,
        }
        record["iterations"].append(it)
        print(f"  {knob:12s} sim {cur_t['t_phase_sim_s']:.3e} -> {cand_t['t_phase_sim_s']:.3e} "
              f"({cur_t['t_phase_sim_s']/cand_t['t_phase_sim_s']:.2f}x) "
              f"dom->{cand_t['dominant']} frac={frac:.1f}%  [{verdict}]")
        if improved:
            cur, cur_t = cand, cand_t

    record["tuned"] = {k: cur_t[k] for k in (
        "t_compute_s", "t_memory_s", "t_collective_s", "t_phase_sim_s",
        "hbm_state_bytes", "dominant")}
    record["speedup_estimate"] = base["t_phase_sim_s"] / cur_t["t_phase_sim_s"]
    record["tuned_roofline_frac_pct"] = mf / MESH.chips / 197e12 / cur_t["t_phase_sim_s"] * 100

    # ---- real compile validation ----------------------------------------
    print(f"  [compile-validate] {spec['real_note']}")
    real = run_cell(spec["arch"], spec["shape"], multi_pod=False,
                    dist=spec["real_dist"](), verbose=False)
    record["real_tuned_dryrun"] = {
        "ok": real["ok"],
        "memory": real.get("memory"),
        "collectives": real.get("collectives"),
        "error": real.get("error"),
    }
    base_path = os.path.join(
        os.path.dirname(__file__), "dryrun", f"{spec['arch']}_{spec['shape']}_16x16.json"
    )
    if os.path.exists(base_path):
        b = json.load(open(base_path))
        record["real_baseline_dryrun"] = {
            "memory": b.get("memory"), "collectives": b.get("collectives")
        }
        bm, tm = b.get("memory", {}), real.get("memory", {})
        bc, tc = b.get("collectives", {}), real.get("collectives", {})
        if real["ok"]:
            print(f"    temp {bm.get('temp_bytes',0)/1e9:.1f} -> {tm.get('temp_bytes',0)/1e9:.1f} GB | "
                  f"args {bm.get('argument_bytes',0)/1e9:.1f} -> {tm.get('argument_bytes',0)/1e9:.1f} GB | "
                  f"hlo collectives(1-visit) {bc.get('total',0)/1e9:.2f} -> {tc.get('total',0)/1e9:.2f} GB")
        else:
            print(f"    REAL VALIDATION FAILED: {real.get('error')}")
    return record


def run_dse_campaign(seeds=(1, 2, 3), max_iterations=400) -> dict:
    """§DSE hillclimb on the Campaign API: the same hypothesis→measure cycle
    the cells above run on sharding knobs, applied to the SoC explorer — a
    multi-seed × awareness grid per AR workload, every live exploration's
    neighbour batch cross-batched through one shared `JaxBatchedBackend`
    dispatch stream (the scalar-Python campaign is re-run as the baseline
    measurement). Writes perf/dse_campaign.json.

      PYTHONPATH=src python experiments/hillclimb.py --dse
    """
    from repro.core import Campaign, HardwareDatabase, ar_complex, calibrated_budget

    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    record = {"seeds": list(seeds), "max_iterations": max_iterations, "backends": {}}
    for backend in ("python", "jax"):
        camp = Campaign.sweep(
            db, {g.name: g}, bud, seeds=seeds,
            awareness=("farsi", "sa"), backend=backend,
            max_iterations=max_iterations,
        )
        res = camp.run()
        stats = res.backend_stats[g.name]
        record["backends"][backend] = {
            "aggregate": res.aggregate,
            "wall_s": res.wall_s,
            "n_dispatches": stats.n_dispatches,
            "sims_per_dispatch": stats.n_sims / max(stats.n_dispatches, 1),
            "sim_wall_s": stats.wall_s,
            "n_compiles": stats.n_compiles,
            "sim_wall_per_sim_ms": stats.wall_s / max(stats.n_sims, 1) * 1e3,
            "runs": {
                name: {
                    "converged": r.converged,
                    "iterations": r.iterations,
                    "n_sims": r.n_sims,
                    "best_distance": r.best_distance.city_block(),
                }
                for name, r in res.runs.items()
            },
        }
        print(f"[dse:{backend}] runs={int(res.aggregate['n_runs'])} "
              f"converged={int(res.aggregate['n_converged'])} "
              f"sims={int(res.aggregate['n_sims_total'])} "
              f"dispatches={stats.n_dispatches} wall={res.wall_s:.1f}s "
              f"sim_wall={stats.wall_s:.1f}s")
    py, jx = record["backends"]["python"], record["backends"]["jax"]
    record["sim_wall_speedup"] = py["sim_wall_s"] / max(jx["sim_wall_s"], 1e-9)
    # float32 flips some SA accepts, so the two grids walk different
    # trajectories and sim *counts* differ — per-sim throughput is the
    # backend comparison; sim_wall_speedup is the whole-grid outcome
    record["per_sim_speedup"] = (
        py["sim_wall_per_sim_ms"] / max(jx["sim_wall_per_sim_ms"], 1e-9)
    )
    path = os.path.join(OUT_DIR, "dse_campaign.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=2, default=str)
    print(f"wrote {path} (jax vs python: {record['per_sim_speedup']:.2f}x per-sim, "
          f"{record['sim_wall_speedup']:.2f}x whole-grid, "
          f"{jx['n_compiles']} jit compiles)")
    return record


def main() -> None:
    if "--dse" in sys.argv:
        run_dse_campaign()
        return
    out = {}
    for tag, spec in CELLS.items():
        out[tag] = run_one(tag, spec)
    with open(os.path.join(OUT_DIR, "hillclimb.json"), "w") as f:
        json.dump(out, f, indent=2, default=str)
    print(f"\nwrote {os.path.join(OUT_DIR, 'hillclimb.json')}")
    for tag, r in out.items():
        print(f"{tag}: {r['speedup_estimate']:.2f}x est, "
              f"frac {r['baseline_roofline_frac_pct']:.1f}% -> {r['tuned_roofline_frac_pct']:.1f}%, "
              f"real_ok={r['real_tuned_dryrun']['ok']}")


if __name__ == "__main__":
    main()
