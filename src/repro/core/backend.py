"""Pluggable simulation backends: one batched ``evaluate`` API.

The paper's headline claim is an *agile* simulator (8,400X vs Platform
Architect at 98.5% accuracy) driving the DSE, and its own profile (Fig. 8)
puts 79.9% of exploration time in design evaluation overhead. This module
makes the evaluator a pluggable component behind a single batched interface
so the search loop never cares how a design is priced:

  ``PythonBackend``     — the reference phase-driven simulator
                          (`phase_sim.simulate`), one design at a time.
  ``JaxBatchedBackend`` — flat-array encodings evaluated under `vmap` in one
                          XLA dispatch per batch (`phase_sim_jax`), with a
                          jit cache keyed on power-of-two padded
                          slot/batch/NoC-chain shapes. Multi-NoC chains are
                          encoded natively (NoC fork/join moves are ordinary
                          deltas); the transparent per-design fallback to the
                          Python path remains only for shapes the encoding
                          cannot host (``UnsupportedDesignError`` — chains
                          beyond ``phase_sim_jax.MAX_NOC``).

The DSE hot path is :meth:`evaluate_candidates`: the explorer submits
lightweight :class:`Candidate` records (base design + recorded move delta —
no cloned object graphs), the backend applies each delta onto the cached
encoding of the base (`phase_sim_jax.apply_delta`) inside persistent
preallocated shape-bucket buffers, and one non-blocking dispatch returns
:class:`SimHandle` objects. A handle's Eq.-7 ``fitness`` (computed on
device) and scalar PPA columns are one small host transfer for the whole
batch; the full ``SimResult`` (per-task finish/bottleneck/energy dicts) is
reconstructed lazily on first ``result()`` — only the candidate the explorer
accepts ever pays the decode.

``evaluate(designs)`` stays as the eager compatibility wrapper (it decodes
everything). Both backends must agree on latency/finish times (asserted in
tests/test_backend_campaign.py); simulation-count and wall-clock accounting
live here, in ``BackendStats``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from .blocks import BlockKind
from .budgets import Budget, Distance, distance
from .database import HardwareDatabase
from .design import Design
from .moves import MoveDelta, MoveSpec, apply_spec
from .phase_sim import SimResult, simulate
from .scal_layout import (
    KIND_START as _KIND_START,
    KIND_STOP as _KIND_STOP,
    N_SCAL as _N_FIXED_SCAL,
    SCAL_PREFIX as _SCAL_COLS,
    TOP_MEM_COL as _TOP_MEM_COL,
    TOP_PE_COL as _TOP_PE_COL,
)
from .ppa import total_leakage_w
from .tdg import TaskGraph, workload_of

_BNECK_KINDS = ("pe", "mem", "noc")


@dataclasses.dataclass
class BackendStats:
    """Evaluation accounting — the backend owns n_sims and sim wall-clock.

    ``wall_s`` covers time inside ``evaluate``/``evaluate_candidates``;
    the encode/dispatch/decode breakdown splits the JAX hot path: host-side
    delta encoding into the batch buffers, XLA dispatch submission (async —
    device time is hidden behind it), and lazy ``SimResult`` reconstruction
    (paid per *accessed* handle, possibly after the dispatch returns, so
    ``decode_s`` is not a subset of ``wall_s``).

    ``n_inflight_max`` is the deepest the dispatch pipeline ever got: the
    number of dispatches simultaneously un-consumed on device. ≥ 2 means a
    later batch was encoded+submitted while an earlier one was still being
    scored — the host-encode/device-compute overlap multi-session serving
    relies on (many sessions' batches in flight at once)."""

    n_sims: int = 0  # designs evaluated (cache-served candidates included)
    n_dispatches: int = 0  # evaluate() calls
    n_batched: int = 0  # designs through the vectorized path
    n_fallback: int = 0  # designs through the scalar Python path
    n_compiles: int = 0  # distinct padded shapes seen by the jit cache
    n_inflight_max: int = 0  # deepest concurrent-dispatch pipeline seen
    # content-addressed evaluation cache (serve.DesignStore, when attached):
    # hits never dispatch a device row — they are served from a memoized row
    # of an earlier identical (encoding, workload, budget) evaluation or
    # alias a duplicate row inside the same dispatch; bypasses are scalar-
    # fallback candidates the cache cannot host. All zero with no store.
    n_cache_hits: int = 0
    n_cache_misses: int = 0  # rows dispatched and registered in the store
    n_cache_bypass: int = 0
    # rows whose device fitness came back NaN/Inf at the host scal pull —
    # the serve layer's non-finite guard rejects these; a nonzero count on a
    # healthy backend means a numerical escape worth investigating
    n_nonfinite_rows: int = 0
    wall_s: float = 0.0  # total time inside evaluate()
    encode_s: float = 0.0  # incremental encoding into batch buffers
    dispatch_s: float = 0.0  # XLA dispatch submission
    decode_s: float = 0.0  # lazy SimResult reconstruction + score fetches


@dataclasses.dataclass
class Candidate:
    """One design to price: a shared *base* design plus an optional recorded
    move. The move is replayed (``apply_spec``) only when a full ``Design``
    is needed — python fallback, lazy decode, or explorer acceptance; the
    vectorized path prices the candidate straight from ``delta`` without
    ever materializing the object graph."""

    base: Design
    spec: Optional[MoveSpec] = None
    delta: Optional[MoveDelta] = None
    budget: Optional[Budget] = None  # enables device-side Eq.-7 fitness
    alpha: float = 0.05

    @staticmethod
    def of_design(design: Design, budget: Optional[Budget] = None,
                  alpha: float = 0.05) -> "Candidate":
        return Candidate(base=design, budget=budget, alpha=alpha)

    def vectorizable(self) -> bool:
        """True when the *resulting* design stays inside the encodable
        regime (a chain of at most ``phase_sim_jax.MAX_NOC`` NoCs) and (for
        moved candidates) the delta path can encode it — topology moves
        included, since NoC fork/join record chain/attachment edits."""
        from .phase_sim_jax import MAX_NOC

        n = len(self.base.noc_chain)
        if self.spec is not None:
            if self.delta is None or self.delta.topology:
                return False
            blocks = self.base.blocks
            for b in self.delta.added:
                n += b.kind == BlockKind.NOC
            for name in self.delta.removed:
                blk = blocks.get(name)
                n -= blk is not None and blk.kind == BlockKind.NOC
        return 1 <= n <= MAX_NOC

    def _replay(self, tdg: TaskGraph) -> None:
        """Replay the recorded move, then rename any block the replay minted
        back to the name recorded in the delta: every materialization of
        this candidate — pricing fallback, lazy decode, and the final
        ``accept`` — must agree on block names, or the decoded
        ``SimResult``'s per-task block references would dangle in the
        accepted design."""
        before = None
        if self.delta is not None and self.delta.added:
            before = set(self.base.blocks)
        ok = apply_spec(self.base, tdg, self.spec)
        assert ok, f"recorded move failed to replay: {self.spec}"
        if before is not None:
            minted = [n for n in self.base.blocks if n not in before]
            for fresh, rec in zip(minted, self.delta.added):
                if fresh != rec.name:
                    self.base.rename_block(fresh, rec.name)

    @contextlib.contextmanager
    def materialized(self, tdg: TaskGraph) -> Iterator[Design]:
        """Temporarily turn the candidate into a real ``Design`` (apply the
        recorded move in place, roll back on exit). The base must be in the
        state it had when the move was recorded — the explorer guarantees
        that by materializing/accepting before mutating ``cur``."""
        if self.spec is None:
            yield self.base
            return
        ck = self.base.checkpoint()
        self._replay(tdg)
        try:
            yield self.base
        finally:
            self.base.restore(ck)

    def accept(self, tdg: TaskGraph) -> None:
        """Apply the recorded move to the base permanently (the one full
        materialization the whole batch pays)."""
        if self.spec is not None:
            self._replay(tdg)


@runtime_checkable
class SimHandle(Protocol):
    """Lazy result of pricing one candidate."""

    @property
    def fitness(self) -> float:
        """Eq.-7 distance-to-budget fitness (requires Candidate.budget)."""
        ...

    def scalars(self) -> Dict[str, float]:
        """Cheap PPA columns: latency_s / power_w / area_mm2 (no decode)."""
        ...

    def result(self) -> SimResult:
        """Full SimResult; reconstructed on first access."""
        ...

    def telemetry(self) -> "SimTelemetry":
        """Selection-grade view (device bottleneck columns + Eq.-7
        distance) — what the heuristic-policy layer reasons over instead of
        a full decode. Same validity contract as ``result()``: the
        candidate's base design must be in its priced (pre-accept) state."""
        ...

    def result_for(self, design: Design) -> SimResult:
        """Decode against an explicitly provided materialized design — for
        consumers (the explorer's final best-design decode) that read a
        handle long after the candidate's base has mutated past it."""
        ...


@runtime_checkable
class SimulatorBackend(Protocol):
    """Anything that prices a batch of designs for one task graph."""

    name: str
    tdg: TaskGraph
    db: HardwareDatabase

    def evaluate(self, designs: Sequence[Design]) -> List[SimResult]:
        """Simulate every design eagerly; results align with the input order."""
        ...

    def evaluate_candidates(self, cands: Sequence[Candidate]) -> List[SimHandle]:
        """Price a batch of candidates, returning lazy handles — the DSE hot
        path. The call is NON-BLOCKING on asynchronous backends (it returns
        once the dispatch is submitted; nothing crosses the device boundary
        until a handle is read), so several batches may be in flight at
        once. ``flush()`` is the only way to wait without consuming."""
        ...

    def flush(self) -> None:
        """Block until every in-flight dispatch has finished scoring.
        Synchronous backends are already drained — no-op. Call it before
        tearing a backend down or timing device work; reading any handle of
        a batch also implicitly completes that batch."""
        ...

    def supports(self, design: Design) -> bool:
        """True if ``design`` takes the backend's fast path (capability hook;
        unsupported designs must still evaluate correctly via fallback)."""
        ...

    def stats(self) -> BackendStats:
        ...


class _ReadyHandle:
    """Handle over an already-decoded SimResult (python path / fallbacks).

    Carries its candidate so ``adopt_encoding`` can tell WHOSE cached base
    encoding to invalidate when a fallback-priced move gets accepted."""

    __slots__ = ("_res", "_fitness", "_cand", "_tdg")

    def __init__(self, res: SimResult, fitness: float,
                 cand: Optional[Candidate] = None,
                 tdg: Optional[TaskGraph] = None) -> None:
        self._res = res
        self._fitness = fitness
        self._cand = cand
        self._tdg = tdg

    @property
    def fitness(self) -> float:
        return self._fitness

    def scalars(self) -> Dict[str, float]:
        return {
            "latency_s": self._res.latency_s,
            "power_w": self._res.power_w,
            "area_mm2": self._res.area_mm2,
        }

    def result(self) -> SimResult:
        return self._res

    def result_for(self, design: Design) -> SimResult:
        return self._res  # already decoded; the design played no further part

    def telemetry(self) -> "SimTelemetry":
        assert self._tdg is not None, "handle was built without its TaskGraph"
        design = self._cand.base if self._cand is not None else None
        return SimTelemetry.of_result(self._res, self._tdg, design)


def _host_fitness(res: SimResult, cand: Candidate) -> float:
    if cand.budget is None:
        return float("nan")
    return distance(res, cand.budget).fitness(cand.alpha)


class _PPAView:
    """Duck-typed stand-in for the three SimResult fields `budgets.distance`
    reads — lets a telemetry view reuse the one true Eq.-7 distance code."""

    __slots__ = ("workload_latency_s", "power_w", "area_mm2")

    def __init__(self, wl: Dict[str, float], power: float, area: float) -> None:
        self.workload_latency_s = wl
        self.power_w = power
        self.area_mm2 = area


class SimTelemetry:
    """Selection-grade view of one priced candidate — the input the
    heuristic-policy layer (`repro.core.policy`) reasons over.

    It exposes (a) the device-side bottleneck telemetry columns — per-block
    binding-bottleneck seconds, the argmax ("top bottleneck") PE/MEM block,
    and the comp-vs-comm attribution split — and (b) the per-task /
    per-metric accessors FARSI's selection reasoning needs (task durations,
    per-task dynamic energy, memory residency, per-task binding resource),
    plus the Eq.-7 ``Distance``. What it does NOT do is materialize the full
    ``SimResult`` dict set: on the JAX backend a view is a handful of
    zero-copy column reads plus an O(T) host scalar rollup, which is what
    makes the winner's full ``_decode`` policy-optional.

    Built either over an already-decoded ``SimResult`` (`of_result` — the
    Python backend and fallback-priced candidates; every accessor proxies
    the result, so policies see bit-identical floats on either backend) or
    over one row of a JAX batch's host columns (`of_row`). Row-backed
    construction snapshots the task→block maps and recomputes the
    design-dependent scalars (energy, power, area, capacities) exactly as
    the lazy decode would — shared backend helpers — so telemetry-driven
    searches take the same decisions as decode-driven ones (asserted by the
    golden-sequence policy-equivalence tests). Construction has the same
    contract as ``SimHandle.result()``: the candidate's base design must
    still be in its priced state."""

    __slots__ = (
        "_tdg", "_res", "_design",
        "latency_s", "power_w", "area_mm2",
        "_wl_lat", "_tep", "_cap",
        "_fin", "_index", "_codes", "_task_pe", "_task_mem", "_nocs",
        "_pe_names", "_mem_names", "_pe_busy", "_mem_busy", "_noc_busy",
        "_kind", "_top_pe", "_top_mem",
    )

    # ---- births ----------------------------------------------------------
    @staticmethod
    def of_result(res: SimResult, tdg: TaskGraph,
                  design: Optional[Design] = None) -> "SimTelemetry":
        t = SimTelemetry()
        t._tdg, t._res, t._design = tdg, res, design
        t.latency_s = res.latency_s
        t.power_w = res.power_w
        t.area_mm2 = res.area_mm2
        t._top_pe = t._top_mem = None  # resolved lazily through the design
        return t

    @staticmethod
    def of_row(batch: "_JaxBatch", j: int, cand: Candidate,
               backend: "JaxBatchedBackend") -> "SimTelemetry":
        out = batch.host()  # forces the batch, like any first handle read
        t = SimTelemetry()
        t._tdg, t._res, t._design = backend.tdg, None, cand.base
        t._index = backend._enc.index
        t._fin = out["finish_s"][j].tolist()
        t._codes = out["bneck_code"][j]
        t._kind = out["bneck_kind_s"][j]
        t._pe_busy = out["pe_bneck_s"][j]
        t._mem_busy = out["mem_bneck_s"][j]
        t._noc_busy = out["noc_bneck_s"][j]
        t.latency_s = float(out["latency_s"][j])
        # design-dependent snapshot: the base design is only guaranteed to be
        # in the priced state NOW, so task→block maps and the host-exact
        # scalar rollup (the same floats the lazy decode would produce) are
        # captured at construction; everything else indexes device columns
        with cand.materialized(backend.tdg) as design:
            t._tep = backend._task_energy_pj(design)
            t._cap = backend._mem_caps(design)
            t.area_mm2 = backend._area_mm2(design, t._cap)
            energy = sum(t._tep.values()) * 1e-12 + total_leakage_w(
                design, backend.db
            ) * t.latency_s
            t.power_w = energy / t.latency_s if t.latency_s > 0 else 0.0
            t._task_pe = dict(design.task_pe)
            t._task_mem = dict(design.task_mem)
            t._nocs = list(design.noc_chain)
            t._pe_names = [n for n, b in design.blocks.items()
                           if b.kind == BlockKind.PE]
            t._mem_names = [n for n, b in design.blocks.items()
                            if b.kind == BlockKind.MEM]
        t._wl_lat = backend._wl_latency(t._fin)
        t._top_pe = t._pe_names[
            min(int(out["top_bneck_pe"][j]), len(t._pe_names) - 1)]
        t._top_mem = t._mem_names[
            min(int(out["top_bneck_mem"][j]), len(t._mem_names) - 1)]
        return t

    # ---- Eq.-7 distance --------------------------------------------------
    def dist(self, budget: Budget) -> Distance:
        if self._res is not None:
            return distance(self._res, budget)
        return distance(_PPAView(self._wl_lat, self.power_w, self.area_mm2),
                        budget)

    # ---- per-task selection accessors ------------------------------------
    def task_finish_s(self, t: str) -> float:
        if self._res is not None:
            return self._res.task_finish_s.get(t, 0.0)
        return self._fin[self._index[t]]

    def task_duration(self, t: str) -> float:
        """Critical-path duration contribution: finish − latest parent
        finish (what `_task_duration` computed from a decoded result)."""
        start = max(
            (self.task_finish_s(p) for p in self._tdg.parents[t]), default=0.0
        )
        return self.task_finish_s(t) - start

    def task_energy_j(self, t: str) -> float:
        if self._res is not None:
            return self._res.task_energy_j.get(t, 0.0)
        return self._tep.get(t, 0.0) * 1e-12

    def mem_capacity(self, m: str) -> float:
        if self._res is not None:
            return self._res.mem_capacity_bytes.get(m, 0.0)
        return self._cap.get(m, 0.0)

    def task_bneck(self, t: str) -> str:
        if self._res is not None:
            return self._res.task_bottleneck.get(t, "pe")
        # codes are packed: 0/1 = pe/mem, 2 + 3·k = NoC at chain index k
        return _BNECK_KINDS[min(int(self._codes[self._index[t]]), 2)]

    def task_bneck_block(self, t: str) -> Optional[str]:
        if self._res is not None:
            return self._res.task_bottleneck_block.get(t)
        c = int(self._codes[self._index[t]])
        return self._task_pe[t] if c == 0 else (
            self._task_mem[t] if c == 1 else self._nocs[(c - 2) // 3]
        )

    # ---- device bottleneck telemetry -------------------------------------
    @property
    def comp_s(self) -> float:
        """Seconds some running task was compute-bound (kind column 'pe')."""
        if self._res is not None:
            return self._res.bottleneck_s.get("pe", 0.0)
        return float(self._kind[0])

    @property
    def comm_s(self) -> float:
        """Seconds some running task was communication-bound (mem + noc)."""
        if self._res is not None:
            b = self._res.bottleneck_s
            return b.get("mem", 0.0) + b.get("noc", 0.0)
        return float(self._kind[1] + self._kind[2])

    def _top_of_kind(self, kind: BlockKind) -> Optional[str]:
        if self._design is None:
            return None
        best, best_s = None, -1.0
        for n, b in self._design.blocks.items():
            if b.kind == kind:
                s = self._res.block_bottleneck_s.get(n, 0.0)
                if s > best_s:
                    best, best_s = n, s
        return best

    def top_bneck_pe(self) -> Optional[str]:
        """The PE accumulating the most binding-bottleneck seconds — the
        device argmax column on JAX, the host attribution otherwise."""
        if self._top_pe is None and self._res is not None:
            self._top_pe = self._top_of_kind(BlockKind.PE)
        return self._top_pe

    def top_bneck_mem(self) -> Optional[str]:
        if self._top_mem is None and self._res is not None:
            self._top_mem = self._top_of_kind(BlockKind.MEM)
        return self._top_mem

    def block_bneck_s(self) -> Dict[str, float]:
        """Per-block binding-bottleneck seconds (name-resolved)."""
        if self._res is not None:
            return dict(self._res.block_bottleneck_s)
        out = {n: float(self._pe_busy[i]) for i, n in enumerate(self._pe_names)}
        out.update(
            (n, float(self._mem_busy[i])) for i, n in enumerate(self._mem_names)
        )
        out.update(
            (n, float(self._noc_busy[i])) for i, n in enumerate(self._nocs)
        )
        return out


class PythonBackend:
    """Scalar reference path: `phase_sim.simulate` per design."""

    name = "python"
    async_dispatch = False  # evaluates inline: nothing to pipeline behind

    def __init__(self, tdg: TaskGraph, db: HardwareDatabase) -> None:
        self.tdg = tdg
        self.db = db
        self._stats = BackendStats()

    def supports(self, design: Design) -> bool:
        return True

    def flush(self) -> None:
        """Synchronous backend: every evaluate() already returned results."""

    def evaluate(self, designs: Sequence[Design]) -> List[SimResult]:
        t0 = time.perf_counter()
        out = [simulate(d, self.tdg, self.db) for d in designs]
        self._stats.n_sims += len(out)
        self._stats.n_dispatches += 1
        self._stats.wall_s += time.perf_counter() - t0
        return out

    def evaluate_candidates(self, cands: Sequence[Candidate]) -> List[SimHandle]:
        t0 = time.perf_counter()
        out: List[SimHandle] = []
        for c in cands:
            with c.materialized(self.tdg) as d:
                res = simulate(d, self.tdg, self.db)
            out.append(_ReadyHandle(res, _host_fitness(res, c), c, self.tdg))
        self._stats.n_sims += len(out)
        self._stats.n_dispatches += 1
        self._stats.wall_s += time.perf_counter() - t0
        return out

    def stats(self) -> BackendStats:
        return self._stats


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _bucket(n: int) -> int:
    """Padded-size bucket: power of two, floored at 4. Compile time per shape
    dwarfs the padded FLOPs on these tiny kernels, so we buy a near-constant
    shape space (slots and batch rarely leave {4, 8, 16, 32, 64}) with
    padding — but the floor matters on the batch axis: the explorer's
    neighbour batches are ≤ 4 candidates, and padding them to 8 doubled the
    device time the serial loop stalls on."""
    return max(4, _pow2(n))


# layout of the device-packed scalar column block: the jit wrapper stacks
# every per-design scalar into ONE (B, N_SCAL + 2·S + N) matrix, so a batch
# crosses the device boundary as 3 leaves (scal, finish_s, bneck_code) —
# per-leaf transfer + pytree overhead was a measurable slice of the
# explorer's serial iteration. Column order IS ``core.scal_layout`` (the
# single source of truth the Pallas kernel's packed block also derives
# from), so on the kernel path the ops-layer unpack and this repack fold
# to a no-op under jit and a future column lands identically in both —
# `python -m repro.analysis` contract ``scal-cols`` guards the coupling.
# Fixed columns first (the SCAL_PREFIX scalars, then bneck_kind_s, then
# the top-bottleneck slot pair); the per-block bottleneck-seconds
# telemetry (pe_bneck_s, mem_bneck_s — S padded slots each — then
# noc_bneck_s over the N padded chain positions) rides in the
# variable-width tail, split on host via the batch's recorded (S, N) dims.
# (_SCAL_COLS / _N_FIXED_SCAL and the unpack indices are imported from
# core.scal_layout at the top of this module.)


class _JaxBatch:
    """Shared state of one dispatch: device outputs + one memoized host pull.

    The dispatch is non-blocking — nothing transfers until a handle asks.
    The first consumer (any handle's ``fitness``) triggers exactly ONE
    stacked ``device_get`` of the packed output dict: one host↔device sync
    per batch, total. Padded-bucket batches are a few tens of KB, so the
    stacked transfer costs less than a single per-column ``np.asarray``
    used to (each of those paid jit-slicing overhead plus its own sync);
    per-task *dicts* are still only materialized by ``result()``, per
    accessed handle. ``consumed`` flips on the pull — the backend uses it
    to retire the batch from its in-flight pipeline accounting (a completed
    transfer implies the dispatch finished computing)."""

    __slots__ = ("out", "stats", "eds", "dims", "_host", "consumed")

    def __init__(self, out, stats: BackendStats, eds, dims) -> None:
        self.out = out
        self.stats = stats
        self.eds = eds  # per-row EncodedDesign (for adopt_encoding)
        self.dims = dims  # (padded slot count S, padded NoC count N)
        self._host: Optional[Dict[str, np.ndarray]] = None
        self.consumed = False

    def host(self) -> Dict[str, np.ndarray]:
        """The whole batch output on host: one stacked device_get, unpacked
        into the standard output keys as zero-copy column views."""
        if self._host is None:
            import jax

            t0 = time.perf_counter()
            raw = jax.device_get(self.out)
            scal = raw["scal"]
            host = {name: scal[:, i] for i, name in enumerate(_SCAL_COLS)}
            host["bneck_kind_s"] = scal[:, _KIND_START:_KIND_STOP]
            host["top_bneck_pe"] = scal[:, _TOP_PE_COL]
            host["top_bneck_mem"] = scal[:, _TOP_MEM_COL]
            s_busy, n_noc = self.dims
            f = _N_FIXED_SCAL
            host["pe_bneck_s"] = scal[:, f:f + s_busy]
            host["mem_bneck_s"] = scal[:, f + s_busy:f + 2 * s_busy]
            host["noc_bneck_s"] = scal[:, f + 2 * s_busy:f + 2 * s_busy + n_noc]
            host["finish_s"] = raw["finish_s"]
            host["bneck_code"] = raw["bneck_code"]
            # non-finite guard accounting: a NaN/Inf fitness row is the
            # device-side symptom the serve layer must never accept (real
            # rows only — the pow2 pad rows replicate row 0)
            fit = host["fitness"][: len(self.eds)]
            bad = int(np.size(fit) - np.count_nonzero(np.isfinite(fit)))
            if bad:
                self.stats.n_nonfinite_rows += bad
            self._host = host
            self.consumed = True
            self.stats.decode_s += time.perf_counter() - t0
        return self._host

    def fitness(self) -> np.ndarray:
        return self.host()["fitness"]


class _CachedBatch:
    """Duck-typed one-row ``_JaxBatch`` over a memoized store row.

    A `serve.DesignStore` hit serves a candidate from the host columns of an
    earlier identical evaluation. Wrapping that row (leading axis 1) behind
    the ``host()/fitness()`` batch interface lets the ordinary ``_JaxHandle``
    machinery — fitness, scalars, telemetry, lazy decode, ``adopt_encoding``
    — read it through the exact same code path as a fresh dispatch, so a
    cache hit is bit-identical to the dispatch it memoized. ``eds`` carries
    the *consumer's* encoding (computed anyway to derive the cache key): the
    producer's encoding may map different block names onto the same arrays,
    and adoption must stay keyed to the consumer's own design."""

    __slots__ = ("_row", "stats", "eds", "dims", "consumed")

    def __init__(self, row: Dict[str, np.ndarray], stats: BackendStats, ed) -> None:
        self._row = row
        self.stats = stats
        self.eds = [ed]
        self.dims = None  # host() is pre-unpacked; dims only split raw scal
        self.consumed = True  # nothing in flight: the row is already host-side

    def host(self) -> Dict[str, np.ndarray]:
        return self._row

    def fitness(self) -> np.ndarray:
        return self._row["fitness"]


class _JaxHandle:
    """Lazy handle into one row of a `_JaxBatch`."""

    __slots__ = ("_batch", "_j", "_cand", "_backend", "_res", "_ed")

    def __init__(
        self, batch: _JaxBatch, j: int, cand: Candidate, backend, ed=None
    ) -> None:
        self._batch = batch
        self._j = j
        self._cand = cand
        self._backend = backend
        self._res: Optional[SimResult] = None
        # adoption override: a row shared across candidates (same-dispatch
        # cache alias) carries THIS consumer's encoding here — the row
        # owner's `eds[j]` may map different block names to the same arrays
        self._ed = ed

    @property
    def fitness(self) -> float:
        return float(self._batch.fitness()[self._j])

    def scalars(self) -> Dict[str, float]:
        s = self._batch.host()
        return {k: float(s[k][self._j]) for k in ("latency_s", "power_w", "area_mm2")}

    def result(self) -> SimResult:
        if self._res is None:
            t0 = time.perf_counter()
            with self._cand.materialized(self._backend.tdg) as design:
                self._res = self._decode_against(design)
            self._batch.stats.decode_s += time.perf_counter() - t0
        return self._res

    def result_for(self, design: Design) -> SimResult:
        """Decode against a caller-provided materialized design (e.g. the
        explorer's best-design snapshot, long after the candidate's base
        moved on). Bypasses — and does not populate — the memoized
        ``result()``."""
        t0 = time.perf_counter()
        res = self._decode_against(design)
        self._batch.stats.decode_s += time.perf_counter() - t0
        return res

    def _decode_against(self, design: Design) -> SimResult:
        out, j = self._batch.host(), self._j
        return self._backend._decode(
            design,
            float(out["latency_s"][j]),
            out["finish_s"][j],
            out["bneck_code"][j],
            out["bneck_kind_s"][j],
            out["pe_bneck_s"][j],
            out["mem_bneck_s"][j],
            out["noc_bneck_s"][j],
            float(out["alp_time_s"][j]),
            float(out["traffic_bytes"][j]),
            int(out["n_phases"][j]),
        )

    def telemetry(self) -> SimTelemetry:
        t0 = time.perf_counter()
        tel = SimTelemetry.of_row(self._batch, self._j, self._cand, self._backend)
        self._batch.stats.decode_s += time.perf_counter() - t0
        return tel


class JaxBatchedBackend:
    """One batched dispatch per batch of candidates (multi-NoC included).

    Latency/finish times and the Eq.-7 fitness come from the vectorized
    phase+scoring kernel; the rest of ``SimResult`` is reconstructed exactly
    on the host, lazily: PPA rollups are O(blocks) closed forms, and per-task
    dynamic energy depends only on total drained work (every task runs to
    completion) and its route hop count, not on phase rates. Chain
    topologies are encoded natively up to ``phase_sim_jax.MAX_NOC`` NoCs —
    topology moves (NoC fork/join) price on device like any other move;
    only shapes the encoding cannot host (``UnsupportedDesignError``) fall
    back to the Python simulator per design, inside the same
    ``evaluate_candidates`` call.

    Two device formulations of the same math sit behind the jit cache:

      * ``use_kernel=False`` — `phase_sim_jax.simulate_batch`, the `vmap`-of-
        `fori_loop` XLA reference;
      * ``use_kernel=True`` — the fused Pallas launch
        (`repro.kernels.phase_sim`): one kernel over the (B, T) grid with
        the co-residency masks in VMEM scratch (Mosaic on TPU, interpret
        mode elsewhere — interpret trades speed for exercising the real
        kernel path, which is why CPU defaults to the XLA reference).

    ``use_kernel=None`` resolves from ``REPRO_PHASE_SIM_KERNEL`` (``1``
    forces the kernel, ``0`` forbids it) and otherwise turns it on exactly
    when running on TPU.

    Dispatch is asynchronous and multi-dispatch-capable:
    ``evaluate_candidates`` returns after submission, host batch buffers are
    double-buffered per shape bucket (on CPU, XLA may alias the numpy input
    rather than copy — the *next* encode must not scribble over a buffer an
    in-flight dispatch is still reading), and ``flush()`` drains whatever is
    outstanding. For the device-resident explorer, :meth:`run_chains`
    prices a whole fused (R, K) chain block per dispatch
    (`repro.core.device_explore`)."""

    name = "jax"
    async_dispatch = True  # dispatch returns before the device scores it

    def __init__(
        self, tdg: TaskGraph, db: HardwareDatabase,
        use_kernel: Optional[bool] = None,
    ) -> None:
        import os

        import jax

        from .phase_sim_jax import EncodedWorkload

        self.tdg = tdg
        self.db = db
        self._enc = EncodedWorkload.of(tdg)
        if use_kernel is None:
            env = os.environ.get("REPRO_PHASE_SIM_KERNEL", "").lower()
            if env in ("1", "true"):
                use_kernel = True
            elif env in ("0", "false"):
                use_kernel = False
            else:
                use_kernel = jax.default_backend() == "tpu"
        self._use_kernel = bool(use_kernel)
        self._interpret = jax.default_backend() != "tpu"
        if self._use_kernel:
            self.name = "jax_pallas"
        self._jit = None  # single kernel: shapes vary only via padded buckets
        # shape bucket -> two alternating host rows buffers (double-buffered
        # so a fresh encode never mutates what the device may still read)
        self._buffers: Dict[tuple, List[Optional[Dict[str, np.ndarray]]]] = {}
        self._bufsel: Dict[tuple, int] = {}
        # (bucket, buffer-slot) -> (base_ed, budget, dirty cells) enabling the
        # steady-state restore-only refill (see _evaluate_batch)
        self._buf_state: Dict[tuple, tuple] = {}
        # (bucket, buffer-slot) -> the _JaxBatch that last read the slot
        # (reuse guard against >2-deep callers overwriting aliased inputs)
        self._buf_owner: Dict[tuple, _JaxBatch] = {}
        self._inflight: List[_JaxBatch] = []
        # content-addressed evaluation cache (serve.DesignStore) — opt-in
        # via attach_store(); None keeps the historic uncached behaviour
        self._store = None
        self._wl_digest: Optional[bytes] = None
        # id(design) -> (design, EncodedDesign) adopted via adopt_encoding;
        # the design ref doubles as an identity guard against id() reuse
        self._adopted: Dict[int, tuple] = {}
        self._shapes: set = set()
        self._stats = BackendStats()
        # device-resident chain runner (device_explore) — built lazily so
        # host-loop users never pay for it; shares the workload encoding
        self._chains = None
        # static per-task tables for host-side SimResult reconstruction:
        # totals are design-independent; only the block subtype scales energy
        names = self._enc.names
        self._ops = [float(tdg.tasks[n].work_ops) for n in names]
        self._rw = [float(tdg.tasks[n].read_bytes + tdg.tasks[n].write_bytes) for n in names]
        self._wbytes = [float(tdg.tasks[n].write_bytes) for n in names]
        self._wl_of = [workload_of(n) if "." in n else tdg.name for n in names]
        e = db.energy
        self._pe_pj = {"acc": e.acc_pj_per_op, "gpp": e.gpp_pj_per_op}
        self._mem_pj = {"sram": e.sram_pj_per_byte, "dram": e.dram_pj_per_byte}
        self._noc_pj = e.noc_pj_per_byte_hop

    def supports(self, design: Design) -> bool:
        from .phase_sim_jax import MAX_NOC

        return 1 <= len(design.noc_chain) <= MAX_NOC

    def stats(self) -> BackendStats:
        return self._stats

    def attach_store(self, store) -> None:
        """Attach a content-addressed evaluation cache (`serve.DesignStore`).
        Every subsequent vectorizable candidate is keyed on
        ``hash(EncodedDesign leaves, workload, budget)``: key hits are served
        from the memoized row of an earlier identical evaluation (no device
        row dispatched — bit-identical scalars, see ``_CachedBatch``),
        duplicate keys *within* one batch alias a single dispatched row, and
        every freshly dispatched row is registered for future sessions. The
        store may be shared across backends/workloads (the workload digest
        namespaces the keys)."""
        self._store = store
        self._wl_digest = store.workload_digest(self._enc) if store is not None else None

    def _note_bypass(self) -> None:
        """A candidate the cache cannot host (scalar fallback — no device
        row to memoize). Counted only while a store is attached."""
        if self._store is not None:
            self._stats.n_cache_bypass += 1
            self._store.note_bypass()

    def flush(self) -> None:
        """Drain the dispatch pipeline: block until every outstanding batch
        has been scored (e.g. batches a finished session never consumed)."""
        import jax

        for batch in self._inflight:
            if not batch.consumed:
                jax.block_until_ready(batch.out["scal"])
                batch.consumed = True
        self._inflight.clear()

    def chain_runner(self):
        """The lazily-built :class:`~repro.core.device_explore.
        DeviceChainRunner` this backend prices chain blocks with. Shares the
        workload encoding and kernel selection; owns its own jit cache and
        compile/fallback counters (the bench smoke guard asserts on them)."""
        if self._chains is None:
            from .device_explore import DeviceChainRunner

            self._chains = DeviceChainRunner(
                self.tdg, self.db, self._enc,
                use_kernel=self._use_kernel, interpret=self._interpret,
            )
        return self._chains

    def run_chains(self, req):
        """Price one fused (R, K) exploration block
        (:class:`~repro.core.device_explore.ChainRequest` in,
        :class:`~repro.core.device_explore.ChainBlockResult` out) — the
        device-resident counterpart of ``evaluate_candidates``: one dispatch
        runs K accept/reject iterations for R chains. Counted in the backend
        stats as R·K simulated designs in one dispatch."""
        runner = self.chain_runner()
        t0 = time.perf_counter()
        res = runner.run_chains(
            req.design, req.budget, r=req.r, k=req.k, seed=req.seed,
            it0=req.it0, menu=req.menu, alpha=req.alpha,
            temperature0=req.temperature0, temp_decay=req.temp_decay,
            taboo_ttl=req.taboo_ttl, carry=req.carry, alloc=req.alloc,
            cap_pe=req.cap_pe, cap_mem=req.cap_mem,
        )
        self._stats.n_sims += req.r * req.k
        self._stats.n_batched += req.r * req.k
        self._stats.n_dispatches += 1
        self._stats.wall_s += time.perf_counter() - t0
        return res

    def adopt_encoding(self, handle: SimHandle) -> None:
        """Promote ``handle``'s row encoding to be its base design's cached
        encoding for future dispatches. The explorer calls this right after
        accepting a move (`Candidate.accept` has just mutated the base to
        exactly the state the row's delta-encoding describes —
        ``apply_delta`` is bit-identical to a from-scratch encode), so the
        per-dispatch ``EncodedDesign.of`` walk disappears from the steady
        state: rejected iterations reuse the adopted base, accepted ones
        adopt the winner. Only the caller may mutate the design afterwards,
        and only through another accept+adopt.

        A winner priced through the Python FALLBACK (e.g. a topology move)
        has no row encoding — accepting it still mutates the base, so the
        call must *invalidate* any previously adopted encoding for that
        design instead of silently keeping a stale one (that exact staleness
        produced phantom missing-block KeyErrors in multi-hundred-iteration
        campaigns before the invalidation existed)."""
        cand = getattr(handle, "_cand", None)
        if cand is None:
            return  # foreign handle: no candidate, nothing to (in)validate
        if not isinstance(handle, _JaxHandle) or handle._batch.eds is None:
            self._adopted.pop(id(cand.base), None)
            return
        if len(self._adopted) > 512:  # bound design refs kept alive
            self._adopted.clear()
        ed = handle._ed if handle._ed is not None else handle._batch.eds[handle._j]
        self._adopted[id(cand.base)] = (cand.base, ed)

    def _track_inflight(self, batch: _JaxBatch) -> None:
        # in-flight = dispatched, not yet consumed by the host. The device
        # may already have finished — the overlap claim is about SUBMISSION
        # overlapping an un-consumed predecessor, which is what hides host
        # encode behind device scoring, so readiness does not retire a batch
        # from the depth metric while the list stays short. Abandoned
        # batches (a failed session's) are never consumed; to bound the
        # list WITHOUT voiding the flush() drain guarantee, overflow first
        # sheds batches whose compute already finished (nothing left to
        # drain) and only then applies backpressure (blocks) on the oldest
        # stragglers.
        alive = [b for b in self._inflight if not b.consumed]
        if len(alive) > 7:
            import jax

            still = []
            for b in alive:
                ready = getattr(b.out["scal"], "is_ready", None)
                if ready is not None and ready():
                    continue  # finished: safe to untrack, flush owes it nothing
                still.append(b)
            for b in still[:-7]:
                jax.block_until_ready(b.out["scal"])
            alive = still[-7:]
        self._inflight = alive
        self._inflight.append(batch)
        self._stats.n_inflight_max = max(
            self._stats.n_inflight_max, len(self._inflight)
        )

    def _fn(self):
        if self._jit is None:
            import jax
            import jax.numpy as jnp

            if self._use_kernel:
                from ..kernels.phase_sim import phase_sim

                sim = lambda rows: phase_sim(self._enc, rows, interpret=self._interpret)
            else:
                from .phase_sim_jax import simulate_batch

                sim = lambda rows: simulate_batch(self._enc, rows)

            def packed(rows):
                # pack the per-design scalars into one (B, 14 + 2·S) matrix
                # on device (_SCAL_COLS + bneck_kind_s + top-bottleneck slot
                # pair + the per-slot bottleneck telemetry): 3 output leaves
                # per dispatch (wl_latency_s is dropped — the lazy decode
                # recomputes per-workload latency from finish times on
                # host). Free under jit: XLA fuses the stack.
                out = sim(rows)
                scal = jnp.stack(
                    [
                        out[k] if out[k].dtype == jnp.float32
                        else out[k].astype(jnp.float32)
                        for k in _SCAL_COLS
                    ],
                    axis=1,
                )
                tops = jnp.stack(
                    [
                        out["top_bneck_pe"].astype(jnp.float32),
                        out["top_bneck_mem"].astype(jnp.float32),
                    ],
                    axis=1,
                )
                scal = jnp.concatenate(
                    [scal, out["bneck_kind_s"], tops,
                     out["pe_bneck_s"], out["mem_bneck_s"],
                     out["noc_bneck_s"]],
                    axis=1,
                )
                return {
                    "scal": scal,
                    "finish_s": out["finish_s"],
                    "bneck_code": out["bneck_code"],
                }

            self._jit = jax.jit(packed)
        return self._jit

    # ------------------------------------------------------------------
    def evaluate(self, designs: Sequence[Design]) -> List[SimResult]:
        """Eager compatibility path: price + decode everything."""
        handles = self.evaluate_candidates([Candidate.of_design(d) for d in designs])
        return [h.result() for h in handles]

    def evaluate_candidates(self, cands: Sequence[Candidate]) -> List[SimHandle]:
        t0 = time.perf_counter()
        results: List[Optional[SimHandle]] = [None] * len(cands)
        fast = [i for i, c in enumerate(cands) if c.vectorizable()]
        fast_set = set(fast)
        for i, c in enumerate(cands):
            if i not in fast_set:
                with c.materialized(self.tdg) as d:
                    res = simulate(d, self.tdg, self.db)
                results[i] = _ReadyHandle(res, _host_fitness(res, c), c, self.tdg)
                self._stats.n_fallback += 1
                self._note_bypass()
        if fast:
            self._evaluate_batch([cands[i] for i in fast], fast, results)
        self._stats.n_sims += len(cands)
        self._stats.n_dispatches += 1
        self._stats.wall_s += time.perf_counter() - t0
        return results  # type: ignore[return-value]

    def _evaluate_batch(
        self, batch: List[Candidate], idx: List[int], results: List[Optional[SimHandle]]
    ) -> None:
        from .phase_sim_jax import (
            ENCODED_FIELDS, EncodedDesign, UnsupportedDesignError, alloc_rows,
            apply_delta, fill_budget, fill_row, fill_row_fields,
        )

        tE = time.perf_counter()
        # incremental encoding: each distinct base design is encoded once per
        # dispatch (candidates of one explorer iteration share their base),
        # then every candidate is the base row plus its recorded move delta.
        # apply_delta is copy-on-write, so `ed.f is base.f` marks untouched
        # fields — the buffer fill below broadcasts the base row per group
        # and rewrites only what each move changed.
        base_encs: Dict[int, EncodedDesign] = {}
        eds: List[EncodedDesign] = []
        keep: List[int] = []
        # content-addressed cache bookkeeping (store attached): per-row cache
        # keys to register after dispatch, same-dispatch alias rows, and the
        # batch-local key → row map that dedupes identical candidates two
        # co-batched sessions submit in one scheduler tick
        store = self._store
        row_keys: List[bytes] = []
        aliases: List[tuple] = []  # (results index, dispatched row, Candidate, ed)
        batch_rows: Dict[bytes, int] = {}
        bud_digests: Dict[tuple, bytes] = {}
        for pos, c in enumerate(batch):
            key = id(c.base)
            try:
                ed = base_encs.get(key)
                if ed is None:
                    # adopted encodings first: the explorer promotes the
                    # accepted winner's delta-encoding (bit-identical to a
                    # from-scratch encode of the mutated design), so steady-
                    # state dispatches never re-walk the base design's
                    # object graph at all
                    adopted = self._adopted.get(key)
                    if adopted is not None and adopted[0] is c.base:
                        ed = adopted[1]
                    else:
                        ed = EncodedDesign.of(c.base, self.tdg, self.db, self._enc)
                    base_encs[key] = ed
                if c.spec is not None:
                    ed = apply_delta(ed, c.delta, c.base, self.tdg, self.db, self._enc)
            except UnsupportedDesignError:
                # the typed capability check: shapes the encoding cannot
                # host route to the exact scalar path, mid-batch
                with c.materialized(self.tdg) as d:
                    res = simulate(d, self.tdg, self.db)
                results[idx[pos]] = _ReadyHandle(
                    res, _host_fitness(res, c), c, self.tdg
                )
                self._stats.n_fallback += 1
                self._note_bypass()
                continue
            if store is not None:
                bkey = (id(c.budget), c.alpha)
                bud_dig = bud_digests.get(bkey)
                if bud_dig is None:
                    bud_dig = bud_digests[bkey] = store.budget_digest(
                        c.budget, c.alpha
                    )
                ckey = store.key_of(ed, self._wl_digest, bud_dig)
                row = store.lookup(ckey)
                if row is not None:
                    # store hit: serve from the memoized row of an earlier
                    # identical evaluation — no device row dispatched. The
                    # consumer's own encoding rides along for adoption.
                    results[idx[pos]] = _JaxHandle(
                        _CachedBatch(row, self._stats, ed), 0, c, self
                    )
                    self._stats.n_cache_hits += 1
                    continue
                dup = batch_rows.get(ckey)
                if dup is not None:
                    # same-dispatch alias: an identical candidate is already
                    # in this batch — share its row instead of paying one
                    # (the consumer's own ed rides along for adoption)
                    aliases.append((idx[pos], dup, c, ed))
                    self._stats.n_cache_hits += 1
                    store.note_alias_hit()
                    continue
                batch_rows[ckey] = len(eds)
                row_keys.append(ckey)
            keep.append(pos)
            eds.append(ed)
        if len(keep) != len(batch):
            batch = [batch[p] for p in keep]
            idx = [idx[p] for p in keep]
            if not batch:
                return

        # pad slots and batch to power-of-two buckets: the jit cache then sees
        # a handful of shapes over a whole exploration instead of one per
        # block-count the moves walk through. Slot counts are bounded by the
        # task count (moves allocate at most ~one block per task), so pinning
        # the shared PE/MEM slot bucket at pow2(T) collapses that shape axis
        # to one entry per workload; only the batch axis still varies. The
        # NoC-chain axis buckets to pow2 WITHOUT a floor: the dominant
        # single-NoC regime stays at N = 1 (compiling to exactly the
        # historic kernel), and topology-heavy searches add at most
        # log2(MAX_NOC) shapes.
        # bucket over the candidate encodings AND their bases: the group
        # fill broadcasts each base row before applying diffs, so a batch of
        # all-join candidates (one slot/NoC fewer than base) must still
        # host the base's shape
        all_encs = list(base_encs.values())
        all_encs.extend(eds)
        need = max(max(e.pe_peak.shape[0], e.mem_bw.shape[0]) for e in all_encs)
        slots = _bucket(max(need, len(self._enc.names)))
        n_noc = max(1, _pow2(max(e.noc_bw.shape[0] for e in all_encs)))
        b = len(batch)
        b_pad = _bucket(b)
        key = (b_pad, slots, n_noc)
        # double-buffered per bucket: the previous dispatch of this shape may
        # still be reading its (possibly zero-copy-aliased) host buffer, so a
        # fresh encode flips to the other one. Two in-flight batches per
        # bucket suffice; anything deeper would flush first.
        pair = self._buffers.get(key)
        if pair is None:
            pair = self._buffers[key] = [None, None]
        sel = self._bufsel.get(key, 0)
        self._bufsel[key] = 1 - sel
        rows = pair[sel]
        if rows is None:
            rows = pair[sel] = alloc_rows(
                b_pad, len(self._enc.names), slots, slots,
                len(self._enc.wl_names), n_noc,
            )
        # reuse guard: two buffers cover two un-consumed dispatches per
        # bucket, but the protocol lets callers keep MORE un-consumed. If
        # the dispatch that last encoded into this slot might still be
        # reading it (CPU XLA may alias the numpy buffer zero-copy), wait
        # for its compute to finish before scribbling over its inputs.
        owner = self._buf_owner.get((key, sel))
        if owner is not None and not owner.consumed:
            ready = getattr(owner.out["scal"], "is_ready", None)
            if ready is None or not ready():
                import jax

                jax.block_until_ready(owner.out["scal"])

        # steady-state fast path (the explorer regime: one adopted base, one
        # budget, full bucket): the buffer already holds base-row content
        # everywhere except the cells last dispatch's diffs touched — restore
        # just those from the base instead of refilling every row
        bufkey = (key, sel)
        prev = self._buf_state.get(bufkey)
        c0 = batch[0]
        uniform = all(
            c.budget is c0.budget and c.alpha == c0.alpha for c in batch[1:]
        )
        state0 = len(base_encs) == 1 and b == b_pad and uniform
        fast = (
            state0 and prev is not None
            and prev[0] is base_encs[id(c0.base)]
            and prev[1] is c0.budget
            and prev[2] == c0.alpha
        )
        dirty: List[tuple] = []
        if fast:
            base_ed = prev[0]
            for k, f in prev[3]:
                fill_row_fields(rows, k, base_ed, (f,))
            for k in range(b):
                ed = eds[k]
                if ed is not base_ed:
                    changed = [
                        f for f in ENCODED_FIELDS
                        if getattr(ed, f) is not getattr(base_ed, f)
                    ]
                    fill_row_fields(rows, k, ed, changed)
                    dirty.extend((k, f) for f in changed)
            self._buf_state[bufkey] = (base_ed, c0.budget, c0.alpha, dirty)
        else:
            # fill per base-group: write the base encoding + budget once,
            # broadcast across the group's rows, then apply per-candidate diffs
            j = 0
            while j < b:
                cg = batch[j]
                base_ed = base_encs[id(cg.base)]
                end = j + 1
                while end < b and batch[end].base is cg.base:
                    end += 1
                fill_row(rows, j, base_ed)
                bud = cg.budget
                if bud is not None:
                    fill_budget(rows, j, self._enc, bud.latency_s, bud.power_w,
                                bud.area_mm2, cg.alpha)
                else:  # neutral scoring row (buffers are reused across dispatches)
                    fill_budget(rows, j, self._enc, {}, 1e30, 1e30, 0.0)
                if end - j > 1:
                    for arr in rows.values():
                        arr[j + 1:end] = arr[j]
                for k in range(j, end):
                    ed, c = eds[k], batch[k]
                    if ed is not base_ed:
                        changed = [
                            f for f in ENCODED_FIELDS
                            if getattr(ed, f) is not getattr(base_ed, f)
                        ]
                        fill_row_fields(rows, k, ed, changed)
                        dirty.extend((k, f) for f in changed)
                    if k > j and c.budget is not bud:
                        if c.budget is not None:
                            fill_budget(rows, k, self._enc, c.budget.latency_s,
                                        c.budget.power_w, c.budget.area_mm2, c.alpha)
                        else:
                            fill_budget(rows, k, self._enc, {}, 1e30, 1e30, 0.0)
                j = end
            if b < b_pad:  # pad the batch axis with copies of row 0
                for arr in rows.values():
                    arr[b:b_pad] = arr[0]
            # the invariant the fast path needs: every row holds base+budget
            # content except `dirty` — only true for single-group, uniform-
            # budget, full-bucket dispatches
            if state0:
                self._buf_state[bufkey] = (
                    base_encs[id(c0.base)], c0.budget, c0.alpha, dirty
                )
            else:
                self._buf_state.pop(bufkey, None)
        if key not in self._shapes:
            self._shapes.add(key)
            self._stats.n_compiles += 1
        self._stats.encode_s += time.perf_counter() - tE

        tD = time.perf_counter()
        out = self._fn()(rows)  # non-blocking: no host transfer here
        self._stats.dispatch_s += time.perf_counter() - tD
        shared = _JaxBatch(out, self._stats, eds, (slots, n_noc))
        self._buf_owner[(key, sel)] = shared
        self._track_inflight(shared)
        for j, i in enumerate(idx):
            results[i] = _JaxHandle(shared, j, batch[j], self)
            self._stats.n_batched += 1
        if store is not None:
            # register every dispatched row for future sessions (lazy: the
            # entry holds (batch, row) until a hit materializes it) and wire
            # same-dispatch aliases onto the rows they dedupe against
            for j, ckey in enumerate(row_keys):
                store.insert(ckey, shared, j)
                self._stats.n_cache_misses += 1
            for i, j, c, ed in aliases:
                results[i] = _JaxHandle(shared, j, c, self, ed)

    # ------------------------------------------------------------------
    # host-exact scalar rollups, shared between the lazy ``_decode`` and the
    # policy-layer ``SimTelemetry`` so both produce bit-identical floats
    def _task_energy_pj(self, design: Design) -> Dict[str, float]:
        """Per-task dynamic energy: rate-independent (every task drains its
        full (ops, read, write) totals); the NoC term scales with the task's
        route hop count on multi-NoC chains."""
        blocks, d_pe, d_mem = design.blocks, design.task_pe, design.task_mem
        pe_pj, mem_pj, noc_pj = self._pe_pj, self._mem_pj, self._noc_pj
        if len(design.noc_chain) == 1:  # hops == 1 everywhere: skip routing
            return {
                n: pe_pj[blocks[d_pe[n]].subtype] * self._ops[k]
                + (mem_pj[blocks[d_mem[n]].subtype] + noc_pj) * self._rw[k]
                for k, n in enumerate(self._enc.names)
            }
        pos = {m: i for i, m in enumerate(design.noc_chain)}
        att = design.attached_noc
        return {
            n: pe_pj[blocks[d_pe[n]].subtype] * self._ops[k]
            + (
                mem_pj[blocks[d_mem[n]].subtype]
                + noc_pj * (abs(pos[att[d_pe[n]]] - pos[att[d_mem[n]]]) + 1)
            ) * self._rw[k]
            for k, n in enumerate(self._enc.names)
        }

    def _mem_caps(self, design: Design) -> Dict[str, float]:
        cap: Dict[str, float] = {m: 0.0 for m in design.mems()}
        d_mem = design.task_mem
        for k, n in enumerate(self._enc.names):
            cap[d_mem[n]] += self._wbytes[k]
        return cap

    def _area_mm2(self, design: Design, cap: Dict[str, float]) -> float:
        db = self.db
        area = 0.0
        for bname, blk in design.blocks.items():
            if blk.kind == BlockKind.MEM and blk.subtype == "sram":
                area += db.area.sram_mm2_per_mb * max(cap[bname], 1.0) / 1e6
            else:
                area += db.block_area_mm2(blk)
        return area

    def _wl_latency(self, fin: List[float]) -> Dict[str, float]:
        wl_latency: Dict[str, float] = {}
        for w, f in zip(self._wl_of, fin):
            if f > wl_latency.get(w, 0.0):
                wl_latency[w] = f
        return wl_latency

    def _decode(
        self,
        design: Design,
        latency: float,
        finish: np.ndarray,
        bneck: np.ndarray,
        kind_s: np.ndarray,
        pe_busy: np.ndarray,
        mem_busy: np.ndarray,
        noc_busy: np.ndarray,
        alp_time: float,
        traffic: float,
        n_phases: int,
    ) -> SimResult:
        db = self.db
        names = self._enc.names
        blocks, d_pe, d_mem = design.blocks, design.task_pe, design.task_mem
        chain = design.noc_chain
        fin = finish.tolist()
        codes = bneck.tolist()
        finish_s = dict(zip(names, fin))
        # codes are packed: 0/1 = pe/mem, 2 + 3·k = NoC at chain index k
        task_bneck = {n: _BNECK_KINDS[min(c, 2)] for n, c in zip(names, codes)}
        task_bneck_block = {
            n: d_pe[n] if c == 0 else (
                d_mem[n] if c == 1 else chain[(c - 2) // 3]
            )
            for n, c in zip(names, codes)
        }
        task_energy_pj = self._task_energy_pj(design)
        energy_j = sum(task_energy_pj.values()) * 1e-12 + total_leakage_w(
            design, db
        ) * latency
        wl_latency = self._wl_latency(fin)
        # fused mem-capacity + area rollup (ppa.mem_capacities/total_area_mm2
        # recomputed here with the precomputed write-bytes table)
        cap = self._mem_caps(design)
        area = self._area_mm2(design, cap)
        # per-block bottleneck seconds: device telemetry columns resolved to
        # block names via the encoding slot order (= block insertion order;
        # NoC columns are in chain order)
        block_bneck_s: Dict[str, float] = {}
        ipe = imem = 0
        for bname, blk in blocks.items():
            if blk.kind == BlockKind.PE:
                block_bneck_s[bname] = float(pe_busy[ipe])
                ipe += 1
            elif blk.kind == BlockKind.MEM:
                block_bneck_s[bname] = float(mem_busy[imem])
                imem += 1
        for i, bname in enumerate(chain):
            block_bneck_s[bname] = float(noc_busy[i])
        return SimResult(
            latency_s=latency,
            workload_latency_s=wl_latency,
            energy_j=energy_j,
            power_w=energy_j / latency if latency > 0 else 0.0,
            area_mm2=area,
            n_phases=n_phases,
            bottleneck_s={k: float(kind_s[i]) for i, k in enumerate(_BNECK_KINDS)},
            task_bottleneck=task_bneck,
            task_finish_s=finish_s,
            mem_capacity_bytes=cap,
            task_bottleneck_block=task_bneck_block,
            task_energy_j={n: e * 1e-12 for n, e in task_energy_pj.items()},
            block_bottleneck_s=block_bneck_s,
            avg_accel_parallelism=alp_time / latency if latency > 0 else 1.0,
            total_traffic_bytes=traffic,
        )


def _jax_pallas_backend(tdg: TaskGraph, db: HardwareDatabase) -> "JaxBatchedBackend":
    return JaxBatchedBackend(tdg, db, use_kernel=True)


BACKENDS = {
    "python": PythonBackend,
    "jax": JaxBatchedBackend,
    "jax_batched": JaxBatchedBackend,
    # fused Pallas phase-sim kernel (Mosaic on TPU; interpret mode elsewhere,
    # so on CPU prefer "jax" for speed and this for kernel-path coverage)
    "pallas": _jax_pallas_backend,
    "jax_pallas": _jax_pallas_backend,
}


def make_backend(name: str, tdg: TaskGraph, db: HardwareDatabase) -> SimulatorBackend:
    """Instantiate a registered backend by name (`ExplorerConfig.backend`)."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; known: {sorted(BACKENDS)}") from None
    return cls(tdg, db)
