"""§Roofline table: all (arch × shape) baseline cells on the single-pod mesh.

Per cell: the three terms (compute / HBM / ICI seconds), dominant bottleneck,
MODEL_FLOPS, MODEL_FLOPS / executed-FLOPs ratio, the FARSI phase-sim step
estimate, and — when the dry-run JSON records exist (experiments/dryrun) —
the compiled memory analysis and whole-graph collective parse for
cross-reference."""
from __future__ import annotations

import json
import os
from typing import List

from repro.configs.base import SHAPES, shape_applicable
from repro.configs.registry import arch_names, get_config
from repro.core.tpu_design import simulate_step
from repro.roofline.analytic import MeshShape, model_flops
from repro.sharding.rules import DistConfig

from .common import Row

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def baseline_dist(cfg) -> DistConfig:
    rules = {
        "qkv": ("model",), "kv_qkv": ("model",), "mlp": ("model",),
        "ssm_inner": ("model",), "ssm_conv": ("model",), "expert_mlp": ("model",),
        "seq_res": ("model",), "embed": ("data",),
    }
    micro = 8 if cfg.param_counts()["total"] >= 50e9 else 4
    return DistConfig(rules=rules, microbatches=micro)


def run() -> List[Row]:
    mesh = MeshShape(16, 16)
    rows: List[Row] = []
    for arch in arch_names():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if not shape_applicable(cfg, shape):
                rows.append((f"roofline.{arch}.{shape_name}", 0.0, "SKIP=needs-subquadratic-attn"))
                continue
            dist = baseline_dist(cfg)
            t = simulate_step(cfg, shape, mesh, dist)
            mf = model_flops(cfg, shape)
            ratio = mf / (t["flops"] * mesh.chips)
            frac = mf / mesh.chips / 197e12 / t["t_phase_sim_s"] if t["t_phase_sim_s"] else 0
            derived = (
                f"t_comp={t['t_compute_s']:.3e} t_hbm={t['t_memory_s']:.3e} "
                f"t_ici={t['t_collective_s']:.3e} dom={t['dominant']} "
                f"sim={t['t_phase_sim_s']:.3e} model_flops={mf:.3e} "
                f"useful_ratio={ratio:.2f} roofline_frac={frac*100:.1f}%"
            )
            tag = f"{arch}_{shape_name}_16x16.json"
            path = os.path.join(DRYRUN_DIR, tag)
            if os.path.exists(path):
                rec = json.load(open(path))
                mem = rec.get("memory", {})
                coll = rec.get("collectives", {})
                derived += (
                    f" | dryrun: temp={mem.get('temp_bytes', 0)/1e9:.1f}GB "
                    f"args={mem.get('argument_bytes', 0)/1e9:.1f}GB "
                    f"hlo_coll={coll.get('total', 0)/1e9:.2f}GB(1-visit)"
                )
            rows.append((f"roofline.{arch}.{shape_name}", t["t_phase_sim_s"] * 1e6, derived))
    return rows
