"""A *design* = hardware blocks + topology + software→hardware mapping.

Topology model (paper §3.2 "many NoC" systems): NoCs form a chain (a bus
hierarchy); every PE and every MEM attaches to exactly one NoC. The route of a
(task, buffer) pair is the NoC sub-chain between the task's PE and the buffer's
MEM; every NoC on the route carries the traffic (multi-hop congestion, spatial
locality = short routes).

FARSI starts from the simplest base design — one GPP, one NoC, one DRAM
(paper §3.3 "Development-cost Awareness") — and grows it incrementally via
moves.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, List, Optional, Tuple

from .blocks import Block, BlockKind, make_gpp, make_mem, make_noc
from .tdg import TaskGraph


@dataclasses.dataclass
class DesignCheckpoint:
    """Cheap snapshot of a :class:`Design` for in-place move trial/rollback.

    The DSE hot loop applies a candidate move to the *current* design, encodes
    the result, and rolls back — no Python object graph is cloned per
    neighbour (the paper's Fig.-8b hot-spot). Block objects are shared with
    the design; only their mutable knob fields are snapshotted, so restore is
    a handful of dict copies plus one attribute sweep."""

    blocks: Dict[str, Block]
    noc_chain: List[str]
    attached_noc: Dict[str, str]
    task_pe: Dict[str, str]
    task_mem: Dict[str, str]
    block_states: List[Tuple[Block, str, int, int, int, int, Optional[str]]]


class Design:
    def __init__(self) -> None:
        self.blocks: Dict[str, Block] = {}
        self.noc_chain: List[str] = []  # ordered NoC names
        self.attached_noc: Dict[str, str] = {}  # PE/MEM name -> NoC name
        self.task_pe: Dict[str, str] = {}  # task -> PE name
        self.task_mem: Dict[str, str] = {}  # task buffer -> MEM name

    # ------------------------------------------------------------------
    @staticmethod
    def base(tdg: TaskGraph) -> "Design":
        """One GPP + one NoC + one DRAM; all tasks on the GPP, all buffers in
        DRAM (paper: 'FARSI starts with a very simple base design')."""
        d = Design()
        noc = d.add_block(make_noc())
        pe = d.add_block(make_gpp(), attach_to=noc.name)
        mem = d.add_block(make_mem("dram"), attach_to=noc.name)
        for t in tdg.tasks:
            d.task_pe[t] = pe.name
            d.task_mem[t] = mem.name
        return d

    # ---- block/topology editing ---------------------------------------
    def add_block(self, block: Block, attach_to: Optional[str] = None,
                  after_noc: Optional[str] = None) -> Block:
        self.blocks[block.name] = block
        if block.kind == BlockKind.NOC:
            if after_noc is None:
                self.noc_chain.append(block.name)
            else:
                self.noc_chain.insert(self.noc_chain.index(after_noc) + 1, block.name)
        else:
            assert attach_to is not None and self.blocks[attach_to].kind == BlockKind.NOC
            self.attached_noc[block.name] = attach_to
        return block

    def remove_block(self, name: str) -> None:
        blk = self.blocks.pop(name)
        if blk.kind == BlockKind.NOC:
            self.noc_chain.remove(name)
        else:
            self.attached_noc.pop(name)

    def rename_block(self, old: str, new: str) -> None:
        """Rename a block in place, preserving its insertion-order slot (slot
        order is what the flat encoding keys on). Used to make move replays
        name-deterministic: a replayed fork re-clones the block under a fresh
        uid, and the caller renames it back to the recorded one."""
        assert new not in self.blocks, (old, new)
        blk = self.blocks[old]
        blk.name = new
        self.blocks = {(new if k == old else k): v for k, v in self.blocks.items()}
        self.noc_chain = [new if n == old else n for n in self.noc_chain]
        self.attached_noc = {
            (new if k == old else k): (new if v == old else v)
            for k, v in self.attached_noc.items()
        }
        for m in (self.task_pe, self.task_mem):
            for t, b in m.items():
                if b == old:
                    m[t] = new

    def pes(self) -> List[str]:
        return [n for n, b in self.blocks.items() if b.kind == BlockKind.PE]

    def mems(self) -> List[str]:
        return [n for n, b in self.blocks.items() if b.kind == BlockKind.MEM]

    def nocs(self) -> List[str]:
        return list(self.noc_chain)

    def attached(self, noc_name: str) -> List[str]:
        return [n for n, c in self.attached_noc.items() if c == noc_name]

    # ---- routing -------------------------------------------------------
    def route(self, task: str) -> List[str]:
        """NoC names on the PE→MEM path of ``task`` (inclusive)."""
        pe_noc = self.attached_noc[self.task_pe[task]]
        mem_noc = self.attached_noc[self.task_mem[task]]
        i, j = self.noc_chain.index(pe_noc), self.noc_chain.index(mem_noc)
        lo, hi = min(i, j), max(i, j)
        return self.noc_chain[lo:hi + 1]

    def hops(self, task: str) -> int:
        return len(self.route(task))

    # ---- bookkeeping ----------------------------------------------------
    def tasks_on_pe(self, pe: str) -> List[str]:
        return [t for t, p in self.task_pe.items() if p == pe]

    def buffers_on_mem(self, mem: str) -> List[str]:
        return [t for t, m in self.task_mem.items() if m == mem]

    def tasks_via_noc(self, noc: str) -> List[str]:
        return [t for t in self.task_pe if noc in self.route(t)]

    def clone(self, rename: bool = True) -> "Design":
        """Design duplication — the paper's own profiled hot-spot (Fig. 8b:
        79.9% of generation time). We keep it cheap: blocks are shallow-copied
        via their own ``clone`` and mappings are dict copies (no generic
        deepcopy). ``rename=False`` keeps block names stable so results priced
        against the original still resolve (explorer best-design snapshots).
        The DSE inner loop avoids cloning entirely via
        :meth:`checkpoint`/:meth:`restore` + flat-array neighbour encodings
        (``core/phase_sim_jax.py``)."""
        d = Design.__new__(Design)
        d.blocks = {}
        names: Dict[str, str] = {}
        for name, b in self.blocks.items():
            nb = b.clone()
            if not rename:
                nb.name = name
            names[name] = nb.name
            d.blocks[nb.name] = nb
        d.noc_chain = [names[n] for n in self.noc_chain]
        d.attached_noc = {names[k]: names[v] for k, v in self.attached_noc.items()}
        d.task_pe = {t: names[p] for t, p in self.task_pe.items()}
        d.task_mem = {t: names[m] for t, m in self.task_mem.items()}
        return d

    # ---- in-place trial/rollback (clone-free neighbour generation) ------
    def checkpoint(self) -> DesignCheckpoint:
        """Snapshot for :meth:`restore`. O(blocks + tasks) dict/tuple copies,
        no Block construction — the whole point versus :meth:`clone`."""
        return DesignCheckpoint(
            blocks=dict(self.blocks),
            noc_chain=list(self.noc_chain),
            attached_noc=dict(self.attached_noc),
            task_pe=dict(self.task_pe),
            task_mem=dict(self.task_mem),
            block_states=[
                (b, b.subtype, b.freq_mhz, b.width_bytes, b.n_links, b.unroll,
                 b.hardened_for)
                for b in self.blocks.values()
            ],
        )

    def restore(self, ck: DesignCheckpoint) -> None:
        """Undo every mutation since ``ck`` was taken: topology, mappings, and
        knob edits on blocks that existed then. Blocks added afterwards are
        dropped (any captured references stay valid but detached)."""
        self.blocks = dict(ck.blocks)
        self.noc_chain = list(ck.noc_chain)
        self.attached_noc = dict(ck.attached_noc)
        self.task_pe = dict(ck.task_pe)
        self.task_mem = dict(ck.task_mem)
        for b, subtype, freq, width, links, unroll, hardened in ck.block_states:
            b.subtype = subtype
            b.freq_mhz = freq
            b.width_bytes = width
            b.n_links = links
            b.unroll = unroll
            b.hardened_for = hardened

    def deep_clone_reference(self) -> "Design":
        """Naive ``copy.deepcopy`` clone, kept as the reference the paper
        profiles against (benchmarks/bench_generation.py measures both)."""
        return copy.deepcopy(self)

    # ---- complexity metrics (paper §6.1) --------------------------------
    def block_counts(self) -> Dict[str, int]:
        return {
            "pe": len(self.pes()),
            "mem": len(self.mems()),
            "noc": len(self.nocs()),
        }

    def heterogeneity_cv(self, kind: BlockKind, knob: str) -> float:
        """Coefficient of variation of a knob across blocks of one kind —
        the paper's system-heterogeneity metric (Fig. 15)."""
        vals = [getattr(b, knob) for b in self.blocks.values() if b.kind == kind]
        if len(vals) < 2:
            return 0.0
        mean = sum(vals) / len(vals)
        if mean == 0:
            return 0.0
        var = sum((v - mean) ** 2 for v in vals) / len(vals)
        return (var ** 0.5) / mean

    # knob axes the development-cost variation metric averages over: one CV
    # per (kind, knob) pair the swap ladders actually walk
    _VARIATION_AXES = (
        (BlockKind.PE, "freq_mhz"), (BlockKind.PE, "unroll"),
        (BlockKind.MEM, "freq_mhz"), (BlockKind.MEM, "width_bytes"),
        (BlockKind.NOC, "freq_mhz"), (BlockKind.NOC, "width_bytes"),
    )

    def complexity_metrics(self) -> Dict[str, float]:
        """The paper's §5.3/§6.1 development-cost pair: total component
        count and system variation (mean heterogeneity CV over the knob
        ladders), plus the NoC-subsystem component count the §5.3 NoC
        simplification result is stated in."""
        return {
            "components": float(len(self.blocks)),
            "noc_components": float(len(self.noc_chain)),
            "variation": sum(
                self.heterogeneity_cv(k, knob) for k, knob in self._VARIATION_AXES
            ) / len(self._VARIATION_AXES),
        }

    def signature(self) -> tuple:
        return (
            tuple(sorted(b.signature() for b in self.blocks.values())),
            tuple(sorted(self.task_pe.items())),
            tuple(sorted(self.task_mem.items())),
        )
