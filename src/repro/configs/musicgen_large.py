"""MusicGen-large [arXiv:2306.05284; hf:facebook/musicgen-large].

Decoder-only transformer over EnCodec tokens: 48L, d_model=2048, 32 heads
(kv=32 → MHA, head_dim 64), d_ff=8192, vocab=2048 (one EnCodec codebook).
The audio frontend (EnCodec + codebook delay interleave) is a stub —
``input_specs()`` provides precomputed frame embeddings (batch, seq, d_model).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    n_layers=48,
    d_model=2048,
    vocab_size=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    mlp_kind="gelu",  # MusicGen uses an ungated GELU FFN (d_ff = 4·d_model)
    rope_kind="none",  # musicgen uses learned sinusoidal offsets; stubbed NoPE
    input_mode="embeddings",
    block_kinds=("attn",),
    mlp_kinds=("dense",),
)
