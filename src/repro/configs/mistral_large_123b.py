"""Mistral Large 2 (123B) [hf:mistralai/Mistral-Large-Instruct-2407; unverified].

Dense, 88L, d_model=12288, 96 q / 8 kv heads (GQA), d_ff=28672, vocab=32768.
The deepest/widest dense assignment — the memory-capacity stress cell.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    n_layers=88,
    d_model=12288,
    vocab_size=32768,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    mlp_kind="swiglu",
    rope_kind="rope",
    rope_theta=1e6,
    block_kinds=("attn",),
    mlp_kinds=("dense",),
)
