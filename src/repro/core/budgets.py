"""Budgets and the Eq.-7 fitness (paper §3.4).

  distance_to_budget = Σ_m α_m · (Des_m − Bud_m) / Bud_m ,
  m ∈ {performance, power, area}

α dampens metrics that already meet their budget so the explorer keeps a small
incentive to bank slack (the paper: "a dampening factor to the metrics already
meeting budget"). Convergence is declared on the *undampened* city-block
distance of unmet metrics reaching zero (§5: "distance to goal").

Latency budgets are per workload (Table 4a); power/area budgets are
system-wide (sum over all workload components).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from .phase_sim import SimResult

METRICS = ("latency", "power", "area")


@dataclasses.dataclass(frozen=True)
class Budget:
    latency_s: Dict[str, float]  # per workload
    power_w: float
    area_mm2: float

    def scaled(self, factor: float) -> "Budget":
        """Budget relaxation for the §6.1 case study (1X/2X/4X)."""
        return Budget(
            latency_s={k: v * factor for k, v in self.latency_s.items()},
            power_w=self.power_w * factor,
            area_mm2=self.area_mm2 * factor,
        )


@dataclasses.dataclass
class Distance:
    per_metric: Dict[str, float]  # signed normalized (Des-Bud)/Bud, worst wl for latency
    per_workload_latency: Dict[str, float]

    def fitness(self, alpha_met: float = 0.05) -> float:
        """Eq. 7 with dampening α on met metrics."""
        out = 0.0
        for m, d in self.per_metric.items():
            out += d if d > 0 else alpha_met * d
        return out

    def city_block(self) -> float:
        """Normalized city-block distance of *unmet* metrics (Fig. 9 y-axis)."""
        return sum(max(0.0, d) for d in self.per_metric.values()) + sum(
            max(0.0, d) for d in self.per_workload_latency.values()
        )

    def converged(self) -> bool:
        return self.city_block() <= 0.0

    def farthest_metric(self) -> str:
        """The metric contributing most to the distance — FARSI 'typically
        pick[s] the metric farthest from its budget' (§3.3)."""
        cand = dict(self.per_metric)
        return max(cand, key=lambda m: cand[m])


def distance(result: SimResult, budget: Budget) -> Distance:
    per_wl = {
        w: (result.workload_latency_s.get(w, 0.0) - b) / b
        for w, b in budget.latency_s.items()
    }
    per_metric = {
        "latency": max(per_wl.values()) if per_wl else 0.0,
        "power": (result.power_w - budget.power_w) / budget.power_w,
        "area": (result.area_mm2 - budget.area_mm2) / budget.area_mm2,
    }
    return Distance(per_metric=per_metric, per_workload_latency=per_wl)
