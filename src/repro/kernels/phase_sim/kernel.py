"""Pallas kernel for the FARSI phase-driven simulator (fused batch pricing).

Grid: ``(B,)`` — one program per candidate design, each owning one ``(1, T)``
tile row of every per-design input and running the full phase loop for its
candidate. The per-phase work is the same co-residency formulation as the
XLA reference (`phase_sim_jax.simulate_one`): same-slot (T, T) matvecs for
the PE/MEM shares (Eq. 1/2/4), rank-residue link striping for the NoC
(Eq. 3), Eq.-6 phase length, then the Eq.-7 fitness/energy/area rollup —
fused into ONE launch instead of a `vmap` of `fori_loop`, so every
per-phase intermediate lives on-chip for the whole candidate instead of
round-tripping through XLA's loop-carried HLO buffers.

VMEM scratch holds the loop-invariant stage: the one-hot task→slot maps
(T, S) and the same-PE / same-MEM co-residency masks (T, T), computed once
per program and re-read every phase. Working set at (T=128, S=64):
4·(T·S + T·T) ≈ 0.3 MB — far under the ~16 MB VMEM budget; T is padded to
the lane width by ``ops.phase_sim``, with padded tasks born *completed* so
they never run, never join a share, and contribute zero to every rollup.

Gathers are expressed as one-hot matmuls (``onehot_pe @ pe_coeffs``) rather
than vector-indexed loads — MXU-shaped on TPU and exact in f32 for the
0/1 masks involved. Interpret mode (CPU) is bit-compatible with Mosaic
compilation up to f32 reassociation; parity ≤ 1e-5 against the oracle is
asserted in tests/test_phase_sim_kernel.py.
"""
from __future__ import annotations

import functools
from typing import Dict

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# scal output column layout (see _phase_sim_kernel rollup): the shared
# ``core.scal_layout`` tuple — backend._SCAL_COLS is its prefix, so the
# backend's device-side repack of the ops-layer dict folds to a no-op.
# The layout module is the single source of truth (dependency-free, safe
# mid-package-init); the contract checker (`python -m repro.analysis`)
# guards that both sides keep deriving from it and that the rollup write
# below stays the same width.
from ...core.scal_layout import N_SCAL, SCAL_COLS  # re-exported for ops.py

BIG = 1e30

# nocs input column layout (packed per-candidate scalars; the per-NoC chain
# arrays — bw/links/leak/area — ride as their own (1, N) tiles now that the
# chain is encoded natively)
NOCS_COLS = ("noc_pj", "power_budget", "area_budget", "alpha")
N_NOCS = len(NOCS_COLS)


def _phase_sim_kernel(
    # --- static workload tensors (shared by every program) ---------------
    work_ref,   # (1, T) f32  total ops per task
    rd_ref,     # (1, T) f32  read bytes
    wr_ref,     # (1, T) f32  write bytes
    burst_ref,  # (1, T) f32  burst bytes
    pmask_ref,  # (T, T) f32  [i, j] = 1 iff j is a parent of i
    wlhot_ref,  # (T, NW) f32 one-hot of the task's workload id
    # --- per-candidate rows (one (1, X) tile per program) ----------------
    task_pe_ref,   # (1, T) i32
    task_mem_ref,  # (1, T) i32
    accel_ref,     # (1, T) f32
    pe_peak_ref,   # (1, S) f32
    pe_pj_ref,     # (1, S) f32
    pe_leak_ref,   # (1, S) f32
    pe_area_ref,   # (1, S) f32
    pe_noc_ref,    # (1, S) i32  chain index each PE slot attaches to
    pe_active_ref,  # (1, S) f32 active-slot mask (0 ⇒ priced as absent)
    mem_bw_ref,    # (1, S) f32
    mem_pj_ref,    # (1, S) f32
    mem_leak_ref,  # (1, S) f32
    mem_af_ref,    # (1, S) f32  fixed area
    mem_amb_ref,   # (1, S) f32  area per MB
    mem_noc_ref,   # (1, S) i32  chain index each MEM slot attaches to
    mem_active_ref,  # (1, S) f32 active-slot mask
    noc_bw_ref,    # (1, N) f32  per-NoC per-link bandwidth (chain order)
    noc_links_ref,  # (1, N) i32 per-NoC channel count
    noc_leak_ref,  # (1, N) f32
    noc_area_ref,  # (1, N) f32
    noc_active_ref,  # (1, N) f32 active-slot mask
    nocs_ref,      # (1, N_NOCS) f32 packed scalars (NOCS_COLS order)
    wlbud_ref,     # (1, NW) f32 per-workload latency budget
    # --- outputs ----------------------------------------------------------
    finish_ref,  # (1, T) f32
    bneck_ref,   # (1, T) i32 packed: 0/1 = pe/mem, 2 + 3·k = NoC chain idx k
    wllat_ref,   # (1, NW) f32
    scal_ref,    # (1, N_SCAL) f32 (SCAL_COLS order)
    pe_bneck_ref,   # (1, S) f32 per-PE-slot binding-bottleneck seconds
    mem_bneck_ref,  # (1, S) f32 per-MEM-slot binding-bottleneck seconds
    noc_bneck_ref,  # (1, N) f32 per-NoC binding-bottleneck seconds
    # --- VMEM scratch (loop-invariant stage, reused across phases) -------
    ohp_ref,       # (T, S) f32 one-hot task→PE-slot
    ohm_ref,       # (T, S) f32 one-hot task→MEM-slot
    same_pe_ref,   # (T, T) f32 co-residency on the same PE slot
    same_mem_ref,  # (T, T) f32 co-residency on the same MEM slot
    *,
    t_real: int,
):
    t = work_ref.shape[1]
    s_pe = pe_peak_ref.shape[1]
    s_mem = mem_bw_ref.shape[1]  # PE/MEM slot axes pad independently
    n_noc = noc_bw_ref.shape[1]
    f32 = jnp.float32

    work = work_ref[0]
    rd_b = rd_ref[0]
    wr_b = wr_ref[0]
    burst = burst_ref[0]
    pmask = pmask_ref[...]
    task_pe = task_pe_ref[0]
    task_mem = task_mem_ref[0]

    # ---- loop-invariant stage into VMEM scratch -------------------------
    ohp_ref[...] = (
        task_pe[:, None] == jax.lax.broadcasted_iota(jnp.int32, (t, s_pe), 1)
    ).astype(f32)
    ohm_ref[...] = (
        task_mem[:, None] == jax.lax.broadcasted_iota(jnp.int32, (t, s_mem), 1)
    ).astype(f32)
    dot = functools.partial(jnp.dot, preferred_element_type=f32)
    same_pe_ref[...] = dot(ohp_ref[...], ohp_ref[...].T)
    same_mem_ref[...] = dot(ohm_ref[...], ohm_ref[...].T)

    peak_eff = dot(ohp_ref[...], pe_peak_ref[0]) * accel_ref[0]
    mem_peak = dot(ohm_ref[...], mem_bw_ref[0])
    links = jnp.maximum(noc_links_ref[0].astype(f32), 1.0)  # (N,)
    noc_bw = noc_bw_ref[0]  # (N,)
    # chain routing: gather the chain positions through the one-hot maps
    # (positions are small ints — exact in f32), then the route mask
    pe_pos = dot(ohp_ref[...], pe_noc_ref[0].astype(f32))
    mem_pos = dot(ohm_ref[...], mem_noc_ref[0].astype(f32))
    lo = jnp.minimum(pe_pos, mem_pos)
    hi = jnp.maximum(pe_pos, mem_pos)
    hops = hi - lo + 1.0
    nidx_f = jax.lax.broadcasted_iota(jnp.int32, (t, n_noc), 1).astype(f32)
    on_route = jnp.where(
        (nidx_f >= lo[:, None]) & (nidx_f <= hi[:, None]), 1.0, 0.0
    )  # (T, N)

    def noc_share(runf):
        """Eq. 3 per NoC: rank-residue link striping within each NoC's
        users, end-to-end bandwidth = min over the route, binding NoC =
        first argmin in chain order. ``n_noc == 1`` is the historic
        single-NoC formulation, bit-for-bit."""
        if n_noc == 1:
            order = jnp.cumsum(runf)
            same_link = (runf[:, None] * runf[None, :]) * jnp.where(
                (order[:, None] - order[None, :]) % links[0] == 0, 1.0, 0.0
            )
            link_t = dot(same_link, burst)
            return noc_bw[0] * burst / jnp.maximum(link_t, 1e-30), jnp.zeros((t,), f32)
        # multi-NoC: rank-residue striping through a (T, 8) link one-hot
        # (ladder max 8 channels) — O(T·8) per NoC instead of a (T, T)
        # co-residency mask; user u's link is (rank_u − 1) mod n_links
        lidx = jax.lax.broadcasted_iota(jnp.int32, (t, 8), 1).astype(f32)
        best = jnp.full((t,), BIG, f32)
        arg = jnp.zeros((t,), f32)
        for k in range(n_noc):  # static unroll over the padded chain bucket
            use_k = on_route[:, k] * runf
            order = jnp.cumsum(use_k)
            link = jnp.where(use_k > 0, (order - 1.0) % links[k], -1.0)
            oh = jnp.where(link[:, None] == lidx, 1.0, 0.0)
            link_load = dot(burst * use_k, oh)  # (8,) burst per link
            link_t = dot(oh, link_load)
            bw_k = jnp.where(
                use_k > 0, noc_bw[k] * burst / jnp.maximum(link_t, 1e-30), BIG
            )
            better = bw_k < best
            arg = jnp.where(better, f32(k), arg)
            best = jnp.where(better, bw_k, best)
        return best, arg

    # padded tasks (index ≥ t_real) are born completed: they never run,
    # never enter a share, and their zero work/bytes vanish in every sum
    task_ids = jax.lax.broadcasted_iota(jnp.int32, (t, 1), 0)[:, 0]
    completed0 = task_ids >= t_real
    kind_ids = jax.lax.broadcasted_iota(jnp.int32, (t, 3), 1)

    def phase(_, state):
        (rem_ops, rem_rd, rem_wr, completed, now, finish, bneck, bneck_noc,
         kind_s, pe_bt, mem_bt, noc_bt, alp_t, traffic, nph) = state
        same_pe = same_pe_ref[...]
        same_mem = same_mem_ref[...]
        # ready ⟺ zero incomplete parents (counts are exact small ints)
        pending = dot(pmask, jnp.where(completed, 0.0, 1.0))
        running = (~completed) & (pending < 0.5)
        runf = jnp.where(running, 1.0, 0.0)
        burst_run = burst * runf

        # Eq. 1/2: preemptive equal share per PE slot
        load_t = dot(same_pe, runf)
        compute = peak_eff / jnp.maximum(load_t, 1.0)

        # Eq. 4: burst-proportional memory share
        mem_t = dot(same_mem, burst_run)
        m_bw = mem_peak * burst / jnp.maximum(mem_t, 1e-30)

        # Eq. 3: per-NoC rank-residue link striping, min over the route
        n_bw, noc_arg = noc_share(runf)

        bw = jnp.minimum(m_bw, n_bw)
        comp_t = rem_ops / compute
        comm_t = jnp.maximum(rem_rd, rem_wr) / bw
        c_t = jnp.where(running, jnp.maximum(comp_t, comm_t), BIG)
        phi_raw = jnp.min(c_t)  # Eq. 6
        any_run = phi_raw < BIG * 0.5
        phi = jnp.where(any_run, phi_raw, 0.0)
        phi_run = jnp.where(running, phi, 0.0)

        # binding resource per running task (total work over current rates;
        # compute wins ties, then mem vs noc by the tighter pipe)
        tot_comp_t = work / compute
        tot_comm_t = jnp.maximum(rd_b, wr_b) / bw
        code = jnp.where(tot_comp_t >= tot_comm_t, 0, jnp.where(m_bw <= n_bw, 1, 2))
        kind_s = kind_s + jnp.sum(
            jnp.where(code[:, None] == kind_ids, phi_run[:, None], 0.0), axis=0
        )
        # per-TASK bottleneck-time accumulators: the task→slot resolution
        # (one VMEM one-hot matvec each) is hoisted to after the loop —
        # in-loop the telemetry costs two (T,) masked adds
        pe_bt = pe_bt + jnp.where(code == 0, phi_run, 0.0)
        mem_bt = mem_bt + jnp.where(code == 1, phi_run, 0.0)
        # per-NoC binding seconds: the binding NoC is contention-dependent
        # per phase, so multi-NoC chains accumulate in-loop (single-NoC
        # resolves from kind_s[2] after the loop)
        if n_noc > 1:
            noc_bt = noc_bt + dot(
                jnp.where(code == 2, phi_run, 0.0),
                jnp.where(noc_arg[:, None] == nidx_f, 1.0, 0.0),
            )

        # mask rates BEFORE the phi multiply (inf · 0 would poison remains)
        d_ops = jnp.where(running, compute, 0.0) * phi
        d_bw = jnp.where(running, bw, 0.0) * phi
        dr_ops = jnp.maximum(rem_ops - d_ops, 0.0)
        dr_rd = jnp.maximum(rem_rd - d_bw, 0.0)
        dr_wr = jnp.maximum(rem_wr - d_bw, 0.0)
        newly_done = running & (c_t <= phi * (1 + 1e-9))
        keep = ~newly_done
        now = now + phi
        finish = jnp.where(newly_done, now, finish)
        bneck = jnp.where(newly_done, code, bneck)
        if n_noc > 1:
            bneck_noc = jnp.where(newly_done, noc_arg, bneck_noc)
        alp_t = alp_t + phi * jnp.sum(runf / jnp.maximum(load_t, 1.0))
        traffic = traffic + jnp.sum(
            jnp.where(running, jnp.minimum(dr_rd + dr_wr, d_bw + d_bw), 0.0)
        )
        nph = nph + jnp.where(any_run, 1.0, 0.0)
        return (
            jnp.where(keep, dr_ops, 0.0), jnp.where(keep, dr_rd, 0.0),
            jnp.where(keep, dr_wr, 0.0), completed | newly_done, now, finish,
            bneck, bneck_noc, kind_s, pe_bt, mem_bt, noc_bt, alp_t, traffic,
            nph,
        )

    state = (
        work, rd_b, wr_b, completed0,
        f32(0.0), jnp.zeros((t,), f32), jnp.zeros((t,), jnp.int32),
        jnp.zeros((t,), f32),
        jnp.zeros((3,), f32), jnp.zeros((t,), f32), jnp.zeros((t,), f32),
        jnp.zeros((n_noc,), f32),
        f32(0.0), f32(0.0), f32(0.0),
    )
    # every phase retires ≥ 1 of the t_real live tasks, so t_real iterations
    # suffice; once all are done, phases are zero-length no-ops
    (_, _, _, completed, now, finish, bneck, bneck_noc, kind_s, pe_bt,
     mem_bt, noc_bt, alp_t, traffic, nph) = jax.lax.fori_loop(
        0, t_real, phase, state)
    # slot-resolve the per-task bottleneck time once (phase-invariant maps)
    pe_b = dot(pe_bt, ohp_ref[...])
    mem_b = dot(mem_bt, ohm_ref[...])
    noc_b = kind_s[2:3] if n_noc == 1 else noc_bt

    # ---- device-side PPA rollup + Eq.-7 fitness -------------------------
    wlhot = wlhot_ref[...]
    wl_lat = jnp.max(jnp.where(wlhot > 0.5, finish[:, None], 0.0), axis=0)
    dyn_pj = jnp.sum(
        dot(ohp_ref[...], pe_pj_ref[0]) * work
        + (dot(ohm_ref[...], mem_pj_ref[0]) + nocs_ref[0, 0] * hops)
        * (rd_b + wr_b)
    )
    # active-slot masked rollups (inactive slots price as absent hardware;
    # host rows are all-active so the ×1.0 multiply is bit-exact)
    leak_w = (
        jnp.sum(pe_leak_ref[0] * pe_active_ref[0])
        + jnp.sum(mem_leak_ref[0] * mem_active_ref[0])
        + jnp.sum(noc_leak_ref[0] * noc_active_ref[0])
    )
    energy = dyn_pj * 1e-12 + leak_w * now
    power = jnp.where(now > 0, energy / jnp.maximum(now, 1e-30), 0.0)
    cap = dot(wr_b, ohm_ref[...])  # per-MEM-slot resident bytes
    area = (
        jnp.sum(pe_area_ref[0] * pe_active_ref[0])
        + jnp.sum(
            (mem_af_ref[0] + mem_amb_ref[0] * jnp.maximum(cap, 1.0) / 1e6)
            * mem_active_ref[0]
        )
        + jnp.sum(noc_area_ref[0] * noc_active_ref[0])
    )
    wlbud = wlbud_ref[0]
    alpha = nocs_ref[0, 3]
    dists = jnp.stack([
        jnp.max((wl_lat - wlbud) / wlbud),
        (power - nocs_ref[0, 1]) / nocs_ref[0, 1],
        (area - nocs_ref[0, 2]) / nocs_ref[0, 2],
    ])
    fitness = jnp.sum(jnp.where(dists > 0, dists, alpha * dists))

    finish_ref[0] = finish
    # packed binding code: 0/1 = pe/mem, NoC-bound = 2 + 3·(chain index)
    bneck_ref[0] = jnp.where(
        bneck == 2, 2 + 3 * bneck_noc.astype(jnp.int32), bneck
    )
    wllat_ref[0] = wl_lat
    pe_bneck_ref[0] = pe_b
    mem_bneck_ref[0] = mem_b
    noc_bneck_ref[0] = noc_b
    scal_ref[0] = jnp.stack([
        now, energy, power, area, fitness, alp_t, traffic, nph,
        jnp.where(jnp.all(completed), 1.0, 0.0),
        kind_s[0], kind_s[1], kind_s[2],
        jnp.argmax(pe_b).astype(f32), jnp.argmax(mem_b).astype(f32),
    ])


def phase_sim_batch(
    work: jax.Array,      # (1, T) f32, T padded
    rd: jax.Array,        # (1, T)
    wr: jax.Array,        # (1, T)
    burst: jax.Array,     # (1, T)
    pmask: jax.Array,     # (T, T)
    wlhot: jax.Array,     # (T, NW)
    task_pe: jax.Array,   # (B, T) i32
    task_mem: jax.Array,  # (B, T) i32
    accel: jax.Array,     # (B, T)
    pe_coeffs: Dict[str, jax.Array],   # 5 × (B, S) f32 + (B, S) i32 pe_noc
    mem_coeffs: Dict[str, jax.Array],  # 6 × (B, S) f32 + (B, S) i32 mem_noc
    noc_arrays: Dict[str, jax.Array],  # 5 × (B, N) per-NoC chain columns
    nocs: jax.Array,      # (B, N_NOCS) packed scalars
    wlbud: jax.Array,     # (B, NW)
    *,
    t_real: int,
    interpret: bool = False,
):
    """One fused launch over the (B, T) grid; returns (finish, bneck,
    wl_latency, scal, pe_bneck, mem_bneck, noc_bneck) with the scal columns
    laid out as ``SCAL_COLS`` and the per-slot bottleneck-seconds telemetry
    in the trailing (B, S)/(B, N) blocks."""
    b, t = task_pe.shape
    s_pe = pe_coeffs["pe_peak"].shape[1]
    s_mem = mem_coeffs["mem_bw"].shape[1]
    n_noc = noc_arrays["noc_bw"].shape[1]
    n_wl = wlhot.shape[1]

    shared = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    perb = lambda w: pl.BlockSpec((1, w), lambda i: (i, 0))

    kernel = functools.partial(_phase_sim_kernel, t_real=t_real)
    finish, bneck, wllat, scal, pe_bneck, mem_bneck, noc_bneck = pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            shared((1, t)), shared((1, t)), shared((1, t)), shared((1, t)),
            shared((t, t)), shared((t, n_wl)),
            perb(t), perb(t), perb(t),
            perb(s_pe), perb(s_pe), perb(s_pe), perb(s_pe), perb(s_pe),
            perb(s_pe),
            perb(s_mem), perb(s_mem), perb(s_mem), perb(s_mem), perb(s_mem),
            perb(s_mem), perb(s_mem),
            perb(n_noc), perb(n_noc), perb(n_noc), perb(n_noc), perb(n_noc),
            perb(N_NOCS), perb(n_wl),
        ],
        out_specs=[perb(t), perb(t), perb(n_wl), perb(N_SCAL),
                   perb(s_pe), perb(s_mem), perb(n_noc)],
        out_shape=[
            jax.ShapeDtypeStruct((b, t), jnp.float32),
            jax.ShapeDtypeStruct((b, t), jnp.int32),
            jax.ShapeDtypeStruct((b, n_wl), jnp.float32),
            jax.ShapeDtypeStruct((b, N_SCAL), jnp.float32),
            jax.ShapeDtypeStruct((b, s_pe), jnp.float32),
            jax.ShapeDtypeStruct((b, s_mem), jnp.float32),
            jax.ShapeDtypeStruct((b, n_noc), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((t, s_pe), jnp.float32),
            pltpu.VMEM((t, s_mem), jnp.float32),
            pltpu.VMEM((t, t), jnp.float32),
            pltpu.VMEM((t, t), jnp.float32),
        ],
        interpret=interpret,
    )(
        work, rd, wr, burst, pmask, wlhot,
        task_pe, task_mem, accel,
        pe_coeffs["pe_peak"], pe_coeffs["pe_pj"],
        pe_coeffs["pe_leak"], pe_coeffs["pe_area"], pe_coeffs["pe_noc"],
        pe_coeffs["pe_active"],
        mem_coeffs["mem_bw"], mem_coeffs["mem_pj"], mem_coeffs["mem_leak"],
        mem_coeffs["mem_area_fixed"], mem_coeffs["mem_area_per_mb"],
        mem_coeffs["mem_noc"], mem_coeffs["mem_active"],
        noc_arrays["noc_bw"], noc_arrays["noc_links"],
        noc_arrays["noc_leak"], noc_arrays["noc_area"],
        noc_arrays["noc_active"],
        nocs, wlbud,
    )
    return finish, bneck, wllat, scal, pe_bneck, mem_bneck, noc_bneck
