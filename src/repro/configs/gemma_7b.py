"""Gemma 7B [arXiv:2403.08295; hf:google/gemma-7b].

Dense: 28L, d_model=3072, 16 heads with head_dim=256 (q/k/v project to 4096 >
d_model — exercised explicitly), kv=16 (MHA on 7b; MQA on 2b), GeGLU with
d_ff=24576, vocab=256000 (the embedding-dominated assignment), embeddings
scaled by sqrt(d_model), tied LM head.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    vocab_size=256000,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    mlp_kind="geglu",
    rope_kind="rope",
    rope_theta=1e4,
    embed_scale=True,
    tie_embeddings=True,
    block_kinds=("attn",),
    mlp_kinds=("dense",),
)
