"""Shared power/area rollup used by both simulators (paper §3.2 "Power/Area
Modeling": AccelSeeker-style IP estimates + CACTI-style memory/NoC estimates,
here served by the parametric database)."""
from __future__ import annotations

from typing import Dict

from .blocks import BlockKind
from .database import HardwareDatabase
from .design import Design
from .tdg import TaskGraph


def mem_capacities(design: Design, tdg: TaskGraph) -> Dict[str, float]:
    """Bytes resident per memory block: each task's output buffer lives on its
    mapped memory (conservative, no liveness analysis)."""
    cap = {m: 0.0 for m in design.mems()}
    for t, m in design.task_mem.items():
        cap[m] += tdg.tasks[t].write_bytes
    return cap


def total_area_mm2(design: Design, tdg: TaskGraph, db: HardwareDatabase) -> float:
    cap = mem_capacities(design, tdg)
    area = 0.0
    for b in design.blocks.values():
        if b.kind == BlockKind.MEM and b.subtype == "sram":
            area += db.area.sram_mm2_per_mb * max(cap[b.name], 1.0) / 1e6
        else:
            area += db.block_area_mm2(b)
    return area


def total_leakage_w(design: Design, db: HardwareDatabase) -> float:
    return sum(db.leakage_w(b) for b in design.blocks.values())
