"""Exploration engine (paper §3.3–3.4, Algorithm 1).

Simulated annealing is the base search; FARSI augments its neighbour
generation with architectural reasoning. A neighbour is produced by choosing
the 5-tuple (Metric, Direction, Task, Block, Move):

  metric    — the one farthest from budget (co-design: changes per iteration)
  direction — +1 buy performance / −1 return it
  task      — highest distance contribution (critical-path duration for
              latency, dynamic energy for power)
  block     — the task's bottleneck block (Eq. 5 attribution)
  move      — Algorithm 1 reasoning + development-cost precedence
              (join > migrate > fork > swap > fork_swap), sampled
              probabilistically by precedence weight

All of that reasoning lives in the pluggable **policy layer**
(`repro.core.policy`): the Explorer owns the mechanics — neighbour
materialization, dispatch bookkeeping, the device chain-block driver — and
delegates every selection and accept decision to the
:class:`~repro.core.policy.HeuristicPolicy` named by
``ExplorerConfig.policy`` (default: derived from the historical
``awareness`` ladder — ``sa``/``task``/``task_block``/``farsi``, paper
Fig. 9b). Policies reason over :class:`~repro.core.backend.SimTelemetry`
views fed from the device-side bottleneck telemetry columns, so the
winner's full ``SimResult`` decode is paid ONCE per exploration (for the
returned best design), not per accepted move.

If no neighbour improves, the failed (task, block) target goes on the
policy's short taboo list so the next iteration targets "the task/block
with the next highest distance" (§3.4), and classic SA temperature
occasionally accepts a worse design.

For throughput-bound searches the host loop itself is the bottleneck (one
dispatch + one round trip per iteration); :meth:`Explorer.run_chains`
drives the device-resident formulation instead — fused (R, K) accept-loop
blocks priced in one dispatch each (`repro.core.device_explore`), with the
winning chain reconciled onto the live design between blocks.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Generator, List, Optional

import random

from .backend import Candidate, SimHandle, SimTelemetry, SimulatorBackend, make_backend
from .budgets import Budget, Distance
from .codesign import CodesignLedger, FocusRecord
from .database import HardwareDatabase
from .design import Design
from .device_explore import (
    ChainBlockResult,
    ChainRequest,
    reconcile_alloc,
    reconcile_mapping,
)
from .moves import MoveDelta, MoveSpec, apply_move
from .phase_sim import SimResult
from .policy import AWARENESS_POLICY, Focus, HeuristicPolicy, make_policy
from .tdg import TaskGraph, workload_of

AWARENESS_LEVELS = ("sa", "task", "task_block", "farsi")


@dataclasses.dataclass
class _Sel:
    """One dispatched iteration's selection context (the focus and the
    candidates a resolution needs back after its batch was scored)."""

    it: int
    focus: Focus
    neighbors: List["Candidate"]


@dataclasses.dataclass
class ExplorerConfig:
    awareness: str = "farsi"
    # HeuristicPolicy registry name (policy.POLICIES). Empty string — the
    # default — derives the policy from ``awareness`` (sa → naive_sa, … ,
    # farsi → farsi) so the historical knob keeps working; naming a policy
    # explicitly overrides the ladder (e.g. "bottleneck", "locality").
    policy: str = ""
    neighbors_per_iter: int = 4
    max_iterations: int = 1500
    seed: int = 0
    temperature0: float = 0.05
    temp_decay: float = 0.997
    alpha_met: float = 0.05
    dev_cost_aware: bool = True
    codesign: bool = True  # False => fixate focus until the focused metric is met
    taboo_ttl: int = 5
    backend: str = "python"  # SimulatorBackend registry name (backend.BACKENDS)
    # device-resident chain blocks (run_chains / serve chain-batched ticks):
    # chain_r > 0 opts the search into the fused accept loop — R independent
    # chains × K fused iterations per dispatch. chain_menu picks the device
    # move menu ("" derives it from the policy's ``device_menu``; see
    # device_explore.MENUS).
    chain_r: int = 0
    chain_k: int = 32
    chain_menu: str = ""
    # chain_alloc widens the device move table from mapping-only migrates to
    # the mixed mapping+allocation menu (PE/MEM fork/join/frequency-swap +
    # NoC attach over capacity-padded slot inventories). The whole run then
    # explores platform shape on device; the winning chain's platform is
    # reconciled onto the live design ONCE, after the last block
    # (device_explore.reconcile_alloc), so the seed encoding — which the
    # carry's fork provenance indexes — stays valid across blocks.
    chain_alloc: bool = False


@dataclasses.dataclass
class ExplorationResult:
    best_design: Design
    best_result: SimResult
    best_distance: Distance
    converged: bool
    iterations: int
    n_sims: int  # committed evaluations this search dispatched
    wall_s: float
    history: List[dict]
    ledger: CodesignLedger
    backend_name: str = "python"
    policy_name: str = "farsi"
    sim_wall_s: float = 0.0  # time inside backend.evaluate for this run
    chained: bool = False  # ran as device-resident (R, K) chain blocks
    chain_r: int = 0  # chain population size (chained runs only)

    def iterations_to_budget(self, cap: Optional[int] = None) -> float:
        """Iterations this run needed to reach budget — the policy-comparison
        metric (paper Fig. 9b): the iteration count when converged, else
        ``cap`` (default: the iterations actually run) as a censored floor."""
        if self.converged:
            return float(self.iterations)
        return float(cap if cap is not None else self.iterations)


class Explorer:
    def __init__(
        self,
        tdg: TaskGraph,
        db: HardwareDatabase,
        budget: Budget,
        config: ExplorerConfig = ExplorerConfig(),
        backend: Optional[SimulatorBackend] = None,
    ) -> None:
        self.tdg = tdg
        self.db = db
        self.budget = budget
        self.cfg = config
        assert config.awareness in AWARENESS_LEVELS
        self.rng = random.Random(config.seed)
        self.backend = backend or make_backend(config.backend, tdg, db)
        self.policy: HeuristicPolicy = make_policy(
            config.policy or AWARENESS_POLICY[config.awareness]
        )
        self.policy.bind(tdg, db, budget, config, self.rng)
        self.n_sims = 0  # designs this run submitted (backend stats aggregate
        # across sharers; this stays per-exploration under Campaign)
        self.n_nonfinite = 0  # candidate rows rejected for NaN/Inf fitness
        # crash-restart support (serve layer): when enabled, each committed
        # loop top snapshots (rng state, policy checkpoint, iteration) so a
        # dead coroutine can be rebuilt from its last committed accept
        self.track_restart = False
        self._restart_ck: Optional[tuple] = None
        # session-yield point (serve.Session): called whenever an accepted
        # move improves the best-so-far design, with a small event dict —
        # always from committed accept-path state, so every event is a
        # committed improvement
        self.on_improve: Optional[Callable[[dict], None]] = None

    # ---- neighbour generation --------------------------------------------
    def _make_neighbors(
        self, design: Design, focus: Focus, moves: List[str], n: int
    ) -> List[Candidate]:
        """Up to ``n`` *distinct* neighbours: one per move of the policy's
        ordered list (candidate generation in SA, §3.4).

        Clone-free: each move is trialled in place on ``design`` (checkpoint
        → apply, recording its encoding delta → rollback), and the neighbour
        is shipped to the backend as a lightweight :class:`Candidate` — the
        paper's Fig.-8b design-duplication hot-spot never runs. Only the
        accepted candidate is ever materialized (``Candidate.accept``)."""
        direction = +1 if focus.metric == "latency" else -1
        out: List[Candidate] = []
        ck = design.checkpoint()
        for move in moves:
            if len(out) >= n:
                break
            task = focus.task
            delta = MoveDelta()
            ok = apply_move(
                design, self.tdg, move, focus.block, task, direction,
                focus.bneck, focus.metric, self.rng, delta,
            )
            design.restore(ck)
            if not ok and move in ("fork", "fork_swap") and task:
                # a targeted fork is inapplicable when the focus task is the
                # block's anchor (it must stay — apply_fork refuses rather
                # than silently migrating a different task). The untargeted
                # fork — split half the hosted load — is the legitimate
                # relief move for that same congestion, so offer it instead.
                task = None
                delta = MoveDelta()
                ok = apply_move(
                    design, self.tdg, move, focus.block, None, direction,
                    focus.bneck, focus.metric, self.rng, delta,
                )
                design.restore(ck)
            if ok:
                spec = MoveSpec(
                    move, focus.block, task, direction, focus.bneck,
                    focus.metric,
                )
                out.append(
                    Candidate(
                        base=design, spec=spec, delta=delta,
                        budget=self.budget, alpha=self.cfg.alpha_met,
                    )
                )
        return out

    # ---- main loop ---------------------------------------------------------
    def run_steps(
        self, initial: Optional[Design] = None
    ) -> Generator[List[Candidate], List[SimHandle], ExplorationResult]:
        """Coroutine form of the search: yields each iteration's candidate
        batch (lightweight :class:`Candidate` records sharing the current
        design — no clones) and is resumed (``gen.send``) with the matching
        :class:`SimHandle` list. The winner is picked from the handles'
        fitness column (device-computed on the JAX backend); an accepted
        winner yields only a :class:`SimTelemetry` view (device bottleneck
        columns + host-exact scalars) for the policy's next selection — the
        full ``SimResult`` decode is paid once, at exploration end, for the
        returned best design.

        This is the HOST accept loop: one yield (one dispatch, one round
        trip) per SA iteration. Searches that only need the shape-preserving
        move menu should prefer :meth:`run_chains`, which fuses K iterations
        per dispatch on device and prices R chains at once.

        ``run()`` drives it against ``self.backend``; `Campaign` drives many
        explorers' generators in lockstep so one dispatch prices the pending
        neighbours of *all* live explorations. The ``StopIteration`` value
        is the :class:`ExplorationResult`."""
        t0 = time.perf_counter()
        cur = initial or Design.base(self.tdg)
        pol = self.policy
        self._cur = cur  # committed design (mutated in place on accept only)
        if self.track_restart:
            self._restart_ck = (self.rng.getstate(), pol.checkpoint(), 0)
        adopt = getattr(self.backend, "adopt_encoding", None)
        self.n_sims += 1
        (h0,) = yield [Candidate.of_design(cur, self.budget, self.cfg.alpha_met)]
        cur_view: SimTelemetry = h0.telemetry()
        cur_dist = cur_view.dist(self.budget)
        if adopt is not None:
            adopt(h0)
        # best keeps (handle, stable-name design snapshot): cur mutates in
        # place hereafter. The snapshot CLONE is deferred (best_stale) until
        # right after the next dispatch is submitted, so its dict-copy cost
        # hides behind the device scoring that batch — cur cannot mutate
        # again before then. The handle is decoded into the best SimResult
        # only at exploration end (the one decode the search pays).
        best_design, best_handle, best_dist = cur.clone(rename=False), h0, cur_dist
        best_stale = False
        history: List[dict] = []
        max_it = self.cfg.max_iterations

        def select_from(it: int) -> Optional[_Sel]:
            """The head of one serial iteration, from the CURRENT search
            state: policy taboo decay → focus selection → move proposal →
            neighbour generation; iterations yielding no neighbours are
            taboo'd and skipped. Returns None once the iteration budget is
            spent or the search converged (convergence only moves on
            accept)."""
            while it < max_it and not cur_dist.converged():
                pol.tick()
                focus = pol.select_focus(cur, cur_dist, cur_view)
                moves = pol.propose_moves(cur, focus)
                neighbors = self._make_neighbors(
                    cur, focus, moves, self.cfg.neighbors_per_iter
                )
                if neighbors:
                    return _Sel(it, focus, neighbors)
                pol.mark_failed(focus.task, focus.block)
                it += 1
            return None

        def resolve(sel: _Sel, handles: List[SimHandle], u: float) -> bool:
            """Rank batch ``sel`` from its fitness column (the one host pull
            that forces the dispatch) and run the policy's accept test with
            the pre-drawn uniform ``u`` — directly on that column: the
            backend's fitness IS Eq.-7 (device-computed on JAX,
            `budgets.distance` on Python), so a rejected iteration never
            reads anything else. Only an accepted winner yields its
            telemetry view for the next selection. Commits the accept-path
            state change; the reject-path taboo add is the caller's."""
            nonlocal cur_view, cur_dist, best_design, best_handle, best_dist, best_stale
            assert len(handles) == len(sel.neighbors)
            # stable argmin preserves the precedence order on ties; the
            # policy's move_penalty rides on the fitness column (0.0 — and
            # bit-neutral — for every policy but dev_cost, so the guard below
            # fires on the backend's fitness, not the penalty), so a system-
            # growing move must buy more PPA than its development cost.
            # Non-finite rows (a poisoned device row, a NaN that leaked
            # through the scal pull) are clamped to +inf so they lose every
            # ranking — argmin over NaN is undefined — and can never be
            # accepted even when the whole batch is poisoned
            fits = []
            for h, c in zip(handles, sel.neighbors):
                f = h.fitness + pol.move_penalty(cur, c)
                if not math.isfinite(f):
                    self.n_nonfinite += 1
                    f = float("inf")
                fits.append(f)
            j = min(range(len(fits)), key=fits.__getitem__)
            cand, move = sel.neighbors[j], sel.neighbors[j].spec.move
            d_before = cur_dist.fitness(self.cfg.alpha_met)
            accept = math.isfinite(fits[j]) and pol.accept(sel.it, d_before, fits[j], u)
            dist_after = None
            if accept:
                # telemetry view, not a decode: device bottleneck columns +
                # the host-exact scalar rollup the next selection needs
                if pol.needs_result:
                    view = SimTelemetry.of_result(
                        handles[j].result(), self.tdg, cand.base
                    )
                else:
                    view = handles[j].telemetry()
                dist_after = view.dist(self.budget)
            pol.record(
                FocusRecord(
                    iteration=sel.it,
                    metric=sel.focus.metric,
                    workload=workload_of(sel.focus.task),
                    comm_comp="comp" if sel.focus.bneck == "pe" else "comm",
                    move=move,
                    distance_before=cur_dist.city_block(),
                    distance_after=dist_after.city_block() if accept else cur_dist.city_block(),
                )
            )
            if accept:
                cand.accept(self.tdg)  # materialize the move onto cur
                if adopt is not None:
                    adopt(handles[j])  # cur's encoding == the winner's row
                cur_view, cur_dist = view, dist_after
                if cur_dist.city_block() < best_dist.city_block():
                    best_handle, best_dist, best_stale = handles[j], cur_dist, True
                    if self.on_improve is not None:
                        # streamed best-design-so-far event: scalars only
                        # (the batch is already forced by the fitness read;
                        # no decode) — the full design decode stays deferred
                        # to exploration end
                        self.on_improve(
                            {
                                "iteration": sel.it,
                                "distance": best_dist.city_block(),
                                "fitness": best_dist.fitness(self.cfg.alpha_met),
                                "move": move,
                                "converged": best_dist.converged(),
                                **handles[j].scalars(),
                            }
                        )
            history.append(
                {
                    "iteration": sel.it,
                    "n_sims": self.n_sims,
                    "distance": best_dist.city_block(),
                    "fitness": best_dist.fitness(self.cfg.alpha_met),
                    "metric": sel.focus.metric,
                    "move": move,
                    "accepted": accept,
                    "wall_s": time.perf_counter() - t0,
                }
            )
            return accept

        sel = select_from(0)
        if sel is not None:
            self.n_sims += len(sel.neighbors)
            handles = yield sel.neighbors
        while sel is not None:
            # loop-top state is always the committed truth: cur only mutates
            # on accept — the one safe point to snapshot for crash-restart
            if self.track_restart:
                self._restart_ck = (self.rng.getstate(), pol.checkpoint(), sel.it)
            # the SA accept draw: consumed BEFORE the next iteration's
            # selection draws, so the rng stream is a pure function of the
            # accepted-move sequence
            u = self.rng.random()
            accepted = resolve(sel, handles, u)  # first host pull forces batch i
            if not accepted:
                pol.mark_failed(sel.focus.task, sel.focus.block)
            sel = select_from(sel.it + 1)
            if sel is None:
                break
            self.n_sims += len(sel.neighbors)
            handles = yield sel.neighbors
            if best_stale:  # deferred snapshot: hides behind the dispatch
                best_design, best_stale = cur.clone(rename=False), False

        if best_stale:
            best_design = cur.clone(rename=False)
        # the exploration's ONE full decode: the returned best result, read
        # against the stable best-design snapshot (the winner's own base has
        # long since mutated past the priced state)
        best_res = best_handle.result_for(best_design)
        return ExplorationResult(
            best_design=best_design,
            best_result=best_res,
            best_distance=best_dist,
            converged=best_dist.converged(),
            iterations=len(history),
            n_sims=self.n_sims,
            wall_s=time.perf_counter() - t0,
            history=history,
            ledger=pol.ledger,
            backend_name=self.backend.name,
            policy_name=pol.name,
        )

    def restart_state(self) -> Optional[dict]:
        """Crash-restart snapshot (serve layer; ``track_restart`` must have
        been on). Returns the last committed accept's ``design`` clone, the
        ``rng``/``policy`` state to restore onto a fresh Explorer, and the
        ``iteration`` the search had reached — or None if the coroutine died
        before the tracking was primed."""
        ck = self._restart_ck
        cur = getattr(self, "_cur", None)
        if ck is None or cur is None:
            return None
        rng_state, pol_ck, it = ck
        return {
            "design": cur.clone(rename=False),
            "rng": rng_state,
            "policy": pol_ck,
            "iteration": it,
        }

    def run(self, initial: Optional[Design] = None) -> ExplorationResult:
        """Drive :meth:`run_steps` against ``self.backend`` — exactly one
        ``backend.evaluate_candidates`` call per search iteration (plus one
        for the initial design). Drains any in-flight dispatch on exit."""
        gen = self.run_steps(initial)
        sim_wall = 0.0
        try:
            pending = next(gen)
            while True:
                t0 = time.perf_counter()
                handles = self.backend.evaluate_candidates(pending)
                sim_wall += time.perf_counter() - t0
                pending = gen.send(handles)
        except StopIteration as stop:
            flush = getattr(self.backend, "flush", None)
            if flush is not None:
                flush()
            result: ExplorationResult = stop.value
            result.sim_wall_s = sim_wall
            return result

    # ---- device-resident chain blocks -------------------------------------
    def run_chain_steps(
        self, initial: Optional[Design] = None
    ) -> Generator[object, list, ExplorationResult]:
        """Chain-batched coroutine form of the search: instead of yielding a
        candidate list per SA iteration, yields one :class:`ChainRequest`
        per fused (R, K) device block and is resumed with the matching
        :class:`ChainBlockResult` (wrapped in a one-element list, so the
        serve ``Session`` send protocol is unchanged). Between blocks the
        winning chain's final mapping is reconciled onto the live design and
        the device carry is stored on the policy (``device_sa`` checkpoints
        it, so crash restart resumes mid-population). The FINAL yield is an
        ordinary one-candidate batch: the winner pays the usual single
        decode, and nothing else in the search is ever decoded."""
        t0 = time.perf_counter()
        cfg = self.cfg
        r = max(1, cfg.chain_r)
        k = max(1, cfg.chain_k)
        menu = cfg.chain_menu or getattr(self.policy, "device_menu", "naive_sa")
        cur = initial or Design.base(self.tdg)
        pol = self.policy
        self._cur = cur
        carry = getattr(pol, "device_carry", None)
        history: List[dict] = []
        it, max_it = 0, cfg.max_iterations
        res: Optional[ChainBlockResult] = None
        while it < max_it:
            kk = min(k, max_it - it)
            req = ChainRequest(
                design=cur, budget=self.budget, r=r, k=kk, seed=cfg.seed,
                it0=it, menu=menu, alpha=cfg.alpha_met,
                temperature0=cfg.temperature0, temp_decay=cfg.temp_decay,
                taboo_ttl=cfg.taboo_ttl, carry=carry, alloc=cfg.chain_alloc,
            )
            (res,) = yield req
            self.n_sims += r * kk
            carry = res.carry
            if hasattr(pol, "device_carry"):
                pol.device_carry = carry
            if self.track_restart:
                self._restart_ck = (self.rng.getstate(), pol.checkpoint(), it + kk)
            w = res.winner
            for s in range(kk):
                history.append(
                    {
                        "iteration": it + s,
                        "n_sims": self.n_sims,
                        # device path: the trace is the winner chain's Eq.-7
                        # fitness (its city-block distance is only known
                        # after the final decode)
                        "fitness": float(res.fit_trace[w, s]),
                        "move": "chain_mixed" if cfg.chain_alloc
                        else "chain_migrate",
                        "accepted": bool(res.accepted[w, s]),
                        "wall_s": time.perf_counter() - t0,
                    }
                )
            it += kk
            if cfg.chain_alloc:
                # allocation state lives in the carry; the design must stay
                # the seed the provenance columns index, so nothing is
                # reconciled until the run ends (below)
                changed = {"task_pe": {}, "task_mem": {}}
            else:
                changed = reconcile_mapping(
                    cur, res, self.tdg, self.db, self._chain_enc()
                )
            if self.on_improve is not None and (
                changed["task_pe"] or changed["task_mem"]
            ):
                self.on_improve(
                    {
                        "iteration": it,
                        "fitness": float(res.fitness[w]),
                        "move": "chain_block",
                        "chains": r,
                        "changed": sum(map(len, changed.values())),
                    }
                )
        if cfg.chain_alloc and res is not None:
            # one shape change per search: replay the winning chain's
            # platform (clones, removals, retunes, re-homes, mapping)
            # through the moves.py allocation bridge
            reconcile_alloc(cur, res, self.tdg, self.db, self._chain_enc())
        # the ONE decode of the search: the reconciled winner
        self.n_sims += 1
        (h,) = yield [Candidate.of_design(cur, self.budget, cfg.alpha_met)]
        best_dist = h.telemetry().dist(self.budget)
        best_design = cur.clone(rename=False)
        return ExplorationResult(
            best_design=best_design,
            best_result=h.result_for(best_design),
            best_distance=best_dist,
            converged=best_dist.converged(),
            iterations=it,
            n_sims=self.n_sims,
            wall_s=time.perf_counter() - t0,
            history=history,
            ledger=pol.ledger,
            backend_name=self.backend.name,
            policy_name=pol.name,
            chained=True,
            chain_r=r,
        )

    def _chain_enc(self):
        """The backend's cached workload encoding when it has one (so slot
        dicts match its rows), else a lazily-built local one."""
        enc = getattr(self.backend, "_enc", None)
        if enc is None:
            enc = getattr(self, "_own_enc", None)
            if enc is None:
                from .phase_sim_jax import EncodedWorkload

                enc = self._own_enc = EncodedWorkload.of(self.tdg)
        return enc

    def run_chains(self, initial: Optional[Design] = None) -> ExplorationResult:
        """Drive :meth:`run_chain_steps` against ``self.backend`` — one
        ``backend.run_chains`` dispatch per (R, K) block (the backend must
        support device chains, i.e. expose ``run_chains``), plus the final
        winner decode through the ordinary candidate path."""
        if not hasattr(self.backend, "run_chains"):
            raise ValueError(
                f"backend {self.backend.name!r} does not support device "
                "chain blocks (no run_chains)"
            )
        gen = self.run_chain_steps(initial)
        sim_wall = 0.0
        try:
            pending = next(gen)
            while True:
                t0 = time.perf_counter()
                if isinstance(pending, ChainRequest):
                    answer = [self.backend.run_chains(pending)]
                else:
                    answer = self.backend.evaluate_candidates(pending)
                sim_wall += time.perf_counter() - t0
                pending = gen.send(answer)
        except StopIteration as stop:
            flush = getattr(self.backend, "flush", None)
            if flush is not None:
                flush()
            result: ExplorationResult = stop.value
            result.sim_wall_s = sim_wall
            return result
