"""Pallas TPU flash-attention kernel (causal, GQA).

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks) — the kv axis is
innermost, so for each (b, h, i) the online-softmax state (m, l, acc) carries
across kv iterations in VMEM scratch (TPU grid execution is sequential).
BlockSpecs tile q/out to (q_block, head_dim) and k/v to (kv_block, head_dim)
VMEM blocks; GQA maps query head h to kv head h·KH//H in the index map, so
grouped heads re-read the same KV tile (VMEM-resident — no HBM re-fetch
between consecutive h with the same kv head).

Fully-masked causal blocks (block_start_col > block_end_row) skip their
matmuls via ``pl.when`` — the MXU does no work above the diagonal, unlike the
masked-dense reference (the §Perf win this kernel exists for).

Block shapes default to (512, 128-aligned head_dim): q·kᵀ tiles of
512×1024×fp32 ≈ 2 MB and two (kv_block, dh) operand tiles keep the working
set well inside the ~16 MB/core VMEM budget while giving the MXU
128-multiple contraction dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, qb, dh)
    k_ref,  # (1, 1, kb, dh)
    v_ref,  # (1, 1, kb, dh)
    o_ref,  # (1, 1, qb, dh)
    m_ref,  # VMEM (qb, 1) f32
    l_ref,  # VMEM (qb, 1) f32
    acc_ref,  # VMEM (qb, dh) f32
    *,
    causal: bool,
    scale: float,
    q_block: int,
    kv_block: int,
    nkv: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal block skip: the first row of this q block vs last col of kv block
    block_live = (not causal) or (i + 1) * q_block > j * kv_block

    def compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (qb, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (kb, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (qb, kb)
        if causal:
            rows = i * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]  # (qb, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)  # (qb, 1)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        pl.when(block_live)(compute)
    else:
        compute()

    @pl.when(j == nkv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_bhsd(
    q: jax.Array,  # (B, H, Sq, Dh)
    k: jax.Array,  # (B, KH, Skv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    b, h, sq, dh = q.shape
    kh, skv = k.shape[1], k.shape[2]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    assert sq % q_block == 0 and skv % kv_block == 0
    assert h % kh == 0
    nq, nkv = sq // q_block, skv // kv_block
    scale = 1.0 / math.sqrt(dh)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        scale=scale,
        q_block=q_block,
        kv_block=kv_block,
        nkv=nkv,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec(
                (1, 1, kv_block, dh), lambda b_, h_, i, j: (b_, h_ * kh // h, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, kv_block, dh), lambda b_, h_, i, j: (b_, h_ * kh // h, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, dh), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, 1), jnp.float32),
            pltpu.VMEM((q_block, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
