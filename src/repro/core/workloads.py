"""The three AR workloads (paper §2.2–2.3, Fig. 2, Table 1).

TDG structures follow the paper's description: Audio has 15 tasks and the
highest task-level parallelism; CAVA is a serial ISP pipeline (TaLP = 1);
Edge Detection has 6 tasks, modest TaLP (4) and the highest LLP / data
movement. Per-task Gables numbers are spread deterministically around the
Table-1 per-task averages (the paper's appendix task tables are not in the
text) so that every Table-1 average is matched exactly.

Budgets: Table 4a gives 21/34/34 ms latencies with 8.737 mW / 17.475 mm²
system budgets at 5 nm. Those power numbers are not reachable under *any*
physical pJ/op constant given Table 1's own op counts (CAVA alone runs
~170 Gops per 34 ms frame → ≥1 W at 5 nm-class 0.3 pJ/op; the paper's internal
AccelSeeker database evidently counts "ops" differently). We therefore keep
the paper's latency budgets and latency *ratios*, and calibrate power/area
budgets against our own database (``calibrated_budget``) so that convergence
experiments are demanding but feasible — see EXPERIMENTS.md §Deviations.
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
from typing import Dict, List, Optional

from .budgets import Budget
from .database import HardwareDatabase
from .tdg import Task, TaskGraph, merge_graphs

MOPS = 1e6
MB = 1e6


def _spread(center: float, names: List[str], lo: float = 0.5, hi: float = 1.5) -> Dict[str, float]:
    """Deterministic per-task factors in [lo, hi], rescaled to preserve the
    mean exactly (Table-1 values are per-task averages)."""
    raw = {}
    for n in names:
        h = int.from_bytes(hashlib.sha256(n.encode()).digest()[:8], "big") / 2**64
        raw[n] = lo + (hi - lo) * h
    mean = sum(raw.values()) / len(raw)
    return {n: center * v / mean for n, v in raw.items()}


def audio() -> TaskGraph:
    """Audio decoder: pose-driven soundfield rotation/zoom + speaker mapping.
    15 tasks: source-decode → 8 parallel ambisonic channel encoders → combine
    → 4 parallel band rotate/zoom stages → binaural mix (high TaLP)."""
    g = TaskGraph("audio")
    names = (
        ["src_decode"]
        + [f"enc_ch{i}" for i in range(8)]
        + ["combine"]
        + [f"rotzoom_b{i}" for i in range(4)]
        + ["binaural_mix"]
    )
    f = _spread(13 * MOPS, names)
    llp = _spread(2392.0, names)
    for n in names:
        g.add_task(
            Task(n, work_ops=f[n], i_read=8.0, i_write=12.0, llp=llp[n], burst_bytes=256)
        )
    edge = 0.19 * MB  # Table-1 average data movement per task
    for i in range(8):
        g.add_edge("src_decode", f"enc_ch{i}", edge)
        g.add_edge(f"enc_ch{i}", "combine", edge)
    for i in range(4):
        g.add_edge("combine", f"rotzoom_b{i}", edge)
        g.add_edge(f"rotzoom_b{i}", "binaural_mix", edge)
    g.validate()
    return g


def cava() -> TaskGraph:
    """CAVA camera-vision ISP pipeline (Nikon-D7000-modelled kernel): a strict
    serial chain — TaLP = 1, only loop-level parallelism (Table 1)."""
    g = TaskGraph("cava")
    names = ["scale", "demosaic", "denoise", "wbalance", "cspace", "gamut", "tonemap"]
    f = _spread(24_252 * MOPS, names)
    llp = _spread(151.0, names)
    for n in names:
        g.add_task(
            Task(n, work_ops=f[n], i_read=67e3, i_write=74e3, llp=llp[n], burst_bytes=1024)
        )
    for a, b in zip(names, names[1:]):
        g.add_edge(a, b, 0.33 * MB)
    g.validate()
    return g


def edge_detection() -> TaskGraph:
    """Edge detection: 6 tasks, gradient operators run in parallel (TaLP = 4),
    massive LLP (per-pixel independence) and the highest data movement."""
    g = TaskGraph("ed")
    names = ["grayscale", "gauss_blur", "grad_x", "grad_y", "laplacian", "magnitude"]
    f = _spread(1_098 * MOPS, names)
    llp = _spread(1_365_376.0, names)
    for n in names:
        g.add_task(
            Task(n, work_ops=f[n], i_read=126.0, i_write=1.23e6, llp=llp[n], burst_bytes=4096)
        )
    g.add_edge("grayscale", "gauss_blur", 7.01 * MB)
    for n in ("grad_x", "grad_y", "laplacian"):
        g.add_edge("gauss_blur", n, 7.01 * MB)
        g.add_edge(n, "magnitude", 7.01 * MB)
    g.validate()
    return g


def all_workloads() -> Dict[str, TaskGraph]:
    return {"audio": audio(), "cava": cava(), "ed": edge_detection()}


def ar_complex() -> TaskGraph:
    """The §5 SoC scenario: all three workloads running together."""
    return merge_graphs(all_workloads().values(), name="ar_complex")


PAPER_LATENCY_S = {"audio": 21e-3, "cava": 34e-3, "ed": 34e-3}


def paper_budget() -> Budget:
    """Table 4a verbatim (see module docstring for why power/area are not
    directly usable with our stand-in database)."""
    return Budget(latency_s=dict(PAPER_LATENCY_S), power_w=8.737e-3, area_mm2=17.475)


def ideal_latency_s(g: TaskGraph, db: HardwareDatabase) -> float:
    """Critical-path latency with every task on its own maxed accelerator and
    infinite bandwidth — the analytic floor used for budget calibration."""
    best: Dict[str, float] = {}
    for name, t in g.tasks.items():
        p = db.gpp_ops_per_cycle * 800e6 * db.a_peak(name, t.llp, 1024)
        best[name] = t.work_ops / p

    memo: Dict[str, float] = {}

    def finish(n: str) -> float:
        if n not in memo:
            memo[n] = best[n] + max((finish(p) for p in g.parents[n]), default=0.0)
        return memo[n]

    return max(finish(n) for n in g.tasks)


def _power_area_rails(
    graphs, db: HardwareDatabase, lat_s: float,
    power_slack: float, area_slack: float,
):
    """Shared power/area budget rails: best-case dynamic energy
    (all-accelerator, all-SRAM) spread over ``lat_s`` plus a base leakage,
    and one hardened IP per task + modest NoC/Mem overhead. Used by both
    `calibrated_budget` (paper workloads) and `synthetic_budget` (generated
    scenarios) so the floor model stays in one place."""
    e_floor = 0.0
    n_tasks = 0
    for g in graphs:
        for t in g.tasks.values():
            e_floor += t.work_ops * db.energy.acc_pj_per_op * 1e-12
            e_floor += t.data_bytes * db.energy.sram_pj_per_byte * 1e-12
            n_tasks += 1
    base_leak_w = n_tasks * db.energy.acc_leak_w + 10e-3
    power = power_slack * (e_floor / lat_s + base_leak_w)
    area = area_slack * (
        n_tasks * db.area.acc_mm2 + 2 * db.area.dram_phy_mm2 + 2.0
    )
    return power, area


def calibrated_budget(
    db: HardwareDatabase,
    latency_slack: float = 8.0,
    power_slack: float = 1.2,
    area_slack: float = 1.15,
) -> Budget:
    """Budgets derived from analytic floors × slack so they are demanding but
    feasible under our stand-in PPA database (see module docstring):

      latency — per-workload critical-path floor × slack (at least the
                paper's Table-4a value, preserving the 21:34:34 ratio)
      power   — best-case dynamic energy (all-accelerator, all-SRAM) spread
                over the slowest latency budget, plus a base leakage
      area    — one hardened IP per task + modest NoC/Mem overhead
    """
    lats = {}
    for name, g in all_workloads().items():
        floor = ideal_latency_s(g, db)
        lats[name] = max(PAPER_LATENCY_S[name], floor * latency_slack)

    power, area = _power_area_rails(
        all_workloads().values(), db, max(lats.values()), power_slack, area_slack
    )
    return Budget(latency_s=lats, power_w=power, area_mm2=area)


# ---------------------------------------------------------------------------
# generative scenario family (policy × scenario sweeps)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Scenario:
    """One synthetic exploration scenario: a generated TDG plus a budget
    calibrated against that graph's own analytic floors, ready to drop into
    a ``Campaign`` run grid."""

    name: str
    tdg: TaskGraph
    budget: Budget


# archetype envelopes bracketing the three AR workloads (Table 1): op count
# per task, operational intensities, LLP, burst, and edge data movement
_ARCHETYPES = {
    # audio-like: small tasks, wide fan-out, modest data movement
    "audio": dict(ops=(5, 40), i_rd=(4.0, 16.0), i_wr=(6.0, 24.0),
                  llp=(500.0, 5000.0), burst=256, edge_mb=(0.05, 0.4)),
    # cava-like: op-heavy serial stages, very high intensity
    "cava": dict(ops=(5_000, 40_000), i_rd=(30e3, 120e3), i_wr=(40e3, 140e3),
                 llp=(50.0, 400.0), burst=1024, edge_mb=(0.1, 0.6)),
    # ed-like: write-dominated, massive LLP, heavy data movement
    "ed": dict(ops=(300, 3_000), i_rd=(60.0, 300.0), i_wr=(0.5e6, 3e6),
               llp=(2e5, 3e6), burst=4096, edge_mb=(2.0, 10.0)),
}


def synthetic_budget(
    g: TaskGraph,
    db: HardwareDatabase,
    speedup_target: float = 8.0,
    power_slack: float = 1.4,
    area_slack: float = 1.2,
) -> Budget:
    """`calibrated_budget` for a single generated graph — demanding but
    feasible, so iterations-to-budget is a meaningful cross-policy metric on
    every scenario.

    The latency budget is calibrated against a *simulation of the base
    design* (everything on one GPP + one DRAM): budget = base latency /
    ``speedup_target``. The fully-idealized analytic floor
    (`ideal_latency_s`) is useless here — high-LLP archetypes put it 3–4
    orders of magnitude below anything a bounded search reaches, which
    would turn every scenario into a censored non-convergence. A base-
    relative target instead demands real optimization (hardening, forking,
    memory re-mapping) that an architecture-aware policy finds in tens of
    iterations. Power/area keep the analytic-floor × slack calibration of
    `calibrated_budget` (they are the non-binding guard rails)."""
    from .design import Design
    from .phase_sim import simulate

    base = simulate(Design.base(g), g, db)
    lat = base.latency_s / speedup_target
    power, area = _power_area_rails([g], db, lat, power_slack, area_slack)
    return Budget(latency_s={g.name: lat}, power_w=power, area_mm2=area)


def synthetic_family(
    seed: int = 0,
    n: int = 6,
    db: Optional[HardwareDatabase] = None,
    min_tasks: int = 6,
    max_tasks: int = 16,
    speedup_target: float = 8.0,
) -> List[Scenario]:
    """Generate ``n`` randomized AR-like TDG scenarios (+ calibrated budgets).

    Each scenario is built stage-wise from the structural motifs of the
    paper's workloads — serial **chains** (CAVA), **fan-outs** into parallel
    stages (Audio's channel encoders, ED's gradient operators), and
    **merges** back into a combiner — with per-task Gables characteristics
    drawn from one of three archetype envelopes bracketing Table 1, jittered
    per task. Graphs are DAGs by construction (edges only flow from the open
    frontier to newly minted tasks) and every graph closes on a single sink,
    so ``validate()`` holds for any (seed, n).

    Budgets come from :func:`synthetic_budget`: base-design-relative latency
    targets plus analytic-floor power/area rails — demanding but feasible,
    so iterations-to-budget is a meaningful cross-policy comparison on every
    scenario. Deterministic in ``seed``: scenario *i* only consumes scenario
    *i*'s sub-rng."""
    db = db or HardwareDatabase()
    out: List[Scenario] = []
    for i in range(n):
        rng = random.Random((seed << 16) ^ (0x5EED + i))
        arch = _ARCHETYPES[rng.choice(sorted(_ARCHETYPES))]
        name = f"syn{seed}_{i}"
        g = TaskGraph(name)
        n_tasks = rng.randint(min_tasks, max_tasks)

        def mk_task(tag: str) -> str:
            ops = rng.uniform(*arch["ops"]) * MOPS
            t = Task(
                tag,
                work_ops=ops,
                i_read=rng.uniform(*arch["i_rd"]),
                i_write=rng.uniform(*arch["i_wr"]),
                llp=rng.uniform(*arch["llp"]),
                burst_bytes=arch["burst"],
            )
            g.add_task(t)
            return tag

        def edge(a: str, b: str) -> None:
            g.add_edge(a, b, rng.uniform(*arch["edge_mb"]) * MB)

        frontier = [mk_task("t0_src")]
        k = 1
        while k < n_tasks - 1:
            motif = rng.choices(
                ("chain", "fanout", "merge"), weights=(3, 3, 2)
            )[0]
            if motif == "fanout" and k + 2 <= n_tasks - 1:
                src = rng.choice(frontier)
                width = min(rng.randint(2, 4), n_tasks - 1 - k)
                kids = [mk_task(f"t{k + j}_fan") for j in range(width)]
                for c in kids:
                    edge(src, c)
                frontier.remove(src)
                frontier.extend(kids)
                k += width
            elif motif == "merge" and len(frontier) >= 2:
                m = rng.randint(2, len(frontier))
                srcs = rng.sample(frontier, m)
                t = mk_task(f"t{k}_merge")
                for s in srcs:
                    edge(s, t)
                frontier = [f for f in frontier if f not in srcs] + [t]
                k += 1
            else:  # chain
                src = rng.choice(frontier)
                t = mk_task(f"t{k}_chain")
                edge(src, t)
                frontier[frontier.index(src)] = t
                k += 1
        sink = mk_task(f"t{k}_sink")
        for s in frontier:
            edge(s, sink)
        g.validate()
        out.append(
            Scenario(name, g, synthetic_budget(g, db, speedup_target=speedup_target))
        )
    return out
