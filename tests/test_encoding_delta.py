"""Array-native DSE hot path: incremental encoding ≡ from-scratch encoding
(bit-identical, per move kind), checkpoint/restore symmetry, bounded jit
shapes over a long exploration, lazy SimHandle decode, and the >8-link NoC
segment regression."""
import random

import numpy as np
import pytest

from repro.core import (
    Candidate,
    Design,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    JaxBatchedBackend,
    PythonBackend,
    ar_complex,
    calibrated_budget,
    edge_detection,
    random_single_noc_designs,
)
from repro.core.moves import MOVE_KINDS, MoveDelta, apply_move
from repro.core.phase_sim_jax import EncodedDesign, EncodedWorkload, apply_delta

_ED_FIELDS = (
    "task_pe", "task_mem", "pe_accel",
    "pe_peak", "pe_pj", "pe_leak", "pe_area", "pe_noc",
    "mem_bw", "mem_pj", "mem_leak", "mem_area_fixed", "mem_area_per_mb",
    "mem_noc",
    "noc_bw", "noc_links", "noc_leak", "noc_area",
)


def _assert_bit_identical(got: EncodedDesign, ref: EncodedDesign, ctx) -> None:
    for f in _ED_FIELDS:
        a, b = getattr(got, f), getattr(ref, f)
        assert a.dtype == b.dtype and a.shape == b.shape, (ctx, f)
        assert np.array_equal(a, b), (ctx, f, a, b)
    assert got.pe_slot == ref.pe_slot and got.mem_slot == ref.mem_slot, ctx
    assert got.noc_slot == ref.noc_slot, ctx


@pytest.mark.parametrize("move", MOVE_KINDS)
def test_delta_encoding_bit_identical_per_move_kind(move):
    """Every move kind: the delta-applied encoding equals a from-scratch
    ``EncodedDesign.of`` of the mutated design, bit for bit — and the
    checkpoint rollback returns the design to its exact pre-move state."""
    db = HardwareDatabase()
    g = ar_complex()
    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, 6, seed=17)
    tasks = sorted(g.tasks)
    rng = random.Random(23)
    applied = 0
    for i, d in enumerate(designs):
        base_enc = EncodedDesign.of(d, g, db, enc)
        sig0 = d.signature()
        for trial in range(8):
            block = rng.choice(list(d.blocks))
            task = rng.choice(tasks)
            direction = rng.choice([-1, 1])
            ck = d.checkpoint()
            delta = MoveDelta()
            ok = apply_move(
                d, g, move, block, task, direction,
                rng.choice(["pe", "mem", "noc"]),
                rng.choice(["latency", "power", "area"]),
                random.Random(0), delta,
            )
            if not ok:
                d.restore(ck)
                continue
            # every built-in move — NoC fork/join included — now records an
            # encodable delta; `topology` stays False throughout
            assert not delta.topology, (move, i, trial)
            ref = EncodedDesign.of(d, g, db, enc)
            d.restore(ck)
            assert d.signature() == sig0, (move, i, trial)
            got = apply_delta(base_enc, delta, d, g, db, enc)
            _assert_bit_identical(got, ref, (move, i, trial))
            # the base encoding itself must be untouched (it is a live cache)
            _assert_bit_identical(base_enc, EncodedDesign.of(d, g, db, enc), (move, i))
            applied += 1
    assert applied >= 3, f"move {move!r} never applied — test vacuous"


def test_candidate_evaluation_matches_python_on_moved_candidates():
    """Candidates (base + recorded delta) price identically through the
    vectorized path and the scalar path — fitness column included. Uses the
    same candidate-batch builder as the throughput benchmark so test and
    bench exercise identical candidate shapes."""
    from benchmarks.bench_simbackend import make_candidates

    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    base = random_single_noc_designs(g, 1, seed=5)[0]
    cands = make_candidates(g, base, bud, 12, seed=7)
    hp = PythonBackend(g, db).evaluate_candidates(cands)
    hj = JaxBatchedBackend(g, db).evaluate_candidates(cands)
    for k, (a, b) in enumerate(zip(hp, hj)):
        assert abs(a.fitness - b.fitness) / max(abs(a.fitness), 1e-9) < 1e-3, k
        ra, rb = a.result(), b.result()
        assert abs(ra.latency_s - rb.latency_s) / ra.latency_s < 1e-4, k
        assert ra.task_bottleneck == rb.task_bottleneck, k


def test_accepted_fork_keeps_decoded_block_names():
    """Replays are name-deterministic: after decoding a fork candidate's
    result and accepting it, every block the result references exists in the
    accepted design (a naive replay would re-clone the forked block under a
    fresh uid, leaving task_bottleneck_block/mem_capacity_bytes dangling and
    silently degrading the explorer's block-selection heuristics)."""
    from benchmarks.bench_simbackend import make_candidates

    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    base = random_single_noc_designs(g, 1, seed=3)[0]
    cands = [c for c in make_candidates(g, base, bud, 24, seed=11) if c.delta.added]
    assert cands, "no fork candidates generated — test vacuous"
    for be in (JaxBatchedBackend(g, db), PythonBackend(g, db)):
        c = cands[0]
        res = be.evaluate_candidates([c])[0].result()
        ck = base.checkpoint()
        c.accept(g)
        try:
            assert set(res.task_bottleneck_block.values()) <= set(base.blocks)
            assert set(res.mem_capacity_bytes) == set(base.mems())
            for t, pe in base.task_pe.items():
                assert pe in base.blocks, t
        finally:
            base.restore(ck)


def test_lazy_handles_decode_only_on_access():
    """Consuming the fitness column must not decode any SimResult; only the
    accessed handle pays ``result()``. Timing breakdown fields populate."""
    db = HardwareDatabase()
    g = edge_detection()
    bud = calibrated_budget(db)
    jb = JaxBatchedBackend(g, db)
    cands = [Candidate.of_design(d, bud) for d in random_single_noc_designs(g, 8, seed=2)]
    handles = jb.evaluate_candidates(cands)
    fits = [h.fitness for h in handles]
    assert all(np.isfinite(f) for f in fits)
    assert all(h._res is None for h in handles), "fitness access must not decode"
    j = int(np.argmin(fits))
    res = handles[j].result()
    ref = PythonBackend(g, db).evaluate([cands[j].base])[0]
    assert abs(res.latency_s - ref.latency_s) / ref.latency_s < 1e-4
    assert sum(1 for h in handles if h._res is not None) == 1
    s = jb.stats()
    assert s.encode_s > 0.0 and s.dispatch_s > 0.0 and s.decode_s > 0.0
    # scalar PPA columns come from the same shared batch pull, no decode
    sc = handles[(j + 1) % len(handles)].scalars()
    assert set(sc) == {"latency_s", "power_w", "area_mm2"}
    assert handles[(j + 1) % len(handles)]._res is None


def test_jit_shape_bucket_stays_bounded_over_long_exploration():
    """200 search iterations must stay within ≤4 compiled shapes (pow-2
    padded slot/batch/link buckets) — recompiles are the throughput killer."""
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db).scaled(0.25)  # tight: keeps the search running
    ex = Explorer(g, db, bud, ExplorerConfig(max_iterations=200, seed=9, backend="jax"))
    res = ex.run()
    s = ex.backend.stats()
    assert res.iterations >= 150, "exploration ended too early to exercise shapes"
    assert s.n_compiles <= 4, s
    assert s.n_batched > 0


def test_noc_links_beyond_eight_segment_regression():
    """A design with >8 NoC links must price identically through both
    backends. The old kernel segment-summed link shares over a hardcoded 8
    segments: links 8+ lost their bandwidth attribution and their tasks
    arbitrated against link 7's burst total (out-of-bounds gather clamp) —
    on this scenario that mis-prices NoC-bound finish times by ~2x (97%
    relative error). The rank-residue striping formulation is exact for any
    link count.

    Scenario: 12 independent NoC-bound tasks (own 800 MHz GPP each, fat
    memory, narrow 16-link NoC) with small bursts on stripe orders 0–7 and
    large bursts on 8–11, so the clamped share would be ≫1."""
    from repro.core.blocks import make_gpp
    from repro.core.tdg import Task, TaskGraph

    db = HardwareDatabase()
    g = TaskGraph("wide")
    for k in range(12):
        burst = 64.0 if k < 8 else 4096.0
        g.add_task(Task(f"t{k:02d}", work_ops=1e6, i_read=0.1, i_write=1e6,
                        burst_bytes=burst))
    g.validate()

    d = Design.base(g)
    noc = d.blocks[d.noc_chain[0]]
    noc.n_links = 16
    noc.width_bytes = 4
    mem = d.blocks[d.mems()[0]]
    mem.freq_mhz, mem.width_bytes = 800, 256
    for k, t in enumerate(sorted(g.tasks)):
        if k:
            d.task_pe[t] = d.add_block(make_gpp(800), attach_to=noc.name).name

    ref = PythonBackend(g, db).evaluate([d])[0]
    got = JaxBatchedBackend(g, db).evaluate([d])[0]
    assert abs(got.latency_s - ref.latency_s) / ref.latency_s < 1e-4
    for t, f in ref.task_finish_s.items():
        assert abs(got.task_finish_s[t] - f) / max(f, 1e-12) < 1e-4, t
