"""SimulatorBackend shoot-out: scalar-Python vs array-native JAX evaluation.

Measures the DSE hot path the perf work targets, and writes it to
``BENCH_simbackend.json`` (next to this file, mirrored to the repo root by
``benchmarks/run.py``) so future PRs can track the speedup trajectory:

  1. neighbour-evaluation throughput — the regime the explorer actually
     runs: one base design, a batch of move candidates (recorded deltas, no
     clones), priced by ``PythonBackend`` (simulate() per candidate) and by
     a warm ``JaxBatchedBackend`` (incremental encode → one batched dispatch
     → fitness column consumed, no decode), in candidates/second;
  2. the backend's encode/dispatch/decode wall-clock breakdown
     (``BackendStats``) over the measured dispatches, plus a kernel-vs-ref
     column: the same candidate batch dispatched through the fused Pallas
     phase-sim kernel (interpret mode on CPU — it exists for Mosaic/TPU, so
     on CPU this column measures the interpreter, not a win) with its
     fitness column asserted ≤ 1e-5 against the XLA reference path;
  3. end-to-end explorer iteration rate — fixed-seed exploration runs with
     each backend, in iterations/second, best-of-``reps`` to cut scheduler
     noise (jit warm-up excluded via a priming run);
  4. the device-resident explorer (``repro.core.device_explore``): fused
     (R, K) chain blocks vs the host-driven loop (the SAME compiled step
     dispatched one iteration at a time), in chain-iterations/second, plus
     the R×K sweep (R ∈ {1, 16, 256}, K ∈ {8, 64}) against the host
     explorer's e2e rate in the full run.

A policy-convergence comparison (paper §5.2 / Fig. 9b) rides along: every
policy of the comparison set (naive SA → telemetry-driven bottleneck /
locality → full FARSI) explores the workload under a reachable budget and
reports iterations-to-budget; the full run additionally sweeps the
generated synthetic-scenario family through ``Campaign.policy_sweep``.

A ``serve`` payload measures the continuous-batching service
(`repro.serve.DseService`): aggregate evals/s and p50/p95 session latency
at 1/8(/64 in the full run) concurrent sessions on one service with the
cache off (pure co-batching economics), plus the content-addressed
``DesignStore`` hit-rate on a repeated-scenario session mix (64 sessions in
the full run, which asserts hit-rate > 0.3 with ``n_fallback == 0``).

``run(smoke=True)`` is the CI guard (`python -m benchmarks.run --smoke`):
tiny iteration counts, and it *asserts* (a) JAX beats Python on
neighbour-eval throughput, (b) both backends agree on the winning
candidate's latency, (b') multi-NoC chain batches dispatch at ≥ 0.5x the
single-NoC throughput with ``n_fallback == 0`` (the array-native topology
regime), (c) kernel-vs-ref fitness parity ≤ 1e-5, (d) the device-loop
guard: the fused (R=16, K) chain block must sustain ≥ 2x the host-driven
loop's chain-iteration rate with ``n_compiles ≤ 4`` and ``n_fallback ==
0``, and at R=1 the fused block replays the host-driven loop's
(move, accepted) sequence bit-for-bit, (d') the speculative host pipeline
is retired: its counters must be ABSENT from ``ExplorationResult`` (the
tombstone), (e) the policy guard:
``FarsiPolicy`` reaches budget in no more iterations than ``NaiveSA`` on
the audio workload, the shared policy backend staying within the same
jit-cache footprint, (f) the serve guard: 8 co-batched sessions
sustain ≥ 0.7x the single-session *aggregate* throughput and the
repeated-scenario mix hits the cache, and (g) the degraded-mode guard: a
chaos run at a 5% injected dispatch-fault rate (seeded ``FaultInjector``)
must complete ALL sessions with zero failures and ≥ 0.5x the fault-free
aggregate throughput — retry/bisect/degrade overhead bounded, service
never down.
"""
from __future__ import annotations

import dataclasses
import filecmp
import json
import os
import random
import time
from typing import List

import numpy as np

from repro.core import (
    Campaign,
    Candidate,
    DeviceChainRunner,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    JaxBatchedBackend,
    PythonBackend,
    ar_complex,
    audio,
    calibrated_budget,
    random_single_noc_designs,
    synthetic_family,
)
from repro.core.moves import MOVE_KINDS, MoveDelta, MoveSpec, apply_fork, apply_move
from repro.serve import DseService, FaultInjector, RetryPolicy

from .common import Row, timeit

# the §5.2 comparison set: naive SA baseline, the two telemetry-driven
# single-ingredient policies, and the full FARSI composition
POLICY_SET = ("naive_sa", "bottleneck", "locality", "farsi")

JSON_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)), "BENCH_simbackend.json")
BATCH = 64  # campaign-scale cross-batch (explorer alone submits 4/iteration)
EXPLORE_ITERS = 120


def make_candidates(g, base, budget, n: int, seed: int = 7) -> List[Candidate]:
    """``n`` recorded-move candidates off one base design — the shape of an
    explorer/campaign neighbour batch (shared base, delta per candidate)."""
    rng = random.Random(seed)
    tasks = sorted(g.tasks)
    ck = base.checkpoint()
    out: List[Candidate] = []
    while len(out) < n:
        move = rng.choice(MOVE_KINDS)
        block = rng.choice(list(base.blocks))
        task = rng.choice(tasks)
        direction = rng.choice([-1, 1])
        delta = MoveDelta()
        ok = apply_move(base, g, move, block, task, direction, "pe", "latency",
                        random.Random(0), delta)
        base.restore(ck)
        if ok and not delta.topology:
            spec = MoveSpec(move, block, task, direction, "pe", "latency")
            out.append(Candidate(base=base, spec=spec, delta=delta, budget=budget))
    return out


def _consume(handles) -> int:
    """Rank the batch the way the explorer does: fitness column only."""
    fits = [h.fitness for h in handles]
    return min(range(len(fits)), key=fits.__getitem__)


def _serve_mix_config(i: int, iters: int) -> ExplorerConfig:
    """The repeated-scenario session mix: 16 distinct policy×seed configs,
    cycled — replica requests are what the content-addressed cache collapses."""
    return ExplorerConfig(
        policy=POLICY_SET[i % len(POLICY_SET)], seed=(i // len(POLICY_SET)) % 4,
        max_iterations=iters, backend="jax",
    )


def _serve_wave(svc: DseService, g, bud, wave: str, n: int, iters: int) -> dict:
    """Admit ``n`` mixed sessions onto ``svc``, drive to completion, and
    report the wave's aggregate throughput + per-session latency spread.
    Reusing one service across waves keeps the shared backends (and their
    jit caches) warm, so waves compare batching economics, not compiles."""
    handles = [
        svc.submit(f"{wave}.{i}", g, bud, _serve_mix_config(i, iters))
        for i in range(n)
    ]
    t0 = time.perf_counter()
    svc.run()
    wall = time.perf_counter() - t0
    lats = sorted(h.latency_s for h in handles)
    pct = lambda q: lats[min(len(lats) - 1, round(q * (len(lats) - 1)))]
    return {
        "n_sessions": n,
        "wall_s": wall,
        "iters_per_s_aggregate": n * iters / max(wall, 1e-9),
        "evals_per_s": sum(h.result.n_sims for h in handles) / max(wall, 1e-9),
        "latency_p50_s": pct(0.5),
        "latency_p95_s": pct(0.95),
    }


def run(smoke: bool = False) -> List[Row]:
    db = HardwareDatabase()
    batch = 16 if smoke else BATCH
    iters = 20 if smoke else EXPLORE_ITERS
    reps = 3 if smoke else 7
    payload = {"batch": batch, "explore_iterations": iters, "workloads": {}}
    rows: List[Row] = []

    # audio (15 tasks) and the full AR complex (28 tasks) — the two paper
    # workload scales where batching is the DSE's operating point
    graphs = (audio(),) if smoke else (audio(), ar_complex())
    for g in graphs:
        bud = calibrated_budget(db)
        base = random_single_noc_designs(g, 1, seed=7)[0]
        cands = make_candidates(g, base, bud, batch)
        py = PythonBackend(g, db)
        jx = JaxBatchedBackend(g, db)
        _consume(jx.evaluate_candidates(cands))  # compile once; steady state
        _consume(py.evaluate_candidates(cands))
        # interleave the samples so both backends see the same machine
        # conditions (scheduler noise on small graphs otherwise skews ratios)
        t_py = t_jx = float("inf")
        s0 = dataclasses.replace(jx.stats())
        for _ in range(reps):
            t_py = min(t_py, timeit(lambda: _consume(py.evaluate_candidates(cands)), n=1))
            t_jx = min(t_jx, timeit(lambda: _consume(jx.evaluate_candidates(cands)), n=1))
        s1 = jx.stats()
        evals_py = batch / (t_py * 1e-6)
        evals_jx = batch / (t_jx * 1e-6)
        n_disp = s1.n_dispatches - s0.n_dispatches
        breakdown = {
            "encode_s_per_dispatch": (s1.encode_s - s0.encode_s) / n_disp,
            "dispatch_s_per_dispatch": (s1.dispatch_s - s0.dispatch_s) / n_disp,
            "decode_s_per_dispatch": (s1.decode_s - s0.decode_s) / n_disp,
            "n_compiles": s1.n_compiles,
        }

        # kernel-vs-ref: the same batch through the fused Pallas kernel
        # (interpret on CPU) — parity asserted, dispatch wall recorded
        jk = JaxBatchedBackend(g, db, use_kernel=True)
        hk = jk.evaluate_candidates(cands)
        hr = jx.evaluate_candidates(cands)
        fit_k = [h.fitness for h in hk]
        fit_r = [h.fitness for h in hr]
        k_rel = max(
            abs(a - b) / max(abs(a), 1e-12) for a, b in zip(fit_k, fit_r)
        )
        assert k_rel <= 1e-5, f"pallas kernel vs ref fitness parity: {k_rel}"
        t_k = min(
            timeit(lambda: _consume(jk.evaluate_candidates(cands)), n=1)
            for _ in range(2)
        )
        breakdown["kernel_dispatch_wall_s"] = t_k * 1e-6
        breakdown["ref_dispatch_wall_s"] = t_jx * 1e-6
        breakdown["kernel_vs_ref_parity"] = k_rel

        # ---- multi-NoC vs single-NoC dispatch throughput -----------------
        # the array-native topology regime: chain designs (one NoC fork on
        # top of the same random single-NoC population) must price through
        # the batched path — n_fallback == 0 — at ≥ 0.5x the single-NoC
        # dispatch throughput (the padded-N striping loop is the only cost)
        singles = random_single_noc_designs(g, batch, seed=23)
        multis = random_single_noc_designs(g, batch, seed=23)
        for d in multis:
            apply_fork(d, g, d.noc_chain[0])
        c_single = [Candidate.of_design(d, bud) for d in singles]
        c_multi = [Candidate.of_design(d, bud) for d in multis]
        jm = JaxBatchedBackend(g, db)
        _consume(jm.evaluate_candidates(c_single))  # compile both buckets
        _consume(jm.evaluate_candidates(c_multi))
        t_s1 = t_m1 = float("inf")
        for _ in range(reps):
            t_s1 = min(t_s1, timeit(lambda: _consume(jm.evaluate_candidates(c_single)), n=1))
            t_m1 = min(t_m1, timeit(lambda: _consume(jm.evaluate_candidates(c_multi)), n=1))
        multi_ratio = t_s1 / max(t_m1, 1e-9)  # multi-NoC throughput / single
        assert jm.stats().n_fallback == 0, jm.stats()
        breakdown["multi_noc_vs_single_dispatch"] = multi_ratio
        rows.append(
            (
                f"simbackend.{g.name}.multi_noc",
                t_m1 / batch,
                f"multi={batch/(t_m1*1e-6):.0f}/s single={batch/(t_s1*1e-6):.0f}/s "
                f"ratio={multi_ratio:.2f}x n_fallback=0 batch={batch}",
            )
        )

        if smoke:
            assert evals_jx / max(evals_py, 1e-9) >= 1.0, (
                f"jax neighbour-eval slower than python: {evals_jx:.0f}/s vs {evals_py:.0f}/s"
            )
            assert multi_ratio >= 0.5, (
                f"multi-NoC dispatch regression: {multi_ratio:.2f}x of the "
                f"single-NoC path (floor 0.5x)"
            )
            hj = jx.evaluate_candidates(cands)
            hp = py.evaluate_candidates(cands)
            j = _consume(hj)
            a, b = hp[j].result(), hj[j].result()
            rel = abs(a.latency_s - b.latency_s) / a.latency_s
            assert rel < 1e-4, f"backend latency mismatch on winner: {rel}"

        # end-to-end: fixed-seed exploration per backend, best-of-reps (prime
        # the jit cache with a short run so shape-bucket compiles don't bill
        # the measure runs)
        Explorer(g, db, bud, ExplorerConfig(max_iterations=iters, seed=2),
                 backend=jx).run()
        e2e_reps = 1 if smoke else 3
        it_stats = {}
        last = None
        for name, backend in (("python", py), ("jax", jx)):
            best = None
            for _ in range(e2e_reps):
                res = Explorer(
                    g, db, bud,
                    ExplorerConfig(max_iterations=iters, seed=3),
                    backend=backend,
                ).run()
                if best is None or res.wall_s < best.wall_s:
                    best = res
            last = best
            it_stats[name] = {
                "iterations": best.iterations,
                "wall_s": best.wall_s,
                "sim_wall_s": best.sim_wall_s,
                "iters_per_s": best.iterations / max(best.wall_s, 1e-9),
                "converged": best.converged,
            }
        if smoke:
            # tombstone: the speculative host pipeline is retired — its
            # counters must not quietly reappear on ExplorationResult
            for gone in ("n_spec_hits", "n_sims_wasted", "spec_auto_disabled",
                         "pipelined"):
                assert not hasattr(last, gone), (
                    f"speculative-pipeline counter resurrected: {gone}"
                )
            assert jx.stats().n_compiles <= 4, jx.stats()

        # ---- device-resident explorer (smoke: hard assertions) -----------
        # the fused (R, K) chain block vs the host-driven loop: the SAME
        # compiled step dispatched K=1 per iteration with the carry pulled
        # back to host — the classic host-loop regime. Parity first (at R=1
        # the fused block must replay the host loop bit-for-bit), then
        # throughput at an R=16 population.
        runner = DeviceChainRunner(g, db)
        dev_k = 32
        par_f = runner.run_chains(base, bud, r=1, k=dev_k, seed=5)
        par_h = runner.run_chains_host(base, bud, r=1, n_steps=dev_k, seed=5)
        parity_ok = par_f.seq(0) == par_h.seq(0)
        assert parity_ok, "fused device block diverged from the host loop"
        dev_r = 16
        runner.run_chains(base, bud, r=dev_r, k=dev_k, seed=5)  # compile
        runner.run_chains(base, bud, r=dev_r, k=1, seed=5)  # warm k=1 block
        t_dev = t_hloop = float("inf")
        for _ in range(reps):
            t_dev = min(
                t_dev, runner.run_chains(base, bud, r=dev_r, k=dev_k, seed=5).wall_s
            )
        for _ in range(max(1, reps - 1)):
            t_hloop = min(
                t_hloop,
                runner.run_chains_host(
                    base, bud, r=dev_r, n_steps=dev_k, seed=5
                ).wall_s,
            )
        dev_its = dev_r * dev_k / max(t_dev, 1e-9)
        hloop_its = dev_r * dev_k / max(t_hloop, 1e-9)
        fused_vs_host_loop = dev_its / max(hloop_its, 1e-9)
        if smoke:
            assert fused_vs_host_loop >= 2.0, (
                f"device-loop regression: fused block at "
                f"{fused_vs_host_loop:.2f}x of the host-driven loop (floor 2x)"
            )
            assert runner.n_compiles <= 4, runner.n_compiles
            assert runner.n_fallback == 0, runner.n_fallback
        device_explore = {
            "r": dev_r,
            "k": dev_k,
            "device_iters_per_s": dev_its,
            "host_loop_iters_per_s": hloop_its,
            "fused_vs_host_loop": fused_vs_host_loop,
            "vs_host_explorer_jax": (
                dev_its / max(it_stats["jax"]["iters_per_s"], 1e-9)
            ),
            "vs_host_explorer_python": (
                dev_its / max(it_stats["python"]["iters_per_s"], 1e-9)
            ),
            "parity_r1": parity_ok,
            "n_compiles": runner.n_compiles,
            "n_fallback": runner.n_fallback,
        }
        if not smoke:
            # the R×K block sweep (R=256 is the slow, full-run-only point):
            # chain-iterations/second per fused shape, against the host
            # explorer's end-to-end rate
            sweep = {}
            for rr in (1, 16, 256):
                for kk in (8, 64):
                    runner.run_chains(base, bud, r=rr, k=kk, seed=5)  # compile
                    t_blk = min(
                        runner.run_chains(base, bud, r=rr, k=kk, seed=5).wall_s
                        for _ in range(3)
                    )
                    blk_its = rr * kk / max(t_blk, 1e-9)
                    sweep[f"r{rr}.k{kk}"] = {
                        "iters_per_s": blk_its,
                        "wall_s": t_blk,
                        "vs_host_explorer_jax": blk_its
                        / max(it_stats["jax"]["iters_per_s"], 1e-9),
                    }
            device_explore["sweep"] = sweep
        rows.append(
            (
                f"simbackend.{g.name}.device_explore",
                t_dev * 1e6,
                f"fused={dev_its:.0f}it/s host_loop={hloop_its:.0f}it/s "
                f"({fused_vs_host_loop:.1f}x) r={dev_r} k={dev_k} "
                f"vs_explorer={device_explore['vs_host_explorer_jax']:.1f}x "
                f"compiles={runner.n_compiles} fallback={runner.n_fallback}",
            )
        )

        # ---- mixed mapping+allocation chains (device_explore.alloc) ------
        # the widened move table: PE/MEM fork/join/frequency-swap + NoC
        # attach over capacity-padded slot inventories, sampled in the same
        # lax.scan block as the migrates. R∈{1,16}: parity first (the fused
        # mixed-move block must replay the host-driven loop bit-for-bit at
        # R=1 — same threefry draws, same f32 accept math, allocation
        # columns included), then fused-vs-host-loop throughput at R=16.
        # Fresh runner: the alloc jit cache is its own budget (≤ 6 entries).
        arunner = DeviceChainRunner(g, db)
        apar_f = arunner.run_chains(
            base, bud, r=1, k=dev_k, seed=5, menu="farsi", alloc=True
        )
        apar_h = arunner.run_chains_host(
            base, bud, r=1, n_steps=dev_k, seed=5, menu="farsi", alloc=True
        )
        alloc_parity = (
            apar_f.seq(0) == apar_h.seq(0)
            and all(
                np.array_equal(x, y)
                for x, y in zip(apar_f.carry, apar_h.carry)
            )
        )
        assert alloc_parity, (
            "fused mixed-move block diverged from the host loop"
        )
        arunner.run_chains(
            base, bud, r=dev_r, k=dev_k, seed=5, menu="farsi", alloc=True
        )  # compile
        arunner.run_chains(
            base, bud, r=dev_r, k=1, seed=5, menu="farsi", alloc=True
        )  # warm k=1 block
        t_adev = t_ahloop = float("inf")
        for _ in range(reps):
            t_adev = min(
                t_adev,
                arunner.run_chains(
                    base, bud, r=dev_r, k=dev_k, seed=5, menu="farsi",
                    alloc=True,
                ).wall_s,
            )
        for _ in range(max(1, reps - 1)):
            t_ahloop = min(
                t_ahloop,
                arunner.run_chains_host(
                    base, bud, r=dev_r, n_steps=dev_k, seed=5, menu="farsi",
                    alloc=True,
                ).wall_s,
            )
        adev_its = dev_r * dev_k / max(t_adev, 1e-9)
        ahloop_its = dev_r * dev_k / max(t_ahloop, 1e-9)
        alloc_vs_host_loop = adev_its / max(ahloop_its, 1e-9)
        if smoke:
            assert alloc_vs_host_loop >= 2.0, (
                f"mixed-move device-loop regression: fused block at "
                f"{alloc_vs_host_loop:.2f}x of the host-driven loop "
                f"(floor 2x)"
            )
            assert arunner.n_compiles <= 6, arunner.n_compiles
            assert arunner.n_fallback == 0, arunner.n_fallback
        device_explore["alloc"] = {
            "r": dev_r,
            "k": dev_k,
            "menu": "farsi",
            "n_moves": apar_f.n_moves,
            "device_iters_per_s": adev_its,
            "host_loop_iters_per_s": ahloop_its,
            "fused_vs_host_loop": alloc_vs_host_loop,
            "vs_host_explorer_jax": (
                adev_its / max(it_stats["jax"]["iters_per_s"], 1e-9)
            ),
            "parity_r1": alloc_parity,
            "n_compiles": arunner.n_compiles,
            "n_fallback": arunner.n_fallback,
        }
        rows.append(
            (
                f"simbackend.{g.name}.device_explore.alloc",
                t_adev * 1e6,
                f"fused={adev_its:.0f}it/s host_loop={ahloop_its:.0f}it/s "
                f"({alloc_vs_host_loop:.1f}x) r={dev_r} k={dev_k} "
                f"menu=farsi moves={apar_f.n_moves} "
                f"vs_explorer={device_explore['alloc']['vs_host_explorer_jax']:.1f}x "
                f"compiles={arunner.n_compiles} fallback={arunner.n_fallback}",
            )
        )

        # ---- policy-convergence comparison (§5.2 / Fig. 9b) --------------
        # iterations-to-budget per registered policy under a relaxed budget
        # the searches can actually reach within the iteration cap — the
        # guard is the paper's qualitative ORDERING (FarsiPolicy needs no
        # more iterations than NaiveSA), not endurance. One shared backend
        # across policies keeps the jit-cache footprint covered too.
        jpol = JaxBatchedBackend(g, db)
        pol_bud = bud.scaled(2.0)
        pol_cap = 150 if smoke else 400
        policy_conv = {}
        for pol in POLICY_SET:
            resp = Explorer(
                g, db, pol_bud,
                ExplorerConfig(policy=pol, max_iterations=pol_cap, seed=11),
                backend=jpol,
            ).run()
            policy_conv[pol] = {
                "iterations_to_budget": resp.iterations_to_budget(pol_cap),
                "converged": resp.converged,
                "best_distance": resp.best_distance.city_block(),
            }
        it_farsi = policy_conv["farsi"]["iterations_to_budget"]
        it_naive = policy_conv["naive_sa"]["iterations_to_budget"]
        policy_conv["naive_over_farsi"] = it_naive / max(it_farsi, 1.0)
        if smoke:
            assert it_farsi <= it_naive, (
                f"policy-convergence regression: farsi needed {it_farsi} "
                f"iterations vs naive_sa {it_naive}"
            )
            assert jpol.stats().n_compiles <= 4, jpol.stats()
        rows.append(
            (
                f"simbackend.{g.name}.policy_convergence",
                0.0,
                " ".join(
                    f"{p}={policy_conv[p]['iterations_to_budget']:.0f}"
                    + ("*" if policy_conv[p]["converged"] else "")
                    for p in POLICY_SET
                )
                + f" naive/farsi={policy_conv['naive_over_farsi']:.1f}x",
            )
        )

        payload["workloads"][g.name] = {
            "n_tasks": len(g.tasks),
            "python_evals_per_s": evals_py,
            "jax_evals_per_s": evals_jx,
            "eval_throughput_speedup": evals_jx / max(evals_py, 1e-9),
            "jax_breakdown": breakdown,
            "policy_convergence": policy_conv,
            "device_explore": device_explore,
            "explorer": it_stats,
            "explorer_iters_per_s_speedup": (
                it_stats["jax"]["iters_per_s"] / max(it_stats["python"]["iters_per_s"], 1e-9)
            ),
        }
        rows.append(
            (
                f"simbackend.{g.name}.eval_throughput",
                t_jx / batch,
                f"jax={evals_jx:.0f}/s python={evals_py:.0f}/s "
                f"speedup={evals_jx/max(evals_py,1e-9):.1f}x batch={batch}",
            )
        )
        rows.append(
            (
                f"simbackend.{g.name}.breakdown",
                0.0,
                "encode={encode_s_per_dispatch:.2e}s dispatch={dispatch_s_per_dispatch:.2e}s "
                "decode={decode_s_per_dispatch:.2e}s compiles={n_compiles} "
                "kernel={kernel_dispatch_wall_s:.2e}s "
                "ref={ref_dispatch_wall_s:.2e}s".format(**breakdown),
            )
        )
        rows.append(
            (
                f"simbackend.{g.name}.explorer",
                it_stats["jax"]["wall_s"] * 1e6,
                f"jax={it_stats['jax']['iters_per_s']:.1f}it/s "
                f"python={it_stats['python']['iters_per_s']:.1f}it/s "
                f"speedup={payload['workloads'][g.name]['explorer_iters_per_s_speedup']:.1f}x "
                f"device={device_explore['device_iters_per_s']:.0f}it/s",
            )
        )

    # ---- continuous-batching serve economics -----------------------------
    # One DseService, repeated-scenario session mix. Throughput waves run
    # with the cache OFF (pure co-batching: does packing N sessions into
    # shared dispatches keep aggregate throughput?); the cache run measures
    # the repeated-scenario hit-rate the DesignStore exists for. Per-session
    # rate necessarily drops with N (each session still pays its own host-
    # side explorer step) — the economics claim is about the AGGREGATE.
    g_serve = audio()
    bud_serve = calibrated_budget(db)
    serve_iters = 12 if smoke else 30
    sizes = (1, 8) if smoke else (1, 8, 64)
    svc = DseService(db, backend="jax", cache=False)
    # prime at full length: the measure waves replay identical configs
    # (deterministic searches), so every shape bucket / jit entry they will
    # walk through is compiled before anything is timed
    for n in sizes:
        _serve_wave(svc, g_serve, bud_serve, f"prime{n}", n, serve_iters)
    thr = {str(n): _serve_wave(svc, g_serve, bud_serve, f"t{n}", n, serve_iters)
           for n in sizes}
    eff8 = (thr["8"]["iters_per_s_aggregate"]
            / max(thr["1"]["iters_per_s_aggregate"], 1e-9))

    cache_sessions = 16 if smoke else 64
    svc_c = DseService(db, backend="jax")  # cache on (fresh DesignStore)
    for i in range(cache_sessions):
        svc_c.submit(f"c{i}", g_serve, bud_serve,
                     _serve_mix_config(i, serve_iters))
    cstats = svc_c.run()
    assert cstats.n_fallback == 0, cstats
    if smoke:
        assert eff8 >= 0.7, (
            f"co-batching regression: 8-session aggregate throughput at "
            f"{eff8:.2f}x of single-session (floor 0.7x)"
        )
        assert cstats.cache_hit_rate > 0, cstats
    else:
        # the acceptance-criterion run: 64 repeated-scenario sessions
        assert cstats.cache_hit_rate > 0.3, cstats
    # ---- degraded-mode guard: chaos at 5% injected dispatch faults -------
    # a fresh service (own compile, primed by a warm wave) runs the same
    # 8-session mix with every shared dispatch vetoed at 5%: every fault
    # triggers the bisect → retry → (rarely) degrade ladder, and the guard
    # is that all sessions still complete with bounded throughput loss
    fault_rate = 0.05
    chaos_n = 8
    # seed pinned so faults land in BOTH waves: the warm wave must compile
    # the per-session bisect shape buckets (a fault-free warm wave would
    # leave the measured wave paying those compiles), and the measured wave
    # must actually exercise the bisect/retry ladder for the guard to mean
    # anything
    inj = FaultInjector(seed=1, dispatch_fault_rate=fault_rate)
    svc_f = DseService(db, backend="jax", cache=False, faults=inj,
                       retry=RetryPolicy(backoff_s=0.0))
    _serve_wave(svc_f, g_serve, bud_serve, "fwarm", chaos_n, serve_iters)
    chaos = _serve_wave(svc_f, g_serve, bud_serve, "fchaos", chaos_n, serve_iters)
    fstats = svc_f.stats()
    fault_ratio = (chaos["iters_per_s_aggregate"]
                   / max(thr["8"]["iters_per_s_aggregate"], 1e-9))
    assert fstats.n_failed == 0 and fstats.n_done == 2 * chaos_n, fstats
    if smoke:
        assert fault_ratio >= 0.5, (
            f"degraded-mode regression: chaos throughput at "
            f"{fault_ratio:.2f}x of fault-free (floor 0.5x) with "
            f"{fstats.n_dispatch_faults} injected dispatch faults"
        )
    payload["serve"] = {
        "workload": g_serve.name,
        "iterations_per_session": serve_iters,
        "throughput": thr,
        "batching_efficiency_8": eff8,
        "faults": {
            "dispatch_fault_rate": fault_rate,
            "n_sessions": chaos_n,
            "throughput_ratio_vs_fault_free": fault_ratio,
            "iters_per_s_aggregate": chaos["iters_per_s_aggregate"],
            "n_injected": len(inj.schedule),
            "n_dispatch_faults": fstats.n_dispatch_faults,
            "n_bisects": fstats.n_bisects,
            "n_retries": fstats.n_retries,
            "n_degraded": fstats.n_degraded,
            "n_failed": fstats.n_failed,
        },
        "cache": {
            "n_sessions": cache_sessions,
            "hit_rate": cstats.cache_hit_rate,
            "hits": cstats.cache_hits,
            "misses": cstats.cache_misses,
            "bypasses": cstats.cache_bypasses,
            "n_fallback": cstats.n_fallback,
            "evals_per_s": cstats.evals_per_s,
            "latency_p50_s": cstats.latency_percentile(50),
            "latency_p95_s": cstats.latency_percentile(95),
        },
    }
    rows.append(
        (
            "simbackend.serve.throughput",
            thr[str(sizes[-1])]["wall_s"] * 1e6,
            " ".join(
                f"agg{n}={thr[str(n)]['iters_per_s_aggregate']:.0f}it/s"
                for n in sizes
            )
            + f" eff8={eff8:.2f}x p95_8={thr['8']['latency_p95_s']:.2f}s",
        )
    )
    rows.append(
        (
            "simbackend.serve.cache",
            0.0,
            f"{cache_sessions} sessions hit-rate="
            f"{cstats.cache_hit_rate:.1%} ({cstats.cache_hits}h/"
            f"{cstats.cache_misses}m) fallback={cstats.n_fallback}",
        )
    )
    rows.append(
        (
            "simbackend.serve.faults",
            chaos["wall_s"] * 1e6,
            f"chaos@{fault_rate:.0%} dispatch faults: "
            f"{fault_ratio:.2f}x fault-free throughput, "
            f"{fstats.n_dispatch_faults} faults/"
            f"{fstats.n_retries} retries/{fstats.n_bisects} bisects/"
            f"{fstats.n_degraded} degraded, 0 failed",
        )
    )

    if not smoke:
        # ---- policy × synthetic-scenario sweep through Campaign ----------
        # the generative workload family: per-scenario iterations-to-budget
        # for the full policy set, cross-batched per scenario graph
        scens = synthetic_family(seed=0, n=6, db=db)
        camp = Campaign.policy_sweep(
            db, scens, policies=POLICY_SET, seeds=(0,),
            backend="jax", max_iterations=200,
        )
        cres = camp.run()
        scen_table = {
            s.name: {
                pol: cres.runs[f"{s.name}.{pol}.s0"].iterations_to_budget(200)
                for pol in POLICY_SET
            }
            for s in scens
        }
        farsi_wins = sum(
            1 for v in scen_table.values() if v["farsi"] <= v["naive_sa"]
        )
        payload["policy_scenarios"] = {
            "per_scenario": scen_table,
            "policy_iterations_mean": cres.policy_iterations(200),
            "farsi_beats_naive": farsi_wins,
            "n_scenarios": len(scens),
            "codesign": {
                k: v for k, v in cres.aggregate.items() if k.startswith("codesign")
            },
        }
        rows.append(
            (
                "simbackend.policy_scenarios",
                0.0,
                f"farsi<=naive on {farsi_wins}/{len(scens)} synthetic scenarios; "
                + " ".join(
                    f"{p}={cres.policy_iterations(200)[p]:.0f}" for p in POLICY_SET
                ),
            )
        )
        with open(JSON_PATH, "w") as f:
            json.dump(payload, f, indent=2)
        rows.append(("simbackend.json", 0.0, f"wrote {JSON_PATH}"))
    else:
        # stale-mirror guard: the repo-root copy of the trajectory JSON must
        # be byte-identical to the benchmarks/ source (a full run that died
        # mid-mirror would leave them diverged; run.py now renames the
        # mirror into place atomically, and this asserts the invariant)
        root_mirror = os.path.join(
            os.path.dirname(os.path.dirname(JSON_PATH)),
            os.path.basename(JSON_PATH),
        )
        if os.path.exists(JSON_PATH) and os.path.exists(root_mirror):
            assert filecmp.cmp(JSON_PATH, root_mirror, shallow=False), (
                f"stale root mirror: {root_mirror} != {JSON_PATH} — rerun "
                "the full bench so the tracker reads current numbers"
            )
        rows.append((
            "simbackend.smoke", 0.0,
            "speedup>=1, winner equivalence, kernel parity<=1e-5, "
            "multi-noc dispatch>=0.5x single-noc + n_fallback=0, "
            "device loop>=2x host loop @R=16 + compiles<=4 + fallback=0, "
            "R=1 device/host-loop parity, mixed-move alloc block: R=1 "
            "parity + >=2x host loop @R=16 + compiles<=6 + fallback=0, "
            "bench-json mirror==source, spec-pipeline tombstone, "
            "policy convergence farsi<=naive_sa, "
            "serve: 8-session aggregate>=0.7x single + cache hit-rate>0, "
            "chaos@5% dispatch faults: all sessions complete >=0.5x: OK",
        ))
    return rows
