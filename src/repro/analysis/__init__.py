"""repro.analysis — static contract checker, JAX lint, and jaxpr audit.

Three passes that make the repo's recurring desync bugs un-shippable
(``python -m repro.analysis --strict`` gates tier-1 and the bench smoke):

* :mod:`~repro.analysis.contracts` — cross-file layout contracts
  (scal-column schema, ChainCarry/MoveTable widths, MV_* dispatch
  coverage, policy registry vs docs).
* :mod:`~repro.analysis.lint` — AST rules over ``src/repro/`` for
  host-sync and retracing hazards inside traced scopes.
* :mod:`~repro.analysis.jaxpr_audit` — traces the hot jitted entry
  points and asserts forbidden/required primitives and the jit-cache
  key bound.

See ``docs/ANALYSIS.md`` for the rule list, the ``# repro: noqa[rule]:
reason`` suppression format, and the baseline workflow.

This package is import-light on purpose: importing it (or running
``--help``) must not pull in jax — the passes import their subjects
lazily when they run.
"""
from .findings import Finding, format_findings

__all__ = [
    "Finding",
    "format_findings",
    "run_all",
    "run_contracts",
    "run_lint",
    "run_jaxpr_audit",
]


def run_contracts(*args, **kwargs):
    from .contracts import run_contracts as _rc

    return _rc(*args, **kwargs)


def run_lint(*args, **kwargs):
    from .lint import run_lint as _rl

    return _rl(*args, **kwargs)


def run_jaxpr_audit(*args, **kwargs):
    from .jaxpr_audit import run_jaxpr_audit as _rj

    return _rj(*args, **kwargs)


def run_all(passes=("contracts", "lint", "jaxpr")):
    """All findings from the selected passes, baseline/noqa applied to
    lint (the other passes have no baseline — a contract either holds or
    the build is wrong)."""
    findings = []
    if "contracts" in passes:
        findings.extend(run_contracts())
    if "lint" in passes:
        from .lint import apply_baseline, load_baseline

        findings.extend(apply_baseline(run_lint(), load_baseline()))
    if "jaxpr" in passes:
        findings.extend(run_jaxpr_audit())
    return findings
