"""Single source of truth for the packed scal column layout.

One dispatch crosses the host boundary as a single ``(B, N_SCAL + 2·S + N)``
matrix (``backend._JaxBatch``), and the Pallas kernel writes its own packed
``(1, N_SCAL)`` scal tile in the same order
(``kernels/phase_sim/kernel.SCAL_COLS``) so the ops-layer unpack and the
backend repack fold to a no-op under jit. Both sides used to carry their
own column-tuple literal coupled by a "keep them in sync" comment; this
module is now the ONE place a scal column is named, and
``repro.analysis.contracts`` machine-checks that both consumers still
derive from it (contract ``scal-cols``).

Layout: the 9 host-unpack scalars first (``SCAL_PREFIX`` — what
``backend._SCAL_COLS`` exposes as named host columns), then the
comp-vs-comm kind split triple, then the top-bottleneck slot pair. The
variable-width per-slot telemetry tail (``pe_bneck_s``/``mem_bneck_s``/
``noc_bneck_s``) rides after ``N_SCAL`` and is split on host from the
batch's recorded ``(S, N)`` dims — it never gets column names here.

This module must stay dependency-free (no jax, no numpy): it is imported
by both ``core.backend`` and ``kernels.phase_sim.kernel``, in either
order, possibly mid-package-initialization.
"""

# the named host-unpack scalars (backend._SCAL_COLS)
SCAL_PREFIX = (
    "latency_s", "energy_j", "power_w", "area_mm2", "fitness",
    "alp_time_s", "traffic_bytes", "n_phases", "all_done",
)

# comp-vs-comm attribution split (backend unpacks the triple as one
# ``bneck_kind_s`` (B, 3) column block)
BNECK_KIND_COLS = ("kind_pe_s", "kind_mem_s", "kind_noc_s")

# argmax slots of the per-block bottleneck-seconds telemetry — the block a
# bottleneck-relaxation policy should target next, computed on device
TOP_BNECK_COLS = ("top_bneck_pe", "top_bneck_mem")

# the full fixed-width block, in kernel write order
SCAL_COLS = SCAL_PREFIX + BNECK_KIND_COLS + TOP_BNECK_COLS
N_SCAL = len(SCAL_COLS)

# host-unpack indices (backend._JaxBatch.host) — derived, never hardcoded
KIND_START = len(SCAL_PREFIX)
KIND_STOP = KIND_START + len(BNECK_KIND_COLS)
TOP_PE_COL = KIND_STOP
TOP_MEM_COL = TOP_PE_COL + 1
