"""Logical-axis → mesh-axis rules with per-array conflict/divisibility
resolution.

``resolve(shape, logical, rules, mesh)`` walks the dims in order; each logical
name proposes mesh axes, which are accepted only if (a) not already used by an
earlier dim of the same array and (b) the dim is divisible by the accumulated
axis size. This one mechanism yields all the per-arch fallbacks documented in
DESIGN.md §Arch-applicability: kv-head replication when K·Dh doesn't divide,
EP→expert-TP for grok-1 (8 experts < 16-way model axis), replicated vocab for
mamba2's 50280, replicated batch for long_500k's batch=1 (which then turns on
sequence-sharded KV).

The rules dict is *the* FARSI design point for the distributed layer — the
autotuner's migrate move edits it, swap edits remat/microbatch knobs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig

Axes = Union[None, str, Tuple[str, ...]]


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """One point in the distribution design space (FARSI 'design')."""

    rules: Dict[str, Axes]
    remat: str = "full"  # train-time activation checkpointing
    attn_impl: str = "blockwise"
    q_block: int = 512
    kv_block: int = 1024
    ssd_chunk: int = 64
    microbatches: int = 4  # gradient-accumulation splits of the global batch
    kv_quant: str = "none"  # "int8" halves the decode cache footprint/traffic
    a2a_bytes: int = 2  # MoE dispatch payload width (1 = int8-quantized a2a)
    grad_compress: str = "none"  # "int8" = error-feedback compressed grad sync
    capacity_factor: float = 0.0  # >0 overrides the arch's MoE capacity factor
    moe_impl: str = "dense"  # "shard_map" = EP local-dispatch (models/moe_shard_map.py)
    ici_links: int = 1  # collective schedule: 2 = bidirectional-ring on the torus
    donate_state: bool = True

    def replace(self, **kw) -> "DistConfig":
        return dataclasses.replace(self, **kw)


def default_rules(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> Dict[str, Axes]:
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    # KV projections: shard over 'model' only when the kv-head count divides
    # it; otherwise replicate them (Megatron GQA-style — each model shard
    # computes the full small K/V locally rather than fighting a Dh-split
    # layout through attention).
    kv_sharded = (
        cfg.n_kv_heads > 0 and cfg.n_kv_heads % mesh.shape["model"] == 0
    )
    rules: Dict[str, Axes] = {
        # activations
        "batch": data_axes,
        "seq": None,
        # residual stream between blocks: sequence-sharded over the model
        # axis (Megatron sequence parallelism) — divides the L×tokens×d_model
        # remat-residual stack by the TP degree. Auto-dropped when S % 16 ≠ 0
        # or S == 1 (decode).
        "seq_res": ("model",),
        "act_embed": None,
        "act_heads": ("model",),
        "act_kv_heads": ("model",) if kv_sharded else None,
        "act_kv_dim": None,
        "act_vocab": ("model",),
        "exp_capacity": data_axes,
        # flat (T·k, D) MoE dispatch tensors: shard the token axis over
        # everything available (replicated they cost ~34 GB/device at 1M-token
        # prefill — found via the jamba-prefill buffer dump)
        "moe_flat": data_axes + ("model",),
        # weights: TP over 'model', FSDP over 'data'
        "embed": ("data",),
        "qkv": ("model",),
        "kv_qkv": ("model",) if kv_sharded else None,
        "mlp": ("model",),
        "vocab": ("model",),
        "vocab_table": None,
        "experts": ("model",),
        "expert_mlp": ("model",),
        "ssm_inner": ("model",),
        "ssm_conv": ("model",),
        "ssm_heads": ("model",),
        "layers": None,  # scan axis
        # decode cache
        "cache_seq": None,
        "kv_heads": ("model",),
        "head_dim": ("model",),
    }
    # batch too small to fill the data axes (long_500k): shard the KV cache
    # and activations over sequence instead (flash-decoding style).
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    if shape.kind == "decode" and shape.global_batch < n_data:
        rules["cache_seq"] = data_axes
    if shape.kind != "decode" and shape.global_batch < n_data:
        rules["seq"] = data_axes
    return rules


def resolve(shape: Tuple[int, ...], logical, rules: Dict[str, Axes], mesh: Mesh) -> P:
    used = set()
    parts = []
    for dim, lname in zip(shape, logical):
        axes = rules.get(lname) if lname else None
        if axes is None:
            parts.append(None)
            continue
        if isinstance(axes, str):
            axes = (axes,)
        chosen = []
        size = 1
        for ax in axes:
            if ax in used or ax not in mesh.shape:
                continue
            if dim % (size * mesh.shape[ax]) == 0:
                chosen.append(ax)
                size *= mesh.shape[ax]
        used.update(chosen)
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    return P(*parts)


def sharded_struct(struct, logical, rules: Dict[str, Axes], mesh: Mesh):
    """ShapeDtypeStruct + NamedSharding from a logical spec."""
    spec = resolve(struct.shape, logical, rules, mesh)
    return jax.ShapeDtypeStruct(
        struct.shape, struct.dtype, sharding=NamedSharding(mesh, spec)
    )


def tree_sharded_structs(struct_tree, logical_tree, rules, mesh):
    """Zip a ShapeDtypeStruct tree with its logical-axis tree."""
    is_spec = lambda x: x is None or isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )
    return jax.tree.map(
        lambda s, l: sharded_struct(s, l, rules, mesh),
        struct_tree,
        logical_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def tree_shardings(struct_tree, logical_tree, rules, mesh):
    return jax.tree.map(
        lambda s, l: NamedSharding(mesh, resolve(s.shape, l, rules, mesh)),
        struct_tree,
        logical_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )
