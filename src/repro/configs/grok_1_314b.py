"""Grok-1 314B [hf:xai-org/grok-1; unverified].

Coarse MoE: 64L, d_model=6144, 48 q / 8 kv heads (head_dim 128), 8 experts
top-2 with d_ff=32768, vocab=131072. 8 experts < 16-way model axis → the
sharding rules use expert-TP (shard d_ff within experts) instead of pure EP
(DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    vocab_size=131072,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    mlp_kind="swiglu",
    n_experts=8,
    top_k=2,
    moe_d_ff=32768,
    rope_kind="rope",
    rope_theta=1e4,
    block_kinds=("attn",),
    mlp_kinds=("moe",),
)
