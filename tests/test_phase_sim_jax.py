"""Vectorized phase simulator ≡ the Python reference (single-NoC regime)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Design, HardwareDatabase, ar_complex, edge_detection, random_single_noc_designs, simulate
from repro.core.phase_sim_jax import EncodedWorkload, encode_batch, simulate_batch


@pytest.mark.parametrize("graph_fn", [edge_detection, ar_complex])
def test_vectorized_matches_python(graph_fn):
    db = HardwareDatabase()
    g = graph_fn()
    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, 8, seed=3)
    batch = encode_batch(designs, g, db, enc)
    out = jax.jit(lambda *a: simulate_batch(enc, *a))(*batch)
    assert bool(out["all_done"].all())
    for i, d in enumerate(designs):
        ref = simulate(d, g, db)
        got = float(out["latency_s"][i])
        assert abs(got - ref.latency_s) / ref.latency_s < 1e-3, (i, got, ref.latency_s)
        # per-task finish times agree too
        for j, name in enumerate(enc.names):
            a, b = float(out["finish_s"][i, j]), ref.task_finish_s[name]
            assert abs(a - b) / max(b, 1e-12) < 1e-3


def test_batch_throughput_smoke():
    """One jit'd call evaluates a whole neighbour batch (the Fig-8 answer)."""
    db = HardwareDatabase()
    g = edge_detection()
    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, 32, seed=9)
    batch = encode_batch(designs, g, db, enc)
    out = jax.jit(lambda *a: simulate_batch(enc, *a))(*batch)
    assert out["latency_s"].shape == (32,)
    assert bool(jnp.isfinite(out["latency_s"]).all())
