"""Mamba2-370m [arXiv:2405.21060; hf:state-spaces/mamba2-370m; unverified].

Pure SSM (attention-free): 48L of Mamba-2 (SSD) blocks, d_model=1024,
d_inner=2048 (expand 2, head_dim 64 → 32 ssm heads), ssm_state=128,
vocab=50280, no separate FFN (d_ff=0). Sub-quadratic: runs long_500k.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    n_layers=48,
    d_model=1024,
    vocab_size=50280,
    d_ff=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    rope_kind="none",
    tie_embeddings=True,
    block_kinds=("mamba",),
    mlp_kinds=("none",),
    subquadratic=True,
)
