"""jit'd public wrapper: model layout (B, S, H, Dh) ⇄ kernel layout, with a
custom VJP whose backward uses the blockwise flash gradient (models.flash_ref)
— the kernel accelerates the forward (prefill/serving hot path); training
gradients share the memory-sane blockwise backward.

On CPU (tests, this container) pass ``interpret=True`` — the kernel body runs
unmodified in interpret mode; on TPU it compiles to Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


@partial(jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, S, H, Dh) — model layout
    k: jax.Array,  # (B, S, KH, Dh)
    v: jax.Array,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
    interpret: bool = False,
) -> jax.Array:
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(
        qt, kt, vt, causal=causal, q_block=q_block, kv_block=kv_block, interpret=interpret
    )
    return out.transpose(0, 2, 1, 3)
