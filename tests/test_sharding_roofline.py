"""Sharding rules resolution, logical-spec ↔ param-tree structural agreement
for all 10 archs, elastic mesh shrinking, analytic roofline sanity, and the
FARSI autotuner's improvement guarantees."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES
from repro.configs.registry import arch_names, get_config, reduced_config
from repro.core.tpu_design import simulate_step, step_tdg
from repro.launch.autotune import autotune, estimate
from repro.models.model import init_params
from repro.roofline.analytic import MeshShape, model_flops, roofline_terms, step_costs
from repro.roofline.hlo import collective_bytes
from repro.runtime.elastic import shrink_mesh
from repro.sharding.rules import DistConfig, default_rules, resolve
from repro.sharding.specs import param_logical


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


from _optional_hypothesis import given, settings, st


@given(
    st.lists(st.sampled_from([8, 16, 32, 50, 128, 4096, 151936, 1]), min_size=1, max_size=4),
    st.lists(st.sampled_from([None, "a", "b", "c"]), min_size=1, max_size=4),
)
@settings(max_examples=60, deadline=None)
def test_resolve_never_reuses_axes(dims, names):
    """Property: resolve() never assigns a mesh axis to two dims of one array,
    and every assigned axis divides its dim."""
    n = min(len(dims), len(names))
    dims, names = tuple(dims[:n]), tuple(names[:n])
    rules = {"a": ("model",), "b": ("data", "model"), "c": ("data",)}
    mesh = FakeMesh({"data": 4, "model": 8})
    spec = resolve(dims, names, rules, mesh)
    used = []
    for dim, part in zip(dims, spec):
        if part is None:
            continue
        axes = (part,) if isinstance(part, str) else part
        size = 1
        for ax in axes:
            assert ax not in used
            used.append(ax)
            size *= mesh.shape[ax]
        assert dim % size == 0


def test_resolve_divisibility_fallback():
    rules = {"a": ("model",), "b": ("data",), "c": None}
    # 8 % 16 != 0 -> replicate
    assert resolve((8, 32, 5), ("a", "b", "c"), rules, MESH1) == P(None, "data", None)
    assert resolve((32, 32, 5), ("a", "b", "c"), rules, MESH1) == P("model", "data", None)


def test_resolve_conflict_per_array():
    """Two dims proposing the same axis: first (dim order) wins."""
    rules = {"x": ("model",), "y": ("model",)}
    assert resolve((32, 32), ("x", "y"), rules, MESH1) == P("model", None)


def test_resolve_multi_axis_batch():
    rules = {"batch": ("pod", "data")}
    assert resolve((32, 4), ("batch", None), rules, MESH2) == P(("pod", "data"), None)
    assert resolve((2, 4), ("batch", None), rules, MESH2) == P("pod", None)
    assert resolve((1, 4), ("batch", None), rules, MESH2) == P(None, None)


def test_ordered_fallback_kv_to_head_dim():
    rules = {"kv_heads": ("model",), "head_dim": ("model",)}
    # kv=8 not divisible by 16 -> head_dim picks up the axis
    assert resolve((8, 128), ("kv_heads", "head_dim"), rules, MESH1) == P(None, "model")
    # kv=16 divisible -> head_dim must NOT reuse the axis
    assert resolve((16, 128), ("kv_heads", "head_dim"), rules, MESH1) == P("model", None)


@pytest.mark.parametrize("name", arch_names())
def test_param_logical_matches_param_tree(name):
    """The logical-axis tree must be structurally identical to the real param
    tree (catches drift between init_params and sharding specs)."""
    cfg = reduced_config(name)
    params = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    logical = param_logical(cfg)
    is_spec = lambda x: isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)
    pt = jax.tree_util.tree_structure(params)
    lt = jax.tree_util.tree_structure(logical, is_leaf=is_spec)
    assert pt == lt, f"{name}: param tree != logical tree"
    # every leaf rank matches its logical rank
    flat_p = jax.tree_util.tree_leaves(params)
    flat_l = jax.tree_util.tree_leaves(logical, is_leaf=is_spec)
    for s, l in zip(flat_p, flat_l):
        assert len(s.shape) == len(l), (name, s.shape, l)


def test_shrink_mesh():
    assert shrink_mesh(256) == ((16, 16), ("data", "model"))
    assert shrink_mesh(192) == ((8, 16), ("data", "model"))  # lost a host rack
    assert shrink_mesh(8) == ((1, 8), ("data", "model"))
    for n in (3, 5, 7, 100):
        (d, m), _ = shrink_mesh(n)
        assert d * m <= n and d * m >= n / 2  # uses ≥half the survivors


# ---------------------------------------------------------------------------
# analytic roofline + autotuner
# ---------------------------------------------------------------------------
MESH = MeshShape(16, 16)


def _tp_rules(on=True):
    ax = ("model",) if on else None
    return {
        "qkv": ax, "kv_qkv": ax, "mlp": ax, "ssm_inner": ax, "ssm_conv": ax,
        "expert_mlp": ax, "seq_res": ("model",) if on else None, "embed": ("data",),
    }


def test_tp_off_kills_boundary_collectives():
    cfg, sh = get_config("qwen3-1.7b"), SHAPES["train_4k"]
    on = roofline_terms(step_costs(cfg, sh, MESH, DistConfig(rules=_tp_rules(True))))
    off = roofline_terms(step_costs(cfg, sh, MESH, DistConfig(rules=_tp_rules(False))))
    assert off["ici_bytes"] < 0.2 * on["ici_bytes"]
    assert off["hbm_bytes"] > on["hbm_bytes"]  # replicated weights cost HBM


def test_kernel_attention_halves_core_flops():
    cfg, sh = get_config("mistral-large-123b"), SHAPES["prefill_32k"]
    ref = step_costs(cfg, sh, MESH, DistConfig(rules=_tp_rules(), attn_impl="blockwise"))
    ker = step_costs(cfg, sh, MESH, DistConfig(rules=_tp_rules(), attn_impl="kernel"))
    core_ref = sum(o.flops for o in ref if "attn_core" in o.name)
    core_ker = sum(o.flops for o in ker if "attn_core" in o.name)
    assert abs(core_ker / core_ref - 0.5) < 1e-6


def test_decode_is_memory_bound():
    for arch in ("gemma-7b", "mistral-large-123b"):
        cfg, sh = get_config(arch), SHAPES["decode_32k"]
        t = roofline_terms(step_costs(cfg, sh, MESH, DistConfig(rules=_tp_rules())))
        assert t["dominant"] == "memory", (arch, t)


def test_model_flops_ratio_sane():
    """MODEL_FLOPS ≤ analytic executed FLOPs (waste ≥ 0: remat ×4/3,
    kv-replication at TP>kv, masked-dense attention) and within sane bounds
    (no double counting). The low dense ratios are the baseline's real waste
    — exactly what §Perf hillclimbs."""
    for arch in ("qwen3-1.7b", "mistral-large-123b", "mamba2-370m"):
        cfg, sh = get_config(arch), SHAPES["train_4k"]
        t = roofline_terms(step_costs(cfg, sh, MESH, DistConfig(rules=_tp_rules(), microbatches=4)))
        mf = model_flops(cfg, sh)
        executed = t["flops"] * MESH.chips
        assert 0.15 < mf / executed <= 1.05, (arch, mf / executed)


def test_autotune_improves_or_equals():
    for arch, shape in [("qwen3-1.7b", "train_4k"), ("gemma-7b", "decode_32k")]:
        cfg, sh = get_config(arch), SHAPES[shape]
        d0 = DistConfig(rules=_tp_rules(), microbatches=4)
        res = autotune(cfg, sh, MeshShape(16, 16), d0, iterations=20, seed=0)
        assert res.best_terms["t_phase_sim_s"] <= res.baseline_terms["t_phase_sim_s"] * 1.001
    # the collective-bound train cell must actually move and log hypotheses
    cfg, sh = get_config("qwen3-1.7b"), SHAPES["train_4k"]
    res = autotune(cfg, sh, MeshShape(16, 16), DistConfig(rules=_tp_rules(), microbatches=4), iterations=20)
    assert res.best_terms["t_phase_sim_s"] < 0.5 * res.baseline_terms["t_phase_sim_s"]
    assert res.log and all(r.hypothesis for r in res.log)


def test_step_tdg_structure():
    cfg, sh = get_config("jamba-v0.1-52b"), SHAPES["train_4k"]
    ops = step_costs(cfg, sh, MESH, DistConfig(rules=_tp_rules(), microbatches=8))
    g = step_tdg(ops)
    g.validate()
    assert "embed" in g.tasks and "optimizer" in g.tasks


def test_phase_sim_at_least_roofline():
    """Dependency-aware step estimate ≥ each individual roofline term under
    duplex-ICI accounting (sim can overlap but not beat physics)."""
    cfg, sh = get_config("mistral-large-123b"), SHAPES["train_4k"]
    t = simulate_step(cfg, sh, MESH, DistConfig(rules=_tp_rules(), microbatches=8))
    assert t["t_phase_sim_s"] >= t["t_compute_s"] * 0.999
    assert t["t_phase_sim_s"] >= t["t_memory_s"] * 0.999
    assert t["t_phase_sim_s"] >= t["t_collective_s"] / 2 * 0.999  # duplex ICI


def test_interpod_term():
    """2-pod mesh: only the train gradient sync crosses pods; EF-int8 cuts it
    4×; serving shapes cross nothing."""
    from repro.roofline.analytic import interpod_term

    mesh2 = MeshShape(data=32, model=16, pods=2)
    cfg = get_config("mistral-large-123b")
    t = interpod_term(cfg, SHAPES["train_4k"], mesh2)
    tc = interpod_term(cfg, SHAPES["train_4k"], mesh2, DistConfig(rules={}, grad_compress="int8"))
    assert t > 0 and abs(tc / t - 0.25) < 1e-6
    assert interpod_term(cfg, SHAPES["decode_32k"], mesh2) == 0.0
    assert interpod_term(cfg, SHAPES["train_4k"], MeshShape(16, 16)) == 0.0


def test_collective_parse():
    txt = """
  %all-reduce.1 = f32[16,512]{1,0} all-reduce(f32[16,512]{1,0} %x), replica_groups={}
  %ag = bf16[8,128]{1,0} all-gather(bf16[4,128]{1,0} %y), dimensions={0}
  %cp = f32[4]{0} collective-permute(f32[4]{0} %z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(txt)
    assert out["all-reduce"] == 16 * 512 * 4
    assert out["all-gather"] == 8 * 128 * 2
    assert out["collective-permute"] == 16
    assert out["count"] == 3
