"""Fused phase-driven simulator kernel (Pallas).

One launch prices a whole candidate batch: grid over the batch axis, each
program running the full ≤T-phase loop of one design with the (T, T)
co-residency masks staged in VMEM scratch. ``ops.phase_sim`` is the
drop-in counterpart of ``repro.core.phase_sim_jax.simulate_batch`` (same
rows-dict in, same output dict out); ``ref.phase_sim_ref`` is the pure-jnp
oracle the kernel is tested against (tests/test_phase_sim_kernel.py).
"""
from .chain import resimulate_chains
from .ops import phase_sim
from .ref import phase_sim_ref

__all__ = ["phase_sim", "phase_sim_ref", "resimulate_chains"]
