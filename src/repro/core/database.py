"""Stage-1 database (paper §3.1): software characteristics + hardware PPA.

The paper populates this from perf/AccelSeeker/HPVM profiles and CACTI; none of
those are available offline, so we ship a parametric library with the same
*shape*: per-(task, mapping) performance entries (GPP ops/s, accelerator
A_peak), per-block power/area entries over the Table-3 knob ladders, and the
Table-1 Gables workload profiles. Energy/area constants are order-of-magnitude
figures for a ~5 nm class process (documented in DESIGN.md as stand-ins).

The same interface, instantiated with TPU v5e constants (`TPU_DB`), prices the
distributed-training design space (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict

from .blocks import Block, BlockKind


def _stable_unit(name: str) -> float:
    """Deterministic pseudo-random in [0,1) from a task name (used to give
    every task a stable accelerator speedup without an RNG)."""
    h = hashlib.sha256(name.encode()).digest()
    return int.from_bytes(h[:8], "big") / 2**64


@dataclasses.dataclass(frozen=True)
class EnergyModel:
    gpp_pj_per_op: float = 15.0  # fetch/decode overhead dominates (paper §1)
    acc_pj_per_op: float = 0.25  # hardened datapath, 5 nm-class MAC
    dram_pj_per_byte: float = 15.0
    sram_pj_per_byte: float = 1.0
    noc_pj_per_byte_hop: float = 0.8
    # static leakage, W per block (scaled by freq for PEs)
    gpp_leak_w: float = 2e-3
    acc_leak_w: float = 5e-4
    mem_leak_w_per_mb: float = 2e-3
    noc_leak_w: float = 5e-4


@dataclasses.dataclass(frozen=True)
class AreaModel:
    gpp_mm2: float = 1.2
    acc_mm2: float = 0.35
    sram_mm2_per_mb: float = 0.45
    dram_phy_mm2: float = 0.6
    noc_mm2_per_byte_width: float = 0.004


class HardwareDatabase:
    """PPA estimates queried by the simulator and the explorer."""

    def __init__(
        self,
        gpp_ops_per_cycle: float = 2.0,
        a_peak_range: tuple = (8.0, 64.0),
        energy: EnergyModel = EnergyModel(),
        area: AreaModel = AreaModel(),
        sram_capacity_mb: float = 4.0,
    ) -> None:
        self.gpp_ops_per_cycle = gpp_ops_per_cycle
        self.a_peak_range = a_peak_range
        self.energy = energy
        self.area = area
        self.sram_capacity_mb = sram_capacity_mb
        self._apeak_cache: Dict[str, float] = {}

    # ---- performance ----------------------------------------------------
    def pe_peak_ops(self, block: Block) -> float:
        """P_peak_CPU for GPPs; accelerators are priced via ``a_peak`` (Eq. 2)."""
        return block.freq_mhz * 1e6 * self.gpp_ops_per_cycle

    def a_peak_base(self, task_name: str) -> float:
        """Per-task hardened-datapath speedup at unroll=1 (AccelSeeker-style
        entry; deterministic per task so results are reproducible)."""
        if task_name not in self._apeak_cache:
            lo, hi = self.a_peak_range
            self._apeak_cache[task_name] = lo + (hi - lo) * _stable_unit(task_name)
        return self._apeak_cache[task_name]

    def a_peak(self, task_name: str, llp: float = 1.0, unroll: int = 1) -> float:
        """Eq. 2's A_peak. Loop unrolling (Table 3 swap knob) multiplies the
        datapath speedup but is capped by the task's loop-level parallelism —
        this is how the explorer's customization move *exploits LLP* (§5.4)."""
        return self.a_peak_base(task_name) * max(1.0, min(float(unroll), llp))

    # ---- power ------------------------------------------------------------
    def compute_energy_pj(self, block: Block, ops: float) -> float:
        per = self.energy.acc_pj_per_op if block.subtype == "acc" else self.energy.gpp_pj_per_op
        return per * ops

    def mem_energy_pj(self, block: Block, nbytes: float) -> float:
        per = self.energy.sram_pj_per_byte if block.subtype == "sram" else self.energy.dram_pj_per_byte
        return per * nbytes

    def noc_energy_pj(self, nbytes_hops: float) -> float:
        return self.energy.noc_pj_per_byte_hop * nbytes_hops

    def leakage_w(self, block: Block) -> float:
        f_scale = block.freq_mhz / 400.0
        if block.kind == BlockKind.PE:
            base = self.energy.acc_leak_w if block.subtype == "acc" else self.energy.gpp_leak_w
            return base * f_scale
        if block.kind == BlockKind.MEM:
            cap = self.sram_capacity_mb if block.subtype == "sram" else 0.5
            return self.energy.mem_leak_w_per_mb * cap * f_scale
        return self.energy.noc_leak_w * block.n_links * f_scale

    # ---- area ---------------------------------------------------------------
    def block_area_mm2(self, block: Block) -> float:
        f_scale = 0.6 + 0.4 * (block.freq_mhz / 800.0)  # freq costs area (timing closure)
        if block.kind == BlockKind.PE:
            base = self.area.acc_mm2 if block.subtype == "acc" else self.area.gpp_mm2
            return base * f_scale
        if block.kind == BlockKind.MEM:
            if block.subtype == "sram":
                return self.area.sram_mm2_per_mb * self.sram_capacity_mb * f_scale
            return self.area.dram_phy_mm2
        return self.area.noc_mm2_per_byte_width * block.width_bytes * block.n_links * f_scale


# ---------------------------------------------------------------------------
# TPU v5e-class constants (the §Roofline hardware terms), expressed through the
# same database interface so `repro.core` prices pod-level designs unchanged.
# ---------------------------------------------------------------------------
TPU_PEAK_FLOPS_BF16 = 197e12  # per chip
TPU_HBM_BYTES_PER_S = 819e9  # per chip
TPU_ICI_BYTES_PER_S_PER_LINK = 50e9


class TPUDatabase(HardwareDatabase):
    """Prices pod-level designs: PE=chip MXU, MEM=HBM, NOC=ICI."""

    def __init__(self) -> None:
        super().__init__(
            energy=EnergyModel(
                gpp_pj_per_op=0.6,  # bf16 MXU FLOP (~0.3-1 pJ public estimates)
                acc_pj_per_op=0.6,
                dram_pj_per_byte=12.0,  # HBM access
                sram_pj_per_byte=1.2,  # VMEM
                noc_pj_per_byte_hop=4.0,  # ICI serdes
                gpp_leak_w=30.0,  # chip idle
                acc_leak_w=30.0,
                mem_leak_w_per_mb=0.0,
                noc_leak_w=1.0,
            )
        )

    def pe_peak_ops(self, block: Block) -> float:
        return TPU_PEAK_FLOPS_BF16

    def mem_peak_bw(self) -> float:
        return TPU_HBM_BYTES_PER_S

    def ici_peak_bw(self, n_links: int = 1) -> float:
        return TPU_ICI_BYTES_PER_S_PER_LINK * n_links
