"""Blockwise causal GQA attention with a FlashAttention-style custom VJP
[arXiv:2205.14135, 2307.08691], in pure JAX.

Why custom VJP: differentiating the naive blockwise double-scan makes XLA
save the per-iteration probability blocks for *every* (q-block × kv-block)
pair — O(S²) residuals, exactly what blockwise attention exists to avoid
(observed: 135 GB/device temps on train_4k). The flash backward stores only
(q, k, v, out, row-logsumexp) and recomputes score blocks in the backward
scan, restoring O(S·block) memory.

Layout: q (B, Sq, H, Dh); k,v (B, Skv, K, Dh); GQA via grouped reshape.
Forward math in fp32 online-softmax; inputs/outputs keep the input dtype.
The Pallas kernel (kernels/flash_attention) implements the same contract for
TPU; this function is its shape-for-shape oracle and the dry-run lowering.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _reshape_blocks(x: jax.Array, nblk: int, blk: int):
    """(B, S, H, D) -> (nblk, B, blk, H, D) for scanning."""
    b, s, h, d = x.shape
    return x.reshape(b, nblk, blk, h, d).transpose(1, 0, 2, 3, 4)


def _fwd_impl(q, k, v, causal: bool, q_block: int, kv_block: int):
    b, sq, h, dh = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    nq, nkv = sq // q_block, skv // kv_block
    scale = 1.0 / math.sqrt(dh)

    qs = _reshape_blocks(q, nq, q_block).reshape(nq, b, q_block, kh, g, dh)
    ks = _reshape_blocks(k, nkv, kv_block)
    vs = _reshape_blocks(v, nkv, kv_block)

    def q_step(_, qi_i):
        qi, iq = qi_i
        rows = iq * q_block + jnp.arange(q_block)

        def kv_step(carry, kv_j):
            m, l, acc = carry
            kj, vj, jk = kv_j
            cols = jk * kv_block + jnp.arange(kv_block)
            s_blk = (
                jnp.einsum("bqkgd,bskd->bkgqs", qi.astype(jnp.float32), kj.astype(jnp.float32))
                * scale
            )
            if causal:
                s_blk = jnp.where(
                    (rows[:, None] >= cols[None, :])[None, None, None], s_blk, NEG_INF
                )
            m_new = jnp.maximum(m, s_blk.max(axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kh, g, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, vs, jnp.arange(nkv)))
        l = jnp.maximum(l, 1e-30)
        out = (acc / l[..., None]).transpose(0, 3, 1, 2, 4)  # (B, qb, K, G, Dh)
        lse = m + jnp.log(l)  # (B, K, G, qb)
        return None, (out, lse)

    _, (outs, lses) = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dh).astype(q.dtype)
    lse = lses.transpose(1, 0, 4, 2, 3).reshape(b, sq, kh, g)  # (B, Sq, K, G)
    return out, lse


def _bwd_impl(q, k, v, out, lse, dout, causal: bool, q_block: int, kv_block: int):
    b, sq, h, dh = q.shape
    skv, kh = k.shape[1], k.shape[2]
    g = h // kh
    nq, nkv = sq // q_block, skv // kv_block
    scale = 1.0 / math.sqrt(dh)

    # D_i = rowsum(dout ⊙ out)  (B, Sq, K, G)
    delta = jnp.sum(
        dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    ).reshape(b, sq, kh, g)

    qs = _reshape_blocks(q, nq, q_block).reshape(nq, b, q_block, kh, g, dh)
    dos = _reshape_blocks(dout, nq, q_block).reshape(nq, b, q_block, kh, g, dh)
    lses = lse.reshape(b, nq, q_block, kh, g).transpose(1, 0, 2, 3, 4)
    deltas = delta.reshape(b, nq, q_block, kh, g).transpose(1, 0, 2, 3, 4)
    ks = _reshape_blocks(k, nkv, kv_block)
    vs = _reshape_blocks(v, nkv, kv_block)

    def kv_step(dq_acc, kv_j):
        kj, vj, jk = kv_j
        cols = jk * kv_block + jnp.arange(kv_block)

        def q_step(carry, q_i):
            dk_j, dv_j = carry
            qi, doi, lsei, di, iq = q_i
            rows = iq * q_block + jnp.arange(q_block)
            s_blk = (
                jnp.einsum("bqkgd,bskd->bkgqs", qi.astype(jnp.float32), kj.astype(jnp.float32))
                * scale
            )
            if causal:
                s_blk = jnp.where(
                    (rows[:, None] >= cols[None, :])[None, None, None], s_blk, NEG_INF
                )
            # p = exp(s - lse)
            p = jnp.exp(s_blk - lsei.transpose(0, 2, 3, 1)[..., None])
            dv_j = dv_j + jnp.einsum("bkgqs,bqkgd->bskd", p, doi.astype(jnp.float32))
            dp = jnp.einsum("bqkgd,bskd->bkgqs", doi.astype(jnp.float32), vj.astype(jnp.float32))
            ds = p * (dp - di.transpose(0, 2, 3, 1)[..., None]) * scale
            dk_j = dk_j + jnp.einsum("bkgqs,bqkgd->bskd", ds, qi.astype(jnp.float32))
            dq_i = jnp.einsum("bkgqs,bskd->bqkgd", ds, kj.astype(jnp.float32))
            return (dk_j, dv_j), dq_i

        dk0 = jnp.zeros((b, kv_block, kh, dh), jnp.float32)
        dv0 = jnp.zeros((b, kv_block, kh, dh), jnp.float32)
        (dk_j, dv_j), dq_blocks = jax.lax.scan(
            q_step, (dk0, dv0), (qs, dos, lses, deltas, jnp.arange(nq))
        )
        return dq_acc + dq_blocks, (dk_j, dv_j)

    dq0 = jnp.zeros((nq, b, q_block, kh, g, dh), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(kv_step, dq0, (ks, vs, jnp.arange(nkv)))
    dq = dq.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, dh).astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, skv, kh, dh).astype(k.dtype)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, skv, kh, dh).astype(v.dtype)
    return dq, dk, dv


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 1024,
) -> jax.Array:
    out, _ = _fwd_impl(q, k, v, causal, q_block, kv_block)
    return out


def _vjp_fwd(q, k, v, causal, q_block, kv_block):
    out, lse = _fwd_impl(q, k, v, causal, q_block, kv_block)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, q_block, kv_block, res, dout):
    q, k, v, out, lse = res
    return _bwd_impl(q, k, v, out, lse, dout, causal, q_block, kv_block)


flash_attention_ref.defvjp(_vjp_fwd, _vjp_bwd)
