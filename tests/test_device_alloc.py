"""Device-resident allocation moves: MoveTable edge cases (single-slot
classes, all-taboo menus, capacity-saturated fork masks) and the PR
acceptance pins for the mixed mapping+allocation block — bit-exact R=1
parity against the host-driven loop, chain-i identity across population
sizes, and the ``reconcile_alloc`` device→host round trip."""
import copy

import numpy as np
import pytest

from repro.core import (
    DeviceChainRunner,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    MoveTable,
    audio,
    calibrated_budget,
    distance,
    random_single_noc_designs,
    simulate,
)
from repro.core.design import Design
from repro.core.device_explore import (
    MV_FORK_MEM,
    MV_FORK_PE,
    MV_JOIN_PE,
    MV_MIG_MEM,
    MV_MIG_PE,
    MV_SWAP_PE,
)
from repro.core.phase_sim_jax import BIG, EncodedDesign


def _fixture(seed=7):
    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    d = random_single_noc_designs(g, 1, seed=seed)[0]
    return g, db, bud, d


def _kinds_of(runner, design, *, alloc, cap_pe=None, cap_mem=None):
    """The packed table's kind column, for mapping move_idx → MV_* codes."""
    ed = EncodedDesign.of(design, runner.g, runner.db, runner.enc)
    tab = MoveTable.of(
        ed, runner.enc, alloc=alloc, cap_pe=cap_pe, cap_mem=cap_mem
    )
    return tab.kind


# ---------------------------------------------------------------------------
# MoveTable edge cases
# ---------------------------------------------------------------------------
def test_single_slot_classes_self_mask_every_move():
    """``Design.base`` has one PE and one MEM: mapping-only, every migrate
    row's destination is the task's current slot, so the whole menu is
    self-masked — ``any_valid`` is false on every chain every step, the
    block force-rejects throughout (no accepts, fitness pinned at the
    fresh-carry BIG, task maps and taboo untouched)."""
    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    d = Design.base(g)
    runner = DeviceChainRunner(g, db)
    res = runner.run_chains(d, bud, r=4, k=8, seed=3)
    assert int(res.accepted.sum()) == 0
    assert np.all(res.fit_trace == np.float32(BIG))
    t = res.task_pe.shape[1]
    assert np.array_equal(res.task_pe, np.zeros((4, t), res.task_pe.dtype))
    assert np.array_equal(res.task_mem, np.zeros((4, t), res.task_mem.dtype))
    # forced rejects must not burn taboo slots on the (unsampleable) menu
    assert int(res.carry.taboo.max()) == 0
    assert runner.n_fallback == 0


def test_movetable_structure_and_delta_guard():
    """Mapping-only tables are pure migrate crosses; ``alloc=True`` adds
    fork crosses, join/swap rows, and (single-NoC design) NO attach rows.
    ``delta_of`` only bridges migrate rows back to host MoveDeltas."""
    g, db, bud, d = _fixture()
    runner = DeviceChainRunner(g, db)
    ed = EncodedDesign.of(d, runner.g, runner.db, runner.enc)
    t = len(runner.enc.names)
    s_pe = int(ed.pe_peak.shape[0])
    s_mem = int(ed.mem_bw.shape[0])

    plain = MoveTable.of(ed, runner.enc)
    assert plain.n_moves == t * s_pe + t * s_mem
    assert set(np.unique(plain.kind)) == {MV_MIG_PE, MV_MIG_MEM}

    cap_pe, cap_mem = 8, 8
    wide = MoveTable.of(ed, runner.enc, alloc=True,
                        cap_pe=cap_pe, cap_mem=cap_mem)
    kinds = set(int(k) for k in np.unique(wide.kind))
    assert {MV_MIG_PE, MV_MIG_MEM, MV_FORK_PE, MV_FORK_MEM,
            MV_JOIN_PE, MV_SWAP_PE} <= kinds
    assert MV_FORK_MEM in kinds
    # single NoC chain → attach rows are degenerate and omitted
    assert all(int(k) <= 7 for k in kinds)
    # migrate rows now cross the padded capacity, not just the real slots
    assert np.sum(wide.kind == MV_MIG_PE) == t * cap_pe

    fork_rows = np.flatnonzero(wide.kind == MV_FORK_PE)
    with pytest.raises(ValueError):
        wide.delta_of(int(fork_rows[0]), runner.enc, ed)
    mig_rows = np.flatnonzero(wide.kind == MV_MIG_PE)
    delta = wide.delta_of(int(mig_rows[0]), runner.enc, ed)
    assert delta is not None


def test_all_taboo_menu_force_rejects_until_decay():
    """A carry whose taboo column is saturated masks the ENTIRE menu: the
    block must force-reject (no accepts, no state drift, no taboo
    re-stamping) until the counters decay to zero."""
    g, db, bud, d = _fixture(seed=11)
    runner = DeviceChainRunner(g, db)
    warm = runner.run_chains(d, bud, r=4, k=4, seed=2, alloc=True)
    # counters decrement BEFORE the validity check: 4 keeps every row
    # masked for the whole 3-step block (4→3→2→1, never 0)
    frozen = warm.carry._replace(
        taboo=np.full_like(warm.carry.taboo, 4)
    )
    res = runner.run_chains(
        d, bud, r=4, k=3, seed=2, it0=4, carry=frozen, alloc=True
    )
    assert int(res.accepted.sum()) == 0
    assert np.array_equal(res.fit_trace,
                          np.repeat(warm.fitness[:, None], 3, axis=1))
    assert np.array_equal(res.carry.task_pe, warm.carry.task_pe)
    assert np.array_equal(res.carry.task_mem, warm.carry.task_mem)
    assert np.array_equal(res.carry.pe_active, warm.carry.pe_active)
    # counters only decayed — never re-stamped to ttl by a forced reject
    assert int(res.carry.taboo.max()) == 1
    assert int(res.carry.taboo.min()) == 1
    assert runner.n_fallback == 0


def test_capacity_saturated_fork_mask():
    """With explicit caps pinned to the real slot counts every slot starts
    active, so no fork row is samplable at step 0 — the validity mask, not
    luck, keeps forks out of the menu (and the explicit-cap path must not
    desync the taboo width from the widened table)."""
    g, db, bud, d = _fixture(seed=5)
    runner = DeviceChainRunner(g, db)
    ed = EncodedDesign.of(d, runner.g, runner.db, runner.enc)
    s_pe = int(ed.pe_peak.shape[0])
    s_mem = int(ed.mem_bw.shape[0])
    res = runner.run_chains(
        d, bud, r=32, k=1, seed=13, alloc=True, cap_pe=s_pe, cap_mem=s_mem
    )
    kinds = _kinds_of(runner, d, alloc=True, cap_pe=s_pe, cap_mem=s_mem)
    sampled = kinds[res.move_idx[:, 0]]
    assert not np.any((sampled == MV_FORK_PE) | (sampled == MV_FORK_MEM))
    assert runner.n_fallback == 0


# ---------------------------------------------------------------------------
# mixed-move acceptance pins
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("menu", ["naive_sa", "telemetry", "farsi"])
def test_mixed_block_parity_with_host_loop(menu):
    """Tentpole acceptance bar: at R=1 the fused mixed mapping+allocation
    block replays the host-driven loop bit-for-bit on every menu — moves,
    accepts, fitness trace, and the full carry (active masks, allocation
    columns, provenance included)."""
    g, db, bud, d = _fixture(seed=7)
    runner = DeviceChainRunner(g, db)
    fused = runner.run_chains(d, bud, r=1, k=12, seed=7, menu=menu,
                              alloc=True)
    host = runner.run_chains_host(d, bud, r=1, n_steps=12, seed=7,
                                  menu=menu, alloc=True)
    assert fused.seq(0) == host.seq(0)
    assert np.array_equal(fused.fit_trace, host.fit_trace)
    for a, b in zip(fused.carry, host.carry):
        assert np.array_equal(a, b)
    assert runner.n_fallback == 0


def test_mixed_block_samples_allocation_moves():
    """The widened table must actually exercise allocation rows — a run
    whose sampled kinds never leave the migrate class means the menu
    collapsed back to PR-8 mapping-only."""
    g, db, bud, d = _fixture(seed=7)
    runner = DeviceChainRunner(g, db)
    res = runner.run_chains(d, bud, r=16, k=24, seed=7, menu="farsi",
                            alloc=True)
    kinds = _kinds_of(runner, d, alloc=True)
    sampled = kinds[res.move_idx]
    assert np.any(sampled > MV_MIG_MEM), "no allocation move ever sampled"


def test_mixed_chain_sequence_independent_of_population():
    """fold_in(seed, chain) keying must survive the widened table: chain
    i's mixed-move sequence is identical in an R=8 and an R=64 run."""
    g, db, bud, d = _fixture(seed=11)
    runner = DeviceChainRunner(g, db)
    small = runner.run_chains(d, bud, r=8, k=8, seed=3, menu="telemetry",
                              alloc=True)
    big = runner.run_chains(d, bud, r=64, k=8, seed=3, menu="telemetry",
                            alloc=True)
    for chain in (0, 3, 7):
        assert small.seq(chain) == big.seq(chain), chain
    assert np.array_equal(small.fit_trace, big.fit_trace[:8])
    assert np.array_equal(small.carry.pe_active, big.carry.pe_active[:8])
    assert np.array_equal(small.carry.task_pe, big.carry.task_pe[:8])


def test_reconcile_alloc_round_trips_to_host_fitness():
    """Decoding the winning chain back into a Design (clones, retunes,
    re-homes, removals) must land on the device fitness when re-priced by
    the host simulator — f32-tolerance, not shape-tolerance."""
    g, db, bud, d = _fixture(seed=7)
    runner = DeviceChainRunner(g, db)
    res = runner.run_chains(d, bud, r=8, k=32, seed=9, menu="farsi",
                            alloc=True)
    dev_fit = float(res.fitness[res.winner])
    assert np.isfinite(dev_fit)
    d2 = copy.deepcopy(d)
    runner.reconcile_alloc(d2, res)
    host_fit = distance(simulate(d2, g, db), bud).fitness(0.05)
    assert host_fit == pytest.approx(dev_fit, rel=1e-4, abs=1e-4)


def test_explorer_chain_alloc_end_to_end():
    """``ExplorerConfig(chain_alloc=True)`` runs host-free mixed blocks:
    history records ``chain_mixed`` moves, n_sims counts R·K device steps
    plus the single final decode, and the reconciled winner's host-priced
    fitness matches the device trace's final winner fitness."""
    g, db, bud, d = _fixture()
    res = Explorer(
        g, db, bud,
        ExplorerConfig(policy="device_sa", max_iterations=48, seed=4,
                       backend="jax", chain_r=8, chain_k=16,
                       chain_alloc=True),
    ).run_chains()
    moves = {h["move"] for h in res.history}
    assert moves == {"chain_mixed"}
    assert res.chained and res.chain_r == 8
    assert res.n_sims == 8 * 48 + 1  # R·K device steps + one winner decode
    dev_fit = res.history[-1]["fitness"]
    host_fit = res.best_distance.fitness(0.05)
    assert host_fit == pytest.approx(dev_fit, rel=1e-4, abs=1e-4)
