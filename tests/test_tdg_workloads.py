"""TDG structure + the AR workloads' Table-1 characteristics."""
import math

import pytest

from repro.core import all_workloads, ar_complex, audio, cava, edge_detection
from repro.core.tdg import Task, TaskGraph, merge_graphs, workload_of

MOPS = 1e6
MB = 1e6


def test_graph_validates_and_topo():
    for g in all_workloads().values():
        g.validate()
        order = g.topo_order()
        pos = {n: i for i, n in enumerate(order)}
        for (s, d) in g.edge_bytes:
            assert pos[s] < pos[d]


def test_task_counts_match_paper():
    # paper Fig. 2: Audio has the most tasks (15), Edge Detection the least (6)
    assert len(audio().tasks) == 15
    assert len(edge_detection().tasks) == 6
    assert len(cava().tasks) in range(5, 12)


@pytest.mark.parametrize(
    "maker,f_mops,dm_mb",
    [(audio, 13, 0.19), (cava, 24_252, 0.33), (edge_detection, 1_098, 7.01)],
)
def test_table1_averages(maker, f_mops, dm_mb):
    g = maker()
    assert math.isclose(g.avg_work_ops(), f_mops * MOPS, rel_tol=1e-6)
    if maker is not cava:  # CAVA edges are serial-chain (n-1 edges)
        pass
    # edge bytes carry the Table-1 average data movement
    mean_edge = sum(g.edge_bytes.values()) / len(g.edge_bytes)
    assert math.isclose(mean_edge, dm_mb * MB, rel_tol=1e-6)


def test_talp_ordering():
    # paper Table 1: Audio has the highest TaLP, CAVA exactly 1 (serial)
    t = {n: g.talp() for n, g in all_workloads().items()}
    assert t["cava"] == 1.0
    assert t["ed"] == 4.0
    assert t["audio"] > t["ed"] > t["cava"]


def test_llp_ordering():
    l = {n: g.avg_llp() for n, g in all_workloads().items()}
    # ED has the highest LLP, CAVA the lowest (Table 1)
    assert l["ed"] > l["audio"] > l["cava"]


def test_merge_namespacing():
    g = ar_complex()
    assert len(g.tasks) == 15 + 7 + 6
    for t in g.tasks:
        assert workload_of(t) in ("audio", "cava", "ed")


def test_parallel_tasks_of():
    g = edge_detection()
    par = set(g.parallel_tasks_of("grad_x"))
    assert "grad_y" in par and "laplacian" in par
    assert "gauss_blur" not in par and "magnitude" not in par
