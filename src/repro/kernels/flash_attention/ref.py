"""Pure-jnp oracle for the flash-attention kernel: plain masked-dense causal
GQA attention in the kernel's (B, H, S, Dh) layout."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_reference(
    q: jax.Array,  # (B, H, Sq, Dh)
    k: jax.Array,  # (B, KH, Skv, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
) -> jax.Array:
    b, h, sq, dh = q.shape
    kh, skv = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, sq, dh).astype(jnp.float32)
    s = jnp.einsum("bkgqd,bksd->bkgqs", qg, k.astype(jnp.float32)) / math.sqrt(dh)
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, sq, dh).astype(q.dtype)
