"""Error-feedback int8 gradient compression [1-bit Adam / EF-SGD lineage;
Seide et al. 2014, arXiv:2102.02888].

The DP gradient reduction is the only cross-pod collective in the training
step; quantizing its payload to int8 (per-leaf absmax scale) cuts the
inter-pod ICI term ~4× for fp32 grads. The quantization residual is carried
in an error-feedback buffer so the *accumulated* update is unbiased — the
standard trick that keeps convergence intact.

Usage (wired via DistConfig.grad_compress="int8"):
    grads_q, err = compress_with_feedback(grads, err)
    # all-reduce grads_q.payload (int8) + scale, then
    grads = decompress(grads_q)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Compressed(NamedTuple):
    payload: Any  # int8 pytree
    scale: Any  # fp32 scalar per leaf


def init_error(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_with_feedback(grads, error) -> Tuple[Compressed, Any]:
    """Quantize (grads + carried error) to int8; return new error = residual."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        new_e = x - q.astype(jnp.float32) * scale
        return q, scale, new_e

    flat, treedef = jax.tree.flatten(grads)
    eflat = jax.tree.leaves(error)
    qs, scales, errs = [], [], []
    for g, e in zip(flat, eflat):
        q, s, ne = one(g, e)
        qs.append(q)
        scales.append(s)
        errs.append(ne)
    unf = lambda xs: jax.tree.unflatten(treedef, xs)
    return Compressed(unf(qs), unf(scales)), unf(errs)


def decompress(c: Compressed):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, c.payload, c.scale
    )
