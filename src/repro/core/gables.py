"""Extended-Gables analytical models (paper §3.2, Eqs. 1–5).

Given the set of tasks *running in a phase* and their mappings, compute each
block's per-task processing rate and each task's completion time:

  Eq. 1  P_CPU  = P_peak_CPU / |T|                 (preemptive equal share)
  Eq. 2  P_IP   = A_peak · P_peak_CPU / |T|
  Eq. 3  B_NoC  = per-task share of link bandwidth, burst-ratio arbitrated
  Eq. 4  B_Mem  = B_peak_Mem · Burst_i / Σ_j Burst_j
  Eq. 5  C_T    = max(f/P, D_r/B_mem_r, D_w/B_mem_w, D/B_noc, ...)

Note on Eqs. 3/4: the paper's printed equations *divide* by the burst ratio,
which is dimensionally inverted (a task with a larger share would get *less*
bandwidth, and a lone task with ratio 1.0 would see exactly B_peak only by
accident). The prose — "this division is determined by the burst size ratio of
the task over the total bursts of all running tasks" — describes proportional
arbitration, which is what we implement: share_i = Burst_i / Σ Burst. For NoCs,
``n_links`` parallel channels serve disjoint task subsets (multi-channel
routers for master/slave combinations, §3.2): tasks are striped over links
round-robin and arbitrate within their link.

Reads and writes are split (I_read / I_write) because "modern routers/memories
support separate channels for each" — so read and write streams of one memory
do not contend with each other.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .blocks import BlockKind
from .design import Design
from .database import HardwareDatabase
from .tdg import TaskGraph


@dataclasses.dataclass
class TaskRates:
    """Per-running-task processing rates for the current phase."""

    compute_ops_s: float
    read_bw: float  # bytes/s end-to-end for the read stream (min of path)
    write_bw: float
    # per-resource attribution for bottleneck analysis:
    binding: Dict[str, float] = dataclasses.field(default_factory=dict)
    # the slowest NoC instance on the task's route (bottleneck-block targeting)
    noc_name: str = ""


class RouteContext:
    """Per-design route/topology cache. A design is immutable while being
    simulated; precomputing routes removes the O(tasks²·chain) rediscovery
    from every phase (the simulator hot path)."""

    def __init__(self, design: Design, tdg: TaskGraph):
        self.routes: Dict[str, tuple] = {t: tuple(design.route(t)) for t in tdg.tasks}
        self.hops: Dict[str, int] = {t: len(r) for t, r in self.routes.items()}

    def route(self, t: str) -> tuple:
        return self.routes[t]


def phase_rates(
    design: Design,
    tdg: TaskGraph,
    running: List[str],
    db: HardwareDatabase,
    ctx: RouteContext = None,
) -> Dict[str, TaskRates]:
    """Compute every running task's rates under current contention."""
    ctx = ctx or RouteContext(design, tdg)
    # --- Eq. 1/2: PE rates, preemptive equal sharing --------------------
    pe_load: Dict[str, int] = {}
    for t in running:
        pe_load[design.task_pe[t]] = pe_load.get(design.task_pe[t], 0) + 1

    # --- burst bookkeeping for Mem (Eq. 4) and NoC (Eq. 3) --------------
    mem_burst_read: Dict[str, float] = {}
    mem_burst_write: Dict[str, float] = {}
    # NoC link assignment: tasks using a NoC are striped over its links
    # round-robin (stable order), then burst-arbitrated within the link.
    noc_users: Dict[str, List[str]] = {}
    for t in sorted(running):
        for noc_name in ctx.route(t):
            noc_users.setdefault(noc_name, []).append(t)
    noc_link_tasks: Dict[tuple, List[str]] = {}
    link_of: Dict[tuple, int] = {}
    for noc_name, users in noc_users.items():
        n_links = design.blocks[noc_name].n_links
        for i, t in enumerate(users):
            link = i % n_links
            link_of[(t, noc_name)] = link
            noc_link_tasks.setdefault((noc_name, link), []).append(t)
    for t in sorted(running):
        task = tdg.tasks[t]
        mem = design.task_mem[t]
        mem_burst_read[mem] = mem_burst_read.get(mem, 0.0) + task.burst_bytes
        mem_burst_write[mem] = mem_burst_write.get(mem, 0.0) + task.burst_bytes

    out: Dict[str, TaskRates] = {}
    for t in running:
        task = tdg.tasks[t]
        pe = design.blocks[design.task_pe[t]]
        mem = design.blocks[design.task_mem[t]]
        n_on_pe = pe_load[pe.name]

        # Eq. 1 / Eq. 2
        p_peak = db.pe_peak_ops(pe)
        if pe.subtype == "acc":
            a = (
                db.a_peak(task.name, task.llp, pe.unroll)
                if pe.hardened_for == task.name
                else 1.0
            )
            compute = a * p_peak / n_on_pe
        else:
            compute = p_peak / n_on_pe

        # Eq. 4 (proportional burst arbitration; read/write channels separate)
        b_mem_peak = mem.peak_bandwidth(db)
        share_r = task.burst_bytes / mem_burst_read[mem.name]
        share_w = task.burst_bytes / mem_burst_write[mem.name]
        mem_read_bw = b_mem_peak * share_r
        mem_write_bw = b_mem_peak * share_w

        # Eq. 3: per-link arbitration along the route; end-to-end = min link
        noc_bw, slow_noc = float("inf"), ""
        for noc_name in ctx.route(t):
            noc = design.blocks[noc_name]
            peers = noc_link_tasks[(noc_name, link_of[(t, noc_name)])]
            total_burst = sum(tdg.tasks[p].burst_bytes for p in peers)
            share = task.burst_bytes / total_burst
            bw = noc.peak_bandwidth(db) * share
            if bw < noc_bw:
                noc_bw, slow_noc = bw, noc_name

        read_bw = min(mem_read_bw, noc_bw)
        write_bw = min(mem_write_bw, noc_bw)
        out[t] = TaskRates(
            compute_ops_s=compute,
            read_bw=read_bw,
            write_bw=write_bw,
            binding={
                "pe": compute,
                "mem_read": mem_read_bw,
                "mem_write": mem_write_bw,
                "noc": noc_bw,
            },
            noc_name=slow_noc,
        )
    return out


def binding_block(design: Design, t: str, rates: TaskRates, kind: str) -> str:
    """Resolve a bottleneck class to the concrete block instance to target."""
    if kind == "pe":
        return design.task_pe[t]
    if kind == "mem":
        return design.task_mem[t]
    return rates.noc_name or design.route(t)[0]


def completion_time(task, rates: TaskRates) -> float:
    """Eq. 5: the task finishes when its *slowest* component finishes."""
    return max(
        task.work_ops / rates.compute_ops_s,
        task.read_bytes / rates.read_bw,
        task.write_bytes / rates.write_bw,
    )


def bottleneck_of(task, rates: TaskRates) -> str:
    """Which block class binds Eq. 5's max — drives Algorithm-1 reasoning and
    the Fig.-12 comm/comp boundedness characterization."""
    comp = task.work_ops / rates.compute_ops_s
    rd = task.read_bytes / rates.read_bw
    wr = task.write_bytes / rates.write_bw
    if comp >= rd and comp >= wr:
        return "pe"
    # communication-bound: memory or NoC, whichever is the tighter pipe
    mem_bw = rates.binding["mem_read"] if rd >= wr else rates.binding["mem_write"]
    return "mem" if mem_bw <= rates.binding["noc"] else "noc"
