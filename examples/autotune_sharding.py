"""FARSI as the framework's auto-configuration engine (DESIGN.md §2): explore
the distributed-execution design space of an (arch × shape) cell on the
production mesh, printing each hypothesis → measurement cycle.

  PYTHONPATH=src python examples/autotune_sharding.py --arch qwen3-1.7b --shape train_4k
"""
import argparse

from repro.configs.base import SHAPES
from repro.configs.registry import arch_names, get_config
from repro.launch.autotune import autotune
from repro.roofline.analytic import MeshShape, model_flops
from repro.sharding.rules import DistConfig


def baseline_rules():
    return {
        "qkv": ("model",), "kv_qkv": ("model",), "mlp": ("model",),
        "ssm_inner": ("model",), "ssm_conv": ("model",), "expert_mlp": ("model",),
        "seq_res": ("model",), "embed": ("data",),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=arch_names(), default="qwen3-1.7b")
    ap.add_argument("--shape", choices=sorted(SHAPES), default="train_4k")
    ap.add_argument("--iterations", type=int, default=30)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = MeshShape(16, 16)
    micro = 8 if cfg.param_counts()["total"] >= 50e9 else 4
    d0 = DistConfig(rules=baseline_rules(), microbatches=micro)

    res = autotune(cfg, shape, mesh, d0, iterations=args.iterations)
    b, a = res.baseline_terms, res.best_terms
    print(f"{args.arch} × {args.shape} on 16×16 (256 chips)\n")
    print(f"{'':12s}{'baseline':>14s}{'tuned':>14s}")
    for k, label in [("t_compute_s", "compute"), ("t_memory_s", "HBM"),
                     ("t_collective_s", "ICI"), ("t_phase_sim_s", "step est")]:
        print(f"{label:12s}{b[k]*1e3:12.1f}ms{a[k]*1e3:12.1f}ms")
    print(f"{'HBM state':12s}{b['hbm_state_bytes']/1e9:12.1f}GB{a['hbm_state_bytes']/1e9:12.1f}GB")
    speedup = b["t_phase_sim_s"] / a["t_phase_sim_s"]
    mf = model_flops(cfg, shape) / mesh.chips
    frac_b = mf / 197e12 / b["t_phase_sim_s"] * 100
    frac_a = mf / 197e12 / a["t_phase_sim_s"] * 100
    print(f"\nestimated speedup: {speedup:.2f}x   roofline fraction: {frac_b:.1f}% → {frac_a:.1f}%")
    print(f"tuned config: microbatches={res.best.microbatches} remat={res.best.remat} "
          f"attn={res.best.attn_impl} tp={'on' if res.best.rules.get('qkv') else 'off'} "
          f"sp={'on' if res.best.rules.get('seq_res') else 'off'}\n")
    print("hypothesis → measurement log:")
    for r in res.log:
        mark = "✓" if r.accepted else "✗"
        print(f" {mark} it{r.iteration:02d} {r.move}:{r.knob:14s} "
              f"{r.before['t_phase_sim_s']*1e3:9.1f} → {r.after['t_phase_sim_s']*1e3:9.1f} ms | {r.hypothesis}")


if __name__ == "__main__":
    main()
