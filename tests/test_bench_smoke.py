"""Perf-regression guard: `python -m benchmarks.run --smoke` must pass in
tier-1 CI. The smoke mode prices one neighbour-candidate batch through both
backends at tiny sizes and *asserts* (1) the JAX array-native path is at
least as fast as the scalar Python path, (2) both agree on the winning
candidate's latency, (3) the fused Pallas phase-sim kernel matches the XLA
reference path ≤ 1e-5 on the fitness column, and (4) the device-loop
guard: the fused (R=16, K) chain block sustains ≥ 2x the host-driven
loop's chain-iteration rate with ``n_compiles ≤ 4`` and ``n_fallback ==
0``, replaying the host loop bit-for-bit at R=1 — and (5) the same
contract for the mixed mapping+allocation block on the widened move table
(R=1 parity, ≥ 2x at R=16, ``n_compiles ≤ 6``, ``n_fallback == 0``) —
while the retired speculative-pipeline counters stay absent from
``ExplorationResult`` (the tombstone). A regression in the
incremental-encoding / lazy-decode / fused-chain hot path fails fast
instead of silently eroding the BENCH numbers. Also guards the bench-json
root mirror: it must be byte-identical to its benchmarks/ source (run.py
mirrors atomically via tmp + rename; a diverged pair means a torn or
stale mirror the perf tracker would misread)."""
import filecmp
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_benchmarks_smoke_cli():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--smoke"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=480,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "simbackend.smoke" in out.stdout, out.stdout
    # smoke must never touch the tracked trajectory file nor its root mirror
    assert "wrote" not in out.stdout
    assert "\nmirror," not in out.stdout


def test_bench_json_mirror_matches_source():
    """The repo-root BENCH_simbackend.json mirror must be byte-identical to
    the benchmarks/ source whenever both exist (atomic tmp+rename mirroring
    makes a torn copy impossible; this catches a *stale* one)."""
    src = os.path.join(REPO, "benchmarks", "BENCH_simbackend.json")
    dst = os.path.join(REPO, "BENCH_simbackend.json")
    if not (os.path.exists(src) and os.path.exists(dst)):
        return
    assert filecmp.cmp(src, dst, shallow=False), (
        "root BENCH_simbackend.json diverged from benchmarks/ source — "
        "rerun the full bench so the mirror is refreshed atomically"
    )
