"""Paper Fig. 9: convergence of the DSE.

9a — simulator agility's impact: the same heuristic with the phase-driven
simulator vs the event-driven reference as its inner loop (the paper
extrapolates PA; we actually run both and extrapolate per-sim cost).
9b — architecture awareness: SA / Task-aware / Task&Block-aware / FARSI
distance-vs-iteration, averaged over seeds.
"""
from __future__ import annotations

import statistics
import time
from typing import List

from repro.core import (
    AWARENESS_LEVELS,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    ar_complex,
    calibrated_budget,
    simulate_events,
)

from .common import Row

SEEDS = (1, 2, 3)
MAX_ITERS = 600


def run() -> List[Row]:
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    rows: List[Row] = []

    # --- 9b: awareness ladder -------------------------------------------
    per_level = {}
    for level in AWARENESS_LEVELS:
        iters, dists, walls, blocks, conv = [], [], [], [], 0
        for seed in SEEDS:
            ex = Explorer(g, db, bud, ExplorerConfig(awareness=level, max_iterations=MAX_ITERS, seed=seed))
            res = ex.run()
            iters.append(res.iterations if res.converged else MAX_ITERS)
            dists.append(res.best_distance.city_block())
            walls.append(res.wall_s)
            blocks.append(sum(res.best_design.block_counts().values()))
            conv += res.converged
        per_level[level] = statistics.mean(iters)
        rows.append(
            (
                f"fig9b.{level}",
                statistics.mean(walls) * 1e6,
                f"iters_avg={statistics.mean(iters):.0f} dist_avg={statistics.mean(dists):.3f} "
                f"converged={conv}/{len(SEEDS)} blocks_avg={statistics.mean(blocks):.1f}",
            )
        )
    if per_level["farsi"] > 0:
        rows.append(
            (
                "fig9b.speedup_vs_sa",
                0.0,
                f"sa/farsi={per_level['sa']/per_level['farsi']:.1f}x "
                f"task/farsi={per_level['task']/per_level['farsi']:.1f}x "
                f"task_block/farsi={per_level['task_block']/per_level['farsi']:.1f}x",
            )
        )

    # --- 9a: simulator agility -------------------------------------------
    ex = Explorer(g, db, bud, ExplorerConfig(max_iterations=MAX_ITERS, seed=1))
    res = ex.run()
    phase_wall = res.wall_s
    n_sims = res.n_sims
    # measured per-sim cost of the reference simulator on the final design
    t0 = time.perf_counter()
    simulate_events(res.best_design, g, db, max_chunks=128)
    event_per_sim = time.perf_counter() - t0
    est_event_wall = event_per_sim * n_sims
    rows.append(
        (
            "fig9a.convergence_time",
            phase_wall * 1e6,
            f"farsi_sim={phase_wall:.1f}s est_with_event_sim={est_event_wall:.0f}s "
            f"ratio={est_event_wall/max(phase_wall,1e-9):.0f}x sims={n_sims}",
        )
    )
    return rows
