"""Pallas TPU fused RMSNorm kernel.

One VMEM pass per (row_block, d_model) tile: fp32 mean-of-squares reduction,
rsqrt, scale by (1 + w) — avoiding the separate square/reduce/mul HBM round
trips of the unfused lowering. Grid tiles the flattened token axis; d_model
stays whole per tile (norms reduce over it), bounding VMEM at
row_block × d_model × 4 B (default 256 × d ≤ ~12 MB for d ≤ 12288).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (bm, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


def rmsnorm_2d(
    x: jax.Array,  # (rows, d)
    w: jax.Array,  # (d,)
    *,
    eps: float = 1e-6,
    row_block: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, d = x.shape
    row_block = min(row_block, rows)
    assert rows % row_block == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(rows // row_block,),
        in_specs=[
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((row_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, w)
