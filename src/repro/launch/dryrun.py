import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch × shape × mesh) cell lowers, SPMD-
partitions, and compiles — with per-device memory analysis and cost analysis
recorded for §Roofline.

MUST be run as its own process (the XLA_FLAGS line above must execute before
any jax device initialization — hence before every other import, and why this
flag is never set globally in conftest/pyproject).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --out experiments/dryrun
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from ..configs.base import SHAPES, ModelConfig, ShapeConfig, shape_applicable  # noqa: E402
from ..configs.registry import arch_names, get_config  # noqa: E402
from ..models.model import RunFlags, init_cache, init_params  # noqa: E402
from ..optim.adamw import AdamWConfig  # noqa: E402
from ..roofline.hlo import collective_bytes  # noqa: E402
from ..sharding.act import activation_rules  # noqa: E402
from ..sharding.rules import (  # noqa: E402
    DistConfig,
    default_rules,
    tree_sharded_structs,
)
from ..sharding.specs import batch_logical, cache_logical, param_logical  # noqa: E402
from ..train.step import (  # noqa: E402
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from .mesh import make_production_mesh  # noqa: E402


def _batch_structs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b = shape.global_batch
    s = 1 if shape.kind == "decode" else shape.seq_len
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    else:
        out["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if shape.kind == "train":
        out["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.rope_kind == "mrope":
        out["mrope_positions"] = jax.ShapeDtypeStruct((3, b, s), jnp.int32)
    return out


def build_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    dist: Optional[DistConfig] = None,
):
    """Returns (step_fn, args tuple of sharded ShapeDtypeStructs, mesh)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        raise ValueError(f"{arch} × {shape_name}: inapplicable (see DESIGN.md)")
    if dist is not None and dist.capacity_factor > 0:
        cfg = dataclasses.replace(cfg, capacity_factor=dist.capacity_factor)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(default_rules(cfg, shape, mesh))
    # deeper grad accumulation for ≥50B-param models: the remat-residual
    # stack scales with tokens/device × depth (see DistConfig.microbatches)
    default_micro = 8 if cfg.param_counts()["total"] >= 50e9 else 4
    if dist is None:
        dist = DistConfig(rules=rules, microbatches=default_micro)
    else:
        merged = dict(rules)
        merged.update(dist.rules)
        dist = dist.replace(rules=merged)
    flags = RunFlags(
        attn_impl=dist.attn_impl,
        q_block=dist.q_block,
        kv_block=dist.kv_block,
        remat=dist.remat if shape.kind == "train" else "none",
        ssd_chunk=dist.ssd_chunk,
        moe_impl=dist.moe_impl,
    )

    p_logical = param_logical(cfg)
    batch_l = batch_logical(cfg, shape.kind)
    batch_structs = jax.tree.map(
        lambda s, l: s,
        _batch_structs(cfg, shape),
        batch_l,
    )
    batch_sds = tree_sharded_structs(_batch_structs(cfg, shape), batch_l, dist.rules, mesh)

    if shape.kind == "train":
        state_struct = jax.eval_shape(
            lambda k: init_train_state(cfg, k), jax.random.PRNGKey(0)
        )
        state_logical = {
            "params": p_logical,
            "opt": {"m": p_logical, "v": p_logical, "count": ()},
            "step": (),
        }
        state_sds = tree_sharded_structs(state_struct, state_logical, dist.rules, mesh)
        fn = make_train_step(cfg, flags, AdamWConfig(), microbatches=dist.microbatches)
        args = (state_sds, batch_sds)
        donate = (0,)
    elif shape.kind == "prefill":
        params_struct = jax.eval_shape(
            lambda k: init_params(cfg, k, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
        )
        params_sds = tree_sharded_structs(params_struct, p_logical, dist.rules, mesh)
        fn = make_prefill_step(cfg, flags)
        args = (params_sds, batch_sds)
        donate = ()
    else:  # decode
        params_struct = jax.eval_shape(
            lambda k: init_params(cfg, k, dtype=jnp.bfloat16), jax.random.PRNGKey(0)
        )
        params_sds = tree_sharded_structs(params_struct, p_logical, dist.rules, mesh)
        cache_struct = jax.eval_shape(
            lambda: init_cache(
                cfg, shape.global_batch, shape.seq_len, jnp.bfloat16, dist.kv_quant
            )
        )
        cache_sds = tree_sharded_structs(
            cache_struct, cache_logical(cfg, dist.kv_quant), dist.rules, mesh
        )
        idx_sds = jax.ShapeDtypeStruct((), jnp.int32)
        fn = make_decode_step(cfg, flags)
        args = (params_sds, cache_sds, batch_sds, idx_sds)
        donate = (1,)
    return fn, args, mesh, donate, dist


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    dist: Optional[DistConfig] = None,
    verbose: bool = True,
) -> Dict[str, Any]:
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": False,
    }
    try:
        fn, args, mesh, donate, dist = build_cell(arch, shape_name, multi_pod, dist)
        t0 = time.perf_counter()
        with mesh, activation_rules(dist.rules, mesh):
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            rec["lower_s"] = time.perf_counter() - t0
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = time.perf_counter() - t1
        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "code_bytes": int(mem.generated_code_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
            }
            if verbose:
                print(f"  memory_analysis: {rec['memory']}")
        except Exception as e:  # pragma: no cover - backend-specific
            rec["memory"] = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            rec["cost"] = {
                k: float(v)
                for k, v in cost.items()
                if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")
            }
            if verbose:
                print(f"  cost_analysis: {rec['cost']}")
        except Exception as e:  # pragma: no cover
            rec["cost"] = {"error": str(e)}
        try:
            rec["collectives"] = collective_bytes(compiled.as_text())
        except Exception as e:  # pragma: no cover
            rec["collectives"] = {"error": str(e)}
        rec["ok"] = True
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()
    return rec


def iter_cells(multi_pod: bool):
    for arch in arch_names():
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            if shape_applicable(cfg, shape):
                yield arch, shape_name, multi_pod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None, help="directory for per-cell JSON records")
    # tuned-config knobs (§Perf reproducibility from the CLI)
    ap.add_argument("--moe-impl", choices=("dense", "shard_map"), default="dense")
    ap.add_argument("--kv-quant", choices=("none", "int8"), default="none")
    ap.add_argument("--remat", choices=("full", "none", "dots"), default="full")
    ap.add_argument("--microbatches", type=int, default=0, help="0 = per-arch default")
    args = ap.parse_args()

    dist = None
    if (
        args.moe_impl != "dense"
        or args.kv_quant != "none"
        or args.remat != "full"
        or args.microbatches
    ):
        dist = DistConfig(
            rules={},
            moe_impl=args.moe_impl,
            kv_quant=args.kv_quant,
            remat=args.remat,
            microbatches=args.microbatches or 4,
        )

    cells = (
        list(iter_cells(args.multi_pod))
        if args.all
        else [(args.arch, args.shape, args.multi_pod)]
    )
    n_ok = 0
    for arch, shape_name, mp in cells:
        print(f"[dryrun] {arch} × {shape_name} × {'2x16x16' if mp else '16x16'}")
        rec = run_cell(arch, shape_name, mp, dist=dist)
        status = "OK" if rec["ok"] else f"FAIL: {rec.get('error')}"
        print(
            f"  -> {status}  (lower {rec.get('lower_s', 0):.1f}s, "
            f"compile {rec.get('compile_s', 0):.1f}s)"
        )
        n_ok += rec["ok"]
        if args.out:
            os.makedirs(args.out, exist_ok=True)
            tag = f"{arch}_{shape_name}_{rec['mesh']}".replace("/", "-")
            with open(os.path.join(args.out, f"{tag}.json"), "w") as f:
                json.dump(rec, f, indent=2, default=str)
    print(f"[dryrun] {n_ok}/{len(cells)} cells OK")
    if n_ok < len(cells):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
