"""Device-resident exploration: fused accept loop + vmapped chain populations.

The host-driven accept loop caps the explorer at ~1.2k it/s while the
batched evaluator sustains ~19k evals/s (BENCH_simbackend.json): every SA
iteration pays a dispatch, a device→host fitness transfer, and a Python
accept/taboo update before the next candidate can even be proposed. This
module moves the whole explore step onto the device:

  * :class:`MoveTable` — ``propose_moves`` in packed array form. Every
    shape-preserving candidate move (task → PE slot, task → MEM slot) is
    enumerated up front as three flat int32 columns (``kind``/``task``/
    ``dest``); the loop *samples* an index from this table on device
    instead of materializing `MoveDelta` objects on host. Menus: the
    ``naive_sa`` menu samples uniformly over the valid (non-no-op,
    non-taboo) rows; the ``telemetry`` menu weights rows by the bottleneck
    seconds of the task's *current* slot (the per-slot telemetry columns
    the simulator already emits), so moves that relieve hot blocks are
    proposed more often — FARSI's bottleneck-directed neighbour selection,
    without a host round trip.
  * A ``lax.scan`` accept loop: K iterations of propose → mutate encoding
    → re-simulate → SA accept/reject run entirely on device. The carry is
    the chain state (task→slot maps, current fitness, PRNG key, per-move
    taboo TTLs, per-slot bottleneck telemetry of the incumbent design).
  * Chain populations: the R chains ARE the batch axis of the simulator —
    each scan step prices an (R,)-rows dict through the usual batched
    path (Pallas kernel or XLA reference; ``kernels.phase_sim.chain``).
    Per-chain PRNG keys are ``fold_in(base_key, chain_index)``, so chain
    i's stream — and therefore its accepted-move sequence — is identical
    at R=16 and R=256 (population size never perturbs a chain).

One dispatch prices an (R, K) exploration block. The host calls
:meth:`DeviceChainRunner.run_chains` once per block, reconciles the
winning chain's final mapping onto the live design
(:func:`~repro.core.moves.apply_mapping`), and only the winner pays the
usual single decode. :meth:`DeviceChainRunner.run_chains_host` is the
same compiled step driven one iteration per dispatch — the classic
host-loop regime — which makes it both the parity oracle (bit-identical
accepted-move sequences, same threefry draws, same f32 accept math) and
the speedup baseline the bench reports against.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.phase_sim.chain import resimulate_chains
from .budgets import Budget
from .database import HardwareDatabase
from .design import Design
from .moves import MoveDelta, apply_mapping, mapping_delta
from .phase_sim_jax import (
    BIG,
    EncodedDesign,
    EncodedWorkload,
    alloc_rows,
    fill_budget,
    fill_row,
)
from .tdg import TaskGraph

__all__ = [
    "MENUS",
    "MoveTable",
    "ChainRequest",
    "ChainBlockResult",
    "DeviceChainRunner",
    "copy_carry",
    "reconcile_mapping",
]

MENUS = ("naive_sa", "telemetry")


def reconcile_mapping(
    design: Design,
    res: "ChainBlockResult",
    g: TaskGraph,
    db: HardwareDatabase,
    enc: EncodedWorkload,
    ed: Optional[EncodedDesign] = None,
    delta: Optional[MoveDelta] = None,
) -> Dict[str, Dict[str, str]]:
    """Apply the winning chain's final mapping onto ``design`` in place
    (slot indices → block names via the encoding's slot dicts). Returns the
    changed assignments — empty dicts mean the block improved nothing over
    the incumbent mapping."""
    if ed is None:
        ed = EncodedDesign.of(design, g, db, enc)
    inv_pe = {s: n for n, s in ed.pe_slot.items()}
    inv_mem = {s: n for n, s in ed.mem_slot.items()}
    w = res.winner
    ch_pe: Dict[str, str] = {}
    ch_mem: Dict[str, str] = {}
    for i, name in enumerate(enc.names):
        s = int(res.task_pe[w, i])
        if s != int(ed.task_pe[i]):
            ch_pe[name] = inv_pe[s]
        s = int(res.task_mem[w, i])
        if s != int(ed.task_mem[i]):
            ch_mem[name] = inv_mem[s]
    if ch_pe or ch_mem:
        apply_mapping(design, ch_pe, ch_mem, delta)
    return {"task_pe": ch_pe, "task_mem": ch_mem}


def copy_carry(carry: Optional[tuple]) -> Optional[tuple]:
    """Deep-copy a chain-block carry (tuple of host arrays) so policy
    checkpoints round-trip bit-exactly even if the live carry advances."""
    if carry is None:
        return None
    return tuple(np.array(x, copy=True) for x in carry)


@dataclasses.dataclass(frozen=True)
class MoveTable:
    """``propose_moves`` as packed arrays: row m is the candidate move
    "re-map task ``task[m]`` onto slot ``dest[m]``" (``kind[m]`` = 0 → PE
    slot, 1 → MEM slot). Shape-preserving by construction — no block is
    added, removed, or re-knobbed — so every row stays inside one encoding
    shape and the whole table is samplable inside a jitted loop. Rows whose
    destination equals the task's *current* slot are masked dynamically
    (the current slot lives in the loop carry, not the table)."""

    kind: np.ndarray  # (M,) int32: 0 = task→PE-slot, 1 = task→MEM-slot
    task: np.ndarray  # (M,) int32 task index (EncodedWorkload.names order)
    dest: np.ndarray  # (M,) int32 destination slot (class per ``kind``)

    @property
    def n_moves(self) -> int:
        return int(self.kind.shape[0])

    @staticmethod
    def of(ed: EncodedDesign, enc: EncodedWorkload) -> "MoveTable":
        """Enumerate all T·(S_pe + S_mem) single-task migrates of ``ed``."""
        t = len(enc.names)
        s_pe = int(ed.pe_peak.shape[0])
        s_mem = int(ed.mem_bw.shape[0])
        kind = np.concatenate(
            [np.zeros(t * s_pe, np.int32), np.ones(t * s_mem, np.int32)]
        )
        task = np.concatenate(
            [
                np.repeat(np.arange(t, dtype=np.int32), s_pe),
                np.repeat(np.arange(t, dtype=np.int32), s_mem),
            ]
        )
        dest = np.concatenate(
            [
                np.tile(np.arange(s_pe, dtype=np.int32), t),
                np.tile(np.arange(s_mem, dtype=np.int32), t),
            ]
        )
        return MoveTable(kind=kind, task=task, dest=dest)

    def delta_of(
        self, m: int, enc: EncodedWorkload, ed: EncodedDesign
    ) -> MoveDelta:
        """Unpack row ``m`` into an ordinary :class:`MoveDelta` (absolute
        task→block-name mapping) — the bridge back to the host move system."""
        tname = enc.names[int(self.task[m])]
        d = int(self.dest[m])
        if int(self.kind[m]) == 0:
            inv = {s: n for n, s in ed.pe_slot.items()}
            return mapping_delta({tname: inv[d]}, {})
        inv = {s: n for n, s in ed.mem_slot.items()}
        return mapping_delta({}, {tname: inv[d]})


@dataclasses.dataclass
class ChainRequest:
    """One (R, K) exploration block the explorer asks its backend to price.

    Yielded by ``Explorer.run_chain_steps`` in place of a candidate list;
    the serve scheduler (or ``Explorer.run_chains``) answers it with the
    :class:`ChainBlockResult` of ``backend.run_chains``. ``carry`` resumes
    the chain population from a previous block (or a ``device_sa`` policy
    checkpoint); ``it0`` keeps the SA temperature schedule global across
    blocks."""

    design: Design
    budget: Budget
    r: int
    k: int
    seed: int = 0
    it0: int = 0
    menu: str = "naive_sa"
    alpha: float = 0.05
    temperature0: float = 0.05
    temp_decay: float = 0.997
    taboo_ttl: int = 5
    carry: Optional[tuple] = None


@dataclasses.dataclass
class ChainBlockResult:
    """Host-side view of one priced (R, K) block. ``carry`` is the full
    device state pulled back as numpy (the checkpointable object); the
    per-step traces cover every chain so parity/trajectory tests can replay
    any of them."""

    task_pe: np.ndarray  # (R, T) final task→PE-slot map per chain
    task_mem: np.ndarray  # (R, T) final task→MEM-slot map per chain
    fitness: np.ndarray  # (R,) final Eq.-7 fitness per chain
    move_idx: np.ndarray  # (R, K) sampled MoveTable row per step
    accepted: np.ndarray  # (R, K) bool accept/reject per step
    fit_trace: np.ndarray  # (R, K) incumbent fitness after each step
    carry: tuple  # numpy carry pytree (resume / checkpoint)
    winner: int  # argmin-fitness chain index
    wall_s: float  # dispatch wall-clock (including device sync)
    n_moves: int  # MoveTable rows (M)

    def seq(self, chain: int = 0) -> List[Tuple[int, int]]:
        """(move_idx, accepted) sequence of one chain — the parity object."""
        return [
            (int(m), int(a))
            for m, a in zip(self.move_idx[chain], self.accepted[chain])
        ]


class DeviceChainRunner:
    """Owns the jitted (R, K) chain blocks for one workload.

    The jit cache is keyed on everything that changes the traced program:
    (R, K, slot/chain counts, menu, SA constants). ``n_compiles`` counts
    distinct cache entries — the smoke guard asserts the whole bench run
    stays within a handful. There is no fallback path: a design the flat
    encoding cannot host (``UnsupportedDesignError``) fails loudly instead
    of silently degrading to a host loop, so ``n_fallback`` is 0 by
    construction and asserted in the bench."""

    def __init__(
        self,
        g: TaskGraph,
        db: HardwareDatabase,
        enc: Optional[EncodedWorkload] = None,
        *,
        use_kernel: bool = False,
        interpret: bool = False,
    ):
        self.g = g
        self.db = db
        self.enc = enc if enc is not None else EncodedWorkload.of(g)
        self.use_kernel = use_kernel
        self.interpret = interpret
        self._blocks: Dict[tuple, object] = {}
        self.n_compiles = 0
        self.n_fallback = 0
        self.n_dispatches = 0
        self.n_chain_steps = 0

    # -- host-side staging -------------------------------------------------
    def _row0(self, ed: EncodedDesign, budget: Budget, alpha: float):
        t = len(self.enc.names)
        rows = alloc_rows(
            1, t, int(ed.pe_peak.shape[0]), int(ed.mem_bw.shape[0]),
            len(self.enc.wl_names), int(ed.noc_bw.shape[0]),
        )
        fill_row(rows, 0, ed)
        fill_budget(
            rows, 0, self.enc,
            budget.latency_s, budget.power_w, budget.area_mm2, alpha,
        )
        return {k: v[0] for k, v in rows.items()}

    def _accel_table(self, design: Design, ed: EncodedDesign) -> np.ndarray:
        """(T, S_pe) effective acceleration of task t if mapped to PE slot p
        — ``pe_accel`` is a per-task column, so a device migrate re-gathers
        it from this table instead of asking the hardware DB mid-loop."""
        t = len(self.enc.names)
        tab = np.ones((t, int(ed.pe_peak.shape[0])), np.float32)
        tasks = self.g.tasks
        for name, s in ed.pe_slot.items():
            b = design.blocks[name]
            if b.subtype == "acc" and b.hardened_for in self.enc.index:
                k = self.enc.index[b.hardened_for]
                tab[k, s] = self.db.a_peak(
                    b.hardened_for, tasks[b.hardened_for].llp, b.unroll
                )
        return tab

    def fresh_carry(self, ed: EncodedDesign, r: int, seed: int) -> tuple:
        """Initial chain-population carry: every chain starts from the live
        design with fitness BIG (the first finite candidate is accepted,
        exactly like the host explorer pricing its seed), zero taboo, zero
        telemetry, and key ``fold_in(PRNGKey(seed), chain_index)`` — the
        per-chain stream is a function of (seed, chain) only, never of R."""
        t = len(self.enc.names)
        m = t * (int(ed.pe_peak.shape[0]) + int(ed.mem_bw.shape[0]))
        base = jax.random.PRNGKey(seed)
        keys = np.asarray(
            jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(r))
        )
        return (
            np.broadcast_to(ed.task_pe, (r, t)).copy(),
            np.broadcast_to(ed.task_mem, (r, t)).copy(),
            np.full((r,), BIG, np.float32),
            keys,
            np.zeros((r, m), np.int32),
            np.zeros((r, int(ed.pe_peak.shape[0])), np.float32),
            np.zeros((r, int(ed.mem_bw.shape[0])), np.float32),
        )

    # -- the fused block ---------------------------------------------------
    def _block(
        self, r: int, k: int, ed: EncodedDesign, menu: str,
        t0: float, decay: float, ttl: int,
    ):
        key = (
            r, k, int(ed.pe_peak.shape[0]), int(ed.mem_bw.shape[0]),
            int(ed.noc_bw.shape[0]), menu, float(t0), float(decay), int(ttl),
        )
        fn = self._blocks.get(key)
        if fn is None:
            fn = self._build_block(r, k, menu, float(t0), float(decay), int(ttl))
            self._blocks[key] = fn
            self.n_compiles += 1
        return fn

    def _build_block(
        self, r: int, k: int, menu: str, t0: float, decay: float, ttl: int
    ):
        enc = self.enc
        use_kernel, interpret = self.use_kernel, self.interpret
        t = len(enc.names)
        tidx = jnp.arange(t)
        ridx = jnp.arange(r)
        t0f, decayf = jnp.float32(t0), jnp.float32(decay)

        def block(carry, it0, row0, accel, kind, task, dest):
            # static (non-mapping) row fields broadcast once per block; the
            # carry supplies the three mapping columns every iteration
            rows_static = {
                n: jnp.broadcast_to(v, (r,) + jnp.shape(v))
                for n, v in row0.items()
                if n not in ("task_pe", "task_mem", "pe_accel")
            }

            def step(c, it):
                task_pe, task_mem, fit, key, taboo, pe_b, mem_b = c
                taboo = jnp.maximum(taboo - 1, 0)
                keys = jax.vmap(lambda kk: jax.random.split(kk, 3))(key)
                key, k_move, k_acc = keys[:, 0], keys[:, 1], keys[:, 2]
                # sample one MoveTable row per chain (mask no-ops + taboo)
                cur = jnp.where(
                    kind[None, :] == 0, task_pe[:, task], task_mem[:, task]
                )
                valid = (dest[None, :] != cur) & (taboo == 0)
                if menu == "telemetry":
                    w = jnp.where(
                        kind[None, :] == 0,
                        jnp.take_along_axis(pe_b, task_pe[:, task], axis=1),
                        jnp.take_along_axis(mem_b, task_mem[:, task], axis=1),
                    ) + jnp.float32(1e-6)
                    logw = jnp.log(w)
                else:
                    logw = jnp.zeros((r, kind.shape[0]), jnp.float32)
                logits = jnp.where(valid, logw, jnp.float32(-1e30))
                m = jax.vmap(jax.random.categorical)(k_move, logits)
                # apply the move to the carried mapping columns
                tsel = task[m]
                is_pe = kind[m] == 0
                new_pe = task_pe.at[ridx, tsel].set(
                    jnp.where(is_pe, dest[m], task_pe[ridx, tsel])
                )
                new_mem = task_mem.at[ridx, tsel].set(
                    jnp.where(~is_pe, dest[m], task_mem[ridx, tsel])
                )
                rows = dict(rows_static)
                rows["task_pe"] = new_pe
                rows["task_mem"] = new_mem
                rows["pe_accel"] = accel[tidx[None, :], new_pe]
                res = resimulate_chains(
                    enc, rows, use_kernel=use_kernel, interpret=interpret
                )
                f_new = res["fitness"].astype(jnp.float32)
                # SA accept, f32 mirror of PolicyBase.accept
                temp = t0f * decayf ** it.astype(jnp.float32)
                u = jax.vmap(
                    lambda kk: jax.random.uniform(kk, dtype=jnp.float32)
                )(k_acc)
                ok = jnp.isfinite(f_new) & (
                    (f_new < fit)
                    | (
                        (temp > 0)
                        & (
                            u
                            < jnp.exp(
                                -(f_new - fit)
                                / jnp.maximum(temp, jnp.float32(1e-9))
                            )
                        )
                    )
                )
                task_pe = jnp.where(ok[:, None], new_pe, task_pe)
                task_mem = jnp.where(ok[:, None], new_mem, task_mem)
                fit = jnp.where(ok, f_new, fit)
                taboo = jnp.where(
                    ok[:, None], taboo, taboo.at[ridx, m].set(jnp.int32(ttl))
                )
                pe_b = jnp.where(
                    ok[:, None], res["pe_bneck_s"].astype(jnp.float32), pe_b
                )
                mem_b = jnp.where(
                    ok[:, None], res["mem_bneck_s"].astype(jnp.float32), mem_b
                )
                c = (task_pe, task_mem, fit, key, taboo, pe_b, mem_b)
                return c, (m.astype(jnp.int32), ok, fit)

            its = it0 + jnp.arange(k, dtype=jnp.int32)
            carry, (mv, acc, ft) = jax.lax.scan(step, carry, its)
            return carry, (mv.T, acc.T, ft.T)

        return jax.jit(block)

    # -- entry points ------------------------------------------------------
    def run_chains(
        self,
        design: Design,
        budget: Budget,
        *,
        r: int,
        k: int,
        seed: int = 0,
        it0: int = 0,
        menu: str = "naive_sa",
        alpha: float = 0.05,
        temperature0: float = 0.05,
        temp_decay: float = 0.997,
        taboo_ttl: int = 5,
        carry: Optional[tuple] = None,
    ) -> ChainBlockResult:
        """Price one fused (R, K) exploration block in a single dispatch."""
        if menu not in MENUS:
            raise ValueError(f"unknown device move menu: {menu!r}")
        ed = EncodedDesign.of(design, self.g, self.db, self.enc)
        table = MoveTable.of(ed, self.enc)
        row0 = self._row0(ed, budget, alpha)
        accel = self._accel_table(design, ed)
        fn = self._block(r, k, ed, menu, temperature0, temp_decay, taboo_ttl)
        if carry is None:
            carry = self.fresh_carry(ed, r, seed)
        t_start = time.perf_counter()
        out_carry, (mv, acc, ft) = fn(
            carry, jnp.int32(it0), row0, accel,
            table.kind, table.task, table.dest,
        )
        out_carry = tuple(np.asarray(x) for x in out_carry)
        mv, acc, ft = np.asarray(mv), np.asarray(acc), np.asarray(ft)
        wall = time.perf_counter() - t_start
        self.n_dispatches += 1
        self.n_chain_steps += r * k
        return ChainBlockResult(
            task_pe=out_carry[0],
            task_mem=out_carry[1],
            fitness=out_carry[2],
            move_idx=mv,
            accepted=acc,
            fit_trace=ft,
            carry=out_carry,
            winner=int(np.argmin(out_carry[2])),
            wall_s=wall,
            n_moves=table.n_moves,
        )

    def run_chains_host(
        self,
        design: Design,
        budget: Budget,
        *,
        r: int = 1,
        n_steps: int,
        seed: int = 0,
        it0: int = 0,
        menu: str = "naive_sa",
        alpha: float = 0.05,
        temperature0: float = 0.05,
        temp_decay: float = 0.997,
        taboo_ttl: int = 5,
        carry: Optional[tuple] = None,
    ) -> ChainBlockResult:
        """The host-driven reference accept loop: the SAME compiled chain
        step, dispatched K=1 at a time with the carry pulled back to host
        between iterations — one dispatch + one round trip per SA step,
        the regime of the classic host explorer. Because it shares the
        block body (same threefry draws, same f32 accept math), a fused
        K-step block must replay it bit-for-bit; this is the parity oracle
        and the speedup baseline."""
        t_start = time.perf_counter()
        mvs, accs, fts = [], [], []
        res = None
        for i in range(n_steps):
            res = self.run_chains(
                design, budget, r=r, k=1, seed=seed, it0=it0 + i, menu=menu,
                alpha=alpha, temperature0=temperature0, temp_decay=temp_decay,
                taboo_ttl=taboo_ttl, carry=carry,
            )
            carry = res.carry  # numpy — the per-iteration host round trip
            mvs.append(res.move_idx)
            accs.append(res.accepted)
            fts.append(res.fit_trace)
        wall = time.perf_counter() - t_start
        return ChainBlockResult(
            task_pe=res.task_pe,
            task_mem=res.task_mem,
            fitness=res.fitness,
            move_idx=np.concatenate(mvs, axis=1),
            accepted=np.concatenate(accs, axis=1),
            fit_trace=np.concatenate(fts, axis=1),
            carry=res.carry,
            winner=res.winner,
            wall_s=wall,
            n_moves=res.n_moves,
        )

    def reconcile(
        self,
        design: Design,
        res: ChainBlockResult,
        ed: Optional[EncodedDesign] = None,
        delta: Optional[MoveDelta] = None,
    ) -> Dict[str, Dict[str, str]]:
        """:func:`reconcile_mapping` against this runner's workload."""
        return reconcile_mapping(
            design, res, self.g, self.db, self.enc, ed=ed, delta=delta
        )
