"""`DseService`: the serve-layer front door.

One service hosts many concurrent, multi-tenant exploration sessions over
shared per-workload backends and one content-addressed
:class:`~repro.serve.store.DesignStore`. Sessions are submitted at any time
(`submit` between ticks is the mid-flight join), priced together by the
:class:`~repro.serve.scheduler.ContinuousBatchScheduler`, stream
best-design-so-far events while running, and deliver a final decoded
winner in their ``ExplorationResult``.

Typical use::

    svc = DseService(db, backend="jax")
    h1 = svc.submit("alice.audio", g_audio, budget, ExplorerConfig(seed=1))
    h2 = svc.submit("bob.audio", g_audio, budget, ExplorerConfig(seed=2))
    svc.run()                      # tick until every session completes
    print(h1.result.best_distance.city_block(), svc.stats().cache_hit_rate)

`DseService.step()` exposes single-tick control for callers interleaving
their own admission logic (arrival traces, latency injection, backpressure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from ..core.backend import BackendStats
from ..core.budgets import Budget
from ..core.design import Design
from ..core.explorer import Explorer, ExplorerConfig
from ..core.database import HardwareDatabase
from ..core.tdg import TaskGraph
from .scheduler import BackendSpec, ContinuousBatchScheduler
from .session import BestEvent, Session, SessionRequest
from .store import DesignStore


@dataclasses.dataclass
class ServiceStats:
    """Fleet-level serve accounting, snapshotted by :meth:`DseService.stats`."""

    n_sessions: int
    n_done: int
    n_ticks: int
    wall_s: float  # total time inside tick-driving calls (run/step)
    n_evals: int  # candidate evaluations submitted across all backends
    n_fallback: int  # scalar-path evaluations (0 in the array-native regime)
    cache_hits: int
    cache_misses: int
    cache_bypasses: int
    cache_evictions: int
    session_latency_s: List[float]  # completed sessions, admission → done

    @property
    def cache_hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0

    def latency_percentile(self, p: float) -> float:
        """p in [0, 100] over completed-session latencies (0.0 when none)."""
        lats = sorted(self.session_latency_s)
        if not lats:
            return 0.0
        k = min(len(lats) - 1, max(0, round(p / 100.0 * (len(lats) - 1))))
        return lats[k]

    @property
    def evals_per_s(self) -> float:
        return self.n_evals / self.wall_s if self.wall_s > 0 else 0.0


class SessionHandle:
    """User-facing view of one submitted session: poll ``done``, read the
    streamed ``events``, and collect the final ``result`` after completion."""

    def __init__(self, session: Session) -> None:
        self._session = session

    @property
    def name(self) -> str:
        return self._session.name

    @property
    def done(self) -> bool:
        return self._session.done

    @property
    def events(self) -> List[BestEvent]:
        return self._session.events

    @property
    def latency_s(self) -> float:
        return self._session.latency_s

    @property
    def result(self):
        if self._session.result is None:
            raise RuntimeError(
                f"session {self.name!r} has not completed (state="
                f"{self._session.state}); drive DseService.run()/step() first"
            )
        return self._session.result


class DseService:
    """Multi-session DSE serving over one continuous-batching scheduler.

    The evaluation cache defaults ON (a fresh :class:`DesignStore` per
    service); pass ``store=`` to share one across services or
    ``cache=False`` for the uncached baseline. ``backend`` accepts the
    ``make_backend`` registry names or a factory, exactly like ``Campaign``.
    """

    def __init__(
        self,
        db: HardwareDatabase,
        backend: BackendSpec = "jax",
        store: Optional[DesignStore] = None,
        cache: bool = True,
    ) -> None:
        self.db = db
        self.store = store if store is not None else (DesignStore() if cache else None)
        self.scheduler = ContinuousBatchScheduler(db, backend, store=self.store)
        self._sessions: Dict[str, Session] = {}  # admission order preserved
        self._wall_s = 0.0

    # ---- admission -------------------------------------------------------
    def submit(
        self,
        name: str,
        tdg: TaskGraph,
        budget: Budget,
        config: Optional[ExplorerConfig] = None,
        initial: Optional[Design] = None,
        on_event=None,  # Optional[Callable[[BestEvent], None]]
    ) -> SessionHandle:
        """Admit one exploration session; it joins the next scheduler tick
        (mid-flight joins are the normal case, not an exception).
        ``on_event`` streams the session's BestEvents as they commit."""
        return self.submit_request(
            SessionRequest(name, tdg, budget, config or ExplorerConfig(), initial),
            on_event=on_event,
        )

    def submit_request(self, request: SessionRequest, on_event=None) -> SessionHandle:
        if request.name in self._sessions:
            raise ValueError(f"duplicate session name {request.name!r}")
        explorer = Explorer(
            request.tdg, self.db, request.budget, request.config,
            backend=self.scheduler.backend_for(request.tdg),
        )
        session = Session(request, explorer)
        session.on_event = on_event
        self._sessions[request.name] = session
        self.scheduler.admit(session)
        return SessionHandle(session)

    # ---- drive -----------------------------------------------------------
    def step(self) -> List[SessionHandle]:
        """One scheduler tick; returns handles of sessions that completed."""
        t0 = time.perf_counter()
        done = self.scheduler.tick()
        self._wall_s += time.perf_counter() - t0
        return [SessionHandle(s) for s in done]

    def run(self, max_ticks: Optional[int] = None) -> ServiceStats:
        """Tick until every admitted session completes (or ``max_ticks``),
        drain the backends, and return the service stats snapshot."""
        t0 = time.perf_counter()
        self.scheduler.run_until_idle(max_ticks)
        self.scheduler.flush()
        self._wall_s += time.perf_counter() - t0
        return self.stats()

    # ---- observability ---------------------------------------------------
    @property
    def n_live(self) -> int:
        return self.scheduler.n_live

    def backend_stats(self) -> Dict[str, BackendStats]:
        """Per shared backend, labeled by workload (graph) name — distinct
        graph objects sharing a name get ``#n`` suffixes."""
        labels: Dict[int, str] = {}
        counts: Dict[str, int] = {}
        for s in self._sessions.values():
            key = id(s.request.tdg)
            if key in labels:
                continue
            n = counts.get(s.request.tdg.name, 0)
            labels[key] = s.request.tdg.name if n == 0 else f"{s.request.tdg.name}#{n}"
            counts[s.request.tdg.name] = n + 1
        return {
            labels.get(k, str(k)): b.stats()
            for k, b in self.scheduler.backends().items()
        }

    def stats(self) -> ServiceStats:
        bstats = list(self.scheduler.backend_stats().values())
        sstats = self.store.stats if self.store is not None else None
        return ServiceStats(
            n_sessions=len(self._sessions),
            n_done=sum(1 for s in self._sessions.values() if s.done),
            n_ticks=self.scheduler.n_ticks,
            wall_s=self._wall_s,
            n_evals=sum(b.n_sims for b in bstats),
            n_fallback=sum(b.n_fallback for b in bstats),
            cache_hits=sstats.hits if sstats else 0,
            cache_misses=sstats.misses if sstats else 0,
            cache_bypasses=sstats.bypasses if sstats else 0,
            cache_evictions=sstats.evictions if sstats else 0,
            session_latency_s=[
                s.latency_s for s in self._sessions.values() if s.done
            ],
        )

    def results(self) -> Dict[str, object]:
        """Completed sessions' ExplorationResults, in admission order."""
        return {
            name: s.result for name, s in self._sessions.items() if s.done
        }
