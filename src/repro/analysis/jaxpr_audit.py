"""Jaxpr auditor: trace the hot jitted entry points with abstract inputs
and assert what the lint pass can only infer lexically.

Where ``lint.py`` reads source, this pass reads the *traced program*: it
builds each entry point's jaxpr (no device execution — ``jax.make_jaxpr``
with the same abstract shapes production dispatches) and walks every
equation, recursing through sub-jaxprs (``pjit``, ``scan`` bodies,
``cond`` branches), to check:

  * **no forbidden primitives** — callbacks (``pure_callback`` /
    ``io_callback`` / debug callbacks) and host transfers
    (``infeed``/``outfeed``/``outside_call``) would turn the fused block
    into a per-step host round-trip while still "working";
  * the Pallas wrapper really lowers through ``pallas_call`` (a silent
    fallback to the vmap reference would pass every numeric test at 10×
    the dispatch cost);
  * the **jit-cache key bound**: the backend buckets batch/slot shapes to
    pow2 (floor 4) exactly so the compile-cache key set stays small. The
    audit enumerates the documented production grid (batch and slots up
    to 64, NoC counts up to 8) through the real ``_bucket`` and fails if
    the distinct-key count exceeds :data:`BUCKET_GRID_BOUND` — someone
    widening the bucket function pays for every extra compile here, not
    in a prod flamegraph.

Entry points audited: ``phase_sim_jax.simulate_batch`` (the vmap'd
scoring core), the fused chain block (``DeviceChainRunner._build_block``
on the alloc menu — scan over K steps), and the Pallas wrapper
``ops.phase_sim`` (``interpret=True`` so the audit runs on CPU-only
hosts; the jaxpr is the same either way).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set

from .findings import Finding

__all__ = [
    "FORBIDDEN_SUBSTRINGS",
    "BUCKET_GRID_BOUND",
    "collect_primitives",
    "audit_jaxpr",
    "run_jaxpr_audit",
]

# primitive-name substrings that mean "this traced program talks to the
# host per call"
FORBIDDEN_SUBSTRINGS = (
    "callback", "infeed", "outfeed", "outside_call", "host_local",
)

# distinct (batch-bucket, slot-bucket, noc) jit keys allowed for the
# standard production grid: batch 1..64, slots 1..64, noc ∈ {1, 2, 4, 8}.
# _bucket's pow2-floor-4 gives 5 batch × 5 slot × 4 noc = 100 exactly;
# the bound leaves zero headroom on purpose — widening the bucket set is
# a deliberate decision that must touch docs/ANALYSIS.md too.
BUCKET_GRID_BOUND = 100


def _sub_jaxprs(params: Dict) -> List:
    """Sub-jaxprs hiding in an equation's params (pjit/scan `jaxpr`,
    cond `branches` tuples, closed-call bodies)."""
    out = []
    for v in params.values():
        for cand in (v if isinstance(v, (tuple, list)) else (v,)):
            if hasattr(cand, "jaxpr") or hasattr(cand, "eqns"):
                out.append(cand)
    return out


def collect_primitives(jaxpr) -> Set[str]:
    """Every primitive name reachable from a (Closed)Jaxpr, recursively."""
    core = jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr
    prims: Set[str] = set()
    for eqn in core.eqns:
        prims.add(eqn.primitive.name)
        for sub in _sub_jaxprs(eqn.params):
            prims.update(collect_primitives(sub))
    return prims


def audit_jaxpr(
    name: str,
    jaxpr,
    path: str,
    *,
    require: Sequence[str] = (),
    forbidden: Sequence[str] = FORBIDDEN_SUBSTRINGS,
) -> List[Finding]:
    """Findings for one traced entry point: forbidden primitives present,
    or required ones (``pallas_call``) missing."""
    prims = collect_primitives(jaxpr)
    out: List[Finding] = []
    for p in sorted(prims):
        for bad in forbidden:
            if bad in p:
                out.append(Finding(
                    pass_name="jaxpr", rule="forbidden-primitive",
                    message=f"`{name}` lowers through `{p}` — a per-call "
                    "host round-trip inside the hot path",
                    path=path,
                ))
                break
    for want in require:
        if want not in prims:
            out.append(Finding(
                pass_name="jaxpr", rule="missing-primitive",
                message=f"`{name}` no longer lowers through `{want}` "
                "(primitives seen: "
                f"{', '.join(sorted(prims)[:12])}…) — the kernel path "
                "silently fell back",
                path=path,
            ))
    return out


def _abstract_rows(enc, ed, budget, alpha: float, b: int):
    """A (b,)-batched abstract rows dict shaped exactly like production
    dispatch (reuses the runner's host staging, then broadcasts)."""
    import jax
    import jax.numpy as jnp

    from repro.core.phase_sim_jax import (
        alloc_rows, fill_budget, fill_row,
    )

    t = len(enc.names)
    rows = alloc_rows(
        b, t, int(ed.pe_peak.shape[0]), int(ed.mem_bw.shape[0]),
        len(enc.wl_names), int(ed.noc_bw.shape[0]),
    )
    for j in range(b):
        fill_row(rows, j, ed)
        fill_budget(
            rows, j, enc, budget.latency_s, budget.power_w,
            budget.area_mm2, alpha,
        )
    return {k: jnp.asarray(v) for k, v in rows.items()}


def _fixture():
    from repro.core import (
        DeviceChainRunner, HardwareDatabase, audio, calibrated_budget,
        random_single_noc_designs,
    )
    from repro.core.phase_sim_jax import EncodedDesign

    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    d = random_single_noc_designs(g, 1, seed=7)[0]
    runner = DeviceChainRunner(g, db)
    ed = EncodedDesign.of(d, g, db, runner.enc)
    return runner, d, ed, bud


def _audit_simulate_batch(runner, ed, bud) -> List[Finding]:
    import jax

    from repro.core.phase_sim_jax import simulate_batch

    rows = _abstract_rows(runner.enc, ed, bud, 0.05, b=4)
    jx = jax.make_jaxpr(lambda r: simulate_batch(runner.enc, r))(rows)
    return audit_jaxpr(
        "simulate_batch", jx, "src/repro/core/phase_sim_jax.py"
    )


def _audit_chain_block(runner, d, ed, bud) -> List[Finding]:
    import jax
    import jax.numpy as jnp

    from repro.core.device_explore import MoveTable

    cap_pe = int(ed.pe_peak.shape[0]) + 3
    cap_mem = int(ed.mem_bw.shape[0]) + 2
    table = MoveTable.of(
        ed, runner.enc, alloc=True, cap_pe=cap_pe, cap_mem=cap_mem
    )
    carry = runner.fresh_carry(
        d, ed, r=2, seed=0, cap_pe=cap_pe, cap_mem=cap_mem, alloc=True
    )
    row0 = runner._row0(ed, bud, 0.05)
    fn = runner._build_block(2, 3, "farsi", 0.05, 0.997, 5, cap_pe, cap_mem)
    jx = jax.make_jaxpr(fn)(
        carry, jnp.int32(0), row0, table.kind, table.task, table.dest
    )
    return audit_jaxpr(
        "DeviceChainRunner._build_block(menu='farsi', alloc)", jx,
        "src/repro/core/device_explore.py",
    )


def _audit_pallas_wrapper(runner, ed, bud) -> List[Finding]:
    import jax

    from repro.kernels.phase_sim.ops import phase_sim

    rows = _abstract_rows(runner.enc, ed, bud, 0.05, b=4)
    jx = jax.make_jaxpr(lambda r: phase_sim(runner.enc, r, interpret=True))(
        rows
    )
    return audit_jaxpr(
        "ops.phase_sim", jx, "src/repro/kernels/phase_sim/ops.py",
        require=("pallas_call",),
    )


def _audit_bucket_grid() -> List[Finding]:
    from repro.core.backend import _bucket

    keys = {
        (_bucket(b), _bucket(s), n)
        for b in range(1, 65)
        for s in range(1, 65)
        for n in (1, 2, 4, 8)
    }
    if len(keys) > BUCKET_GRID_BOUND:
        return [Finding(
            pass_name="jaxpr", rule="jit-cache-bound",
            message=f"the standard bucket grid yields {len(keys)} distinct "
            f"jit keys (> documented bound {BUCKET_GRID_BOUND}) — every "
            "extra key is a full XLA compile at serve time; see "
            "docs/ANALYSIS.md before widening `_bucket`",
            path="src/repro/core/backend.py",
            related=("docs/ANALYSIS.md",),
        )]
    return []


def run_jaxpr_audit(entries: Optional[Sequence[str]] = None) -> List[Finding]:
    """Trace and audit all entry points (or a named subset of
    ``{"simulate_batch", "chain_block", "pallas", "buckets"}``)."""
    want = set(entries) if entries is not None else None
    out: List[Finding] = []

    def on(name: str) -> bool:
        return want is None or name in want

    if on("buckets"):
        out.extend(_audit_bucket_grid())
    if on("simulate_batch") or on("chain_block") or on("pallas") \
            or want is None:
        runner, d, ed, bud = _fixture()
        if on("simulate_batch"):
            out.extend(_audit_simulate_batch(runner, ed, bud))
        if on("chain_block"):
            out.extend(_audit_chain_block(runner, d, ed, bud))
        if on("pallas"):
            out.extend(_audit_pallas_wrapper(runner, ed, bud))
    return out
