"""``python -m repro.analysis`` — run the static-analysis passes.

Exit codes: 0 = clean (or report-only mode), 1 = live findings under
``--strict``, 2 = a pass crashed. ``--update-baseline`` rewrites the
frozen lint-debt file from the current tree (contract and jaxpr findings
are never baselined — those either hold or the build is wrong).
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List

from .findings import Finding, format_findings


def main(argv: List[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checker + JAX lint + jaxpr audit",
    )
    ap.add_argument(
        "--passes", nargs="+", default=["contracts", "lint", "jaxpr"],
        choices=["contracts", "lint", "jaxpr"],
        help="which passes to run (default: all)",
    )
    ap.add_argument(
        "--strict", action="store_true",
        help="exit 1 if any live (unsuppressed, unbaselined) finding",
    )
    ap.add_argument(
        "--update-baseline", action="store_true",
        help="rewrite src/repro/analysis/baseline.json from current lint "
        "findings (implies --passes lint)",
    )
    ap.add_argument(
        "--json", action="store_true", dest="as_json",
        help="emit findings as a JSON array instead of text",
    )
    ap.add_argument(
        "--all", action="store_true",
        help="show suppressed/baselined findings too (default: live only)",
    )
    args = ap.parse_args(argv)

    if args.update_baseline:
        from .lint import run_lint, write_baseline

        findings = run_lint()
        path = write_baseline(findings)
        n = sum(1 for f in findings if not f.suppressed)
        print(f"baseline: froze {n} finding(s) -> {path}")
        return 0

    from . import run_all

    try:
        findings = run_all(passes=tuple(args.passes))
    except Exception as e:  # a crashed pass must not look like "clean"
        print(f"analysis pass crashed: {type(e).__name__}: {e}",
              file=sys.stderr)
        return 2

    shown = findings if args.all else [f for f in findings if f.live]
    if args.as_json:
        print(json.dumps([f.__dict__ for f in shown], indent=1,
                         default=list))
    elif shown:
        print(format_findings(shown))

    live = [f for f in findings if f.live]
    n_sup = sum(1 for f in findings if f.suppressed)
    n_base = sum(1 for f in findings if f.baselined)
    print(
        f"repro.analysis: {len(live)} live finding(s) "
        f"({n_sup} suppressed, {n_base} baselined) "
        f"across passes: {', '.join(args.passes)}",
        file=sys.stderr,
    )
    return 1 if (args.strict and live) else 0


if __name__ == "__main__":
    sys.exit(main())
