"""One multi-tenant exploration session around the Explorer coroutine.

A :class:`Session` owns one Explorer coroutine —
:meth:`~repro.core.explorer.Explorer.run_steps` (host accept loop), or
:meth:`~repro.core.explorer.Explorer.run_chain_steps` when the request's
config opts into chain-batched ticks (``chain_r > 0``) — and the
bookkeeping the scheduler needs to co-batch it with strangers: the pending
batch, lifecycle state, streamed best-design events, and per-session
latency/throughput accounting. The session never talks to a backend — the
scheduler prices its pending batch (packed with every other live
session's, or dispatched as one fused device block for a chain session)
and hands the result back through :meth:`resume`.

Streaming contract: every committed best-so-far improvement fires a
:class:`BestEvent` (wired to ``Explorer.on_improve`` — scalar columns only,
no decode); the final decoded winner arrives once, in the
``ExplorationResult`` captured at ``StopIteration``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from ..core.backend import Candidate, SimHandle
from ..core.budgets import Budget
from ..core.design import Design
from ..core.explorer import ExplorationResult, Explorer, ExplorerConfig
from ..core.tdg import TaskGraph

PENDING = "pending"
RUNNING = "running"
DONE = "done"
FAILED = "failed"


@dataclasses.dataclass
class SessionRequest:
    """One exploration request, shaped like ``campaign.RunSpec`` — the serve
    layer's admission unit.

    ``deadline_s`` is a per-session admission→completion wall-clock SLO,
    enforced at the top of every scheduler tick (a session past it fails
    with ``DeadlineExceeded``). ``max_restarts`` bounds crash recovery: a
    coroutine that dies with restarts left is rebuilt from the explorer's
    last committed accept (rng + policy checkpoint) instead of failing."""

    name: str
    tdg: TaskGraph
    budget: Budget
    config: ExplorerConfig = dataclasses.field(default_factory=ExplorerConfig)
    initial: Optional[Design] = None
    deadline_s: Optional[float] = None
    max_restarts: int = 0


@dataclasses.dataclass(frozen=True)
class BestEvent:
    """One streamed best-design-so-far improvement (scalars only — the full
    decode is paid once, for the final winner)."""

    session: str
    iteration: int
    distance: float
    fitness: float
    move: str
    converged: bool
    latency_s: float
    power_w: float
    area_mm2: float
    wall_s: float  # seconds since the session was admitted


class Session:
    """Lifecycle: ``PENDING`` (declared) → ``RUNNING`` (``start`` primed the
    coroutine; ``pending`` holds the batch awaiting pricing) → ``DONE``
    (``result`` captured). Joining mid-flight is just calling ``start``
    between two scheduler ticks — co-batching never perturbs a session's
    own search (per-row results are independent of batch composition, which
    is what makes a late joiner converge exactly as if it ran alone)."""

    def __init__(self, request: SessionRequest, explorer: Explorer) -> None:
        self.request = request
        self.explorer = explorer
        self.state = PENDING
        self.pending: List[Candidate] = []
        self.result: Optional[ExplorationResult] = None
        self.error: Optional[BaseException] = None  # set iff FAILED
        self.events: List[BestEvent] = []
        self.on_event: Optional[Callable[[BestEvent], None]] = None
        self.sim_wall_s = 0.0  # attributed share of shared-dispatch wall
        self.n_ticks = 0
        self.admitted_at: Optional[float] = None
        self.done_at: Optional[float] = None
        self.degraded = False  # pinned to the PythonBackend fallback
        self.n_consec_dispatch_failures = 0  # drives the degradation ladder
        self.n_restarts = 0
        self._nonfinite_base = 0  # rejections from pre-restart explorers
        explorer.on_improve = self._improved
        if request.max_restarts > 0:
            explorer.track_restart = True

    @property
    def name(self) -> str:
        return self.request.name

    @property
    def done(self) -> bool:
        return self.state == DONE

    @property
    def failed(self) -> bool:
        return self.state == FAILED

    @property
    def restarts_left(self) -> int:
        return max(0, self.request.max_restarts - self.n_restarts)

    @property
    def n_nonfinite_rejected(self) -> int:
        """Non-finite candidate rows this session's search rejected (summed
        across crash-restarted explorer instances)."""
        return self._nonfinite_base + getattr(self.explorer, "n_nonfinite", 0)

    def past_deadline(self) -> bool:
        d = self.request.deadline_s
        return (
            d is not None
            and self.admitted_at is not None
            and time.perf_counter() - self.admitted_at > d
        )

    @property
    def latency_s(self) -> float:
        """Admission → completion wall clock (the serve latency metric);
        admission → now while still running."""
        if self.admitted_at is None:
            return 0.0
        end = self.done_at if self.done_at is not None else time.perf_counter()
        return end - self.admitted_at

    def _improved(self, ev: dict) -> None:
        # chain-block events carry fitness only (the winner's PPA scalars
        # stay on device until the final decode) — missing columns default
        event = BestEvent(
            session=self.request.name,
            iteration=ev["iteration"],
            distance=ev.get("distance", float("nan")),
            fitness=ev["fitness"],
            move=ev["move"],
            converged=ev.get("converged", False),
            latency_s=ev.get("latency_s", float("nan")),
            power_w=ev.get("power_w", float("nan")),
            area_mm2=ev.get("area_mm2", float("nan")),
            wall_s=time.perf_counter() - (self.admitted_at or time.perf_counter()),
        )
        self.events.append(event)
        if self.on_event is not None:
            self.on_event(event)

    def _make_gen(self, explorer: Explorer, initial: Optional[Design]):
        """The session's coroutine: the chain-batched generator when the
        request opted into device chain blocks (``chain_r > 0``), the host
        accept loop otherwise."""
        if self.request.config.chain_r > 0:
            return explorer.run_chain_steps(initial)
        return explorer.run_steps(initial)

    # ---- scheduler interface --------------------------------------------
    def start(self) -> None:
        """Prime the coroutine: after this the session is RUNNING and
        ``pending`` holds its first candidate batch (the initial design)."""
        assert self.state == PENDING, f"session {self.name!r} already started"
        self.admitted_at = time.perf_counter()
        self._gen = self._make_gen(self.explorer, self.request.initial)
        try:
            self.pending = next(self._gen)
            self.state = RUNNING
        except StopIteration as stop:  # pragma: no cover — degenerate search
            self._finish(stop.value)

    def resume(self, handles: Sequence[SimHandle]) -> bool:
        """Feed the priced handles for the current ``pending`` batch; returns
        True when the session just completed."""
        assert self.state == RUNNING, self.state
        self.n_ticks += 1
        try:
            self.pending = self._gen.send(list(handles))
            return False
        except StopIteration as stop:
            self._finish(stop.value)
            return True

    def _finish(self, result: ExplorationResult) -> None:
        result.sim_wall_s = self.sim_wall_s
        self.result = result
        self.pending = []
        self.state = DONE
        self.done_at = time.perf_counter()

    # ---- fault handling --------------------------------------------------
    def fail(self, exc: BaseException) -> None:
        """Quarantine the session: record the error, transition to FAILED,
        and close the coroutine so half-finished search state cannot leak.
        Idempotent for already-terminal sessions (the first error wins)."""
        if self.state in (DONE, FAILED):
            return
        self.error = exc
        self.pending = []
        self.state = FAILED
        self.done_at = time.perf_counter()
        gen = getattr(self, "_gen", None)
        if gen is not None:
            try:
                gen.close()
            except Exception:  # a broken coroutine must not take the tick down
                pass

    def crash(self, exc: BaseException) -> Optional[BaseException]:
        """Throw ``exc`` into the session coroutine (the injected-crash
        path). Returns the exception that escaped — usually ``exc`` itself —
        or None if the coroutine absorbed it / ran to completion."""
        assert self.state == RUNNING, self.state
        self.pending = []
        try:
            self.pending = self._gen.throw(exc)
            return None  # absorbed; pending is the next batch
        except StopIteration as stop:  # pragma: no cover — graceful wind-down
            self._finish(stop.value)
            return None
        except BaseException as escaped:
            return escaped

    def resurrect(self, explorer: Explorer, initial: Optional[Design]) -> None:
        """Crash-restart: swap in a fresh explorer (rng/policy already
        restored to the last committed accept by the scheduler) and re-prime
        the coroutine from ``initial`` — the last accepted design. Events,
        latency accounting, and tick counts carry over; only the in-flight
        (uncommitted) step is lost."""
        assert self.state == RUNNING, self.state
        self._nonfinite_base += getattr(self.explorer, "n_nonfinite", 0)
        self.explorer = explorer
        explorer.on_improve = self._improved
        explorer.track_restart = True
        self.n_restarts += 1
        self._gen = self._make_gen(explorer, initial)
        try:
            self.pending = next(self._gen)
        except StopIteration as stop:  # pragma: no cover — budget exhausted
            self._finish(stop.value)
