"""Paper Figs. 12–13: domain awareness. Per-workload comp/comm boundedness
encountered during exploration (12), and FARSI's response — where it spends
its moves (13): TaLP exploitation (fork/migrate) vs LLP exploitation
(customization swaps), comp vs comm focus."""
from __future__ import annotations

from typing import List

from repro.core import (
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    all_workloads,
    calibrated_budget,
)

from .common import Row


def run() -> List[Row]:
    db = HardwareDatabase()
    rows: List[Row] = []
    bud_all = calibrated_budget(db)
    for name, g in all_workloads().items():
        from repro.core.budgets import Budget

        bud = Budget(
            latency_s={name: bud_all.latency_s[name]},
            power_w=bud_all.power_w,
            area_mm2=bud_all.area_mm2,
        )
        res = Explorer(g, db, bud, ExplorerConfig(max_iterations=400, seed=2)).run()
        # Fig 12: boundedness seen by the simulator on the final design
        b = res.best_result.bottleneck_s
        tot = sum(b.values()) or 1.0
        comp = b["pe"] / tot
        comm = (b["mem"] + b["noc"]) / tot
        # Fig 13: move mix = parallelism (fork/migrate) vs customization (swap)
        hist = res.ledger.move_histogram()
        talp_moves = hist.get("fork", 0) + hist.get("migrate", 0)
        llp_moves = hist.get("swap", 0) + hist.get("fork_swap", 0)
        comm_focus = sum(1 for r in res.ledger.records if r.comm_comp == "comm")
        rows.append(
            (
                f"fig12_13.{name}",
                0.0,
                f"comp_bound={comp:.2f} comm_bound={comm:.2f} "
                f"talp_moves={talp_moves} llp_moves={llp_moves} "
                f"comm_focus_iters={comm_focus} converged={res.converged}",
            )
        )
    return rows
