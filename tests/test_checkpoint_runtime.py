"""Checkpoint manager (atomicity, keep-N, async), data pipeline determinism,
fault-tolerant supervisor recovery, straggler detection, heartbeats."""
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import reduced_config
from repro.data.pipeline import DataConfig, SyntheticLM, for_model
from repro.models.model import RunFlags
from repro.optim.adamw import AdamWConfig
from repro.runtime.health import Heartbeat, StepTimeMonitor, Supervisor
from repro.train.step import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_seekable():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=4, seed=7)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for _ in range(3):
        np.testing.assert_array_equal(a.next_batch()["tokens"], b.next_batch()["tokens"])
    b.skip_to(0)
    a2 = SyntheticLM(cfg)
    np.testing.assert_array_equal(b.next_batch()["tokens"], a2.next_batch()["tokens"])


def test_data_host_sharding_partitions():
    full = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1))
    whole = full.next_batch()["tokens"]
    parts = []
    for h in range(4):
        s = SyntheticLM(
            DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1, n_hosts=4, host_index=h)
        )
        parts.append(s.next_batch()["tokens"])
    np.testing.assert_array_equal(np.concatenate(parts, 0), whole)


def test_labels_are_next_tokens():
    d = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=3))
    b = d.next_batch()
    assert b["tokens"].shape == b["labels"].shape == (2, 8)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------
def _tiny_state(key):
    return {
        "params": {"w": jax.random.normal(key, (4, 4)), "b": jnp.zeros((4,))},
        "step": jnp.int32(7),
    }


def test_checkpoint_roundtrip(tmp_path, rng_key):
    m = CheckpointManager(str(tmp_path), async_save=False)
    state = _tiny_state(rng_key)
    m.save(7, state, extra={"data_step": 9})
    restored, meta = m.restore(state)
    assert meta["step"] == 7 and meta["extra"]["data_step"] == 9
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(a, b)


def test_checkpoint_atomic_ignores_partial(tmp_path, rng_key):
    m = CheckpointManager(str(tmp_path), async_save=False)
    state = _tiny_state(rng_key)
    m.save(1, state)
    # simulate a crash mid-save: stray tmp dir + a committed dir missing meta
    os.makedirs(tmp_path / ".tmp-step_00000002")
    os.makedirs(tmp_path / "step_00000003")
    assert m.latest_step() == 1


def test_checkpoint_keep_n(tmp_path, rng_key):
    m = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    state = _tiny_state(rng_key)
    for s in (1, 2, 3, 4):
        m.save(s, state)
    assert m.all_steps() == [3, 4]


def test_checkpoint_async(tmp_path, rng_key):
    m = CheckpointManager(str(tmp_path), async_save=True)
    state = _tiny_state(rng_key)
    m.save(5, state)
    m.wait()
    assert m.latest_step() == 5


# ---------------------------------------------------------------------------
# health / supervisor
# ---------------------------------------------------------------------------
def test_step_monitor_flags_stragglers():
    mon = StepTimeMonitor(threshold=2.0, warmup=2)
    for i in range(6):
        mon.record(i, 0.1)
    s = mon.record(6, 0.5)
    assert s.is_straggler
    assert len(mon.flagged) == 1
    # outlier must not poison the EMA
    assert abs(mon.ema - 0.1) < 1e-6


def test_heartbeat_dead_detection(tmp_path):
    hb0 = Heartbeat(str(tmp_path), 0)
    hb1 = Heartbeat(str(tmp_path), 1)
    now = time.time()
    hb0.beat(1)
    hb1.beat(1)
    assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=10) == []
    # host 1 goes silent: check at a future "now"
    assert Heartbeat.dead_hosts(str(tmp_path), timeout_s=10, now=now + 100) == [0, 1]


def test_supervisor_recovers_and_matches_uninterrupted_run(tmp_path, rng_key):
    """Kill the step function mid-run; the supervisor restores the last
    checkpoint and the final state matches a run with no failure
    (determinism of the recovery path end-to-end)."""
    cfg = reduced_config("qwen3-1.7b")
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    raw_step = jax.jit(make_train_step(cfg, RunFlags(attn_impl="full"), opt))

    def fresh(dirname):
        data = for_model(cfg, seq_len=16, global_batch=4, seed=0)
        ckpt = CheckpointManager(str(tmp_path / dirname), keep_n=3, async_save=False)
        state = init_train_state(cfg, rng_key)
        return data, ckpt, state

    # uninterrupted reference
    data, ckpt, state = fresh("ref")
    sup = Supervisor(ckpt, data, save_every=4)
    ref = sup.run(state, raw_step, 12, restore_fn=lambda: ckpt.restore(state))

    # faulty run: blow up at global call 7
    data, ckpt, state = fresh("faulty")
    calls = {"n": 0}

    def flaky(s, b):
        calls["n"] += 1
        if calls["n"] == 7:
            raise RuntimeError("injected device failure")
        return raw_step(s, b)

    sup2 = Supervisor(ckpt, data, save_every=4)
    out = sup2.run(state, flaky, 12, restore_fn=lambda: ckpt.restore(state))
    assert sup2.recoveries == 1
    for a, b in zip(jax.tree.leaves(ref["params"]), jax.tree.leaves(out["params"])):
        np.testing.assert_allclose(a, b, atol=1e-6)
