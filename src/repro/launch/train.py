"""Production training launcher: mesh → sharded state → jit'd step with the
logical sharding rules → data pipeline → checkpoints + supervisor.

On a TPU pod this is the entry point per host (jax.distributed handles the
rest); on this CPU container it runs the same code path on the host mesh.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --seq-len 64 --global-batch 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ShapeConfig
from ..configs.registry import arch_names, get_config, reduced_config
from ..data.pipeline import for_model
from ..models.model import RunFlags
from ..optim.adamw import AdamWConfig
from ..runtime.elastic import state_shardings
from ..runtime.health import Supervisor
from ..sharding.act import activation_rules
from ..sharding.rules import default_rules
from ..train.step import init_train_state, make_train_step
from .mesh import make_host_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=arch_names(), default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true", help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mesh = make_host_mesh(model=args.model_axis)
    shape = ShapeConfig("train", args.seq_len, args.global_batch, "train")
    rules = default_rules(cfg, shape, mesh)
    flags = RunFlags(attn_impl="auto", remat="none" if args.reduced else "full")

    state_struct = jax.eval_shape(lambda k: init_train_state(cfg, k), jax.random.PRNGKey(0))
    shardings = state_shardings(cfg, shape, mesh, state_struct, rules)
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=10, total_steps=args.steps)

    with mesh, activation_rules(rules, mesh):
        init = jax.jit(
            lambda k: init_train_state(cfg, k), out_shardings=shardings
        )
        state = init(jax.random.PRNGKey(0))
        step_fn = jax.jit(
            make_train_step(cfg, flags, opt, microbatches=args.microbatches),
            donate_argnums=0,
        )

        data = for_model(cfg, seq_len=args.seq_len, global_batch=args.global_batch, seed=0)
        ckpt = CheckpointManager(args.ckpt_dir, keep_n=3, async_save=True)
        if args.resume and ckpt.latest_step() is not None:
            state, meta = ckpt.restore(state_struct, shardings=shardings)
            data.skip_to(meta["extra"].get("data_step", meta["step"]))
            print(f"resumed from step {meta['step']}")

        sup = Supervisor(ckpt, data, save_every=args.save_every)
        t0 = time.perf_counter()

        def on_metrics(step, m):
            if step % 10 == 0 or step == 1:
                print(
                    f"step {step:4d}  loss={float(m['loss']):.4f}  "
                    f"lr={float(m['lr']):.2e}  gnorm={float(m['grad_norm']):.2f}"
                )

        state = sup.run(
            state, step_fn, args.steps,
            restore_fn=lambda: ckpt.restore(state_struct, shardings=shardings),
            on_metrics=on_metrics,
        )
    print(
        f"done: {args.steps} steps in {time.perf_counter()-t0:.1f}s on "
        f"{jax.device_count()} device(s); stragglers={len(sup.monitor.flagged)}"
    )


if __name__ == "__main__":
    main()
