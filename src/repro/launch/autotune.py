"""FARSI-style auto-configuration of the distributed execution
(the paper's technique as a first-class framework feature).

Design space = DistConfig: sharding rules (mapping — *migrate*), ladder knobs
(microbatches, attention/SSD block sizes, remat, kernel on/off —
customization — *swap*). The explorer is the paper's loop: pick the metric
farthest from budget, attribute it to the costliest op (task) and its
binding resource (block ∈ {MXU, HBM, ICI}), choose moves by architectural
reasoning, keep SA temperature for escapes. The cost oracle is the agile
FARSI phase-sim over the step TDG (core/tpu_design.py); the compiled
multi-pod dry-run plays the Platform-Architect validation role (§Perf logs
both).

Budgets: step latency (performance), energy/step (power proxy), HBM bytes
(area analog, 16 GB/chip).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Tuple

from ..configs.base import ModelConfig, ShapeConfig
from ..roofline.analytic import (
    HBM_BW,
    ICI_BW_PER_LINK,
    PEAK_FLOPS,
    MeshShape,
    roofline_terms,
    step_costs,
)
from ..core.tpu_design import simulate_step
from ..sharding.rules import DistConfig

HBM_CAPACITY = 16e9  # v5e per chip
E_PJ_PER_FLOP = 0.6
E_PJ_PER_HBM_BYTE = 12.0
E_PJ_PER_ICI_BYTE = 4.0

MICRO_LADDER = (1, 2, 4, 8, 16, 32)
QBLOCK_LADDER = (128, 256, 512, 1024)
SSD_LADDER = (32, 64, 128, 256)


@dataclasses.dataclass
class TuneRecord:
    iteration: int
    move: str
    knob: str
    hypothesis: str
    before: Dict[str, float]
    after: Dict[str, float]
    accepted: bool


def estimate(cfg, shape, mesh, dist) -> Dict[str, float]:
    t = simulate_step(cfg, shape, mesh, dist)
    e = (
        t["flops"] * E_PJ_PER_FLOP
        + t["hbm_bytes"] * E_PJ_PER_HBM_BYTE
        + t["ici_bytes"] * E_PJ_PER_ICI_BYTE
    ) * 1e-12
    t["energy_j"] = e
    t["hbm_state_bytes"] = _state_bytes(cfg, shape, mesh, dist)
    return t


def _state_bytes(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshShape, dist) -> float:
    # with TP off, weights/opt-state replicate across the model axis and can
    # only shard over data — 123B-class models become infeasible (the reason
    # the tuner must not pick tp_off for them)
    tp = dist.rules.get("qkv", ("model",)) is not None
    p = cfg.param_counts()["total"] / (mesh.chips if tp else mesh.data)
    if shape.kind == "train":
        state = p * (4 * 3)  # fp32 params + m + v, fully sharded
        tok_dev = shape.global_batch * shape.seq_len / mesh.data / max(dist.microbatches, 1)
        sp = mesh.model if dist.rules.get("seq_res") else 1
        stack = cfg.n_layers * tok_dev * cfg.d_model * 6 / sp  # bf16 + f32 copies
        if dist.remat == "none":
            # no remat saves every per-layer intermediate, not just the
            # residual carry: ≈ (4·d + 2·d_ff)/d wider (the compile-refuted
            # qwen3-moe lesson, baked into the model)
            widen = 4 + 2 * max(cfg.d_ff, cfg.moe_d_ff * min(cfg.top_k, 1) if cfg.n_experts else 0) / cfg.d_model
            stack *= widen
        return state + stack
    state = p * 2  # bf16 weights
    if shape.kind == "decode" and cfg.has_attention():
        n_attn = sum(1 for k in cfg.block_kinds if k == "attn") * cfg.n_cycles
        kv_b = (1.0 + 2.0 / cfg.head_dim) if dist.kv_quant == "int8" else 2.0
        cache = (
            shape.global_batch
            * shape.seq_len
            * cfg.n_kv_heads
            * cfg.head_dim
            * kv_b
            * 2
            * n_attn
            / mesh.chips
        )
        state += cache * 2  # + in-flight copy
    return state


# ---------------------------------------------------------------------------
# moves over DistConfig
# ---------------------------------------------------------------------------
def _ladder_step(ladder, cur, direction):
    i = ladder.index(cur) + direction
    return ladder[i] if 0 <= i < len(ladder) else None


def moves_for(dominant: str, shape: ShapeConfig, cfg: ModelConfig) -> List[Tuple[str, str]]:
    """Algorithm-1 analog: (move, knob) candidates that can relax the
    dominant roofline term, ordered by development-cost precedence
    (mapping flips before kernel/knob customization)."""
    out: List[Tuple[str, str]] = []
    if dominant == "collective":
        # migrate: move weight sharding off the model axis (TP→DP) — kills
        # per-layer boundary collectives at the price of replicated weights
        out += [("migrate", "tp_off"), ("swap", "ring_bidir"), ("migrate", "seq_res_off")]
        if cfg.has_moe():
            out += [("swap", "a2a_int8"), ("swap", "cf_down")]
        if shape.kind == "train":
            out += [("swap", "grad_int8"), ("swap", "remat_none"), ("swap", "micro_down")]
    elif dominant == "memory":
        if shape.kind == "decode":
            out += [("swap", "kv_int8")]
        if shape.kind == "train":
            out += [("swap", "micro_up"), ("migrate", "seq_res_on"), ("swap", "remat_full")]
        out += [("migrate", "tp_on")]
    else:  # compute
        out += [("swap", "kernel_attn")]
        if shape.kind == "train":
            out += [("swap", "remat_none"), ("swap", "micro_down")]
        out += [("swap", "ssd_up")]
    return out


def apply_move(dist: DistConfig, knob: str) -> Optional[Tuple[DistConfig, str]]:
    """Returns (new DistConfig, hypothesis text) or None if inapplicable."""
    r = dict(dist.rules)
    if knob == "tp_off":
        if r.get("qkv") is None:
            return None
        for k in ("qkv", "kv_qkv", "mlp", "ssm_inner", "ssm_conv", "expert_mlp"):
            r[k] = None
        return dist.replace(rules=r), (
            "weights replicated over model axis → per-layer TP boundary "
            "collectives vanish; HBM weight traffic × model-axis"
        )
    if knob == "tp_on":
        if r.get("qkv") is not None:
            return None
        for k in ("qkv", "kv_qkv", "mlp", "ssm_inner", "ssm_conv", "expert_mlp"):
            r[k] = ("model",)
        return dist.replace(rules=r), "re-enable TP: weight HBM traffic ÷ model-axis"
    if knob == "seq_res_off":
        if r.get("seq_res") is None:
            return None
        r["seq_res"] = None
        return dist.replace(rules=r), "drop SP: removes ag/rs at block edges, grows act stack"
    if knob == "seq_res_on":
        if r.get("seq_res") is not None:
            return None
        r["seq_res"] = ("model",)
        return dist.replace(rules=r), "enable SP: remat stack ÷ model-axis"
    if knob == "micro_up":
        n = _ladder_step(MICRO_LADDER, dist.microbatches, +1)
        if n is None:
            return None
        return dist.replace(microbatches=n), "more grad-accum: activation stack ÷ 2"
    if knob == "micro_down":
        n = _ladder_step(MICRO_LADDER, dist.microbatches, -1)
        if n is None:
            return None
        return dist.replace(microbatches=n), "less grad-accum: fewer weight re-reads/collective replays"
    if knob == "kernel_attn":
        if dist.attn_impl == "kernel":
            return None
        return dist.replace(attn_impl="kernel"), (
            "Pallas flash kernel: causal block-skip halves attention FLOPs"
        )
    if knob == "remat_none":
        if dist.remat == "none":
            return None
        return dist.replace(remat="none"), "no remat: −1× forward recompute, +stack memory"
    if knob == "remat_full":
        if dist.remat == "full":
            return None
        return dist.replace(remat="full"), "full remat: stack ÷ L, +1× forward"
    if knob == "ssd_up":
        n = _ladder_step(SSD_LADDER, dist.ssd_chunk, +1)
        if n is None:
            return None
        return dist.replace(ssd_chunk=n), "larger SSD chunk: better MXU shapes, fewer state hops"
    if knob == "kv_int8":
        if dist.kv_quant == "int8":
            return None
        return dist.replace(kv_quant="int8"), (
            "int8 KV cache (per-token/head absmax): cache bytes ≈ ÷1.9 — the "
            "decode step is a cache-read roofline, so t_memory ≈ ÷1.9"
        )
    if knob == "a2a_int8":
        if dist.a2a_bytes == 1:
            return None
        return dist.replace(a2a_bytes=1), (
            "int8 MoE dispatch payload: all-to-all bytes ÷2 (combine in bf16 "
            "upcast on arrival)"
        )
    if knob == "grad_int8":
        if dist.grad_compress == "int8":
            return None
        return dist.replace(grad_compress="int8"), (
            "error-feedback int8 gradient reduce-scatter: DP sync bytes ÷4"
        )
    if knob == "ring_bidir":
        if dist.ici_links >= 2:
            return None
        return dist.replace(ici_links=2), (
            "bidirectional-ring collective schedule: both torus directions "
            "carry the all-reduce/all-gather concurrently → boundary "
            "collective time ÷2 (XLA does this on real ICI; our baseline "
            "models the pessimistic single-direction ring)"
        )
    if knob == "cf_down":
        if 0 < dist.capacity_factor <= 1.0:
            return None
        return dist.replace(capacity_factor=1.0), (
            "MoE capacity factor 1.25→1.0: dispatch volume (a2a bytes AND "
            "expert FLOPs) ×0.8, at the cost of more dropped tokens"
        )
    return None


@dataclasses.dataclass
class TuneResult:
    best: DistConfig
    best_terms: Dict[str, float]
    baseline_terms: Dict[str, float]
    log: List[TuneRecord]


def autotune(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: MeshShape,
    dist0: DistConfig,
    iterations: int = 30,
    seed: int = 0,
    hbm_budget: float = HBM_CAPACITY,
) -> TuneResult:
    rng = random.Random(seed)
    cur = dist0
    cur_t = estimate(cfg, shape, mesh, cur)
    base_t = dict(cur_t)
    best, best_t = cur, cur_t
    log: List[TuneRecord] = []

    def score(t):  # latency with a hard HBM-capacity wall
        penalty = max(0.0, (t["hbm_state_bytes"] - hbm_budget) / hbm_budget) * 10
        return t["t_phase_sim_s"] * (1 + penalty)

    for it in range(iterations):
        dom = cur_t["dominant"]
        if cur_t["hbm_state_bytes"] > hbm_budget:
            dom = "memory"
        cands = moves_for(dom, shape, cfg)
        rng.shuffle(cands)
        # dev-cost precedence: mapping (migrate) before customization (swap)
        cands.sort(key=lambda mk: 0 if mk[0] == "migrate" else 1)
        progressed = False
        for move, knob in cands:
            applied = apply_move(cur, knob)
            if applied is None:
                continue
            cand, hypothesis = applied
            cand_t = estimate(cfg, shape, mesh, cand)
            accept = score(cand_t) < score(cur_t) or rng.random() < 0.05 * (0.9**it)
            log.append(
                TuneRecord(
                    it, move, knob, hypothesis,
                    {k: cur_t[k] for k in ("t_compute_s", "t_memory_s", "t_collective_s", "t_phase_sim_s", "hbm_state_bytes")},
                    {k: cand_t[k] for k in ("t_compute_s", "t_memory_s", "t_collective_s", "t_phase_sim_s", "hbm_state_bytes")},
                    accept,
                )
            )
            if accept:
                cur, cur_t = cand, cand_t
                if score(cur_t) < score(best_t):
                    best, best_t = cur, cur_t
                progressed = True
                break
        if not progressed:
            break
    return TuneResult(best=best, best_terms=best_t, baseline_terms=base_t, log=log)
