"""Vectorized phase-driven simulator: evaluate a *batch* of SA neighbours in
one `vmap`'d XLA call.

The paper profiles its DSE at 79.9% design-duplication overhead (Fig. 8) —
a Python object-copy problem. We remove the object graph entirely: a design
is a flat array encoding (task→PE map, task→MEM map, per-slot knobs and PPA
coefficients), the TDG is dense matrices, and the phase loop is a
`lax.fori_loop` (every phase retires ≥1 task, so ≤T phases). `vmap` over the
design axis then evaluates all candidate neighbours of an explorer iteration
— or entire populations — in one dispatch.

Three things keep the *whole* explore→price→rank loop array-native:

  * **Incremental encoding** — a move emits a
    :class:`~repro.core.moves.MoveDelta`; :func:`apply_delta` turns the
    cached encoding of the current design into the neighbour's encoding
    (bit-identical to a from-scratch :meth:`EncodedDesign.of`) without
    cloning or re-walking the Python object graph.
  * **Device-side scoring** — the kernel folds the Eq.-7 budget distance
    and fitness (latency per workload, energy incl. leakage, area rollup)
    so one dispatch returns a ``(B,)`` fitness vector plus scalar PPA
    columns; the explorer ranks candidates from that small array.
  * **Lazy decode** — per-task dict reconstruction lives in
    ``backend.JaxBatchedBackend`` and is only paid by the winning candidate.

Scope: chain-topology designs with up to ``MAX_NOC`` NoCs. The encoding is
multi-NoC native: per-NoC ``(N,)`` knob/coefficient arrays in chain order, a
per-slot NoC-attachment index for every PE/MEM, and hop distances derived
from chain positions — so NoC fork/join moves emit ordinary encoding deltas
and ride the vectorized path instead of falling back to the Python
simulator. ``N`` pads to a power-of-two bucket per dispatch; the single-NoC
case (``N == 1``) compiles to exactly the formulation this module always
had, so the dominant regime pays nothing for the generality. Designs the
encoding still cannot host (chains beyond ``MAX_NOC``) raise
:class:`UnsupportedDesignError`, which the backend catches to route those
candidates to the scalar fallback. Equivalence against
`phase_sim.simulate` is asserted in tests for both regimes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import Block, BlockKind
from .database import HardwareDatabase
from .design import Design
from .moves import MoveDelta
from .tdg import TaskGraph, workload_of

BIG = 1e30

# the widest NoC chain the flat encoding hosts: chain positions are int32
# slot indices and the kernels unroll the per-NoC striping loop, so the cap
# is a compile-footprint guard, not a numerics limit (the link ladder tops
# out at 8 channels; explorations never grow chains past a handful)
MAX_NOC = 8


class UnsupportedDesignError(ValueError):
    """The design's shape falls outside what the flat encoding can host
    (today: NoC chains longer than ``MAX_NOC``). Typed — rather than a bare
    ``assert`` that vanishes under ``python -O`` — so the batched backend can
    catch it and route the candidate to the scalar Python fallback instead of
    silently mis-pricing it."""


@dataclasses.dataclass
class EncodedWorkload:
    """Static per-workload tensors (shared across all candidate designs)."""

    work_ops: jnp.ndarray  # (T,)
    read_bytes: jnp.ndarray  # (T,)
    write_bytes: jnp.ndarray  # (T,)
    burst: jnp.ndarray  # (T,)
    llp: jnp.ndarray  # (T,)
    parent_mask: jnp.ndarray  # (T, T) bool: [i, j] = j is a parent of i
    wl_id: jnp.ndarray  # (T,) int32 workload index per task
    names: List[str]
    wl_names: List[str]  # index -> workload name (graph name if unnamespaced)
    index: Dict[str, int] = dataclasses.field(default_factory=dict)

    @staticmethod
    def of(g: TaskGraph) -> "EncodedWorkload":
        names = list(g.tasks)
        idx = {n: i for i, n in enumerate(names)}
        t = len(names)
        pm = np.zeros((t, t), bool)
        for n in names:
            for p in g.parents[n]:
                pm[idx[n], idx[p]] = True
        wl_names: List[str] = []
        wl_id = np.zeros(t, np.int32)
        for i, n in enumerate(names):
            w = workload_of(n) if "." in n else g.name
            if w not in wl_names:
                wl_names.append(w)
            wl_id[i] = wl_names.index(w)
        f = lambda attr: jnp.asarray([getattr(g.tasks[n], attr) for n in names], jnp.float32)
        return EncodedWorkload(
            work_ops=f("work_ops"),
            read_bytes=jnp.asarray([g.tasks[n].read_bytes for n in names], jnp.float32),
            write_bytes=jnp.asarray([g.tasks[n].write_bytes for n in names], jnp.float32),
            burst=f("burst_bytes"),
            llp=f("llp"),
            parent_mask=jnp.asarray(pm),
            wl_id=jnp.asarray(wl_id),
            names=names,
            wl_names=wl_names,
            index=idx,
        )


# ---------------------------------------------------------------------------
# per-slot PPA coefficients (host-side closed forms the kernel sums on device)
# ---------------------------------------------------------------------------
def _pe_coeffs(b: Block, db: HardwareDatabase):
    """(peak ops/s, pJ/op, leak W, area mm²) of one PE block."""
    e = db.energy
    pj = e.acc_pj_per_op if b.subtype == "acc" else e.gpp_pj_per_op
    return db.pe_peak_ops(b), pj, db.leakage_w(b), db.block_area_mm2(b)


def _mem_coeffs(b: Block, db: HardwareDatabase):
    """(peak B/s, pJ/B, leak W, fixed area mm², area mm²/MB) of one MEM.

    SRAM area scales with resident capacity (CACTI-style), so it is split
    into a per-MB term the kernel multiplies by the segment-summed write
    bytes; DRAM is a fixed PHY block."""
    e = db.energy
    pj = e.sram_pj_per_byte if b.subtype == "sram" else e.dram_pj_per_byte
    if b.subtype == "sram":
        fixed, per_mb = 0.0, db.area.sram_mm2_per_mb
    else:
        fixed, per_mb = db.block_area_mm2(b), 0.0
    return b.peak_bandwidth(db), pj, db.leakage_w(b), fixed, per_mb


def _accel_of(b: Block, task_name: str, llp: float, db: HardwareDatabase) -> float:
    if b.hardened_for == task_name and b.subtype == "acc":
        return db.a_peak(task_name, llp, b.unroll)
    return 1.0


@dataclasses.dataclass
class EncodedDesign:
    """Flat design encoding: task maps, per-slot knobs *and* per-slot PPA
    coefficients, so pricing never revisits the Python object graph. Slot
    order is the design's block insertion order (PEs and MEMs separately),
    which is what makes :func:`apply_delta` reproducible bit-for-bit."""

    task_pe: np.ndarray  # (T,) int32 PE slot per task
    task_mem: np.ndarray  # (T,) int32 MEM slot per task
    pe_accel: np.ndarray  # (T,) effective acceleration of the task's PE for it
    pe_peak: np.ndarray  # (S_pe,) ops/s at a=1 (freq × ops/cycle)
    pe_pj: np.ndarray  # (S_pe,) dynamic pJ/op
    pe_leak: np.ndarray  # (S_pe,) leakage W
    pe_area: np.ndarray  # (S_pe,) mm²
    mem_bw: np.ndarray  # (S_mem,) bytes/s
    mem_pj: np.ndarray  # (S_mem,) dynamic pJ/byte
    mem_leak: np.ndarray  # (S_mem,) leakage W
    mem_area_fixed: np.ndarray  # (S_mem,) mm² (DRAM PHY; 0 for SRAM)
    mem_area_per_mb: np.ndarray  # (S_mem,) mm²/MB (SRAM; 0 for DRAM)
    # per-class active-slot masks (1.0 = slot exists in the design). Host
    # encodes are always all-ones — padding stays a *buffer* concept — but
    # the device-resident explorer prices allocation moves by toggling these
    # in place over capacity-padded inventories: an inactive slot keeps its
    # pad-neutral rates yet contributes nothing to the leak/area rollup.
    pe_active: np.ndarray  # (S_pe,) f32 mask
    mem_active: np.ndarray  # (S_mem,) f32 mask
    # per-NoC arrays in CHAIN order (index = chain position, so the hop
    # distance between two NoCs is |i − j| and a task's route is the index
    # interval between its PE's and its MEM's attachment)
    noc_bw: np.ndarray  # (N,) bytes/s per link
    noc_links: np.ndarray  # (N,) int32 channels
    noc_leak: np.ndarray  # (N,) leakage W
    noc_area: np.ndarray  # (N,) mm²
    noc_active: np.ndarray  # (N,) f32 mask (see pe_active)
    pe_noc: np.ndarray  # (S_pe,) int32 chain index each PE attaches to
    mem_noc: np.ndarray  # (S_mem,) int32 chain index each MEM attaches to
    noc_pj: np.float32  # dynamic pJ/byte·hop (db constant, rides the row so
    # the kernel never hardcodes an energy-model default)
    pe_slot: Dict[str, int]  # block name -> slot
    mem_slot: Dict[str, int]
    noc_slot: Dict[str, int]  # NoC name -> chain index

    @staticmethod
    def of(design: Design, g: TaskGraph, db: HardwareDatabase, enc: EncodedWorkload) -> "EncodedDesign":
        if not 1 <= len(design.noc_chain) <= MAX_NOC:
            raise UnsupportedDesignError(
                f"NoC chain of {len(design.noc_chain)} outside the encodable "
                f"range [1, {MAX_NOC}]"
            )
        noc_i = {n: i for i, n in enumerate(design.noc_chain)}
        # single pass over blocks: slot index maps + per-slot rates/coefficients
        pe_i: Dict[str, int] = {}
        mem_i: Dict[str, int] = {}
        pe_cols: List[tuple] = []
        mem_cols: List[tuple] = []
        pe_noc: List[int] = []
        mem_noc: List[int] = []
        for n, b in design.blocks.items():
            if b.kind == BlockKind.PE:
                pe_i[n] = len(pe_cols)
                pe_cols.append(_pe_coeffs(b, db))
                pe_noc.append(noc_i[design.attached_noc[n]])
            elif b.kind == BlockKind.MEM:
                mem_i[n] = len(mem_cols)
                mem_cols.append(_mem_coeffs(b, db))
                mem_noc.append(noc_i[design.attached_noc[n]])
        t = len(enc.names)
        d_pe, d_mem, blocks, tasks = design.task_pe, design.task_mem, design.blocks, g.tasks
        task_pe = np.fromiter((pe_i[d_pe[n]] for n in enc.names), np.int32, t)
        task_mem = np.fromiter((mem_i[d_mem[n]] for n in enc.names), np.int32, t)
        accel = np.ones(t, np.float32)
        for k, n in enumerate(enc.names):
            b = blocks[d_pe[n]]
            if b.hardened_for == n and b.subtype == "acc":
                accel[k] = db.a_peak(n, tasks[n].llp, b.unroll)
        nocs = [blocks[n] for n in design.noc_chain]
        f32col = lambda cols, j: np.asarray([c[j] for c in cols], np.float32)
        return EncodedDesign(
            task_pe=task_pe,
            task_mem=task_mem,
            pe_accel=accel,
            pe_peak=f32col(pe_cols, 0),
            pe_pj=f32col(pe_cols, 1),
            pe_leak=f32col(pe_cols, 2),
            pe_area=f32col(pe_cols, 3),
            mem_bw=f32col(mem_cols, 0),
            mem_pj=f32col(mem_cols, 1),
            mem_leak=f32col(mem_cols, 2),
            mem_area_fixed=f32col(mem_cols, 3),
            mem_area_per_mb=f32col(mem_cols, 4),
            pe_active=np.ones(len(pe_cols), np.float32),
            mem_active=np.ones(len(mem_cols), np.float32),
            noc_bw=np.asarray([b.peak_bandwidth(db) for b in nocs], np.float32),
            noc_links=np.asarray([b.n_links for b in nocs], np.int32),
            noc_leak=np.asarray([db.leakage_w(b) for b in nocs], np.float32),
            noc_area=np.asarray([db.block_area_mm2(b) for b in nocs], np.float32),
            noc_active=np.ones(len(nocs), np.float32),
            pe_noc=np.asarray(pe_noc, np.int32),
            mem_noc=np.asarray(mem_noc, np.int32),
            noc_pj=np.float32(db.energy.noc_pj_per_byte_hop),
            pe_slot=pe_i,
            mem_slot=mem_i,
            noc_slot=noc_i,
        )


def _append1(arr: np.ndarray, v) -> np.ndarray:
    """np.append without its ravel/concatenate overhead (hot path)."""
    out = np.empty(arr.shape[0] + 1, arr.dtype)
    out[:-1] = arr
    out[-1] = v
    return out


def _delete1(arr: np.ndarray, s: int) -> np.ndarray:
    """np.delete of one index without its mask machinery (hot path)."""
    out = np.empty(arr.shape[0] - 1, arr.dtype)
    out[:s] = arr[:s]
    out[s:] = arr[s + 1:]
    return out


def _insert1(arr: np.ndarray, s: int, v) -> np.ndarray:
    """np.insert of one value without its generic machinery (hot path)."""
    out = np.empty(arr.shape[0] + 1, arr.dtype)
    out[:s] = arr[:s]
    out[s] = v
    out[s + 1:] = arr[s:]
    return out


_NOC_ARRAY_FIELDS = ("noc_bw", "noc_links", "noc_leak", "noc_area", "noc_active")


def _noc_cols(b: Block, db: HardwareDatabase) -> tuple:
    return (
        np.float32(b.peak_bandwidth(db)), np.int32(b.n_links),
        np.float32(db.leakage_w(b)), np.float32(db.block_area_mm2(b)),
        np.float32(1.0),
    )


def apply_delta(
    base: "EncodedDesign",
    delta: MoveDelta,
    design: Design,
    g: TaskGraph,
    db: HardwareDatabase,
    enc: EncodedWorkload,
) -> "EncodedDesign":
    """Incremental re-encode: the neighbour's :class:`EncodedDesign` from the
    *current* design's cached encoding plus the move's recorded delta —
    bit-identical to ``EncodedDesign.of`` on the mutated design (asserted in
    tests/test_encoding_delta.py), at a handful of O(S)/O(T) numpy edits
    instead of a full Python-object walk.

    ``design`` is the *base* (pre-move) design: only blocks the delta did not
    touch are read from it, so it may be called before or after rollback.
    """
    if delta.topology:
        raise UnsupportedDesignError("delta flagged as unencodable (topology)")
    # copy-on-write: fields the delta does not touch stay *shared* with the
    # base encoding (`ed.f is base.f`), which both keeps a typical swap/
    # migrate delta at a couple of tiny array copies and lets the backend
    # detect exactly which buffer fields need rewriting per candidate
    ed = dataclasses.replace(base)

    def own(*fields: str) -> None:
        for f in fields:
            v = getattr(ed, f)
            if v is getattr(base, f):
                setattr(ed, f, v.copy() if isinstance(v, np.ndarray) else dict(v))

    touched_pe_slots: List[int] = []

    # 1) removals (join): compact slots exactly like a from-scratch encode.
    # A removed NoC compacts the chain; blocks it hosted carry explicit
    # re-attachment edits (delta.attached), applied in step 4b below.
    for name in delta.removed:
        if name in ed.pe_slot:
            s = ed.pe_slot[name]
            for f in ("pe_peak", "pe_pj", "pe_leak", "pe_area", "pe_active"):
                setattr(ed, f, _delete1(getattr(ed, f), s))
            ed.pe_slot = {n: i - (i > s) for n, i in ed.pe_slot.items() if n != name}
            ed.task_pe = ed.task_pe - (ed.task_pe > s)
            ed.pe_noc = _delete1(ed.pe_noc, s)
        elif name in ed.mem_slot:
            s = ed.mem_slot[name]
            for f in (
                "mem_bw", "mem_pj", "mem_leak", "mem_area_fixed",
                "mem_area_per_mb", "mem_active",
            ):
                setattr(ed, f, _delete1(getattr(ed, f), s))
            ed.mem_slot = {n: i - (i > s) for n, i in ed.mem_slot.items() if n != name}
            ed.task_mem = ed.task_mem - (ed.task_mem > s)
            ed.mem_noc = _delete1(ed.mem_noc, s)
        elif name in ed.noc_slot:
            s = ed.noc_slot[name]
            for f in _NOC_ARRAY_FIELDS:
                setattr(ed, f, _delete1(getattr(ed, f), s))
            ed.noc_slot = {n: i - (i > s) for n, i in ed.noc_slot.items() if n != name}
            ed.pe_noc = ed.pe_noc - (ed.pe_noc > s)
            ed.mem_noc = ed.mem_noc - (ed.mem_noc > s)

    # 2a) NoC additions (fork): INSERT at the recorded chain position — chain
    # order is the slot order, so every downstream chain index shifts by one
    for b in delta.added:
        if b.kind != BlockKind.NOC:
            continue
        p = ed.noc_slot[delta.noc_after] + 1 if delta.noc_after else ed.noc_bw.shape[0]
        ed.noc_slot = {n: i + (i >= p) for n, i in ed.noc_slot.items()}
        ed.noc_slot[b.name] = p
        for f, v in zip(_NOC_ARRAY_FIELDS, _noc_cols(b, db)):
            setattr(ed, f, _insert1(getattr(ed, f), p, v))
        ed.pe_noc = ed.pe_noc + (ed.pe_noc >= p)
        ed.mem_noc = ed.mem_noc + (ed.mem_noc >= p)

    # 2b) PE/MEM additions (fork): append at the end, matching dict insertion
    # order; the new slot's NoC attachment is the recorded one
    for b in delta.added:
        if b.kind == BlockKind.PE:
            own("pe_slot")
            ed.pe_slot[b.name] = ed.pe_peak.shape[0]
            cols = _pe_coeffs(b, db)
            for f, v in zip(("pe_peak", "pe_pj", "pe_leak", "pe_area"), cols):
                setattr(ed, f, _append1(getattr(ed, f), np.float32(v)))
            ed.pe_active = _append1(ed.pe_active, np.float32(1.0))
            ed.pe_noc = _append1(ed.pe_noc, ed.noc_slot[delta.attached[b.name]])
            touched_pe_slots.append(ed.pe_slot[b.name])
        elif b.kind == BlockKind.MEM:
            own("mem_slot")
            ed.mem_slot[b.name] = ed.mem_bw.shape[0]
            cols = _mem_coeffs(b, db)
            for f, v in zip(
                ("mem_bw", "mem_pj", "mem_leak", "mem_area_fixed", "mem_area_per_mb"), cols
            ):
                setattr(ed, f, _append1(getattr(ed, f), np.float32(v)))
            ed.mem_active = _append1(ed.mem_active, np.float32(1.0))
            ed.mem_noc = _append1(ed.mem_noc, ed.noc_slot[delta.attached[b.name]])

    # 3) knob edits (swap): refresh the touched slot's rate + coefficients
    for name, snap in delta.touched.items():
        if snap.kind == BlockKind.NOC:
            s = ed.noc_slot[name]
            own(*_NOC_ARRAY_FIELDS)
            for f, v in zip(_NOC_ARRAY_FIELDS, _noc_cols(snap, db)):
                getattr(ed, f)[s] = v
        elif name in ed.pe_slot:
            s = ed.pe_slot[name]
            own("pe_peak", "pe_pj", "pe_leak", "pe_area")
            for f, v in zip(("pe_peak", "pe_pj", "pe_leak", "pe_area"), _pe_coeffs(snap, db)):
                getattr(ed, f)[s] = np.float32(v)
            touched_pe_slots.append(s)
        elif name in ed.mem_slot:
            s = ed.mem_slot[name]
            own("mem_bw", "mem_pj", "mem_leak", "mem_area_fixed", "mem_area_per_mb")
            for f, v in zip(
                ("mem_bw", "mem_pj", "mem_leak", "mem_area_fixed", "mem_area_per_mb"),
                _mem_coeffs(snap, db),
            ):
                getattr(ed, f)[s] = np.float32(v)

    # 4) mapping edits (migrate / fork / join reassignments)
    moved: List[int] = []
    if delta.task_pe:
        own("task_pe")
        for t, pe in delta.task_pe.items():
            k = enc.index[t]
            ed.task_pe[k] = ed.pe_slot[pe]
            moved.append(k)
    if delta.task_mem:
        own("task_mem")
        for t, mem in delta.task_mem.items():
            ed.task_mem[enc.index[t]] = ed.mem_slot[mem]

    # 4b) NoC re-attachments (NoC fork/join re-home attached blocks; newly
    # added slots were already born attached — re-setting is idempotent)
    for bname, nocname in delta.attached.items():
        p = ed.noc_slot[nocname]
        if bname in ed.pe_slot:
            own("pe_noc")
            ed.pe_noc[ed.pe_slot[bname]] = p
        elif bname in ed.mem_slot:
            own("mem_noc")
            ed.mem_noc[ed.mem_slot[bname]] = p

    # 5) acceleration refresh for every task whose PE (or its knobs) changed
    if touched_pe_slots or moved:
        slot_name = {s: n for n, s in ed.pe_slot.items()}
        affected = set(moved)
        for s in set(touched_pe_slots):
            affected.update(np.nonzero(ed.task_pe == s)[0].tolist())
        block_of: Dict[str, Block] = {b.name: b for b in delta.added}
        block_of.update(delta.touched)
        own("pe_accel")
        for k in affected:
            name = slot_name[int(ed.task_pe[k])]
            b = block_of.get(name) or design.blocks[name]
            tname = enc.names[k]
            ed.pe_accel[k] = _accel_of(b, tname, g.tasks[tname].llp, db)
    return ed


# per-design row keys, in the order buffers are allocated/filled
ROW_KEYS = (
    "task_pe", "task_mem", "pe_accel",
    "pe_peak", "pe_pj", "pe_leak", "pe_area", "pe_noc", "pe_active",
    "mem_bw", "mem_pj", "mem_leak", "mem_area_fixed", "mem_area_per_mb",
    "mem_noc", "mem_active",
    "noc_bw", "noc_links", "noc_leak", "noc_area", "noc_active", "noc_pj",
    "wl_budget", "power_budget", "area_budget", "alpha",
)


def alloc_rows(
    b: int, t: int, n_pe: int, n_mem: int, n_wl: int, n_noc: int = 1
) -> Dict[str, np.ndarray]:
    """Preallocate one batch of padded per-design rows (host buffers the
    backend reuses across dispatches of the same shape bucket). Pad values:
    rates 1.0 (div-by-zero-free, never hosting tasks), coefficients 0.0
    (they are summed), budgets BIG / alpha 0 (neutral scoring). Padded NoC
    slots (chain indices ≥ the design's real chain length) carry no attached
    blocks, so no route ever crosses them."""
    rows = {
        "task_pe": np.zeros((b, t), np.int32),
        "task_mem": np.zeros((b, t), np.int32),
        "pe_accel": np.ones((b, t), np.float32),
        "pe_peak": np.ones((b, n_pe), np.float32),
        "pe_pj": np.zeros((b, n_pe), np.float32),
        "pe_leak": np.zeros((b, n_pe), np.float32),
        "pe_area": np.zeros((b, n_pe), np.float32),
        "pe_noc": np.zeros((b, n_pe), np.int32),
        "pe_active": np.zeros((b, n_pe), np.float32),
        "mem_bw": np.ones((b, n_mem), np.float32),
        "mem_pj": np.zeros((b, n_mem), np.float32),
        "mem_leak": np.zeros((b, n_mem), np.float32),
        "mem_area_fixed": np.zeros((b, n_mem), np.float32),
        "mem_area_per_mb": np.zeros((b, n_mem), np.float32),
        "mem_noc": np.zeros((b, n_mem), np.int32),
        "mem_active": np.zeros((b, n_mem), np.float32),
        "noc_bw": np.ones((b, n_noc), np.float32),
        "noc_links": np.ones((b, n_noc), np.int32),
        "noc_leak": np.zeros((b, n_noc), np.float32),
        "noc_area": np.zeros((b, n_noc), np.float32),
        "noc_active": np.zeros((b, n_noc), np.float32),
        "noc_pj": np.zeros((b,), np.float32),
        "wl_budget": np.full((b, n_wl), BIG, np.float32),
        "power_budget": np.full((b,), BIG, np.float32),
        "area_budget": np.full((b,), BIG, np.float32),
        "alpha": np.zeros((b,), np.float32),
    }
    return rows


_TASK_FIELDS = ("task_pe", "task_mem", "pe_accel")
_PE_FIELDS = ("pe_peak", "pe_pj", "pe_leak", "pe_area", "pe_noc", "pe_active")
_MEM_FIELDS = (
    "mem_bw", "mem_pj", "mem_leak", "mem_area_fixed", "mem_area_per_mb",
    "mem_noc", "mem_active",
)
ENCODED_FIELDS = _TASK_FIELDS + _PE_FIELDS + _MEM_FIELDS + _NOC_ARRAY_FIELDS


def fill_row_fields(
    rows: Dict[str, np.ndarray], j: int, ed: EncodedDesign, fields
) -> None:
    """Write a subset of one design's encoding into row ``j`` — the backend
    pairs this with the copy-on-write :func:`apply_delta` to rewrite only the
    buffer fields a candidate's move actually changed (``ed.f is not
    base.f``); everything else keeps the broadcast base-row content."""
    for f in fields:
        if f in _TASK_FIELDS:
            rows[f][j] = getattr(ed, f)
        elif f in _PE_FIELDS:
            s = ed.pe_peak.shape[0]
            rows[f][j, :s] = getattr(ed, f)
            rows[f][j, s:] = 1.0 if f == "pe_peak" else 0.0
        elif f in _MEM_FIELDS:
            m = ed.mem_bw.shape[0]
            rows[f][j, :m] = getattr(ed, f)
            rows[f][j, m:] = 1.0 if f == "mem_bw" else 0.0
        else:  # per-NoC chain arrays
            n = ed.noc_bw.shape[0]
            rows[f][j, :n] = getattr(ed, f)
            rows[f][j, n:] = 1.0 if f in ("noc_bw", "noc_links") else 0.0


def fill_row(rows: Dict[str, np.ndarray], j: int, ed: EncodedDesign) -> None:
    """Write one design's full encoding into row ``j`` of the padded buffers."""
    fill_row_fields(rows, j, ed, ENCODED_FIELDS)
    rows["noc_pj"][j] = ed.noc_pj


def fill_budget(
    rows: Dict[str, np.ndarray], j: int, enc: EncodedWorkload,
    latency_s: Dict[str, float], power_w: float, area_mm2: float, alpha: float,
) -> None:
    """Write one design's Eq.-7 budget row (device-side fitness inputs).
    Workloads the budget does not name score BIG (distance ≈ −1, never the
    binding term)."""
    rows["wl_budget"][j] = [latency_s.get(w, BIG) for w in enc.wl_names]
    rows["power_budget"][j] = power_w
    rows["area_budget"][j] = area_mm2
    rows["alpha"][j] = alpha


def simulate_one(enc: EncodedWorkload, row: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:  # repro: traced
    """Phase simulation + device-side scoring of ONE candidate row.

    This is the single-candidate oracle shared by :func:`simulate_batch`
    (``vmap`` over the row axis — the XLA reference path) and by
    ``repro.kernels.phase_sim`` (the fused Pallas kernel reimplements this
    math per grid program; parity ≤ 1e-5 is asserted in
    tests/test_phase_sim_kernel.py). See :func:`simulate_batch` for the
    contract and the co-residency-matvec formulation notes.
    """
    t = enc.work_ops.shape[0]
    n_wl = len(enc.wl_names)
    idx3 = jnp.arange(3)

    task_pe, task_mem = row["task_pe"], row["task_mem"]
    n_pe = row["pe_peak"].shape[-1]
    n_mem = row["mem_bw"].shape[-1]
    n_noc = row["noc_bw"].shape[-1]
    noc_bw = row["noc_bw"]
    # loop-invariant hoists: effective peak rates per task and the
    # same-slot co-residency masks behind Eq. 1/2 (PE share) and Eq. 4
    # (burst-proportional memory share)
    peak_eff = row["pe_peak"][task_pe] * row["pe_accel"]
    mem_peak = row["mem_bw"][task_mem]
    same_pe = (task_pe[:, None] == task_pe[None, :]).astype(jnp.float32)
    same_mem = (task_mem[:, None] == task_mem[None, :]).astype(jnp.float32)
    # one-hot task→slot maps: cap rollup and the per-slot bottleneck
    # telemetry accumulate through these instead of segment_sum scatters
    onehot_pe = (task_pe[:, None] == jnp.arange(n_pe)[None, :]).astype(jnp.float32)
    onehot_mem = (task_mem[:, None] == jnp.arange(n_mem)[None, :]).astype(jnp.float32)
    links = jnp.maximum(row["noc_links"], 1)  # (N,)
    # multi-NoC chain routing: a task's route is the chain-index interval
    # between its PE's and its MEM's NoC; hop count scales the NoC energy
    pe_pos = row["pe_noc"][task_pe]
    mem_pos = row["mem_noc"][task_mem]
    lo = jnp.minimum(pe_pos, mem_pos)
    hi = jnp.maximum(pe_pos, mem_pos)
    hops = (hi - lo + 1).astype(jnp.float32)
    nidx = jnp.arange(n_noc, dtype=jnp.int32)
    on_route = (
        (nidx[None, :] >= lo[:, None]) & (nidx[None, :] <= hi[:, None])
    ).astype(jnp.float32)  # (T, N)

    def noc_share(runf):
        """Eq. 3 per NoC: round-robin link striping (same link ⟺ running
        ranks congruent mod n_links), burst arbitration within the link;
        a task's end-to-end NoC bandwidth is the min over its route, and
        the argmin (first, in chain order — matching the Python
        reference's strict-< scan) is the binding NoC instance for the
        telemetry. The ``n_noc == 1`` branch is bit-for-bit the historic
        single-NoC formulation — the dominant regime compiles to exactly
        the math it always had."""
        if n_noc == 1:
            order = jnp.cumsum(runf)
            same_link = (runf[:, None] * runf[None, :]) * jnp.where(
                (order[:, None] - order[None, :]) % links[0] == 0, 1.0, 0.0
            )
            link_t = same_link @ enc.burst
            n_bw = noc_bw[0] * enc.burst / jnp.maximum(link_t, 1e-30)
            return n_bw, jnp.zeros((t,), jnp.int32)
        # multi-NoC: the same rank-residue striping, but through a (T, 8)
        # link one-hot (the link ladder tops out at 8 channels) instead of a
        # (T, T) co-residency mask per NoC — user u's link is
        # (rank_u − 1) mod n_links, link loads are one (8,) segment sum, so
        # the per-NoC cost is O(T·8), not O(T²)
        lidx = jnp.arange(8, dtype=jnp.float32)
        best = jnp.full((t,), BIG, jnp.float32)
        arg = jnp.zeros((t,), jnp.int32)
        for k in range(n_noc):  # N is a static padded bucket: unrolled
            use_k = on_route[:, k] * runf
            order = jnp.cumsum(use_k)
            link = jnp.where(use_k > 0, (order - 1.0) % links[k], -1.0)
            oh = (link[:, None] == lidx[None, :]).astype(jnp.float32)
            link_load = (enc.burst * use_k) @ oh  # (8,) burst per link
            link_t = oh @ link_load
            bw_k = jnp.where(
                use_k > 0,
                noc_bw[k] * enc.burst / jnp.maximum(link_t, 1e-30),
                BIG,
            )
            better = bw_k < best
            arg = jnp.where(better, k, arg)
            best = jnp.where(better, bw_k, best)
        return best, arg

    def phase(_, state):
        (rem_ops, rem_rd, rem_wr, completed, now, finish, bneck, bneck_noc,
         kind_s, pe_bt, mem_bt, noc_bt, alp_t, traffic, nph) = state
        running = (~completed) & jnp.all(~enc.parent_mask | completed[None, :], axis=1)
        runf = jnp.where(running, 1.0, 0.0)
        burst_run = enc.burst * runf

        # Eq. 1/2: preemptive equal share per PE slot
        load_t = same_pe @ runf  # running tasks sharing my PE (incl. me)
        compute = peak_eff / jnp.maximum(load_t, 1.0)

        # Eq. 4: burst-proportional memory share (read/write channels
        # split, but they see identical shares — one bandwidth suffices)
        mem_t = same_mem @ burst_run
        m_bw = mem_peak * enc.burst / jnp.maximum(mem_t, 1e-30)

        # Eq. 3: per-NoC link striping, end-to-end min over the route
        n_bw, noc_arg = noc_share(runf)

        bw = jnp.minimum(m_bw, n_bw)
        comp_t = rem_ops / compute
        comm_t = jnp.maximum(rem_rd, rem_wr) / bw
        c_t = jnp.where(running, jnp.maximum(comp_t, comm_t), BIG)
        phi_raw = jnp.min(c_t)  # Eq. 6
        any_run = phi_raw < BIG * 0.5
        phi = jnp.where(any_run, phi_raw, 0.0)
        phi_run = jnp.where(running, phi, 0.0)

        # binding resource per running task (gables.bottleneck_of — note:
        # attribution uses the task's *total* work over current rates, not
        # the remaining work; compute wins ties, then mem vs noc by the
        # tighter pipe)
        tot_comp_t = enc.work_ops / compute
        tot_comm_t = jnp.maximum(enc.read_bytes, enc.write_bytes) / bw
        code = jnp.where(tot_comp_t >= tot_comm_t, 0, jnp.where(m_bw <= n_bw, 1, 2))
        kind_s = kind_s + jnp.sum(
            jnp.where(code[:, None] == idx3[None, :], phi_run[:, None], 0.0), axis=0
        )
        # per-TASK bottleneck-time accumulators for the block telemetry:
        # task→slot maps are phase-invariant, so the slot resolution (one
        # (T,S) matvec each) happens once AFTER the loop — in-loop this is
        # just two (T,) masked adds, keeping the phase critical path flat
        pe_bt = pe_bt + jnp.where(code == 0, phi_run, 0.0)
        mem_bt = mem_bt + jnp.where(code == 1, phi_run, 0.0)
        # per-NoC binding seconds: the binding NoC varies per phase (it is
        # contention-dependent), so unlike the task→slot maps it cannot be
        # resolved after the loop. One NoC: it is just kind_s[2], resolved
        # post-loop; multi-NoC: one (T,N) masked matvec per phase.
        if n_noc > 1:
            noc_bt = noc_bt + jnp.where(code == 2, phi_run, 0.0) @ (
                noc_arg[:, None] == nidx[None, :]
            ).astype(jnp.float32)

        # mask rates BEFORE the phi multiply: slots hosting no running
        # task price as inf bandwidth, and inf · 0 would poison the
        # remain columns with NaN
        d_ops = jnp.where(running, compute, 0.0) * phi
        d_bw = jnp.where(running, bw, 0.0) * phi
        dr_ops = jnp.maximum(rem_ops - d_ops, 0.0)  # post-drain, pre-retire
        dr_rd = jnp.maximum(rem_rd - d_bw, 0.0)
        dr_wr = jnp.maximum(rem_wr - d_bw, 0.0)
        newly_done = running & (c_t <= phi * (1 + 1e-9))
        keep = ~newly_done
        now = now + phi
        finish = jnp.where(newly_done, now, finish)
        bneck = jnp.where(newly_done, code, bneck)
        if n_noc > 1:  # binding NoC instance at completion (chain index)
            bneck_noc = jnp.where(newly_done, noc_arg, bneck_noc)
        # busy-PE count: each PE with k running tasks contributes k · 1/k
        alp_t = alp_t + phi * jnp.sum(runf / jnp.maximum(load_t, 1.0))
        # phase_sim accumulates min(post-drain bytes, bw·phi) per running
        # task — mirror it exactly so the backends agree on this field too
        traffic = traffic + jnp.sum(
            jnp.where(running, jnp.minimum(dr_rd + dr_wr, d_bw + d_bw), 0.0)
        )
        nph = nph + jnp.where(any_run, 1, 0)
        return (
            jnp.where(keep, dr_ops, 0.0), jnp.where(keep, dr_rd, 0.0),
            jnp.where(keep, dr_wr, 0.0), completed | newly_done, now, finish,
            bneck, bneck_noc, kind_s, pe_bt, mem_bt, noc_bt, alp_t, traffic,
            nph,
        )

    state = (
        enc.work_ops,
        enc.read_bytes,
        enc.write_bytes,
        jnp.zeros((t,), bool),
        jnp.float32(0.0),
        jnp.zeros((t,), jnp.float32),
        jnp.zeros((t,), jnp.int32),
        jnp.zeros((t,), jnp.int32),
        jnp.zeros((3,), jnp.float32),
        jnp.zeros((t,), jnp.float32),
        jnp.zeros((t,), jnp.float32),
        jnp.zeros((n_noc,), jnp.float32),
        jnp.float32(0.0),
        jnp.float32(0.0),
        jnp.int32(0),
    )
    (rem_ops, rem_rd, rem_wr, completed, now, finish, bneck, bneck_noc,
     kind_s, pe_bt, mem_bt, noc_bt, alp_t, traffic, nph) = jax.lax.fori_loop(
        0, t, phase, state)
    # per-BLOCK bottleneck telemetry: phi attribution resolved to the
    # binding slot (task_pe for compute-bound, task_mem for memory-bound;
    # single-NoC chains resolve their one NoC column from kind_s[2])
    pe_b = pe_bt @ onehot_pe
    mem_b = mem_bt @ onehot_mem
    noc_b = kind_s[2:3] if n_noc == 1 else noc_bt

    # ---- device-side PPA rollup + Eq.-7 fitness ----------------------
    # dynamic energy is rate-independent (every task drains its totals;
    # the NoC term scales with the task's route hop count), so it is a
    # coefficient dot
    wl_lat = jax.ops.segment_max(finish, enc.wl_id, num_segments=n_wl)
    dyn_pj = jnp.sum(
        row["pe_pj"][task_pe] * enc.work_ops
        + (row["mem_pj"][task_mem] + row["noc_pj"] * hops)
        * (enc.read_bytes + enc.write_bytes)
    )
    # active-slot masked rollups: inactive slots (device-side joins over the
    # capacity-padded inventory — host rows are all-active with 0.0 pads, so
    # the mask multiply is bit-exact there) price as absent hardware
    leak_w = (
        jnp.sum(row["pe_leak"] * row["pe_active"])
        + jnp.sum(row["mem_leak"] * row["mem_active"])
        + jnp.sum(row["noc_leak"] * row["noc_active"])
    )
    energy = dyn_pj * 1e-12 + leak_w * now
    power = jnp.where(now > 0, energy / jnp.maximum(now, 1e-30), 0.0)
    cap = enc.write_bytes @ onehot_mem
    area = (
        jnp.sum(row["pe_area"] * row["pe_active"])
        + jnp.sum(
            (
                row["mem_area_fixed"]
                + row["mem_area_per_mb"] * jnp.maximum(cap, 1.0) / 1e6
            )
            * row["mem_active"]
        )
        + jnp.sum(row["noc_area"] * row["noc_active"])
    )
    dists = jnp.stack(
        [
            jnp.max((wl_lat - row["wl_budget"]) / row["wl_budget"]),
            (power - row["power_budget"]) / row["power_budget"],
            (area - row["area_budget"]) / row["area_budget"],
        ]
    )
    fitness = jnp.sum(jnp.where(dists > 0, dists, row["alpha"] * dists))
    return {
        "latency_s": now,
        "finish_s": finish,
        "all_done": jnp.all(completed),
        # packed per-task binding code: 0 = pe, 1 = mem, 2 + 3·k = NoC at
        # chain index k (single-NoC packs to the historic {0, 1, 2} values)
        "bneck_code": jnp.where(bneck == 2, 2 + 3 * bneck_noc, bneck),
        "bneck_kind_s": kind_s,
        # per-block bottleneck telemetry (slot order = encoding slot order):
        # seconds each PE/MEM slot was the binding bottleneck, plus the
        # argmax slot per class — the columns the telemetry-driven policies
        # select their next focus from without any host-side decode
        "pe_bneck_s": pe_b,
        "mem_bneck_s": mem_b,
        "noc_bneck_s": noc_b,
        "top_bneck_pe": jnp.argmax(pe_b).astype(jnp.int32),
        "top_bneck_mem": jnp.argmax(mem_b).astype(jnp.int32),
        "alp_time_s": alp_t,
        "traffic_bytes": traffic,
        "n_phases": nph,
        "wl_latency_s": wl_lat,
        "energy_j": energy,
        "power_w": power,
        "area_mm2": area,
        "fitness": fitness,
    }


def simulate_batch(  # repro: traced
    enc: EncodedWorkload,
    rows: Dict[str, jnp.ndarray],
) -> Dict[str, jnp.ndarray]:
    """vmap'd phase simulation + device-side scoring.

    ``rows`` is a dict of per-design arrays (batch axis leading; see
    ``ROW_KEYS``/:func:`alloc_rows`). Returns latency (B,), task finish
    times (B, T), the per-task / per-phase attribution a
    :class:`~repro.core.backend.JaxBatchedBackend` needs to lazily
    reconstruct a full ``SimResult`` (binding-resource code per task,
    time-weighted bottleneck seconds, ALP time, traffic, phase count) —
    plus the scalar PPA columns (energy/power/area, per-workload latency)
    and the Eq.-7 ``fitness`` vector the explorer ranks with, so accepting
    or rejecting a whole neighbour batch transfers O(B) floats, not B
    decoded dicts.

    Contention sums are (T, T) co-residency matvecs, not ``segment_sum``
    scatters: ``task_pe``/``task_mem`` are phase-invariant so the same-slot
    masks hoist out of the loop, and vmapped scatter/gather pairs are the
    dominant cost of the phase loop on CPU XLA (~4x kernel time). NoC
    round-robin striping (Eq. 3) is expressed the same way through rank
    residues — two running tasks share a link iff their running-order ranks
    are congruent mod ``n_links`` — which is exact for *any* link count
    (the old segment-bucketed formulation silently dropped the bandwidth
    attribution of links ≥ its hardcoded segment count).

    This is the XLA *reference* path; ``repro.kernels.phase_sim`` provides
    the fused Pallas formulation of the same math (one launch over the
    (B, T) grid, Mosaic on TPU / interpret elsewhere) selected via
    ``JaxBatchedBackend(use_kernel=True)``.
    """
    return jax.vmap(lambda row: simulate_one(enc, row))(rows)


def encode_batch(
    designs: List[Design],
    g: TaskGraph,
    db: HardwareDatabase,
    enc: EncodedWorkload,
    n_pe: int = 0,
    n_mem: int = 0,
    n_noc: int = 0,
) -> Dict[str, np.ndarray]:
    """Pad a list of designs to common slot/chain counts and stack into a
    :func:`simulate_batch` rows dict (neutral budget rows — callers that
    want device-side fitness fill them via :func:`fill_budget`).

    ``n_pe``/``n_mem``/``n_noc`` optionally force the padded counts —
    backends pad to shape buckets so the jit cache is keyed on a handful of
    shapes instead of recompiling every time a move adds a block or forks a
    NoC. Returns host (numpy) arrays; `jax.jit` transfers them on dispatch.
    """
    encs = [EncodedDesign.of(d, g, db, enc) for d in designs]
    b, t = len(encs), len(enc.names)
    n_pe = max(n_pe, max(e.pe_peak.shape[0] for e in encs))
    n_mem = max(n_mem, max(e.mem_bw.shape[0] for e in encs))
    n_noc = max(n_noc, max(e.noc_bw.shape[0] for e in encs))
    rows = alloc_rows(b, t, n_pe, n_mem, len(enc.wl_names), n_noc)
    for i, e in enumerate(encs):
        fill_row(rows, i, e)
    return rows
