"""Drop-in wrapper for the fused phase-sim kernel.

``phase_sim(enc, rows)`` accepts exactly what
``repro.core.phase_sim_jax.simulate_batch`` accepts (an
:class:`EncodedWorkload` plus the padded per-design rows dict) and returns
the same output dict, so ``JaxBatchedBackend`` can swap the two via its
``use_kernel`` knob without touching buffers or decode.

The wrapper owns the layout differences:

  * the task axis is padded to the kernel tile width — a multiple of 128
    (the TPU lane count) under Mosaic, a multiple of 8 in interpret mode so
    CPU CI exercises the padded-task masking on every run;
  * per-candidate scalars (the NoC energy constant + Eq.-7 budgets) are
    packed into one ``(B, 4)`` array; the per-NoC chain columns
    (bw/links/leak/area, chain order, padded N) and the per-slot
    NoC-attachment indices ride as their own tiles; scalar outputs come
    back as one ``(B, 14)`` column block (``kernel.SCAL_COLS``) plus the
    per-slot and per-NoC bottleneck-seconds telemetry blocks, unpacked
    here;
  * the workload one-hot used for the per-workload latency max is built
    host-side once per trace.

Call it under ``jax.jit`` (the backend does): tracing folds all the
marshalling into the launch, so none of it reruns per dispatch.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.core.phase_sim_jax import EncodedWorkload

from .kernel import N_NOCS, SCAL_COLS, phase_sim_batch

# kernel tile width of the task axis: TPU lanes under Mosaic, one VPU
# sublane row in interpret mode (still > 1 so padded-task masking is
# exercised by CPU CI, without inflating the tiny interpret grids)
LANE = 128
INTERPRET_LANE = 8


def _pad_axis(a: jnp.ndarray, width: int, value) -> jnp.ndarray:
    pad = width - a.shape[-1]
    if pad == 0:
        return a
    return jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, pad)], constant_values=value)


def phase_sim(  # repro: traced
    enc: EncodedWorkload,
    rows: Dict[str, jnp.ndarray],
    *,
    interpret: bool = False,
) -> Dict[str, jnp.ndarray]:
    """Fused-kernel counterpart of ``simulate_batch`` (same contract)."""
    t_real = enc.work_ops.shape[0]
    n_wl = len(enc.wl_names)
    lane = INTERPRET_LANE if interpret else LANE
    t = ((t_real + lane - 1) // lane) * lane

    f32 = jnp.float32
    row1 = lambda a: _pad_axis(jnp.asarray(a, f32)[None, :], t, 0.0)
    work, rd, wr, burst = (
        row1(enc.work_ops), row1(enc.read_bytes), row1(enc.write_bytes), row1(enc.burst)
    )
    pmask = jnp.zeros((t, t), f32).at[:t_real, :t_real].set(
        jnp.asarray(enc.parent_mask, f32)
    )
    wlhot = jnp.zeros((t, n_wl), f32).at[:t_real].set(
        jnp.asarray(np.asarray(enc.wl_id)[:, None] == np.arange(n_wl)[None, :], np.float32)  # repro: noqa[host-sync]: enc.wl_id is host-static workload metadata, folded at trace time
    )

    task_pe = _pad_axis(jnp.asarray(rows["task_pe"], jnp.int32), t, 0)
    task_mem = _pad_axis(jnp.asarray(rows["task_mem"], jnp.int32), t, 0)
    accel = _pad_axis(jnp.asarray(rows["pe_accel"], f32), t, 1.0)

    pe_coeffs = {k: jnp.asarray(rows[k], f32)
                 for k in ("pe_peak", "pe_pj", "pe_leak", "pe_area",
                           "pe_active")}
    pe_coeffs["pe_noc"] = jnp.asarray(rows["pe_noc"], jnp.int32)
    mem_coeffs = {k: jnp.asarray(rows[k], f32)
                  for k in ("mem_bw", "mem_pj", "mem_leak",
                            "mem_area_fixed", "mem_area_per_mb",
                            "mem_active")}
    mem_coeffs["mem_noc"] = jnp.asarray(rows["mem_noc"], jnp.int32)
    noc_arrays = {
        "noc_bw": jnp.asarray(rows["noc_bw"], f32),
        "noc_links": jnp.asarray(rows["noc_links"], jnp.int32),
        "noc_leak": jnp.asarray(rows["noc_leak"], f32),
        "noc_area": jnp.asarray(rows["noc_area"], f32),
        "noc_active": jnp.asarray(rows["noc_active"], f32),
    }
    nocs = jnp.stack(
        [
            jnp.asarray(rows["noc_pj"], f32),
            jnp.asarray(rows["power_budget"], f32),
            jnp.asarray(rows["area_budget"], f32),
            jnp.asarray(rows["alpha"], f32),
        ],
        axis=1,
    )
    assert nocs.shape[1] == N_NOCS
    wlbud = jnp.asarray(rows["wl_budget"], f32)

    finish, bneck, wllat, scal, pe_bneck, mem_bneck, noc_bneck = phase_sim_batch(
        work, rd, wr, burst, pmask, wlhot,
        task_pe, task_mem, accel, pe_coeffs, mem_coeffs, noc_arrays, nocs,
        wlbud, t_real=t_real, interpret=interpret,
    )

    col = {name: scal[:, i] for i, name in enumerate(SCAL_COLS)}
    return {
        "latency_s": col["latency_s"],
        "finish_s": finish[:, :t_real],
        "all_done": col["all_done"] > 0.5,
        "bneck_code": bneck[:, :t_real],
        "bneck_kind_s": jnp.stack(
            [col["kind_pe_s"], col["kind_mem_s"], col["kind_noc_s"]], axis=1
        ),
        "pe_bneck_s": pe_bneck,
        "mem_bneck_s": mem_bneck,
        "noc_bneck_s": noc_bneck,
        "top_bneck_pe": col["top_bneck_pe"].astype(jnp.int32),
        "top_bneck_mem": col["top_bneck_mem"].astype(jnp.int32),
        "alp_time_s": col["alp_time_s"],
        "traffic_bytes": col["traffic_bytes"],
        "n_phases": col["n_phases"].astype(jnp.int32),
        "wl_latency_s": wllat,
        "energy_j": col["energy_j"],
        "power_w": col["power_w"],
        "area_mm2": col["area_mm2"],
        "fitness": col["fitness"],
    }
