"""Task dependency graphs (paper §2.3/§3.1).

A *task* is the smallest unit of simulation. Nodes carry the extended-Gables
software characteristics: work ``f`` (ops), operational intensities
``I_read``/``I_write`` (ops/byte — the paper splits I because modern routers
and memories have separate read/write channels), loop-level parallelism ``llp``
and burst size. Edges carry producer→consumer data movement in bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Tuple


@dataclasses.dataclass(frozen=True)
class Task:
    name: str
    work_ops: float  # f: task work in ops
    i_read: float  # ops per byte read
    i_write: float  # ops per byte written
    llp: float = 1.0  # avg independent loop iterations (loop-level parallelism)
    burst_bytes: float = 64.0  # communication burst size (NoC congestion model)

    @property
    def read_bytes(self) -> float:
        return self.work_ops / max(self.i_read, 1e-30)

    @property
    def write_bytes(self) -> float:
        return self.work_ops / max(self.i_write, 1e-30)

    @property
    def data_bytes(self) -> float:
        """D: total task data transferred (Table 2)."""
        return self.read_bytes + self.write_bytes


class TaskGraph:
    """A DAG of tasks for one workload."""

    def __init__(self, name: str):
        self.name = name
        self.tasks: Dict[str, Task] = {}
        self.parents: Dict[str, List[str]] = {}
        self.children: Dict[str, List[str]] = {}
        self.edge_bytes: Dict[Tuple[str, str], float] = {}
        self._par_cache: Dict[str, List[str]] = {}  # parallel_tasks_of memo
        self._par_set_cache: Dict[str, frozenset] = {}  # parallel_set_of memo

    def add_task(self, task: Task) -> Task:
        assert task.name not in self.tasks, task.name
        self.tasks[task.name] = task
        self.parents.setdefault(task.name, [])
        self.children.setdefault(task.name, [])
        self._par_cache.clear()
        self._par_set_cache.clear()
        return task

    def add_edge(self, src: str, dst: str, nbytes: float = 0.0) -> None:
        assert src in self.tasks and dst in self.tasks
        self.children[src].append(dst)
        self.parents[dst].append(src)
        self.edge_bytes[(src, dst)] = nbytes
        self._par_cache.clear()
        self._par_set_cache.clear()

    # ---- structural queries -------------------------------------------
    def roots(self) -> List[str]:
        return [t for t in self.tasks if not self.parents[t]]

    def topo_order(self) -> List[str]:
        order, seen = [], set()

        def visit(n: str) -> None:
            if n in seen:
                return
            seen.add(n)
            for p in self.parents[n]:
                visit(p)
            order.append(n)

        for n in self.tasks:
            visit(n)
        return order

    def validate(self) -> None:
        order = self.topo_order()
        assert len(order) == len(self.tasks)
        pos = {n: i for i, n in enumerate(order)}
        for (s, d) in self.edge_bytes:
            assert pos[s] < pos[d], f"cycle via {s}->{d}"

    # ---- Gables / domain-awareness metrics (Table 1, Fig. 12) ----------
    def avg_work_ops(self) -> float:
        return sum(t.work_ops for t in self.tasks.values()) / len(self.tasks)

    def avg_data_bytes(self) -> float:
        return sum(t.data_bytes for t in self.tasks.values()) / len(self.tasks)

    def avg_llp(self) -> float:
        return sum(t.llp for t in self.tasks.values()) / len(self.tasks)

    def ancestors(self, name: str) -> set:
        out, stack = set(), list(self.parents[name])
        while stack:
            n = stack.pop()
            if n not in out:
                out.add(n)
                stack.extend(self.parents[n])
        return out

    def concurrent_pairs(self) -> List[Tuple[str, str]]:
        """Task pairs with no ancestor/descendant relation (can run in parallel)."""
        names = list(self.tasks)
        anc = {n: self.ancestors(n) for n in names}
        pairs = []
        for i, a in enumerate(names):
            for b in names[i + 1:]:
                if a not in anc[b] and b not in anc[a]:
                    pairs.append((a, b))
        return pairs

    def talp(self) -> float:
        """(Ta)sk-(L)evel (P)arallelism: number of concurrently runnable task
        combinations (paper counts combinations; we count concurrent pairs + 1
        so a pure chain scores 1, matching CAVA's TaLP=1)."""
        return float(len(self.concurrent_pairs()) + 1) if len(self.tasks) > 1 else 1.0

    def parallel_tasks_of(self, name: str) -> List[str]:
        # memoized: the explorer's Algorithm-1 move selection asks this every
        # iteration, and the O(T²) ancestor walks dominated its host time.
        # The cache clears on any graph edit (add_task/add_edge).
        hit = self._par_cache.get(name)
        if hit is None:
            anc = self.ancestors(name)
            desc = {n for n in self.tasks if name in self.ancestors(n)}
            hit = self._par_cache[name] = [
                n for n in self.tasks if n != name and n not in anc and n not in desc
            ]
        return hit

    def parallel_set_of(self, name: str) -> frozenset:
        """Frozenset view of :meth:`parallel_tasks_of` — the policy layer's
        co-residency checks are set intersections against hosted-task lists,
        and rebuilding a set from the memoized list on every Algorithm-1 move
        selection was the remaining per-iteration graph cost. Same cache
        discipline: cleared on any graph edit."""
        hit = self._par_set_cache.get(name)
        if hit is None:
            hit = self._par_set_cache[name] = frozenset(self.parallel_tasks_of(name))
        return hit


def merge_graphs(graphs: Iterable[TaskGraph], name: str = "combined") -> TaskGraph:
    """A multi-workload SoC runs all TDGs simultaneously (paper §5: 'an SoC
    that runs all three workloads together'). Tasks are namespaced."""
    out = TaskGraph(name)
    for g in graphs:
        for t in g.tasks.values():
            out.add_task(dataclasses.replace(t, name=f"{g.name}.{t.name}"))
        for (s, d), b in g.edge_bytes.items():
            out.add_edge(f"{g.name}.{s}", f"{g.name}.{d}", b)
    return out


def workload_of(task_name: str) -> str:
    """Inverse of the merge namespacing."""
    return task_name.split(".", 1)[0] if "." in task_name else task_name
