"""Training and serving step functions (the jit/pjit roots the launcher and
the multi-pod dry-run lower).

``train_step``: CE loss (+MoE aux) → grads → clip → AdamW. State is a plain
dict {params, opt, step} so shardings mirror parameter shardings exactly.
``prefill_step`` / ``decode_step``: batched serving with KV/SSM cache.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import (
    RunFlags,
    decode_step as model_decode,
    forward,
    forward_hidden,
    head_matrix,
    init_params,
)
from ..sharding.act import constrain
from ..optim import adamw

AUX_LOSS_WEIGHT = 0.01
LOSS_CHUNKS = 8  # sequence chunks for the streamed LM-head CE


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE. logits fp32 (B, S, V); labels int32 (B, S).

    The gold logit is extracted with a masked reduction over the vocab axis
    (NOT take_along_axis): vocab is sharded over the model axis, and a gather
    along a sharded dim makes SPMD all-gather the full fp32 logits
    (~40 GB/device at 1M tokens × 152k vocab); the masked reduce keeps the
    contraction local + one small all-reduce."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    return jnp.mean(logz - gold)


def init_train_state(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    params = init_params(cfg, key, dtype=jnp.float32)
    return {"params": params, "opt": adamw.init(params), "step": jnp.zeros((), jnp.int32)}


def chunked_ce_loss(
    hidden: jax.Array, head_w: jax.Array, labels: jax.Array, n_chunks: int = LOSS_CHUNKS
) -> jax.Array:
    """Streamed LM-head + CE: logits are produced one sequence chunk at a
    time inside a rematerialized scan, so only a (B, S/n, V) fp32 block is
    ever live (fwd *and* bwd) instead of the full (B, S, V) logits."""
    b, s, d = hidden.shape
    while s % n_chunks:
        n_chunks //= 2
    cs = s // n_chunks
    h_chunks = hidden.reshape(b, n_chunks, cs, d).transpose(1, 0, 2, 3)
    l_chunks = labels.reshape(b, n_chunks, cs).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_fn(tot, hl):
        h, lbl = hl
        logits = jnp.einsum("bsd,dv->bsv", h, head_w, preferred_element_type=jnp.float32)
        logits = constrain(logits, ("batch", "seq", "act_vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        gold = jnp.sum(jnp.where(vocab_iota == lbl[..., None], logits, 0.0), axis=-1)
        return tot + jnp.sum(logz - gold), None

    tot, _ = jax.lax.scan(chunk_fn, jnp.zeros((), jnp.float32), (h_chunks, l_chunks))
    return tot / (b * s)


def make_train_step(
    cfg: ModelConfig,
    flags: RunFlags,
    opt_cfg: adamw.AdamWConfig,
    microbatches: int = 1,
):
    """Training step with optional gradient accumulation: the global batch is
    split into ``microbatches`` sequential chunks whose fp32 grads accumulate
    in a params-shaped (fully sharded, small) buffer — the standard lever for
    fitting the L×tokens/device×d_model remat-residual stack in HBM. A FARSI
    swap knob (DistConfig.microbatches)."""

    def loss_fn(params, mb):
        hidden, aux = forward_hidden(params, cfg, mb, flags)
        # re-gather the SP-sharded sequence before the chunked head scan
        hidden = constrain(hidden, ("batch", None, "act_embed"))
        ce = chunked_ce_loss(hidden, head_matrix(params, cfg), mb["labels"])
        return ce + AUX_LOSS_WEIGHT * aux, (ce, aux)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]
        if microbatches == 1:
            (_, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            # batch tensors are (B, S) / (B, S, D) / (3, B, S): split B, make
            # the microbatch dim leading for the accumulation scan
            def split(a):
                bdim = 1 if a.ndim == 3 and a.shape[0] == 3 else 0
                b = a.shape[bdim]
                new = a.reshape(
                    a.shape[:bdim] + (microbatches, b // microbatches) + a.shape[bdim + 1 :]
                )
                return jnp.moveaxis(new, bdim, 0)

            mbs = jax.tree.map(split, batch)
            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )

            def acc(carry, mb):
                g_acc, ce_acc, aux_acc = carry
                (_, (ce, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, ce_acc + ce, aux_acc + aux), None

            (grads, ce, aux), _ = jax.lax.scan(
                acc, (zero_grads, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)), mbs
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            ce, aux = ce / microbatches, aux / microbatches

        new_params, new_opt, om = adamw.update(grads, state["opt"], params, opt_cfg)
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        metrics = {"loss": ce, "aux_loss": aux, **om}
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, flags: RunFlags):
    def prefill_step(params, batch: Dict[str, jax.Array]):
        logits, _, cache = forward(
            params, cfg, batch, flags, compute_dtype=jnp.bfloat16, want_cache=True
        )
        return logits[:, -1], cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, flags: RunFlags):
    def decode_step(params, cache, batch: Dict[str, jax.Array], cur_index: jax.Array):
        logits, new_cache = model_decode(
            params, cfg, cache, batch, cur_index, flags, compute_dtype=jnp.bfloat16
        )
        return logits[:, -1], new_cache

    return decode_step
