"""Continuous-batching DSE serving: N concurrent exploration sessions, one
shared device batch stream, one content-addressed evaluation cache.

Spins up a `DseService`, admits a mix of tenants — different workloads,
policies, and seeds, including replicas of the same request (the repeated-
scenario case the cache exists for) — staggers some arrivals mid-flight,
streams best-design-so-far events as they commit, and reports per-session
winners plus the fleet cache hit-rate.

  PYTHONPATH=src python examples/serve_batch.py [--sessions 12] [--iterations 60]
"""
import argparse
import time

from repro.core import (
    ExplorerConfig,
    HardwareDatabase,
    ar_complex,
    audio,
    calibrated_budget,
)
from repro.serve import DseService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sessions", type=int, default=12,
                    help="total sessions (half admitted up front, half join "
                         "mid-flight)")
    ap.add_argument("--iterations", type=int, default=60)
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the content-addressed DesignStore")
    args = ap.parse_args()

    db = HardwareDatabase()
    budget = calibrated_budget(db)
    graphs = {"audio": audio(), "ar": ar_complex()}
    policies = ("farsi", "bottleneck", "naive_sa")

    svc = DseService(db, backend="jax", cache=not args.no_cache)
    svc_t0 = time.perf_counter()

    def on_event(ev):
        print(f"  [{time.perf_counter() - svc_t0:6.2f}s] {ev.session:<14s} "
              f"iter {ev.iteration:3d}  distance={ev.distance:8.3f}  "
              f"move={ev.move}" + ("  CONVERGED" if ev.converged else ""))

    def submit(i):
        wl = "audio" if i % 2 == 0 else "ar"
        pol = policies[i % len(policies)]
        # seeds repeat every 4 sessions per (workload, policy) mix — replica
        # requests are what the content-addressed cache collapses
        cfg = ExplorerConfig(policy=pol, seed=(i // 2) % 4,
                             max_iterations=args.iterations, backend="jax")
        return svc.submit(f"{wl}.{pol}.{i}", graphs[wl], budget, cfg,
                          on_event=on_event)

    n_head = max(args.sessions // 2, 1)
    handles = [submit(i) for i in range(n_head)]
    print(f"admitted {n_head} sessions up front; "
          f"{args.sessions - n_head} will join mid-flight\n")

    # drive a few ticks, then let latecomers join the live batch stream —
    # the continuous-batching case a lockstep Campaign cannot express
    for _ in range(5):
        svc.step()
    for i in range(n_head, args.sessions):
        handles.append(submit(i))
    stats = svc.run()

    print(f"\n== {stats.n_done}/{stats.n_sessions} sessions done in "
          f"{stats.n_ticks} ticks, {stats.wall_s:.2f}s "
          f"({stats.evals_per_s:,.0f} evals/s aggregate) ==")
    for h in handles:
        r = h.result
        print(f"  {h.name:<16s} iters={r.iterations:3d} "
              f"converged={str(r.converged):<5s} "
              f"distance={r.best_distance.city_block():8.3f}  "
              f"blocks={r.best_design.block_counts()}  "
              f"latency={h.latency_s:.2f}s  events={len(h.events)}")
    print(f"\ncache: hits={stats.cache_hits} misses={stats.cache_misses} "
          f"bypass={stats.cache_bypasses} evictions={stats.cache_evictions} "
          f"hit-rate={stats.cache_hit_rate:.1%}")
    print(f"session latency: p50={stats.latency_percentile(50):.2f}s "
          f"p95={stats.latency_percentile(95):.2f}s; "
          f"fallback evals: {stats.n_fallback}")


if __name__ == "__main__":
    main()
