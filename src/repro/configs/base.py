"""Model/architecture configuration.

One generic decoder stack instantiates every assigned architecture: layers are
grouped into a repeating *cycle* (so heterogeneous stacks like Jamba's 1:7
Mamba:attention interleave scan cleanly over cycles), and each cycle position
declares its sequence mixer ("attn" | "mamba") and its channel mixer
("dense" | "moe" | "none").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    vocab_size: int
    # attention
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    qk_norm: bool = False
    rope_kind: str = "rope"  # "rope" | "mrope" | "none"
    rope_theta: float = 1e4
    mrope_sections: Tuple[int, ...] = (16, 24, 24)  # halves of head_dim
    # channel mixer
    d_ff: int = 0
    mlp_kind: str = "swiglu"  # "swiglu" | "geglu"
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # Mamba-2 (SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    # stack layout: cycle of layer kinds + channel-mixer kinds
    block_kinds: Tuple[str, ...] = ("attn",)
    mlp_kinds: Tuple[str, ...] = ("dense",)
    # IO
    input_mode: str = "tokens"  # "tokens" | "embeddings" (vlm/audio stubs)
    embed_scale: bool = False  # gemma-style sqrt(d_model) embedding scale
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # attention family (long_500k applicability; see DESIGN.md)
    subquadratic: bool = False

    # ---- derived --------------------------------------------------------
    @property
    def cycle_len(self) -> int:
        return len(self.block_kinds)

    @property
    def n_cycles(self) -> int:
        assert self.n_layers % self.cycle_len == 0, (self.name, self.n_layers, self.cycle_len)
        return self.n_layers // self.cycle_len

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def mlp_kind_at(self, pos: int) -> str:
        return self.mlp_kinds[pos % len(self.mlp_kinds)]

    def has_attention(self) -> bool:
        return "attn" in self.block_kinds

    def has_mamba(self) -> bool:
        return "mamba" in self.block_kinds

    def has_moe(self) -> bool:
        return any(k == "moe" for k in self.mlp_kinds)

    # ---- parameter counting (roofline MODEL_FLOPS = 6·N·D) --------------
    def param_counts(self) -> Dict[str, float]:
        D = self.d_model
        per_pos_total = []
        per_pos_active = []
        for pos, kind in enumerate(self.block_kinds):
            p_tot = 2 * D  # two rms norms (approx; mamba-only uses one)
            p_act = 2 * D
            if kind == "attn":
                qkvo = D * self.n_heads * self.head_dim * 2 + D * self.n_kv_heads * self.head_dim * 2
                p_tot += qkvo
                p_act += qkvo
            else:  # mamba2
                d_in = self.ssm_d_inner
                nh = self.ssm_n_heads
                proj = D * (2 * d_in + 2 * self.ssm_state + nh) + d_in * D
                conv = (d_in + 2 * self.ssm_state) * self.ssm_conv_width
                p_tot += proj + conv + 2 * nh + d_in
                p_act += proj + conv + 2 * nh + d_in
            mk = self.mlp_kind_at(pos)
            if mk == "dense":
                n_mats = 2 if self.mlp_kind == "gelu" else 3
                p_tot += n_mats * D * self.d_ff
                p_act += n_mats * D * self.d_ff
            elif mk == "moe":
                f = self.moe_d_ff or self.d_ff
                p_tot += D * self.n_experts + self.n_experts * 3 * D * f
                p_act += D * self.n_experts + self.top_k * 3 * D * f
            per_pos_total.append(p_tot)
            per_pos_active.append(p_act)
        body_tot = self.n_cycles * sum(per_pos_total)
        body_act = self.n_cycles * sum(per_pos_active)
        embed = self.vocab_size * D
        head = 0 if self.tie_embeddings else self.vocab_size * D
        return {
            "total": body_tot + embed + head + D,
            "active": body_act + embed + head + D,
            "embed": embed + head,
            "body": body_tot,
        }


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (input-shape) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (SSM/hybrid only) — pure
    full-attention archs skip it (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        return cfg.subquadratic
    return True
