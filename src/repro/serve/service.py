"""`DseService`: the serve-layer front door.

One service hosts many concurrent, multi-tenant exploration sessions over
shared per-workload backends and one content-addressed
:class:`~repro.serve.store.DesignStore`. Sessions are submitted at any time
(`submit` between ticks is the mid-flight join), priced together by the
:class:`~repro.serve.scheduler.ContinuousBatchScheduler`, stream
best-design-so-far events while running, and deliver a final decoded
winner in their ``ExplorationResult``.

Typical use::

    svc = DseService(db, backend="jax")
    h1 = svc.submit("alice.audio", g_audio, budget, ExplorerConfig(seed=1))
    h2 = svc.submit("bob.audio", g_audio, budget, ExplorerConfig(seed=2))
    svc.run()                      # tick until every session completes
    print(h1.result.best_distance.city_block(), svc.stats().cache_hit_rate)

`DseService.step()` exposes single-tick control for callers interleaving
their own admission logic (arrival traces, latency injection, backpressure).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

from ..core.backend import BackendStats
from ..core.budgets import Budget
from ..core.design import Design
from ..core.explorer import Explorer, ExplorerConfig
from ..core.database import HardwareDatabase
from ..core.tdg import TaskGraph
from .faults import FaultInjector, RetryPolicy, SessionFailed
from .scheduler import BackendSpec, ContinuousBatchScheduler
from .session import BestEvent, Session, SessionRequest
from .store import DesignStore


@dataclasses.dataclass
class ServiceStats:
    """Fleet-level serve accounting, snapshotted by :meth:`DseService.stats`.

    The fault-tolerance block (``n_failed`` … ``n_straggler_ticks``)
    reconciles against a :class:`~repro.serve.faults.FaultInjector`'s
    schedule in the chaos tests and is all-zero on a healthy service."""

    n_sessions: int
    n_done: int
    n_ticks: int
    wall_s: float  # total time inside tick-driving calls (run/step)
    n_evals: int  # candidate evaluations submitted across all backends
    n_fallback: int  # scalar-path evaluations (0 in the array-native regime)
    cache_hits: int
    cache_misses: int
    cache_bypasses: int
    cache_evictions: int
    session_latency_s: List[float]  # completed sessions, admission → done
    # ---- fault tolerance -------------------------------------------------
    n_failed: int = 0  # sessions quarantined to FAILED
    n_degraded: int = 0  # sessions pinned to the PythonBackend fallback
    n_degraded_evals: int = 0  # evaluations priced on fallback backends
    n_restarts: int = 0  # coroutine crash-restarts performed
    n_retries: int = 0  # backed-off per-session dispatch re-attempts
    n_dispatch_faults: int = 0  # dispatch attempts that raised
    n_bisects: int = 0  # shared dispatches split after a fault
    n_deadline_exceeded: int = 0  # sessions failed by their deadline_s SLO
    n_nonfinite_rejected: int = 0  # NaN/Inf candidate rows rejected, never accepted
    n_straggler_ticks: int = 0  # ticks the StepTimeMonitor EMA flagged

    @property
    def cache_hit_rate(self) -> float:
        n = self.cache_hits + self.cache_misses
        return self.cache_hits / n if n else 0.0

    def latency_percentile(self, p: float) -> float:
        """p in [0, 100] over completed-session latencies (0.0 when none)."""
        lats = sorted(self.session_latency_s)
        if not lats:
            return 0.0
        k = min(len(lats) - 1, max(0, round(p / 100.0 * (len(lats) - 1))))
        return lats[k]

    @property
    def evals_per_s(self) -> float:
        return self.n_evals / self.wall_s if self.wall_s > 0 else 0.0


class SessionHandle:
    """User-facing view of one submitted session: poll ``done`` (or
    ``failed``), read the streamed ``events``, and collect the final
    ``result`` after completion. A FAILED session's ``result`` raises
    :class:`~repro.serve.faults.SessionFailed` with the quarantined error
    (also exposed directly as ``error``)."""

    def __init__(self, session: Session) -> None:
        self._session = session

    @property
    def name(self) -> str:
        return self._session.name

    @property
    def done(self) -> bool:
        return self._session.done

    @property
    def failed(self) -> bool:
        return self._session.failed

    @property
    def state(self) -> str:
        return self._session.state

    @property
    def error(self) -> Optional[BaseException]:
        """The error that failed the session (None unless FAILED)."""
        return self._session.error

    @property
    def degraded(self) -> bool:
        """True once the session was pinned to the PythonBackend fallback."""
        return self._session.degraded

    @property
    def events(self) -> List[BestEvent]:
        return self._session.events

    @property
    def latency_s(self) -> float:
        return self._session.latency_s

    @property
    def result(self):
        if self._session.failed:
            raise SessionFailed(
                f"session {self.name!r} failed: {self._session.error!r}"
            ) from self._session.error
        if self._session.result is None:
            raise RuntimeError(
                f"session {self.name!r} has not completed (state="
                f"{self._session.state}); drive DseService.run()/step() first"
            )
        return self._session.result


class DseService:
    """Multi-session DSE serving over one continuous-batching scheduler.

    The evaluation cache defaults ON (a fresh :class:`DesignStore` per
    service); pass ``store=`` to share one across services or
    ``cache=False`` for the uncached baseline. ``backend`` accepts the
    ``make_backend`` registry names or a factory, exactly like ``Campaign``.
    """

    def __init__(
        self,
        db: HardwareDatabase,
        backend: BackendSpec = "jax",
        store: Optional[DesignStore] = None,
        cache: bool = True,
        faults: Optional[FaultInjector] = None,
        retry: Optional[RetryPolicy] = None,
    ) -> None:
        self.db = db
        self.store = store if store is not None else (DesignStore() if cache else None)
        self.scheduler = ContinuousBatchScheduler(
            db, backend, store=self.store, faults=faults, retry=retry
        )
        self._sessions: Dict[str, Session] = {}  # admission order preserved
        self._wall_s = 0.0

    # ---- admission -------------------------------------------------------
    def submit(
        self,
        name: str,
        tdg: TaskGraph,
        budget: Budget,
        config: Optional[ExplorerConfig] = None,
        initial: Optional[Design] = None,
        on_event=None,  # Optional[Callable[[BestEvent], None]]
        deadline_s: Optional[float] = None,
        max_restarts: int = 0,
    ) -> SessionHandle:
        """Admit one exploration session; it joins the next scheduler tick
        (mid-flight joins are the normal case, not an exception).
        ``on_event`` streams the session's BestEvents as they commit;
        ``deadline_s`` is a per-session completion SLO enforced every tick;
        ``max_restarts`` budgets crash-restarts from the last committed
        accept."""
        return self.submit_request(
            SessionRequest(
                name, tdg, budget, config or ExplorerConfig(), initial,
                deadline_s=deadline_s, max_restarts=max_restarts,
            ),
            on_event=on_event,
        )

    def submit_request(self, request: SessionRequest, on_event=None) -> SessionHandle:
        if request.name in self._sessions:
            raise ValueError(f"duplicate session name {request.name!r}")
        explorer = Explorer(
            request.tdg, self.db, request.budget, request.config,
            backend=self.scheduler.backend_for(request.tdg),
        )
        session = Session(request, explorer)
        session.on_event = on_event
        self._sessions[request.name] = session
        self.scheduler.admit(session)
        return SessionHandle(session)

    # ---- drive -----------------------------------------------------------
    def step(self) -> List[SessionHandle]:
        """One scheduler tick; returns handles of sessions that completed."""
        t0 = time.perf_counter()
        done = self.scheduler.tick()
        self._wall_s += time.perf_counter() - t0
        return [SessionHandle(s) for s in done]

    def run(self, max_ticks: Optional[int] = None) -> ServiceStats:
        """Tick until every admitted session completes (or ``max_ticks``),
        drain the backends, and return the service stats snapshot."""
        t0 = time.perf_counter()
        self.scheduler.run_until_idle(max_ticks)
        self.scheduler.flush()
        self._wall_s += time.perf_counter() - t0
        return self.stats()

    # ---- observability ---------------------------------------------------
    @property
    def n_live(self) -> int:
        return self.scheduler.n_live

    def backend_stats(self) -> Dict[str, BackendStats]:
        """Per shared backend, labeled by workload (graph) name — distinct
        graph objects sharing a name get ``#n`` suffixes."""
        labels: Dict[int, str] = {}
        counts: Dict[str, int] = {}
        for s in self._sessions.values():
            key = id(s.request.tdg)
            if key in labels:
                continue
            n = counts.get(s.request.tdg.name, 0)
            labels[key] = s.request.tdg.name if n == 0 else f"{s.request.tdg.name}#{n}"
            counts[s.request.tdg.name] = n + 1
        out = {
            labels.get(k, str(k)): b.stats()
            for k, b in self.scheduler.backends().items()
        }
        for k, b in self.scheduler.fallback_backends().items():
            out[labels.get(k, str(k)) + "~degraded"] = b.stats()
        return out

    def stats(self) -> ServiceStats:
        sched = self.scheduler
        bstats = list(sched.backend_stats().values())
        fstats = [b.stats() for b in sched.fallback_backends().values()]
        sstats = self.store.stats if self.store is not None else None
        return ServiceStats(
            n_sessions=len(self._sessions),
            n_done=sum(1 for s in self._sessions.values() if s.done),
            n_ticks=sched.n_ticks,
            wall_s=self._wall_s,
            n_evals=sum(b.n_sims for b in bstats) + sum(b.n_sims for b in fstats),
            n_fallback=sum(b.n_fallback for b in bstats),
            cache_hits=sstats.hits if sstats else 0,
            cache_misses=sstats.misses if sstats else 0,
            cache_bypasses=sstats.bypasses if sstats else 0,
            cache_evictions=sstats.evictions if sstats else 0,
            session_latency_s=[
                s.latency_s for s in self._sessions.values() if s.done
            ],
            n_failed=sched.n_failed,
            n_degraded=sched.n_degraded,
            n_degraded_evals=sum(b.n_sims for b in fstats),
            n_restarts=sched.n_restarts,
            n_retries=sched.n_retries,
            n_dispatch_faults=sched.n_dispatch_faults,
            n_bisects=sched.n_bisects,
            n_deadline_exceeded=sched.n_deadline_exceeded,
            n_nonfinite_rejected=sum(
                s.n_nonfinite_rejected for s in self._sessions.values()
            ),
            n_straggler_ticks=sched.n_straggler_ticks,
        )

    def results(self) -> Dict[str, object]:
        """Completed sessions' ExplorationResults, in admission order."""
        return {
            name: s.result for name, s in self._sessions.items() if s.done
        }

    def failures(self) -> Dict[str, BaseException]:
        """FAILED sessions' quarantined errors, in admission order."""
        return {
            name: s.error
            for name, s in self._sessions.items()
            if s.failed and s.error is not None
        }
