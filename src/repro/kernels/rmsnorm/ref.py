"""Oracle: same math as models.layers.rms_norm (re-exported for kernel tests)."""
from ...models.layers import rms_norm as rmsnorm_reference  # noqa: F401
