"""Qwen3 1.7B [hf:Qwen/Qwen3-1.7B; family hf:Qwen/Qwen3-8B].

Dense, GQA (16 q / 8 kv heads, head_dim 128), qk-norm (RMSNorm on per-head
q,k before RoPE), SwiGLU. 28L, d_model=2048, d_ff=6144, vocab=151936.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    n_layers=28,
    d_model=2048,
    vocab_size=151936,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    qk_norm=True,
    d_ff=6144,
    mlp_kind="swiglu",
    rope_kind="rope",
    rope_theta=1e6,
    tie_embeddings=True,
    block_kinds=("attn",),
    mlp_kinds=("dense",),
)
