"""SimulatorBackend API: JAX≡Python equivalence, multi-NoC fallback,
one-dispatch-per-iteration Explorer contract, and Campaign aggregation."""
import pytest

from repro.core import (
    Campaign,
    Design,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    JaxBatchedBackend,
    PythonBackend,
    ar_complex,
    audio,
    calibrated_budget,
    edge_detection,
    make_backend,
    random_single_noc_designs,
)
from repro.core.backend import BackendStats
from repro.core.backend import Candidate as JaxCandidate
from repro.core.blocks import make_gpp, make_noc

REL_TOL = 1e-4  # acceptance bar: backends agree on latency within 1e-4


def _multi_noc_design(g):
    """Two-NoC chain: outside the vectorized regime, must take the fallback."""
    d = Design.base(g)
    noc2 = d.add_block(make_noc(), after_noc=d.noc_chain[0])
    pe2 = d.add_block(make_gpp(400), attach_to=noc2.name)
    tasks = sorted(g.tasks)
    for t in tasks[: len(tasks) // 2]:
        d.task_pe[t] = pe2.name
    return d


# ---- equivalence ---------------------------------------------------------
@pytest.mark.parametrize("graph_fn,seed", [(edge_detection, 3), (ar_complex, 5)])
def test_backend_equivalence_randomized(graph_fn, seed):
    """Property-style: random single-NoC designs price identically (within
    float32) through either backend — latency, finish times, power, area."""
    db = HardwareDatabase()
    g = graph_fn()
    designs = random_single_noc_designs(g, 12, seed=seed)
    rp = PythonBackend(g, db).evaluate(designs)
    rj = JaxBatchedBackend(g, db).evaluate(designs)
    for i, (a, b) in enumerate(zip(rp, rj)):
        assert abs(a.latency_s - b.latency_s) / a.latency_s < REL_TOL, i
        for t in a.task_finish_s:
            ref = max(a.task_finish_s[t], 1e-12)
            assert abs(a.task_finish_s[t] - b.task_finish_s[t]) / ref < REL_TOL, (i, t)
        for w in a.workload_latency_s:
            ref = max(a.workload_latency_s[w], 1e-12)
            assert abs(a.workload_latency_s[w] - b.workload_latency_s[w]) / ref < REL_TOL
        assert abs(a.power_w - b.power_w) / a.power_w < 1e-3, i
        assert abs(a.area_mm2 - b.area_mm2) / a.area_mm2 < 1e-6, i
        assert a.mem_capacity_bytes == pytest.approx(b.mem_capacity_bytes)
        # Algorithm-1 inputs must match: bottleneck attribution drives moves
        assert a.task_bottleneck == b.task_bottleneck, i
        assert a.task_bottleneck_block == b.task_bottleneck_block, i
        assert b.total_traffic_bytes == pytest.approx(
            a.total_traffic_bytes, rel=1e-3, abs=1.0
        ), i


def test_jax_backend_prices_multi_noc_natively():
    """Multi-NoC chain designs ride the vectorized path now (no fallback):
    supports() is True, results match Python, and n_fallback stays 0."""
    db = HardwareDatabase()
    g = edge_detection()
    singles = random_single_noc_designs(g, 3, seed=1)
    multi = _multi_noc_design(g)
    jb = JaxBatchedBackend(g, db)
    assert jb.supports(multi) and all(jb.supports(d) for d in singles)

    mixed = [singles[0], multi, singles[1], singles[2]]
    got = jb.evaluate(mixed)
    ref = PythonBackend(g, db).evaluate(mixed)
    for a, b in zip(ref, got):
        assert abs(a.latency_s - b.latency_s) / a.latency_s < REL_TOL
    s = jb.stats()
    assert s.n_sims == 4 and s.n_fallback == 0 and s.n_batched == 4
    assert s.n_dispatches == 1


def test_jax_backend_fallback_beyond_max_noc():
    """Chains the encoding cannot host (> MAX_NOC NoCs) raise the typed
    UnsupportedDesignError inside the backend, which routes exactly those
    candidates to the scalar Python path mid-batch — and the capability
    check survives `python -O` (it is an exception, not an assert)."""
    import pytest as _pytest

    from repro.core.phase_sim_jax import (
        MAX_NOC, EncodedDesign, EncodedWorkload, UnsupportedDesignError,
    )

    db = HardwareDatabase()
    g = edge_detection()
    wide = Design.base(g)
    for _ in range(MAX_NOC):  # chain of MAX_NOC + 1
        wide.add_block(make_noc(), after_noc=wide.noc_chain[-1])
    with _pytest.raises(UnsupportedDesignError):
        EncodedDesign.of(wide, g, db, EncodedWorkload.of(g))

    jb = JaxBatchedBackend(g, db)
    assert not jb.supports(wide)
    single = random_single_noc_designs(g, 1, seed=4)[0]
    got = jb.evaluate([single, wide])
    ref = PythonBackend(g, db).evaluate([single, wide])
    assert got[1].latency_s == ref[1].latency_s  # exact: same scalar path
    assert abs(got[0].latency_s - ref[0].latency_s) / ref[0].latency_s < REL_TOL
    s = jb.stats()
    assert s.n_fallback == 1 and s.n_batched == 1


# ---- explorer contract ---------------------------------------------------
class _CountingBackend:
    """Wraps a backend, recording every dispatch's batch size."""

    def __init__(self, inner):
        self.inner = inner
        self.name = f"counting[{inner.name}]"
        self.tdg, self.db = inner.tdg, inner.db
        self.batches = []

    def supports(self, design):
        return self.inner.supports(design)

    def stats(self):
        return self.inner.stats()

    def evaluate(self, designs):
        self.batches.append(len(designs))
        return self.inner.evaluate(designs)

    def evaluate_candidates(self, cands):
        self.batches.append(len(cands))
        return self.inner.evaluate_candidates(cands)


def test_explorer_one_dispatch_per_iteration():
    db = HardwareDatabase()
    g = edge_detection()
    spy = _CountingBackend(PythonBackend(g, db))
    ex = Explorer(g, db, calibrated_budget(db),
                  ExplorerConfig(max_iterations=25, seed=4), backend=spy)
    res = ex.run()
    # dispatch 0 is the initial design; every search iteration issues at most
    # one evaluate() (exactly one when neighbours were generated)
    assert spy.batches[0] == 1
    assert len(spy.batches) <= res.iterations + 1 + 25  # taboo'd iters skip
    assert all(b >= 1 for b in spy.batches)
    assert sum(spy.batches) == res.n_sims == spy.stats().n_sims
    assert res.sim_wall_s > 0.0


def test_explorer_backend_config_selection():
    db = HardwareDatabase()
    g = edge_detection()
    bud = calibrated_budget(db)
    res_p = Explorer(g, db, bud, ExplorerConfig(max_iterations=15, seed=2)).run()
    res_j = Explorer(
        g, db, bud, ExplorerConfig(max_iterations=15, seed=2, backend="jax")
    ).run()
    assert res_p.backend_name == "python" and res_j.backend_name == "jax"
    # same seed, same decisions modulo float32: the searches track each other
    assert res_j.n_sims == res_p.n_sims
    assert abs(res_j.best_result.latency_s - res_p.best_result.latency_s) / max(
        res_p.best_result.latency_s, 1e-12
    ) < 1e-3
    with pytest.raises(ValueError):
        make_backend("nope", g, db)


# ---- device chain blocks -------------------------------------------------
def test_backend_run_chains_accounting_and_flush():
    """`JaxBatchedBackend.run_chains` prices one fused (R, K) block per
    dispatch and accounts every chain step in the shared stats; handles
    issued before an explicit flush() stay readable after it."""
    from repro.core import ChainRequest

    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    jx = JaxBatchedBackend(g, db)
    d = random_single_noc_designs(g, 1, seed=5)[0]
    block = jx.run_chains(ChainRequest(design=d, budget=bud, r=8, k=16, seed=5))
    assert block.fitness.shape == (8,)
    assert block.move_idx.shape == (8, 16)
    stats = jx.stats()
    assert stats.n_sims == 8 * 16 and stats.n_batched == 8 * 16
    assert stats.n_dispatches == 1 and stats.n_fallback == 0
    assert jx.chain_runner().n_fallback == 0
    # handles issued before an explicit flush stay readable after it
    designs = random_single_noc_designs(g, 3, seed=2)
    cands = [JaxCandidate.of_design(d) for d in designs]
    handles = jx.evaluate_candidates(cands)
    jx.flush()
    assert all(h.result().latency_s > 0 for h in handles)


def test_explorer_run_chains_e2e():
    """`Explorer.run_chains` drives the chain-batched coroutine end to end:
    chained result, per-step history, committed n_sims = R·K per block plus
    the winner's single decode."""
    db = HardwareDatabase()
    g = audio()
    bud = calibrated_budget(db)
    jx = JaxBatchedBackend(g, db)
    ex = Explorer(
        g, db, bud,
        ExplorerConfig(policy="device_sa", max_iterations=32, seed=7,
                       chain_r=8, chain_k=16),
        backend=jx,
    )
    res = ex.run_chains()
    assert res.chained and res.chain_r == 8
    assert res.iterations == 32
    assert len(res.history) == 32
    assert all(h["move"] == "chain_migrate" for h in res.history)
    assert res.n_sims == 8 * 32 + 1  # two blocks of 16 + final decode
    assert res.best_result.latency_s > 0
    assert jx.chain_runner().n_compiles == 1  # one (R, K) shape, one jit


def test_adopt_encoding_invalidates_on_fallback_winner():
    """Accepting a fallback-priced (e.g. topology) move mutates the base
    design without producing a row encoding — adopt_encoding must DROP the
    previously adopted encoding rather than leave a stale one (regression:
    phantom missing-block KeyErrors deep into multi-seed campaigns)."""
    from repro.core.backend import _ReadyHandle
    from repro.core.phase_sim_jax import EncodedDesign

    db = HardwareDatabase()
    g = edge_detection()
    jx = JaxBatchedBackend(g, db)
    d = random_single_noc_designs(g, 1, seed=3)[0]
    cand = JaxCandidate.of_design(d)
    (h,) = jx.evaluate_candidates([cand])
    h.result()
    jx.adopt_encoding(h)
    assert id(d) in jx._adopted
    # same design comes back priced by the fallback path and gets accepted
    ready = _ReadyHandle(h.result(), 0.0, cand)
    jx.adopt_encoding(ready)
    assert id(d) not in jx._adopted
    # and a subsequent dispatch re-encodes from the real object graph
    (h2,) = jx.evaluate_candidates([JaxCandidate.of_design(d)])
    assert abs(h2.result().latency_s - h.result().latency_s) < 1e-12
    # re-adopting the fresh row matches a from-scratch encode of the design
    jx.adopt_encoding(h2)
    fresh = EncodedDesign.of(d, g, db, jx._enc)
    assert set(jx._adopted[id(d)][1].pe_slot) == set(fresh.pe_slot)
    assert set(jx._adopted[id(d)][1].mem_slot) == set(fresh.mem_slot)


# ---- campaign ------------------------------------------------------------
def test_campaign_smoke_two_seeds_two_workloads():
    """2 seeds × 2 workloads: per-run results come back, n_sims aggregates
    exactly, and all runs of one workload share one backend."""
    db = HardwareDatabase()
    g_ed, g_au = edge_detection(), audio()
    bud = calibrated_budget(db)
    camp = Campaign.sweep(
        db, {"ed": g_ed, "audio": g_au}, bud, seeds=(1, 2),
        backend="jax", max_iterations=40,
    )
    res = camp.run()
    assert set(res.runs) == {
        "ed.farsi.s1", "ed.farsi.s2", "audio.farsi.s1", "audio.farsi.s2"
    }
    assert res.aggregate["n_runs"] == 4
    assert res.aggregate["n_converged"] >= 1  # edge_detection converges fast
    assert res.aggregate["n_sims_total"] == sum(r.n_sims for r in res.runs.values())
    # one shared backend per workload, cross-batching all its runs
    assert set(res.backend_stats) == {"ed", "audio"}
    assert isinstance(res.backend_stats["ed"], BackendStats)
    for wl, prefix in (("ed", "ed."), ("audio", "audio.")):
        # every dispatched candidate belongs to exactly one run — the shared
        # backend's count is the sum of the per-run committed n_sims
        per_run = sum(
            r.n_sims for n, r in res.runs.items() if n.startswith(prefix)
        )
        assert res.backend_stats[wl].n_sims == per_run
        # cross-batched: far fewer dispatches than sims (≥2 runs per dispatch)
        assert res.backend_stats[wl].n_dispatches < per_run
    assert res.aggregate["n_sims_total"] == sum(
        s.n_sims for s in res.backend_stats.values()
    )
    assert res.aggregate["sim_wall_s_total"] > 0.0
    assert res.converged_runs()


def test_campaign_distinct_graphs_same_name_keep_separate_stats():
    """Two distinct graph objects sharing a name get distinct backends AND
    distinct backend_stats entries (suffix-disambiguated)."""
    db = HardwareDatabase()
    g1, g2 = edge_detection(), edge_detection()
    bud = calibrated_budget(db)
    camp = (
        Campaign(db)
        .add("a", g1, bud, ExplorerConfig(max_iterations=5))
        .add("b", g2, bud, ExplorerConfig(max_iterations=5))
    )
    res = camp.run()
    assert set(res.backend_stats) == {"ed", "ed#1"}
    assert res.backend_stats["ed"].n_sims == res.runs["a"].n_sims
    assert res.backend_stats["ed#1"].n_sims == res.runs["b"].n_sims


def test_campaign_duplicate_name_rejected():
    db = HardwareDatabase()
    g = edge_detection()
    bud = calibrated_budget(db)
    camp = Campaign(db).add("a", g, bud, ExplorerConfig(max_iterations=5))
    with pytest.raises(ValueError):
        camp.add("a", g, bud, ExplorerConfig(max_iterations=5))
    # a per-run backend that conflicts with the shared campaign backend is
    # refused rather than silently overridden
    with pytest.raises(ValueError):
        camp.add("b", g, bud, ExplorerConfig(max_iterations=5, backend="jax"))
