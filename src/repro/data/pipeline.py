"""Deterministic synthetic LM data pipeline.

Stateless-index design: batch ``i`` is a pure function of (seed, i), so the
pipeline is trivially checkpointable (state = next step index), host-sharded
(each host materializes only its rows), and resume/skip-ahead is O(1) — the
properties a restarted or replaced host needs (DESIGN.md §5 straggler/
fault-tolerance notes).

Token stream: counter-based threefry → Zipf-ish marginal over the vocab (a
uniform stream makes CE trivially flat); labels are next-token shifted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_index: int = 0
    input_mode: str = "tokens"
    d_model: int = 0  # for embeddings mode


class SyntheticLM:
    """Deterministic, seekable synthetic next-token stream."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.n_hosts == 0
        self.cfg = cfg
        self.step = 0

    # ---- checkpointable state ----------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step, "seed": self.cfg.seed}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        assert state["seed"] == self.cfg.seed, "seed mismatch on restore"
        self.step = int(state["step"])

    def skip_to(self, step: int) -> None:
        self.step = step

    # ---- generation ------------------------------------------------------
    def _tokens_for(self, step: int) -> np.ndarray:
        c = self.cfg
        rows = c.global_batch // c.n_hosts
        row0 = c.host_index * rows
        rng = np.random.Generator(
            np.random.Philox(key=c.seed, counter=[0, 0, 0, step])
        )
        # zipf-ish marginal: x ~ U^(alpha) scaled into the vocab
        u = rng.random((c.global_batch, c.seq_len + 1))
        toks = (u**3.0 * (c.vocab_size - 1)).astype(np.int32)
        # mix in a learnable bigram structure: t[i+1] depends on t[i]
        toks[:, 1:] = (toks[:, 1:] + (toks[:, :-1] * 31) % 97) % c.vocab_size
        return toks[row0 : row0 + rows]

    def next_batch(self) -> Dict[str, np.ndarray]:
        toks = self._tokens_for(self.step)
        self.step += 1
        batch: Dict[str, np.ndarray] = {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].copy(),
        }
        if self.cfg.input_mode == "embeddings":
            rng = np.random.Generator(
                np.random.Philox(key=self.cfg.seed + 1, counter=[0, 0, 0, self.step])
            )
            batch["embeds"] = rng.standard_normal(
                (toks.shape[0], self.cfg.seq_len, self.cfg.d_model), dtype=np.float32
            )
            del batch["tokens"]
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()


def for_model(
    cfg: ModelConfig, seq_len: int, global_batch: int, seed: int = 0, **kw
) -> SyntheticLM:
    return SyntheticLM(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=seq_len,
            global_batch=global_batch,
            seed=seed,
            input_mode=cfg.input_mode,
            d_model=cfg.d_model,
            **kw,
        )
    )
