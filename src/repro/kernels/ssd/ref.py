"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) operator
[arXiv:2405.21060, §6 "block decomposition"].

Recurrence (per batch, per head; h ∈ R^{P×N}):
    h_t = exp(dt_t·A) · h_{t-1} + dt_t · x_t ⊗ B_t
    y_t = C_t · h_t
Chunked form: within a chunk of length Q the output is a masked quadratic
(attention-like) matmul against the decay kernel L_ij = exp(cum_i − cum_j);
across chunks a small recurrence carries the (H, P, N) state. This maps the
SSM onto MXU-shaped matmuls — the reason we use SSD rather than Mamba-1's
elementwise scan on TPU (DESIGN.md hardware-adaptation).

All decay/softplus math runs in fp32; A is negative and dt positive, so every
exponent is ≤ 0 (no overflow by construction).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ssd_reference(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — already softplus'd, > 0
    a: jax.Array,  # (H,) — negative
    b_mat: jax.Array,  # (B, S, N)  (single SSM group, broadcast over heads)
    c_mat: jax.Array,  # (B, S, N)
    chunk: int = 64,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B, S, H, P), h_final (B, H, P, N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, q = s // chunk, chunk

    xf = x.astype(jnp.float32).reshape(bsz, nc, q, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, q, h)
    bf = b_mat.astype(jnp.float32).reshape(bsz, nc, q, n)
    cf = c_mat.astype(jnp.float32).reshape(bsz, nc, q, n)
    af = a.astype(jnp.float32)

    da = dtf * af  # (B, nc, Q, H), ≤ 0
    cum = jnp.cumsum(da, axis=2)  # inclusive
    xdt = xf * dtf[..., None]  # (B, nc, Q, H, P)

    # ---- intra-chunk (diagonal blocks): masked decay attention ----------
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B, nc, Q, Q, H)
    mask = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # double-where: masked (upper-tri) diffs are positive → exp overflows and
    # poisons the backward (∂exp at inf × 0 = NaN); zero them *before* exp
    diff = jnp.where(mask, diff, 0.0)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", cf, bf)  # (B, nc, Q, Q)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, xdt)

    # ---- chunk summaries -------------------------------------------------
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (B, nc, Q, H)
    states = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end, bf, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B, nc, H)

    # ---- inter-chunk recurrence ------------------------------------------
    h_init = (
        jnp.zeros((bsz, h, p, n), jnp.float32)
        if h0 is None
        else h0.astype(jnp.float32)
    )

    def step(h_prev, inp):
        st, dec = inp  # (B, H, P, N), (B, H)
        h_next = h_prev * dec[:, :, None, None] + st
        return h_next, h_prev  # emit the state *entering* the chunk

    states_t = states.transpose(1, 0, 2, 3, 4)  # (nc, B, H, P, N)
    decay_t = chunk_decay.transpose(1, 0, 2)  # (nc, B, H)
    h_final, h_befores = jax.lax.scan(step, h_init, (states_t, decay_t))
    h_befores = h_befores.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", cf, jnp.exp(cum), h_befores)

    y = (y_intra + y_inter).reshape(bsz, s, h, p).astype(x.dtype)
    return y, h_final.astype(jnp.float32)


def ssd_decode_step(
    h: jax.Array,  # (B, H, P, N) fp32 state
    x: jax.Array,  # (B, H, P)
    dt: jax.Array,  # (B, H) — softplus'd
    a: jax.Array,  # (H,)
    b_vec: jax.Array,  # (B, N)
    c_vec: jax.Array,  # (B, N)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrent update (serving path). Returns (y (B,H,P), h)."""
    da = jnp.exp(dt.astype(jnp.float32) * a.astype(jnp.float32))  # (B, H)
    upd = jnp.einsum(
        "bh,bhp,bn->bhpn", dt.astype(jnp.float32), x.astype(jnp.float32), b_vec.astype(jnp.float32)
    )
    h_new = h * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_vec.astype(jnp.float32))
    return y.astype(x.dtype), h_new


def ssd_naive(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_mat: jax.Array,
    c_mat: jax.Array,
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Token-by-token recurrence — the ground truth the chunked form must
    match (property tests sweep chunk sizes against this)."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    h_state = (
        jnp.zeros((bsz, h, p, n), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )

    def step(h_prev, inp):
        xt, dtt, bt, ct = inp
        y, h_next = ssd_decode_step(h_prev, xt, dtt, a, bt, ct)
        return h_next, y

    xs = (
        x.transpose(1, 0, 2, 3),
        dt.transpose(1, 0, 2),
        b_mat.transpose(1, 0, 2),
        c_mat.transpose(1, 0, 2),
    )
    h_final, ys = jax.lax.scan(step, h_state, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h_final
