"""Vectorized phase simulator ≡ the Python reference (single-NoC regime)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Design, HardwareDatabase, ar_complex, edge_detection, random_single_noc_designs, simulate
from repro.core.phase_sim_jax import EncodedWorkload, encode_batch, simulate_batch


@pytest.mark.parametrize("graph_fn", [edge_detection, ar_complex])
def test_vectorized_matches_python(graph_fn):
    db = HardwareDatabase()
    g = graph_fn()
    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, 8, seed=3)
    rows = encode_batch(designs, g, db, enc)
    out = jax.jit(lambda r: simulate_batch(enc, r))(rows)
    assert bool(out["all_done"].all())
    for i, d in enumerate(designs):
        ref = simulate(d, g, db)
        got = float(out["latency_s"][i])
        assert abs(got - ref.latency_s) / ref.latency_s < 1e-3, (i, got, ref.latency_s)
        # per-task finish times agree too
        for j, name in enumerate(enc.names):
            a, b = float(out["finish_s"][i, j]), ref.task_finish_s[name]
            assert abs(a - b) / max(b, 1e-12) < 1e-3
        # device-side PPA columns agree with the host rollup (f32 sums)
        assert abs(float(out["power_w"][i]) - ref.power_w) / ref.power_w < 1e-3
        assert abs(float(out["area_mm2"][i]) - ref.area_mm2) / ref.area_mm2 < 1e-4
        for w, lat in ref.workload_latency_s.items():
            got_wl = float(out["wl_latency_s"][i, enc.wl_names.index(w)])
            assert abs(got_wl - lat) / max(lat, 1e-12) < 1e-3


def test_device_side_fitness_matches_host_distance():
    """The kernel's Eq.-7 fitness column equals budgets.distance().fitness()
    computed from the decoded result (the explorer ranks by this column)."""
    from repro.core import calibrated_budget
    from repro.core.budgets import distance
    from repro.core.phase_sim_jax import fill_budget

    db = HardwareDatabase()
    g = ar_complex()
    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, 6, seed=11)
    bud = calibrated_budget(db)
    alpha = 0.05
    rows = encode_batch(designs, g, db, enc)
    for j in range(len(designs)):
        fill_budget(rows, j, enc, bud.latency_s, bud.power_w, bud.area_mm2, alpha)
    out = jax.jit(lambda r: simulate_batch(enc, r))(rows)
    for i, d in enumerate(designs):
        ref = distance(simulate(d, g, db), bud).fitness(alpha)
        got = float(out["fitness"][i])
        assert abs(got - ref) / max(abs(ref), 1e-9) < 1e-3, (i, got, ref)


def test_batch_throughput_smoke():
    """One jit'd call evaluates a whole neighbour batch (the Fig-8 answer)."""
    db = HardwareDatabase()
    g = edge_detection()
    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, 32, seed=9)
    rows = encode_batch(designs, g, db, enc)
    out = jax.jit(lambda r: simulate_batch(enc, r))(rows)
    assert out["latency_s"].shape == (32,)
    assert bool(jnp.isfinite(out["latency_s"]).all())
    assert out["fitness"].shape == (32,)
