"""Pluggable simulation backends: one `evaluate(designs) -> results` API.

The paper's headline claim is an *agile* simulator (8,400X vs Platform
Architect at 98.5% accuracy) driving the DSE, and its own profile (Fig. 8)
puts 79.9% of exploration time in design evaluation overhead. This module
makes the evaluator a pluggable component behind a single batched interface
so the search loop never cares how a design is priced:

  ``PythonBackend``     — the reference phase-driven simulator
                          (`phase_sim.simulate`), one design at a time.
  ``JaxBatchedBackend`` — flat-array encodings evaluated under `vmap` in one
                          XLA dispatch per batch (`phase_sim_jax`), with a
                          jit cache keyed on power-of-two padded slot/batch
                          shapes and a transparent per-design fallback to the
                          Python path for designs outside the vectorized
                          regime (multi-NoC topologies).

`Explorer` submits every iteration's neighbour set through one
``backend.evaluate`` call; `Campaign` goes further and cross-batches pending
requests from many concurrent explorations into single dispatches. Both
backends must agree on latency/finish times (asserted in
tests/test_backend_campaign.py); simulation-count and wall-clock accounting
live here, in ``BackendStats``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from .blocks import BlockKind
from .database import HardwareDatabase
from .design import Design
from .phase_sim import SimResult, simulate
from .ppa import total_leakage_w
from .tdg import TaskGraph, workload_of

_BNECK_KINDS = ("pe", "mem", "noc")


@dataclasses.dataclass
class BackendStats:
    """Evaluation accounting — the backend owns n_sims and sim wall-clock."""

    n_sims: int = 0  # designs evaluated
    n_dispatches: int = 0  # evaluate() calls
    n_batched: int = 0  # designs through the vectorized path
    n_fallback: int = 0  # designs through the scalar Python path
    n_compiles: int = 0  # distinct padded shapes seen by the jit cache
    wall_s: float = 0.0  # total time inside evaluate()


@runtime_checkable
class SimulatorBackend(Protocol):
    """Anything that prices a batch of designs for one task graph."""

    name: str
    tdg: TaskGraph
    db: HardwareDatabase

    def evaluate(self, designs: Sequence[Design]) -> List[SimResult]:
        """Simulate every design; results align with the input order."""
        ...

    def supports(self, design: Design) -> bool:
        """True if ``design`` takes the backend's fast path (capability hook;
        unsupported designs must still evaluate correctly via fallback)."""
        ...

    def stats(self) -> BackendStats:
        ...


class PythonBackend:
    """Scalar reference path: `phase_sim.simulate` per design."""

    name = "python"

    def __init__(self, tdg: TaskGraph, db: HardwareDatabase) -> None:
        self.tdg = tdg
        self.db = db
        self._stats = BackendStats()

    def supports(self, design: Design) -> bool:
        return True

    def evaluate(self, designs: Sequence[Design]) -> List[SimResult]:
        t0 = time.perf_counter()
        out = [simulate(d, self.tdg, self.db) for d in designs]
        self._stats.n_sims += len(out)
        self._stats.n_dispatches += 1
        self._stats.wall_s += time.perf_counter() - t0
        return out

    def stats(self) -> BackendStats:
        return self._stats


def _pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


def _bucket(n: int) -> int:
    """Padded-size bucket: power of two, floored at 8. Compile time per shape
    dwarfs the padded FLOPs on these tiny kernels, so we buy a near-constant
    shape space (slots and batch rarely leave {8, 16, 32, 64}) with padding."""
    return max(8, _pow2(n))


class JaxBatchedBackend:
    """One `vmap` dispatch per batch of single-NoC designs.

    Latency/finish times come from the vectorized phase loop; the rest of
    ``SimResult`` is reconstructed exactly on the host: PPA rollups are
    O(blocks) closed forms, and per-task dynamic energy depends only on total
    drained work (every task runs to completion), not on phase rates.
    Designs outside the single-NoC regime fall back to the Python simulator
    per design, inside the same ``evaluate`` call.
    """

    name = "jax"

    def __init__(self, tdg: TaskGraph, db: HardwareDatabase) -> None:
        import jax

        from .phase_sim_jax import EncodedWorkload, simulate_batch

        self.tdg = tdg
        self.db = db
        self._enc = EncodedWorkload.of(tdg)
        self._fn = jax.jit(lambda *a: simulate_batch(self._enc, *a))
        self._shapes: set = set()
        self._stats = BackendStats()
        # static per-task tables for host-side SimResult reconstruction:
        # totals are design-independent; only the block subtype scales energy
        names = self._enc.names
        self._ops = [float(tdg.tasks[n].work_ops) for n in names]
        self._rw = [float(tdg.tasks[n].read_bytes + tdg.tasks[n].write_bytes) for n in names]
        self._wbytes = [float(tdg.tasks[n].write_bytes) for n in names]
        self._wl_of = [workload_of(n) if "." in n else tdg.name for n in names]
        e = db.energy
        self._pe_pj = {"acc": e.acc_pj_per_op, "gpp": e.gpp_pj_per_op}
        self._mem_pj = {"sram": e.sram_pj_per_byte, "dram": e.dram_pj_per_byte}
        self._noc_pj = e.noc_pj_per_byte_hop

    def supports(self, design: Design) -> bool:
        return len(design.noc_chain) == 1

    def stats(self) -> BackendStats:
        return self._stats

    # ------------------------------------------------------------------
    def evaluate(self, designs: Sequence[Design]) -> List[SimResult]:
        t0 = time.perf_counter()
        results: List[Optional[SimResult]] = [None] * len(designs)
        fast = [i for i, d in enumerate(designs) if self.supports(d)]
        fast_set = set(fast)
        for i in range(len(designs)):
            if i not in fast_set:
                results[i] = simulate(designs[i], self.tdg, self.db)
                self._stats.n_fallback += 1
        if fast:
            self._evaluate_batch([designs[i] for i in fast], fast, results)
        self._stats.n_sims += len(designs)
        self._stats.n_dispatches += 1
        self._stats.wall_s += time.perf_counter() - t0
        return results  # type: ignore[return-value]

    def _evaluate_batch(
        self, batch: List[Design], idx: List[int], results: List[Optional[SimResult]]
    ) -> None:
        import jax

        from .phase_sim_jax import encode_batch

        # pad slots and batch to power-of-two buckets: the jit cache then sees
        # a handful of shapes over a whole exploration instead of one per
        # block-count the moves walk through. Slot counts are bounded by the
        # task count (moves allocate at most ~one block per task), so pinning
        # the shared PE/MEM slot bucket at pow2(T) collapses that shape axis
        # to one entry per workload; only the batch axis still varies.
        need = max(max(len(d.pes()), len(d.mems())) for d in batch)
        slots = _bucket(max(need, len(self._enc.names)))
        n_pe = n_mem = slots
        arrays = list(encode_batch(batch, self.tdg, self.db, self._enc, n_pe, n_mem))
        b = len(batch)
        b_pad = _bucket(b)
        if b_pad > b:
            arrays = [
                np.concatenate([a, np.repeat(a[:1], b_pad - b, axis=0)]) for a in arrays
            ]
        key = (b_pad, n_pe, n_mem)
        if key not in self._shapes:
            self._shapes.add(key)
            self._stats.n_compiles += 1
        out = jax.device_get(self._fn(*arrays))  # one host transfer for all outputs
        lat = out["latency_s"]
        finish = out["finish_s"]
        bneck = out["bneck_code"]
        kind_s = out["bneck_kind_s"]
        alp = out["alp_time_s"]
        traffic = out["traffic_bytes"]
        nph = out["n_phases"]
        for j, i in enumerate(idx):
            results[i] = self._decode(
                batch[j], float(lat[j]), finish[j], bneck[j], kind_s[j],
                float(alp[j]), float(traffic[j]), int(nph[j]),
            )
            self._stats.n_batched += 1

    # ------------------------------------------------------------------
    def _decode(
        self,
        design: Design,
        latency: float,
        finish: np.ndarray,
        bneck: np.ndarray,
        kind_s: np.ndarray,
        alp_time: float,
        traffic: float,
        n_phases: int,
    ) -> SimResult:
        tdg, db = self.tdg, self.db
        names = self._enc.names
        blocks, d_pe, d_mem = design.blocks, design.task_pe, design.task_mem
        noc = design.noc_chain[0]
        fin = finish.tolist()
        codes = bneck.tolist()
        finish_s = dict(zip(names, fin))
        task_bneck = {n: _BNECK_KINDS[c] for n, c in zip(names, codes)}
        task_bneck_block = {
            n: d_pe[n] if c == 0 else (d_mem[n] if c == 1 else noc)
            for n, c in zip(names, codes)
        }
        # dynamic energy is rate-independent: every task drains its full
        # (ops, read, write) totals, and hops == 1 in the single-NoC regime
        pe_pj, mem_pj, noc_pj = self._pe_pj, self._mem_pj, self._noc_pj
        task_energy_pj = {
            n: pe_pj[blocks[d_pe[n]].subtype] * self._ops[k]
            + (mem_pj[blocks[d_mem[n]].subtype] + noc_pj) * self._rw[k]
            for k, n in enumerate(names)
        }
        energy_j = sum(task_energy_pj.values()) * 1e-12 + total_leakage_w(
            design, db
        ) * latency
        wl_latency: Dict[str, float] = {}
        for w, f in zip(self._wl_of, fin):
            if f > wl_latency.get(w, 0.0):
                wl_latency[w] = f
        # fused mem-capacity + area rollup (ppa.mem_capacities/total_area_mm2
        # recomputed here with the precomputed write-bytes table)
        cap: Dict[str, float] = {m: 0.0 for m in design.mems()}
        for k, n in enumerate(names):
            cap[d_mem[n]] += self._wbytes[k]
        area = 0.0
        for bname, blk in blocks.items():
            if blk.kind == BlockKind.MEM and blk.subtype == "sram":
                area += db.area.sram_mm2_per_mb * max(cap[bname], 1.0) / 1e6
            else:
                area += db.block_area_mm2(blk)
        return SimResult(
            latency_s=latency,
            workload_latency_s=wl_latency,
            energy_j=energy_j,
            power_w=energy_j / latency if latency > 0 else 0.0,
            area_mm2=area,
            n_phases=n_phases,
            bottleneck_s={k: float(kind_s[i]) for i, k in enumerate(_BNECK_KINDS)},
            task_bottleneck=task_bneck,
            task_finish_s=finish_s,
            mem_capacity_bytes=cap,
            task_bottleneck_block=task_bneck_block,
            task_energy_j={n: e * 1e-12 for n, e in task_energy_pj.items()},
            avg_accel_parallelism=alp_time / latency if latency > 0 else 1.0,
            total_traffic_bytes=traffic,
        )


BACKENDS = {
    "python": PythonBackend,
    "jax": JaxBatchedBackend,
    "jax_batched": JaxBatchedBackend,
}


def make_backend(name: str, tdg: TaskGraph, db: HardwareDatabase) -> SimulatorBackend:
    """Instantiate a registered backend by name (`ExplorerConfig.backend`)."""
    try:
        cls = BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; known: {sorted(BACKENDS)}") from None
    return cls(tdg, db)
