"""Analytic per-device roofline accounting for one (arch × shape × mesh ×
DistConfig) cell.

Three terms per §Roofline:

  compute    = device_FLOPs / 197e12        (v5e bf16 MXU peak)
  memory     = device_HBM_bytes / 819e9
  collective = device_ICI_bytes / (50e9 per link)

Why analytic rather than whole-graph ``cost_analysis()``: XLA's HLO cost
analysis visits a while-loop body once, so every scanned structure (layer
cycles, microbatches, flash blocks, loss chunks) is undercounted by its trip
count. We account per-op with explicit formulas (each op also becomes a task
in the FARSI step-TDG, core/tpu_design.py), and validate against a
compositional HLO lowering (single cycle body × trip count) in tests — see
EXPERIMENTS.md §Roofline methodology.

All numbers are per device, per step. Conventions:
 * matmul FLOPs = 2·M·N·K; backward = 2× forward; remat="full" re-runs the
   forward inside backward (+1×).
 * the blockwise/flash attention reference computes the full S² extent and
   masks (static trip counts); the Pallas kernel skips fully-masked blocks.
   We report both: ``attn_waste`` carries the difference so MODEL_FLOPS /
   HLO_FLOPs exposes it.
 * collectives: ring cost (n-1)/n ≈ 1 per hop omitted; all-reduce counts 2×
   payload (reduce-scatter + all-gather), matching HLO-parse conventions.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from ..configs.base import ModelConfig, ShapeConfig
from ..sharding.rules import DistConfig

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW_PER_LINK = 50e9  # bytes/s per link
ICI_LINKS = 1  # conservative single-link baseline (knob for §Perf)
# inter-pod (data-center) links: slower and fewer than intra-pod ICI — only
# the 'pod'-axis share of the gradient reduction crosses them
DCI_BW = 25e9  # bytes/s per inter-pod link
DCI_LINKS_PER_POD = 8

BF16 = 2
FP32 = 4


@dataclasses.dataclass
class OpCost:
    """One step-graph op, per device."""

    name: str
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0
    deps: tuple = ()


@dataclasses.dataclass
class MeshShape:
    data: int  # product of ('pod', 'data')
    model: int
    pods: int = 1  # how many pods the data product spans

    @property
    def chips(self) -> int:
        return self.data * self.model


def interpod_term(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshShape, dist=None) -> float:
    """Seconds of inter-pod traffic per step: with batch over ('pod','data'),
    only the gradient reduction crosses pods — each pod exchanges its full
    (model-sharded) gradient partial once over the DCI links (ring over
    pods). Serving shapes cross nothing (requests are pod-local)."""
    if mesh.pods <= 1 or shape.kind != "train":
        return 0.0
    grad_b = 1.0 if (dist and dist.grad_compress == "int8") else FP32
    per_pod_bytes = cfg.param_counts()["total"] / mesh.model * grad_b
    ring = 2 * (mesh.pods - 1) / mesh.pods
    return per_pod_bytes * ring / (DCI_BW * DCI_LINKS_PER_POD)


def _bwd_mult(kind: str, remat: str) -> float:
    """Total (fwd+bwd[+remat]) multiplier over forward FLOPs."""
    base = {"train": 3.0, "prefill": 1.0, "decode": 1.0}[kind]
    if kind == "train" and remat == "full":
        base += 1.0
    return base


def step_costs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    mesh: MeshShape,
    dist: Optional[DistConfig] = None,
) -> List[OpCost]:
    remat = dist.remat if dist else "full"
    kernel_attn = bool(dist and dist.attn_impl == "kernel")
    d, hq, kh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kind = shape.kind
    mult = _bwd_mult(kind, remat)
    chips = mesh.chips
    # TP on/off comes from the sharding rules (the autotuner's migrate knob):
    # with TP off the model axis becomes extra data parallelism — weights are
    # replicated (×model HBM traffic) but per-layer boundary collectives vanish.
    tp = True if dist is None else (dist.rules.get("qkv", ("model",)) is not None)
    n_model_w = mesh.model if tp else 1
    kv_sharded = tp and kh > 0 and kh % mesh.model == 0

    b, s = shape.global_batch, shape.seq_len
    tokens = b * (1 if kind == "decode" else s)
    t_dev = tokens / mesh.data / (1 if kind != "decode" else 1)
    # tokens are replicated across the model axis (TP splits the *work*)
    ops: List[OpCost] = []

    wbytes = BF16  # weights are consumed in bf16
    abytes = BF16

    def add(name, flops_g=0.0, hbm=0.0, ici=0.0, deps=()):
        ops.append(OpCost(name, flops_g, hbm, ici, deps))

    # ---- embedding ------------------------------------------------------
    if cfg.input_mode == "tokens":
        add(
            "embed",
            flops_g=0.0,
            hbm=t_dev * d * abytes + t_dev * 4,  # activation write + token read
        )
    else:
        add("embed", hbm=t_dev * d * abytes * 2)

    # ---- per cycle-position ops ------------------------------------------
    seq_len_ctx = shape.seq_len  # kv extent for decode
    prev = "embed"
    for pos, kindb in enumerate(cfg.block_kinds):
        tag = f"L{pos}"
        n_rep = cfg.n_cycles
        if kindb == "attn":
            # qkv + out projections
            q_flops = 2 * tokens * d * hq * dh
            kv_rep = mesh.model if (tp and not kv_sharded) else 1  # replicated kv compute
            kv_flops = 2 * tokens * d * kh * dh * 2 * kv_rep
            o_flops = 2 * tokens * hq * dh * d
            proj_flops = (q_flops + kv_flops + o_flops) * mult / chips
            w_proj = (d * hq * dh + hq * dh * d) / n_model_w + 2 * d * kh * dh / (
                n_model_w if kv_sharded else 1
            )
            reads = mult if kind == "train" else 1
            add(
                f"{tag}.attn_proj",
                flops_g=n_rep * proj_flops,
                hbm=n_rep
                * (w_proj * wbytes * reads * (dist.microbatches if dist and kind == "train" else 1)
                   + t_dev * d * abytes * 2 * mult),
                deps=(prev,),
            )
            # attention core
            if kind == "decode":
                core = 2 * b * hq * dh * seq_len_ctx * 2  # qk + pv over cache
                # cache sharded over (batch×data, heads-or-dh×model): full read
                kv_b = (
                    1.0 + 2.0 / dh  # int8 payload + bf16 scale per (tok, head)
                    if (dist and dist.kv_quant == "int8")
                    else BF16
                )
                cache_rd = b * seq_len_ctx * kh * dh * 2 * kv_b / chips
                add(
                    f"{tag}.attn_core",
                    flops_g=n_rep * core / chips,
                    hbm=n_rep * cache_rd,
                    deps=(f"{tag}.attn_proj",),
                )
            else:
                full = 4 * b * s * s * hq * dh  # qk^T + pv, full extent
                causal = full / 2
                executed = causal if kernel_attn else full
                # flash bwd ≈ 2.5× fwd (5 block matmuls vs 2): total fwd+bwd
                # (+remat fwd) = mult + 0.5 in units of fwd
                attn_mult = (mult + 0.5) if kind == "train" else mult
                add(
                    f"{tag}.attn_core",
                    flops_g=n_rep * executed * attn_mult / chips,
                    hbm=n_rep * t_dev * hq * dh * abytes * 2 * mult,
                    deps=(f"{tag}.attn_proj",),
                )
            # TP boundary collectives (SP: ag+rs ≈ all-reduce payload)
            tp_bytes = 2 * t_dev * d * abytes * mult if (tp and mesh.model > 1) else 0.0
            add(f"{tag}.attn_tp", ici=n_rep * tp_bytes, deps=(f"{tag}.attn_core",))
            prev_mixer = f"{tag}.attn_tp"
        else:  # mamba2 (SSD)
            d_in = cfg.ssm_d_inner
            nh_ss = cfg.ssm_n_heads
            n_ss = cfg.ssm_state
            p_ss = cfg.ssm_head_dim
            proj = 2 * tokens * d * (2 * d_in + 2 * n_ss + nh_ss) + 2 * tokens * d_in * d
            if kind == "decode":
                ssd = 2 * b * (d_in * n_ss * 2)  # state update + emit
            else:
                q_chunk = dist.ssd_chunk if dist else 64
                per_tok_head = 2 * q_chunk * p_ss + 4 * p_ss * n_ss
                ssd = tokens * nh_ss * per_tok_head + tokens * 2 * q_chunk * n_ss
            state_bytes = b * nh_ss * p_ss * n_ss * FP32 / chips if kind == "decode" else 0
            add(
                f"{tag}.ssm",
                flops_g=n_rep * (proj + ssd) * mult / chips,
                hbm=n_rep
                * (
                    (d * (2 * d_in + 2 * n_ss + nh_ss) + d_in * d)
                    / n_model_w
                    * wbytes
                    * (mult if kind == "train" else 1)
                    * (dist.microbatches if dist and kind == "train" else 1)
                    + t_dev * d * abytes * 2 * mult
                    + state_bytes
                ),
                deps=(prev,),
            )
            tp_bytes = 2 * t_dev * d * abytes * mult if (tp and mesh.model > 1) else 0.0
            add(f"{tag}.ssm_tp", ici=n_rep * tp_bytes, deps=(f"{tag}.ssm",))
            prev_mixer = f"{tag}.ssm_tp"

        mk = cfg.mlp_kind_at(pos)
        if mk == "dense":
            n_mats = 2 if cfg.mlp_kind == "gelu" else 3
            f_flops = n_mats * 2 * tokens * d * cfg.d_ff
            add(
                f"{tag}.mlp",
                flops_g=n_rep * f_flops * mult / chips,
                hbm=n_rep
                * (
                    n_mats * d * cfg.d_ff / n_model_w * wbytes
                    * (mult if kind == "train" else 1)
                    * (dist.microbatches if dist and kind == "train" else 1)
                    + t_dev * d * abytes * 2 * mult
                ),
                deps=(prev_mixer,),
            )
            tp_b = 2 * t_dev * d * abytes * mult if (tp and mesh.model > 1) else 0.0
            add(f"{tag}.mlp_tp", ici=n_rep * tp_b, deps=(f"{tag}.mlp",))
            prev = f"{tag}.mlp_tp"
        elif mk == "moe":
            fe = cfg.moe_d_ff or cfg.d_ff
            cf = (dist.capacity_factor if dist and dist.capacity_factor > 0 else cfg.capacity_factor)
            disp = tokens * cfg.top_k * cf
            r_flops = 2 * tokens * d * cfg.n_experts
            e_flops = 3 * 2 * disp * d * fe
            ep = cfg.n_experts % mesh.model == 0  # EP vs expert-TP (independent of TP)
            w_moe = cfg.n_experts * 3 * d * fe / (mesh.model if (ep or tp) else 1)
            # dispatched activations live sequence/batch-sharded over ALL
            # chips (SP keeps the residual stream model-sharded too), so
            # per-device dispatch traffic divides by chips, not just data
            a2a_quant = getattr(dist, "a2a_bytes", BF16) if dist else BF16
            add(
                f"{tag}.moe",
                flops_g=n_rep * (r_flops + e_flops) * mult / chips,
                hbm=n_rep
                * (
                    w_moe * wbytes * (mult if kind == "train" else 1)
                    * (dist.microbatches if dist and kind == "train" else 1)
                    + (disp / chips) * d * abytes * 2 * mult
                ),
                deps=(prev_mixer,),
            )
            # EP all-to-all (dispatch+combine); expert-TP pays TP all-reduce
            if ep:
                a2a = 2 * (disp / chips) * d * a2a_quant * mult
            elif tp:
                a2a = 2 * t_dev * d * abytes * mult
            else:
                a2a = 0.0
            add(f"{tag}.moe_a2a", ici=n_rep * a2a, deps=(f"{tag}.moe",))
            prev = f"{tag}.moe_a2a"
        else:
            prev = prev_mixer

    # ---- head + loss ------------------------------------------------------
    head_tokens = tokens if kind == "train" else b
    h_flops = 2 * head_tokens * d * cfg.vocab_size * (mult if kind == "train" else 1)
    add(
        "head",
        flops_g=h_flops / chips,
        hbm=d * cfg.vocab_size / mesh.model * wbytes
        + head_tokens / mesh.data * cfg.vocab_size * FP32 / mesh.model,
        deps=(prev,),
    )

    # ---- optimizer + gradient sync (train only) ----------------------------
    if kind == "train":
        p_total = cfg.param_counts()["total"]
        p_local = p_total / chips  # fully sharded state (TP×FSDP)
        add(
            "optimizer",
            flops_g=p_local * 12,
            hbm=p_local * (FP32 * 3 * 2 + FP32),  # p,m,v read+write, grad read
            deps=("head",),
        )
        # FSDP weight all-gather (bf16, fwd+bwd) + grad reduce-scatter
        # (fp32, or int8+scale with error-feedback compression)
        fsdp = mesh.data > 1
        grad_b = 1.0 if (dist and dist.grad_compress == "int8") else FP32
        ag = 2 * p_total / chips * BF16 if fsdp else 0.0
        rs = p_total / chips * grad_b * (1 if fsdp else 2)
        add("grad_sync", ici=ag + rs, deps=("head",))

    return ops


def roofline_terms(ops: List[OpCost], ici_links: int = ICI_LINKS) -> Dict[str, float]:
    f = sum(o.flops for o in ops)
    h = sum(o.hbm_bytes for o in ops)
    c = sum(o.ici_bytes for o in ops)
    t_f = f / PEAK_FLOPS
    t_h = h / HBM_BW
    t_c = c / (ICI_BW_PER_LINK * ici_links)
    dom = max(("compute", t_f), ("memory", t_h), ("collective", t_c), key=lambda kv: kv[1])
    return {
        "flops": f,
        "hbm_bytes": h,
        "ici_bytes": c,
        "t_compute_s": t_f,
        "t_memory_s": t_h,
        "t_collective_s": t_c,
        "t_roofline_s": max(t_f, t_h, t_c),
        "dominant": dom[0],
    }


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N_active·tokens (dense/MoE; +causal attention term).
    The 'useful work' yardstick for the MODEL_FLOPS/HLO_FLOPs ratio."""
    n_active = cfg.param_counts()["active"]
    if shape.kind == "decode":
        tokens = shape.global_batch
        per_tok = 2 * n_active
        attn = 0.0
        if cfg.has_attention():
            n_attn = sum(1 for k in cfg.block_kinds if k == "attn") * cfg.n_cycles
            attn = 4 * tokens * cfg.n_heads * cfg.head_dim * shape.seq_len * n_attn / 2
        return per_tok * tokens + attn
    tokens = shape.global_batch * shape.seq_len
    mult = 6 if shape.kind == "train" else 2  # 2·N fwd (+4·N bwd) per token
    base = mult * n_active * tokens
    attn = 0.0
    if cfg.has_attention():
        n_attn = sum(1 for k in cfg.block_kinds if k == "attn") * cfg.n_cycles
        # causal qk^T+pv = 4·B·S²·H·Dh / 2 forward; ×(mult/2) for bwd
        attn = (
            (mult / 2)
            * 4
            * shape.global_batch
            * shape.seq_len**2
            * cfg.n_heads
            * cfg.head_dim
            * n_attn
            / 2
        )
    return base + attn
