"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only the dry-run subprocess forces 512."""
import os
import sys

import jax
import pytest

# repo root on sys.path: tests reuse benchmark helpers (benchmarks.*)
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
