"""Straggler and failure detection.

SPMD steps are globally synchronous — a straggler host slows every step, and
a dead host kills the step entirely. Detection is therefore host-local and
cheap: (1) a per-host heartbeat file (mtime-based liveness for the launcher /
supervisor), (2) an EMA step-time monitor that flags outlier steps, and
(3) a supervisor loop that converts a detected failure into
checkpoint-restore, optionally onto a shrunken mesh (runtime/elastic.py).

On real pods the same hooks ride the cluster scheduler's health signals; the
file-based transport here lets the whole recovery path run (and be tested)
in one process.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional

import jax


@dataclasses.dataclass
class StepStats:
    step: int
    duration_s: float
    is_straggler: bool
    ema_s: float


class StepTimeMonitor:
    """EMA step-time tracker; flags steps slower than ``threshold×`` EMA."""

    def __init__(self, ema_decay: float = 0.9, threshold: float = 2.0, warmup: int = 3):
        self.ema_decay = ema_decay
        self.threshold = threshold
        self.warmup = warmup
        self.ema: Optional[float] = None
        self.n = 0
        self.flagged: List[StepStats] = []

    def record(self, step: int, duration_s: float) -> StepStats:
        self.n += 1
        if self.ema is None:
            self.ema = duration_s
        is_straggler = (
            self.n > self.warmup and duration_s > self.threshold * self.ema
        )
        if not is_straggler:  # don't poison the EMA with outliers
            self.ema = self.ema_decay * self.ema + (1 - self.ema_decay) * duration_s
        stats = StepStats(step, duration_s, is_straggler, self.ema)
        if is_straggler:
            self.flagged.append(stats)
        return stats


class Heartbeat:
    """File-mtime liveness: each host touches its file; anyone can audit."""

    def __init__(self, directory: str, host_id: int):
        self.dir = directory
        self.host_id = host_id
        os.makedirs(directory, exist_ok=True)
        self.path = os.path.join(directory, f"host_{host_id:05d}.hb")

    def beat(self, step: int) -> None:
        with open(self.path, "w") as f:
            json.dump({"step": step, "t": time.time()}, f)

    @staticmethod
    def dead_hosts(directory: str, timeout_s: float, now: Optional[float] = None) -> List[int]:
        now = now if now is not None else time.time()
        dead = []
        for name in sorted(os.listdir(directory)):
            if not name.endswith(".hb"):
                continue
            path = os.path.join(directory, name)
            try:
                with open(path) as f:
                    t = json.load(f)["t"]
            except Exception:
                t = 0.0
            if now - t > timeout_s:
                dead.append(int(name.split("_")[1].split(".")[0]))
        return dead


class Supervisor:
    """Run a step function with failure → checkpoint-restore recovery.

    ``step_fn(state, batch) -> (state, metrics)`` may raise (a real device
    failure surfaces as an exception from the collective); the supervisor
    restores the last checkpoint, rewinds the data pipeline, and continues —
    the contract examples/fault_tolerance.py and tests exercise with
    injected faults."""

    def __init__(self, ckpt_manager, data, save_every: int = 10):
        self.ckpt = ckpt_manager
        self.data = data
        self.save_every = save_every
        self.monitor = StepTimeMonitor()
        self.recoveries = 0

    def run(
        self,
        state,
        step_fn: Callable,
        n_steps: int,
        restore_fn: Callable,
        on_metrics: Optional[Callable[[int, Dict], None]] = None,
    ):
        step = int(jax.device_get(state["step"])) if "step" in state else 0
        while step < n_steps:
            batch = self.data.next_batch()
            t0 = time.perf_counter()
            try:
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            except Exception:
                # failure: restore last durable state, rewind data, retry
                self.recoveries += 1
                self.ckpt.wait()
                state, meta = restore_fn()
                step = meta["step"]
                self.data.skip_to(meta["extra"].get("data_step", step))
                continue
            self.monitor.record(step, time.perf_counter() - t0)
            step += 1
            if on_metrics:
                on_metrics(step, metrics)
            if step % self.save_every == 0:
                self.ckpt.save(step, state, extra={"data_step": self.data.step})
        self.ckpt.wait()
        return state
