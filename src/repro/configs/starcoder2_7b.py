"""StarCoder2-7B [arXiv:2402.19173; hf:bigcode/starcoder2-7b].

Dense code model: 32L, d_model=4608, 36 q / 4 kv heads (GQA), RoPE,
d_ff=18432, vocab=49152. (The release uses sliding-window attention in half
the layers; the assignment specifies the dense-GQA backbone, which we follow.)
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    n_layers=32,
    d_model=4608,
    vocab_size=49152,
    n_heads=36,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18432,
    mlp_kind="gelu",  # StarCoder2 uses an ungated GELU FFN (d_ff = 4·d_model)
    rope_kind="rope",
    rope_theta=1e5,
    block_kinds=("attn",),
    mlp_kinds=("dense",),
)
