"""Qwen2-VL 2B [arXiv:2409.12191; hf:Qwen/Qwen2-VL-2B-Instruct].

VLM *backbone only* per the assignment: 28L, d_model=1536, 12 q / 2 kv heads
(head_dim 128), d_ff=8960, vocab=151936, M-RoPE (multimodal rotary: the
head_dim halves are split into temporal/height/width sections rotated by
separate position ids). The vision frontend is a stub — ``input_specs()``
provides precomputed patch embeddings of shape (batch, seq, d_model) plus the
(3, batch, seq) M-RoPE position ids.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    n_layers=28,
    d_model=1536,
    vocab_size=151936,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    mlp_kind="swiglu",
    rope_kind="mrope",
    rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    input_mode="embeddings",
    tie_embeddings=True,
    block_kinds=("attn",),
    mlp_kinds=("dense",),
)
