"""Vectorized phase-driven simulator: evaluate a *batch* of SA neighbours in
one `vmap`'d XLA call.

The paper profiles its DSE at 79.9% design-duplication overhead (Fig. 8) —
a Python object-copy problem. We remove the object graph entirely: a design
is a flat array encoding (task→PE map, task→MEM map, per-slot knobs), the
TDG is dense matrices, and the phase loop is a `lax.fori_loop` (every phase
retires ≥1 task, so ≤T phases). `vmap` over the design axis then evaluates
all candidate neighbours of an explorer iteration — or entire populations —
in one dispatch; on TPU this turns the DSE inner loop into batched vector
ops.

Scope: single-NoC designs (every PE/MEM on one bus — the regime our AR
explorations live in; multi-NoC topologies fall back to the Python
simulator). Equivalence against `phase_sim.simulate` is asserted in tests
for this regime.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .blocks import BlockKind
from .database import HardwareDatabase
from .design import Design
from .tdg import TaskGraph

BIG = 1e30


@dataclasses.dataclass
class EncodedWorkload:
    """Static per-workload tensors (shared across all candidate designs)."""

    work_ops: jnp.ndarray  # (T,)
    read_bytes: jnp.ndarray  # (T,)
    write_bytes: jnp.ndarray  # (T,)
    burst: jnp.ndarray  # (T,)
    llp: jnp.ndarray  # (T,)
    parent_mask: jnp.ndarray  # (T, T) bool: [i, j] = j is a parent of i
    names: List[str]

    @staticmethod
    def of(g: TaskGraph) -> "EncodedWorkload":
        names = list(g.tasks)
        idx = {n: i for i, n in enumerate(names)}
        t = len(names)
        pm = np.zeros((t, t), bool)
        for n in names:
            for p in g.parents[n]:
                pm[idx[n], idx[p]] = True
        f = lambda attr: jnp.asarray([getattr(g.tasks[n], attr) for n in names], jnp.float32)
        return EncodedWorkload(
            work_ops=f("work_ops"),
            read_bytes=jnp.asarray([g.tasks[n].read_bytes for n in names], jnp.float32),
            write_bytes=jnp.asarray([g.tasks[n].write_bytes for n in names], jnp.float32),
            burst=f("burst_bytes"),
            llp=f("llp"),
            parent_mask=jnp.asarray(pm),
            names=names,
        )


@dataclasses.dataclass
class EncodedDesign:
    """Flat design encoding: (task maps, per-slot knobs). All (T,) / (S,)."""

    task_pe: np.ndarray  # (T,) int32 PE slot per task
    task_mem: np.ndarray  # (T,) int32 MEM slot per task
    pe_peak: np.ndarray  # (S_pe,) ops/s at a=1 (freq × ops/cycle)
    pe_accel: np.ndarray  # (T,) effective acceleration of the task's PE for it
    mem_bw: np.ndarray  # (S_mem,) bytes/s
    noc_bw: np.ndarray  # () bytes/s (single NoC, per link)
    noc_links: int

    @staticmethod
    def of(design: Design, g: TaskGraph, db: HardwareDatabase, enc: EncodedWorkload) -> "EncodedDesign":
        assert len(design.noc_chain) == 1, "vectorized sim: single-NoC regime"
        # single pass over blocks: slot index maps + peak rates (this runs per
        # candidate design in the DSE inner loop — keep it allocation-light)
        pe_i: Dict[str, int] = {}
        mem_i: Dict[str, int] = {}
        pe_peak: List[float] = []
        mem_bw: List[float] = []
        for n, b in design.blocks.items():
            if b.kind == BlockKind.PE:
                pe_i[n] = len(pe_peak)
                pe_peak.append(db.pe_peak_ops(b))
            elif b.kind == BlockKind.MEM:
                mem_i[n] = len(mem_bw)
                mem_bw.append(b.peak_bandwidth(db))
        t = len(enc.names)
        d_pe, d_mem, blocks, tasks = design.task_pe, design.task_mem, design.blocks, g.tasks
        task_pe = np.fromiter((pe_i[d_pe[n]] for n in enc.names), np.int32, t)
        task_mem = np.fromiter((mem_i[d_mem[n]] for n in enc.names), np.int32, t)
        accel = np.ones(t, np.float32)
        for k, n in enumerate(enc.names):
            b = blocks[d_pe[n]]
            if b.hardened_for == n and b.subtype == "acc":
                accel[k] = db.a_peak(n, tasks[n].llp, b.unroll)
        noc = blocks[design.noc_chain[0]]
        return EncodedDesign(
            task_pe=task_pe,
            task_mem=task_mem,
            pe_peak=np.asarray(pe_peak, np.float32),
            pe_accel=accel,
            mem_bw=np.asarray(mem_bw, np.float32),
            noc_bw=np.float32(noc.peak_bandwidth(db)),
            noc_links=int(noc.n_links),
        )


def _segment_share(values: jnp.ndarray, seg: jnp.ndarray, n_seg: int, mask: jnp.ndarray):
    """Per-element share = value / segment_total(value) over masked elements."""
    v = jnp.where(mask, values, 0.0)
    totals = jax.ops.segment_sum(v, seg, num_segments=n_seg)
    return values / jnp.maximum(totals[seg], 1e-30)


def simulate_batch(
    enc: EncodedWorkload,
    task_pe: jnp.ndarray,  # (B, T) int32
    task_mem: jnp.ndarray,  # (B, T)
    pe_peak: jnp.ndarray,  # (B, S_pe)
    pe_accel: jnp.ndarray,  # (B, T)
    mem_bw: jnp.ndarray,  # (B, S_mem)
    noc_bw: jnp.ndarray,  # (B,)
    noc_links: jnp.ndarray,  # (B,) int32
) -> Dict[str, jnp.ndarray]:
    """vmap'd phase simulation.

    Returns latency (B,), task finish times (B, T), and the per-task /
    per-phase attribution a :class:`~repro.core.backend.JaxBatchedBackend`
    needs to reconstruct a full ``SimResult``: the binding-resource code of
    each task at retirement (0=pe, 1=mem, 2=noc — mirroring
    ``gables.bottleneck_of``), time-weighted bottleneck seconds per class,
    accelerator-level parallelism time, bytes moved, and the phase count.
    """

    t = enc.work_ops.shape[0]
    n_pe = pe_peak.shape[-1]
    n_mem = mem_bw.shape[-1]

    def one(task_pe, task_mem, pe_peak, pe_accel, mem_bw, noc_bw, noc_links):
        def phase(_, state):
            remain, completed, now, finish, bneck, kind_s, alp_t, traffic, nph = state
            done_parents = jnp.all(~enc.parent_mask | completed[None, :], axis=1)
            running = (~completed) & done_parents
            any_run = jnp.any(running)

            # Eq. 1/2: preemptive equal share per PE slot
            load = jax.ops.segment_sum(
                jnp.where(running, 1.0, 0.0), task_pe, num_segments=n_pe
            )
            compute = pe_peak[task_pe] * pe_accel / jnp.maximum(load[task_pe], 1.0)

            # Eq. 4: burst-proportional memory share (read/write channels split)
            mem_share = _segment_share(enc.burst, task_mem, n_mem, running)
            m_bw = mem_bw[task_mem] * mem_share

            # Eq. 3: round-robin link striping, burst arbitration within link
            order = jnp.cumsum(jnp.where(running, 1, 0)) - 1  # rank among running
            link = jnp.where(running, order % jnp.maximum(noc_links, 1), 0)
            l_share = _segment_share(enc.burst, link, 8, running)
            n_bw = noc_bw * l_share

            rd_bw = jnp.minimum(m_bw, n_bw)
            wr_bw = jnp.minimum(m_bw, n_bw)
            comp_t = remain[:, 0] / compute
            rd_t = remain[:, 1] / rd_bw
            wr_t = remain[:, 2] / wr_bw
            c_t = jnp.maximum(comp_t, jnp.maximum(rd_t, wr_t))
            c_t = jnp.where(running, c_t, BIG)
            phi = jnp.min(c_t)  # Eq. 6
            phi = jnp.where(any_run, phi, 0.0)

            # binding resource per running task (gables.bottleneck_of — note:
            # attribution uses the task's *total* work over current rates, not
            # the remaining work; compute wins ties, then mem vs noc by the
            # tighter pipe)
            tot_comp_t = enc.work_ops / compute
            tot_rd_t = enc.read_bytes / rd_bw
            tot_wr_t = enc.write_bytes / wr_bw
            code = jnp.where(
                tot_comp_t >= jnp.maximum(tot_rd_t, tot_wr_t),
                0,
                jnp.where(m_bw <= n_bw, 1, 2),
            )
            kind_s = kind_s + jax.ops.segment_sum(
                jnp.where(running, phi, 0.0), code, num_segments=3
            )

            rates = jnp.stack([compute, rd_bw, wr_bw], axis=1)
            dec = jnp.where(running[:, None], rates * phi, 0.0)
            drained = jnp.maximum(remain - dec, 0.0)  # post-drain, pre-retire
            newly_done = running & (c_t <= phi * (1 + 1e-9))
            new_remain = jnp.where(newly_done[:, None], 0.0, drained)
            now = now + phi
            finish = jnp.where(newly_done, now, finish)
            bneck = jnp.where(newly_done, code, bneck)
            alp_t = alp_t + phi * jnp.sum(load > 0)
            # phase_sim accumulates min(post-drain bytes, bw·phi) per running
            # task — mirror it exactly so the backends agree on this field too
            traffic = traffic + jnp.sum(
                jnp.where(
                    running,
                    jnp.minimum(drained[:, 1] + drained[:, 2], dec[:, 1] + dec[:, 2]),
                    0.0,
                )
            )
            nph = nph + jnp.where(any_run, 1, 0)
            return (
                new_remain, completed | newly_done, now, finish,
                bneck, kind_s, alp_t, traffic, nph,
            )

        remain0 = jnp.stack([enc.work_ops, enc.read_bytes, enc.write_bytes], axis=1)
        state = (
            remain0,
            jnp.zeros((t,), bool),
            jnp.float32(0.0),
            jnp.zeros((t,), jnp.float32),
            jnp.zeros((t,), jnp.int32),
            jnp.zeros((3,), jnp.float32),
            jnp.float32(0.0),
            jnp.float32(0.0),
            jnp.int32(0),
        )
        (remain, completed, now, finish, bneck, kind_s, alp_t, traffic, nph) = (
            jax.lax.fori_loop(0, t, phase, state)
        )
        return {
            "latency_s": now,
            "finish_s": finish,
            "all_done": jnp.all(completed),
            "bneck_code": bneck,
            "bneck_kind_s": kind_s,
            "alp_time_s": alp_t,
            "traffic_bytes": traffic,
            "n_phases": nph,
        }

    return jax.vmap(one)(task_pe, task_mem, pe_peak, pe_accel, mem_bw, noc_bw, noc_links)


def encode_batch(
    designs: List[Design],
    g: TaskGraph,
    db: HardwareDatabase,
    enc: EncodedWorkload,
    n_pe: int = 0,
    n_mem: int = 0,
):
    """Pad a list of single-NoC designs to a common slot count and stack.

    ``n_pe``/``n_mem`` optionally force the padded slot counts — backends pad
    to shape buckets so the jit cache is keyed on a handful of shapes instead
    of recompiling every time a move adds a block. Returns host (numpy)
    arrays; `jax.jit` transfers them on dispatch.
    """
    encs = [EncodedDesign.of(d, g, db, enc) for d in designs]
    b, t = len(encs), len(enc.names)
    n_pe = max(n_pe, max(e.pe_peak.shape[0] for e in encs))
    n_mem = max(n_mem, max(e.mem_bw.shape[0] for e in encs))

    # preallocate padded buffers and fill (pad value 1.0 keeps unused slots
    # free of div-by-zero; they host no tasks so they never contribute)
    task_pe = np.empty((b, t), np.int32)
    task_mem = np.empty((b, t), np.int32)
    pe_accel = np.empty((b, t), np.float32)
    pe_peak = np.ones((b, n_pe), np.float32)
    mem_bw = np.ones((b, n_mem), np.float32)
    noc_bw = np.empty((b,), np.float32)
    noc_links = np.empty((b,), np.int32)
    for i, e in enumerate(encs):
        task_pe[i] = e.task_pe
        task_mem[i] = e.task_mem
        pe_accel[i] = e.pe_accel
        pe_peak[i, : e.pe_peak.shape[0]] = e.pe_peak
        mem_bw[i, : e.mem_bw.shape[0]] = e.mem_bw
        noc_bw[i] = e.noc_bw
        noc_links[i] = e.noc_links
    return task_pe, task_mem, pe_peak, pe_accel, mem_bw, noc_bw, noc_links
