"""Core layers: RMSNorm, RoPE / M-RoPE, GQA attention (full, blockwise
flash-style, and decode), SwiGLU/GeGLU MLPs.

Conventions: activations (batch, seq, ...) in ``compute_dtype`` (bf16);
normalization statistics, rotary math, attention logits/softmax and router
logits in fp32 (mixed-precision policy). Attention tensors are
(B, S, H, head_dim); GQA repeats are expressed via reshape-to-groups einsums
(never materializing repeated KV).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope_inv_freq(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., Dh) with cos/sin broadcastable to (..., Dh/2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    inv = rope_inv_freq(x.shape[-1], theta)  # (Dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: Tuple[int, ...],
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the Dh/2 frequency slots are split into
    temporal/height/width ``sections``; each section rotates by its own
    position stream. x: (B, S, H, Dh); positions: (3, B, S) int32."""
    dh = x.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    inv = rope_inv_freq(dh, theta)  # (Dh/2,)
    ang_all = positions.astype(jnp.float32)[..., None] * inv  # (3, B, S, Dh/2)
    pieces = []
    start = 0
    for axis, sec in enumerate(sections):
        pieces.append(ang_all[axis, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(pieces, axis=-1)  # (B, S, Dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    return _rotate(x, cos, sin)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, K, G, Dh) grouped query; k: (B, Skv, K, Dh) →
    scores (B, K, G, Sq, Skv), fp32."""
    return jnp.einsum(
        "bqkgd,bskd->bkgqs", q.astype(jnp.float32), k.astype(jnp.float32)
    )


def attention_full(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
) -> jax.Array:
    """Reference attention (materializes S² scores — smoke tests / oracle).
    q: (B, Sq, H, Dh); k, v: (B, Skv, K, Dh); returns (B, Sq, H, Dh)."""
    b, sq, h, dh = q.shape
    kheads = k.shape[2]
    g = h // kheads
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, sq, kheads, g, dh)
    scores = _gqa_scores(qg, k) * scale  # (B, K, G, Sq, Skv)
    if causal:
        skv = k.shape[1]
        rows = jnp.arange(sq)[:, None] + q_offset
        cols = jnp.arange(skv)[None, :]
        scores = jnp.where(rows >= cols, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, h, dh)


def attention_blockwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_block: int = 512,
    kv_block: int = 1024,
    causal: bool = True,
) -> jax.Array:
    """FlashAttention-style blockwise attention in pure JAX (the memory-sane
    reference the dry-run lowers; the Pallas kernel is the TPU-optimized
    twin). Online softmax over kv blocks, scanned over q blocks: peak live
    score tensor is (B, K, G, q_block, kv_block).

    Fully-masked kv blocks (strictly above the diagonal) still *compute* and
    are then masked — trip counts stay static so XLA cost analysis remains
    exact; the kernel skips them properly on TPU (see DESIGN.md §Roofline).
    """
    b, s, h, dh = q.shape
    kheads = k.shape[2]
    g = h // kheads
    assert s % q_block == 0 and s % kv_block == 0, (s, q_block, kv_block)
    nq, nkv = s // q_block, s // kv_block
    scale = 1.0 / math.sqrt(dh)

    qs = q.reshape(b, nq, q_block, kheads, g, dh).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(b, nkv, kv_block, kheads, dh).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(b, nkv, kv_block, kheads, dh).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_idx):
        qi, iq = qi_idx  # qi: (B, q_block, K, G, Dh)
        rows = iq * q_block + jnp.arange(q_block)  # (q_block,)

        def kv_step(carry, kv_idx):
            m, l, acc = carry
            (kj, vj, jk) = kv_idx
            cols = jk * kv_block + jnp.arange(kv_block)
            s_blk = (
                jnp.einsum(
                    "bqkgd,bskd->bkgqs",
                    qi.astype(jnp.float32),
                    kj.astype(jnp.float32),
                )
                * scale
            )
            if causal:
                mask = rows[:, None] >= cols[None, :]
                s_blk = jnp.where(mask[None, None, None], s_blk, NEG_INF)
            m_new = jnp.maximum(m, s_blk.max(axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kheads, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kheads, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kheads, g, q_block, dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (ks, vs, jnp.arange(nkv))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, K, G, q_block, Dh)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, q_block, K, G, Dh)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    # outs: (nq, B, q_block, K, G, Dh)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h, dh)
    return out.astype(q.dtype)


def attention_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    cur_index: jax.Array,
) -> jax.Array:
    """One-token decode against a (B, S, K, Dh) KV cache; positions strictly
    after ``cur_index`` are masked. q: (B, 1, H, Dh)."""
    b, _, h, dh = q.shape
    kheads = k_cache.shape[2]
    g = h // kheads
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, 1, kheads, g, dh)
    scores = _gqa_scores(qg, k_cache) * scale  # (B, K, G, 1, S)
    valid = jnp.arange(k_cache.shape[1])[None, :] <= cur_index  # (1, S) vs scalar
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dh)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------
def mlp_apply(params: dict, x: jax.Array, kind: str) -> jax.Array:
    """SwiGLU / GeGLU gated MLP, or plain GELU FFN: (B, S, D) → (B, S, D)."""
    gate = jnp.einsum("bsd,df->bsf", x, params["wi_gate"])
    if kind == "swiglu":
        act = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    elif kind in ("geglu", "gelu"):
        act = jax.nn.gelu(gate.astype(jnp.float32), approximate=True).astype(x.dtype)
    else:
        raise KeyError(kind)
    if kind != "gelu":  # gated variants multiply by the up projection
        act = act * jnp.einsum("bsd,df->bsf", x, params["wi_up"])
    return jnp.einsum("bsf,fd->bsd", act, params["wo"])


def mlp_init(key: jax.Array, d_model: int, d_ff: int, dtype, kind: str = "swiglu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    p = {
        "wi_gate": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "wo": (jax.random.normal(k3, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if kind != "gelu":
        p["wi_up"] = (jax.random.normal(k2, (d_model, d_ff)) * s_in).astype(dtype)
    return p
