"""Paper Fig. 17 (§6.2): divide-and-conquer suboptimality.

Myopic budgeting: split the system budget across workloads a priori
(power-proportional, as the paper does from isolated estimates), optimize
each workload in isolation, and compose. Full-fledged FARSI: one exploration
over the merged TDG with the system budget. Report the power/area degradation
of the composed design vs holistic FARSI."""
from __future__ import annotations

from typing import List

from repro.core import (
    Budget,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    all_workloads,
    ar_complex,
    calibrated_budget,
)

from .common import Row

# isolated power estimates → a-priori budget split (paper problem 1)
MYOPIC_SHARE = {"audio": 0.15, "cava": 0.6, "ed": 0.25}


def run() -> List[Row]:
    db = HardwareDatabase()
    sys_budget = calibrated_budget(db)
    rows: List[Row] = []

    # --- holistic ---------------------------------------------------------
    res_h = Explorer(ar_complex(), db, sys_budget, ExplorerConfig(max_iterations=600, seed=4)).run()
    p_h, a_h = res_h.best_result.power_w, res_h.best_result.area_mm2

    # --- myopic: optimize each workload against its slice ------------------
    p_m = a_m = 0.0
    met = []
    for name, g in all_workloads().items():
        bud = Budget(
            latency_s={name: sys_budget.latency_s[name]},
            power_w=sys_budget.power_w * MYOPIC_SHARE[name],
            area_mm2=sys_budget.area_mm2 * MYOPIC_SHARE[name],
        )
        res = Explorer(g, db, bud, ExplorerConfig(max_iterations=400, seed=4)).run()
        p_m += res.best_result.power_w
        a_m += res.best_result.area_mm2
        met.append(f"{name}:dist={res.best_distance.city_block():.2f}")

    rows.append(
        (
            "fig17.holistic",
            0.0,
            f"power={p_h*1e3:.1f}mW area={a_h:.2f}mm2 converged={res_h.converged}",
        )
    )
    rows.append(
        (
            "fig17.myopic_budgeting",
            0.0,
            f"power={p_m*1e3:.1f}mW area={a_m:.2f}mm2 "
            f"degradation_power={100*(p_m-p_h)/p_h:.0f}% "
            f"degradation_area={100*(a_m-a_h)/a_h:.0f}% [{' '.join(met)}]",
        )
    )
    return rows
