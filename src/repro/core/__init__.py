"""FARSI core: the paper's contribution (hybrid simulator + aware explorer).

Public API re-exports. See DESIGN.md §2 for the paper→TPU mapping.
"""
from .backend import (
    BackendStats,
    Candidate,
    JaxBatchedBackend,
    PythonBackend,
    SimHandle,
    SimTelemetry,
    SimulatorBackend,
    make_backend,
)
from .blocks import Block, BlockKind, make_accelerator, make_gpp, make_mem, make_noc
from .budgets import Budget, Distance, distance
from .campaign import Campaign, CampaignResult, RunSpec
from .codesign import CodesignLedger, FocusRecord
from .database import HardwareDatabase, TPUDatabase
from .design import Design
from .design_space import random_single_noc_designs
from .device_explore import (
    ChainBlockResult,
    ChainCarry,
    ChainRequest,
    DeviceChainRunner,
    MoveTable,
    reconcile_alloc,
)
from .event_sim import simulate_events
from .explorer import AWARENESS_LEVELS, ExplorationResult, Explorer, ExplorerConfig
from .gables import TaskRates, bottleneck_of, completion_time, phase_rates
from .phase_sim import SimResult, simulate
from .policy import (
    POLICIES,
    BottleneckRelaxation,
    DevCostPolicy,
    DeviceSA,
    FarsiPolicy,
    Focus,
    HeuristicPolicy,
    LocalityExploitation,
    NaiveSA,
    make_policy,
)
from .tdg import Task, TaskGraph, merge_graphs, workload_of
from .workloads import (
    Scenario,
    all_workloads,
    ar_complex,
    audio,
    calibrated_budget,
    cava,
    edge_detection,
    paper_budget,
    synthetic_family,
)

__all__ = [
    "BackendStats",
    "Block",
    "BlockKind",
    "Budget",
    "Campaign",
    "CampaignResult",
    "Candidate",
    "ChainBlockResult",
    "ChainCarry",
    "ChainRequest",
    "CodesignLedger",
    "Design",
    "DeviceChainRunner",
    "DeviceSA",
    "MoveTable",
    "SimHandle",
    "JaxBatchedBackend",
    "PythonBackend",
    "RunSpec",
    "SimulatorBackend",
    "Distance",
    "ExplorationResult",
    "Explorer",
    "ExplorerConfig",
    "FocusRecord",
    "HardwareDatabase",
    "SimResult",
    "TPUDatabase",
    "Task",
    "TaskGraph",
    "TaskRates",
    "AWARENESS_LEVELS",
    "POLICIES",
    "BottleneckRelaxation",
    "DevCostPolicy",
    "FarsiPolicy",
    "Focus",
    "HeuristicPolicy",
    "LocalityExploitation",
    "NaiveSA",
    "Scenario",
    "SimTelemetry",
    "all_workloads",
    "ar_complex",
    "audio",
    "bottleneck_of",
    "calibrated_budget",
    "cava",
    "completion_time",
    "distance",
    "edge_detection",
    "make_accelerator",
    "make_backend",
    "make_policy",
    "synthetic_family",
    "make_gpp",
    "make_mem",
    "make_noc",
    "merge_graphs",
    "paper_budget",
    "phase_rates",
    "random_single_noc_designs",
    "simulate",
    "simulate_events",
    "workload_of",
]
