"""Seeded fault injection + fault-tolerance primitives for the serve layer.

The ROADMAP's "thousands of sessions" goal makes the serve path's failure
behaviour a first-class property: one poisoned candidate, a non-finite
fitness row, or a transient backend hiccup must cost *its* session — never
the tick, never the service. This module provides both sides of that
contract:

  * the **policy surface** the scheduler enforces — :class:`RetryPolicy`
    (capped exponential backoff + the K-consecutive-failures degradation
    ladder) and the typed failure taxonomy (:class:`DeadlineExceeded`,
    :class:`DispatchFailed`, :class:`SessionFailed`);
  * a **seeded, deterministic chaos harness** — :class:`FaultInjector` —
    that injects backend dispatch exceptions, non-finite fitness/scalar
    rows, artificial dispatch latency (stragglers), and session-coroutine
    crashes at configurable rates.

Determinism contract: every injection decision is a draw from one seeded
``random.Random`` consulted at scheduler-deterministic points (per tick, in
live-session admission order, per dispatch attempt, per handle row) and
never gated on wall-clock time — so the same seed produces the same fault
schedule, and (because retried/redispatched rows are bit-identical to the
rows a fault-free run would have produced) the same per-session results.
The injector records every injection in ``schedule``; chaos tests reconcile
that record against the scheduler's ``ServiceStats`` fault counters.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Sequence, Set

from ..core.backend import SimHandle

# injection kinds (InjectedFault.kind / FaultInjector rate knobs)
DISPATCH = "dispatch"  # evaluate_candidates raises before submission
NAN_ROW = "nan_row"  # a handle's fitness/scalar row turns non-finite
STRAGGLER = "straggler"  # artificial dispatch latency
CRASH = "crash"  # an exception thrown into the session coroutine

# fault kinds that can change the *affected* session's search (dispatch
# faults and stragglers never do: retried/redispatched rows are
# bit-identical, and latency is not an input to the search)
_RESULT_AFFECTING = (NAN_ROW, CRASH)


# ---------------------------------------------------------------------------
# failure taxonomy
# ---------------------------------------------------------------------------
class DeadlineExceeded(RuntimeError):
    """The session's admission→completion wall clock passed its
    ``SessionRequest.deadline_s`` — enforced at the top of every tick."""


class DispatchFailed(RuntimeError):
    """Every dispatch attempt of one session's batch raised, retries and the
    degradation ladder included — the session is quarantined to FAILED."""


class SessionFailed(RuntimeError):
    """Raised by ``SessionHandle.result`` for a FAILED session; the original
    error rides on ``__cause__`` (and on ``handle.error``)."""


class InjectedDispatchError(RuntimeError):
    """A FaultInjector-vetoed dispatch attempt (transient by construction:
    the next attempt draws again)."""


class InjectedSessionCrash(RuntimeError):
    """A FaultInjector-scheduled coroutine crash, thrown into the session's
    generator so the real unwind/quarantine path runs."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Transient-failure handling for one session's dispatch.

    A failed shared dispatch is first bisected to per-session dispatches
    (quarantining the fault to its owner); each per-session dispatch then
    retries up to ``max_attempts`` times with capped exponential backoff.
    ``degrade_after`` consecutive failed primary-backend attempts (counted
    across ticks, reset on any success) drop that one session onto the
    scalar ``PythonBackend`` fallback — the service keeps serving; only a
    session whose *fallback* dispatch also keeps failing reaches FAILED."""

    max_attempts: int = 4  # dispatch attempts per session per tick
    backoff_s: float = 0.001  # sleep before the first retry
    backoff_cap_s: float = 0.05  # exponential backoff ceiling
    degrade_after: int = 3  # consecutive failures → python fallback


@dataclasses.dataclass(frozen=True)
class InjectedFault:
    """One scheduled injection (the injector's replay/reconciliation log)."""

    tick: int
    kind: str  # DISPATCH | NAN_ROW | STRAGGLER | CRASH
    target: str  # session name, or "shared:<graph>" for a group dispatch


class FaultInjector:
    """Deterministic chaos source for ``ContinuousBatchScheduler``.

    Rates are per decision point: ``dispatch_fault_rate`` per dispatch
    *attempt* (shared group dispatches and per-session redispatches draw
    independently; degraded python-fallback dispatches are never vetoed —
    the fallback models the known-good path), ``nan_row_rate`` per priced
    handle row, ``straggler_rate`` per group dispatch, ``crash_rate`` per
    live session per tick. ``max_faults`` caps the total number of
    injections (handy for "exactly N transient faults" tests); draws past
    the cap still consume rng state, so the schedule prefix is stable.
    """

    def __init__(
        self,
        seed: int = 0,
        dispatch_fault_rate: float = 0.0,
        nan_row_rate: float = 0.0,
        straggler_rate: float = 0.0,
        crash_rate: float = 0.0,
        straggler_delay_s: float = 0.02,
        max_faults: Optional[int] = None,
    ) -> None:
        self.seed = seed
        self.rates: Dict[str, float] = {
            DISPATCH: dispatch_fault_rate,
            NAN_ROW: nan_row_rate,
            STRAGGLER: straggler_rate,
            CRASH: crash_rate,
        }
        self.straggler_delay_s = straggler_delay_s
        self.max_faults = max_faults
        self.schedule: List[InjectedFault] = []
        self._rng = random.Random(seed)
        self._tick = 0

    # ---- scheduler hooks -------------------------------------------------
    def begin_tick(self, tick: int) -> None:
        self._tick = tick

    def _draw(self, kind: str, target: str) -> bool:
        rate = self.rates[kind]
        if rate <= 0.0:
            return False
        hit = self._rng.random() < rate
        if not hit:
            return False
        if self.max_faults is not None and len(self.schedule) >= self.max_faults:
            return False  # capped: the draw still consumed rng state
        self.schedule.append(InjectedFault(self._tick, kind, target))
        return True

    def draw_dispatch_fault(self, target: str) -> bool:
        """One dispatch attempt's veto draw (True → the scheduler raises
        :class:`InjectedDispatchError` instead of dispatching)."""
        return self._draw(DISPATCH, target)

    def draw_straggler(self, target: str) -> float:
        """Artificial dispatch latency for this group dispatch (seconds;
        0.0 = none). The scheduler sleeps it off inside the tick so the
        ``StepTimeMonitor`` sees a genuine outlier step."""
        return self.straggler_delay_s if self._draw(STRAGGLER, target) else 0.0

    def draw_crash(self, session: str) -> bool:
        """Whether to throw :class:`InjectedSessionCrash` into ``session``'s
        coroutine this tick."""
        return self._draw(CRASH, session)

    def poison_rows(self, session: str, handles: Sequence[SimHandle]) -> List[SimHandle]:
        """Per-row non-finite poisoning: each handle draws independently;
        poisoned rows are wrapped so their fitness and PPA scalars read NaN
        (the explorer's non-finite guard must reject — never accept — them)."""
        if self.rates[NAN_ROW] <= 0.0:
            return list(handles)
        return [
            PoisonedHandle(h) if self._draw(NAN_ROW, session) else h
            for h in handles
        ]

    # ---- reconciliation --------------------------------------------------
    def counts(self) -> Dict[str, int]:
        """Injections performed, per kind — what ``ServiceStats`` fault
        counters reconcile against."""
        out = {k: 0 for k in self.rates}
        for f in self.schedule:
            out[f.kind] += 1
        return out

    def affected_sessions(self) -> Set[str]:
        """Sessions whose *search* an injection may have changed (poisoned
        rows, crashes). Dispatch faults and stragglers are excluded: retried
        and redispatched rows are bit-identical, so those sessions must
        still match a fault-free run exactly (asserted in the chaos tests)."""
        return {
            f.target for f in self.schedule if f.kind in _RESULT_AFFECTING
        }


class PoisonedHandle:
    """A :class:`SimHandle` whose fitness/scalar row reads non-finite.

    Only the *scoring* columns are poisoned — ``telemetry``/``result``
    delegate to the wrapped handle so a defensive read never crashes — and
    the explorer's non-finite guard guarantees a poisoned row loses every
    ranking and is never accepted (counted in
    ``ServiceStats.n_nonfinite_rejected``)."""

    __slots__ = ("_inner",)

    def __init__(self, inner: SimHandle) -> None:
        self._inner = inner

    def __getattr__(self, name):
        # everything but the scoring columns behaves like the real row
        # (adopt_encoding reads ``_cand``/encoding attributes, for one)
        return getattr(self._inner, name)

    @property
    def fitness(self) -> float:
        return float("nan")

    def scalars(self) -> Dict[str, float]:
        return {k: float("nan") for k in ("latency_s", "power_w", "area_mm2")}

    def result(self):
        return self._inner.result()

    def result_for(self, design):
        return self._inner.result_for(design)

    def telemetry(self):
        return self._inner.telemetry()
