"""Regenerate tests/golden_policy_seqs.json.

The fixtures pin the Explorer's (iteration, move, accepted) sequence per
(graph, awareness, seed, iteration-cap) cell, per backend: entries whose
python and jax sequences agree carry ``backends: ["python", "jax"]``; cells
where float32 device ranking legitimately diverges split into a base entry
and an ``@jax`` twin. `tests/test_policy.py::test_policy_replays_pre_refactor_golden`
replays every entry on every listed backend bit-for-bit.

Run me (``PYTHONPATH=src python tests/gen_golden_policy_seqs.py``) ONLY when
search behaviour changes deliberately — a move-semantics bugfix, a pricing
change — and say so in the commit. History: captured at the PR-3 tree;
regenerated in PR 5 after (a) `apply_fork` stopped silently migrating a
different task when asked to fork the anchor task and (b) NoC topology moves
started pricing on-device (f32) instead of through the float64 Python
fallback.
"""
import json
import os

from repro.core import (
    Explorer, ExplorerConfig, HardwareDatabase, ar_complex, audio,
    calibrated_budget, edge_detection,
)

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "golden_policy_seqs.json")
GRAPHS = {"audio": audio, "ar_complex": ar_complex, "ed": edge_detection}
CELLS = (
    ("audio", "farsi", 7, 150),
    ("ar_complex", "farsi", 3, 120),
    ("ed", "farsi", 7, 60),
    ("ed", "farsi", 11, 60),
    ("ed", "sa", 5, 80),
    ("ed", "task", 5, 80),
    ("ed", "task_block", 5, 80),
)


def _seq(res):
    return [[h["iteration"], h["move"], int(h["accepted"])] for h in res.history]


def main() -> None:
    db = HardwareDatabase()
    bud = calibrated_budget(db)
    out = {}
    for gname, aware, seed, iters in CELLS:
        runs = {}
        for backend in ("python", "jax"):
            res = Explorer(
                GRAPHS[gname](), db, bud,
                ExplorerConfig(awareness=aware, max_iterations=iters,
                               seed=seed, backend=backend),
            ).run()
            runs[backend] = {"seq": _seq(res), "n_sims": res.n_sims}
        key = f"{gname}.{aware}.s{seed}.it{iters}"
        if runs["python"] == runs["jax"]:
            out[key] = {"backends": ["python", "jax"], **runs["python"]}
        else:
            out[key] = {"backends": ["python"], **runs["python"]}
            out[f"{key}@jax"] = {"backends": ["jax"], **runs["jax"]}
        print(key, "split" if runs["python"] != runs["jax"] else "shared")
    with open(GOLDEN, "w") as f:
        json.dump(out, f)
    print(f"wrote {GOLDEN} ({len(out)} entries)")


if __name__ == "__main__":
    main()
