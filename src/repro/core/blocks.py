"""Hardware block abstractions (paper §3.2, Table 2/3).

A *block* is the lowest abstraction unit of the system simulator: a processing
element (general-purpose processor or accelerator IP), a memory (DRAM/SRAM),
or a NoC (bus/router with ``width × freq`` bandwidth and ``links`` channels).

The same abstraction instantiates the TPU-pod design space (DESIGN.md §2):
a chip's MXU is a PE, HBM is a MEM, and ICI is a NOC — only the database
constants change.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Optional

_uid = itertools.count()


class BlockKind(str, enum.Enum):
    PE = "pe"
    MEM = "mem"
    NOC = "noc"


# Knob ladders (paper Table 3). Swap moves step one rung at a time so that a
# move "only incrementally modifies the original block".
FREQ_LADDER_MHZ = (100, 200, 300, 400, 500, 600, 700, 800)
WIDTH_LADDER_BYTES = (4, 8, 16, 32, 64, 128, 256)
LINK_LADDER = (1, 2, 4, 8)
# Accelerator loop-unrolling ladder (Table 3: "Loop Unrolling — according to
# the task"; the effective factor is capped by the task's LLP at pricing time).
UNROLL_LADDER = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


@dataclasses.dataclass
class Block:
    """One hardware block instance and its knob settings."""

    kind: BlockKind
    subtype: str  # PE: "gpp"|"acc"; MEM: "dram"|"sram"; NOC: "noc"
    freq_mhz: int = 100
    width_bytes: int = 32  # NoC / Mem bus width
    n_links: int = 1  # NoC channels
    unroll: int = 1  # accelerator datapath parallelism (PE subtype "acc")
    # For accelerators: which task this IP is hardened for (A_peak lives in the
    # database, keyed by (task_name, subtype)).
    hardened_for: Optional[str] = None
    name: str = ""

    def __post_init__(self) -> None:
        if not self.name:
            self.name = f"{self.subtype}_{next(_uid)}"

    # ---- peak rates (Gables "peak" terms) ------------------------------
    def peak_compute_ops(self, database) -> float:
        """P_peak for a PE in ops/sec (Eq. 1/2 numerator)."""
        assert self.kind == BlockKind.PE
        return database.pe_peak_ops(self)

    def peak_bandwidth(self, database) -> float:
        """B_peak for MEM/NOC in bytes/sec (per channel for NoCs)."""
        assert self.kind in (BlockKind.MEM, BlockKind.NOC)
        return self.freq_mhz * 1e6 * self.width_bytes

    # ---- knob manipulation (swap move substrate) -----------------------
    def ladder(self, knob: str):
        if knob == "freq_mhz":
            return FREQ_LADDER_MHZ
        if knob == "width_bytes":
            return WIDTH_LADDER_BYTES
        if knob == "n_links":
            return LINK_LADDER
        if knob == "unroll":
            return UNROLL_LADDER
        raise KeyError(knob)

    def step_knob(self, knob: str, direction: int) -> bool:
        """Move one rung along a knob ladder. Returns False at the end stop."""
        ladder = self.ladder(knob)
        cur = getattr(self, knob)
        idx = ladder.index(cur)
        new = idx + direction
        if not (0 <= new < len(ladder)):
            return False
        setattr(self, knob, ladder[new])
        return True

    def clone(self) -> "Block":
        return Block(
            kind=self.kind,
            subtype=self.subtype,
            freq_mhz=self.freq_mhz,
            width_bytes=self.width_bytes,
            n_links=self.n_links,
            unroll=self.unroll,
            hardened_for=self.hardened_for,
        )

    def signature(self) -> tuple:
        """Hashable knob state (used for heterogeneity / CV statistics)."""
        return (
            self.kind.value,
            self.subtype,
            self.freq_mhz,
            self.width_bytes,
            self.n_links,
            self.unroll,
            self.hardened_for,
        )


def make_gpp(freq_mhz: int = 100) -> Block:
    return Block(kind=BlockKind.PE, subtype="gpp", freq_mhz=freq_mhz)


def make_accelerator(task_name: str, freq_mhz: int = 100) -> Block:
    return Block(
        kind=BlockKind.PE, subtype="acc", freq_mhz=freq_mhz, hardened_for=task_name
    )


def make_mem(subtype: str = "dram", freq_mhz: int = 100, width_bytes: int = 32) -> Block:
    return Block(kind=BlockKind.MEM, subtype=subtype, freq_mhz=freq_mhz, width_bytes=width_bytes)


def make_noc(freq_mhz: int = 100, width_bytes: int = 32, n_links: int = 1) -> Block:
    return Block(
        kind=BlockKind.NOC,
        subtype="noc",
        freq_mhz=freq_mhz,
        width_bytes=width_bytes,
        n_links=n_links,
    )
