"""Batched serving driver: prefill → decode loop with a fixed-capacity cache.

``extend_cache`` pads prefill KV to the serving capacity (SSM state is
fixed-size already); ``generate`` runs greedy decode. Used by
examples/serve_batch.py and the decode-consistency tests; the production
entry point jits both steps with the serving shardings.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.model import RunFlags
from ..train.step import make_decode_step, make_prefill_step


def extend_cache(cfg: ModelConfig, cache, max_len: int):
    """Pad per-layer KV from prefill length S to serving capacity."""
    out = []
    for pos, kind in enumerate(cfg.block_kinds):
        c = cache[pos]
        if kind == "attn":
            k, v = c["k"], c["v"]
            # prefill emits (cycles, B, S, K, Dh)
            pad = max_len - k.shape[2]
            assert pad >= 0, (k.shape, max_len)
            widths = [(0, 0), (0, 0), (0, pad), (0, 0), (0, 0)]
            out.append({"k": jnp.pad(k, widths), "v": jnp.pad(v, widths)})
        else:
            out.append(c)
    return tuple(out)


def generate(
    params,
    cfg: ModelConfig,
    prompt: Dict[str, jax.Array],
    n_tokens: int,
    max_len: Optional[int] = None,
    flags: RunFlags = RunFlags(),
    greedy: bool = True,
) -> Tuple[jax.Array, jax.Array]:
    """Greedy generation. prompt: {'tokens': (B, S)} (or embeds). Returns
    (generated (B, n_tokens), all-step logits of the last position)."""
    prefill = jax.jit(make_prefill_step(cfg, flags))
    decode = jax.jit(make_decode_step(cfg, flags))

    if cfg.input_mode == "tokens":
        s0 = prompt["tokens"].shape[1]
        bsz = prompt["tokens"].shape[0]
    else:
        s0 = prompt["embeds"].shape[1]
        bsz = prompt["embeds"].shape[0]
    max_len = max_len or (s0 + n_tokens)

    logits, cache = prefill(params, prompt)
    cache = extend_cache(cfg, cache, max_len)
    outs = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B,)
    for i in range(n_tokens):
        outs.append(tok)
        if cfg.input_mode == "tokens":
            batch = {"tokens": tok[:, None]}
        else:
            # embedding-input archs decode from the embedding of the token
            batch = {"embeds": jnp.zeros((bsz, 1, cfg.d_model), jnp.bfloat16)}
        logits, cache = decode(params, cache, batch, jnp.int32(s0 + i))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jnp.stack(outs, axis=1), logits
