"""Phase-driven simulation (paper §3.2, Fig. 4; Eqs. 5–6).

A *phase* is the longest time quantum within which the system bottleneck stays
constant. Because rates only change when a task is scheduled in or out, the
simulator: (1) schedules every dependency-satisfied task (first-ready-first-
served — the paper's only scheduling policy), (2) prices every running task's
rates with the extended-Gables models, (3) advances the clock by the minimum
completion time (Eq. 6), (4) retires finished tasks and loops.

Each task carries three work components (compute ops, read bytes, write bytes)
that drain *concurrently* at their component rates — Eq. 5's ``max`` is the
completion condition. The event-driven reference (`event_sim.py`) instead
serializes per-burst, which is what bounds this model's fidelity (§4: buses
show the highest error because intra-phase congestion is assumed constant).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .database import HardwareDatabase
from .design import Design
from .gables import RouteContext, binding_block, bottleneck_of, phase_rates
from .ppa import mem_capacities, total_area_mm2, total_leakage_w
from .tdg import TaskGraph, workload_of

_EPS = 1e-12


@dataclasses.dataclass
class SimResult:
    latency_s: float
    workload_latency_s: Dict[str, float]
    energy_j: float
    power_w: float
    area_mm2: float
    n_phases: int
    # time-weighted seconds each resource class was the binding bottleneck
    bottleneck_s: Dict[str, float]
    # per-task binding resource at completion (drives Algorithm-1 selection)
    task_bottleneck: Dict[str, str]
    task_finish_s: Dict[str, float]
    mem_capacity_bytes: Dict[str, float]
    # concrete bottleneck block instance per task + per-task dynamic energy
    task_bottleneck_block: Dict[str, str] = dataclasses.field(default_factory=dict)
    task_energy_j: Dict[str, float] = dataclasses.field(default_factory=dict)
    # bottleneck_s resolved to concrete block instances: seconds each block
    # was the binding bottleneck of some running task (Σ over blocks of one
    # kind == bottleneck_s[kind]). This is the host reference the device-side
    # telemetry columns (pe_bneck_s / mem_bneck_s) are validated against.
    block_bottleneck_s: Dict[str, float] = dataclasses.field(default_factory=dict)
    # Fig-16 system dynamics: time-weighted avg of concurrently-busy PEs
    # (accelerator-level parallelism, Hill & Reddi ALP) and total bytes moved
    avg_accel_parallelism: float = 1.0
    total_traffic_bytes: float = 0.0

    def metric(self, name: str) -> float:
        return {
            "latency": self.latency_s,
            "power": self.power_w,
            "area": self.area_mm2,
        }[name]


@dataclasses.dataclass
class _TaskState:
    ops: float
    rd: float
    wr: float

    def done(self) -> bool:
        return self.ops <= _EPS and self.rd <= _EPS and self.wr <= _EPS


def simulate(
    design: Design,
    tdg: TaskGraph,
    db: HardwareDatabase,
    max_phases: int = 100_000,
) -> SimResult:
    state: Dict[str, _TaskState] = {
        name: _TaskState(t.work_ops, t.read_bytes, t.write_bytes)
        for name, t in tdg.tasks.items()
    }
    completed: set = set()
    finish_s: Dict[str, float] = {}
    task_bneck: Dict[str, str] = {}
    task_bneck_block: Dict[str, str] = {}
    task_energy_pj: Dict[str, float] = {t: 0.0 for t in tdg.tasks}
    bneck_s: Dict[str, float] = {"pe": 0.0, "mem": 0.0, "noc": 0.0}
    block_bneck_s: Dict[str, float] = {b: 0.0 for b in design.blocks}
    energy_pj = 0.0
    now = 0.0
    n_phases = 0
    alp_time = 0.0
    traffic_bytes = 0.0
    ctx = RouteContext(design, tdg)

    while len(completed) < len(tdg.tasks):
        n_phases += 1
        if n_phases > max_phases:
            raise RuntimeError("phase-driven simulation did not terminate")
        running = [
            t
            for t in tdg.tasks
            if t not in completed and all(p in completed for p in tdg.parents[t])
        ]
        assert running, "deadlock: no ready task but graph incomplete"
        rates = phase_rates(design, tdg, running, db, ctx)

        # Eq. 5 on *remaining* work, Eq. 6 over running tasks
        remain: Dict[str, float] = {}
        for t in running:
            r, s = rates[t], state[t]
            remain[t] = max(
                s.ops / r.compute_ops_s, s.rd / r.read_bw, s.wr / r.write_bw
            )
        phi = min(remain.values())  # Eq. 6
        phi = max(phi, _EPS)

        # advance all components concurrently, accumulate energy
        for t in running:
            r, s = rates[t], state[t]
            d_ops = min(s.ops, r.compute_ops_s * phi)
            d_rd = min(s.rd, r.read_bw * phi)
            d_wr = min(s.wr, r.write_bw * phi)
            s.ops -= d_ops
            s.rd -= d_rd
            s.wr -= d_wr
            pe = design.blocks[design.task_pe[t]]
            mem = design.blocks[design.task_mem[t]]
            hops = ctx.hops[t]
            e = (
                db.compute_energy_pj(pe, d_ops)
                + db.mem_energy_pj(mem, d_rd + d_wr)
                + db.noc_energy_pj((d_rd + d_wr) * hops)
            )
            energy_pj += e
            task_energy_pj[t] += e
            kind = bottleneck_of(tdg.tasks[t], r)
            bneck_s[kind] += phi
            blk = binding_block(design, t, r, kind)
            block_bneck_s[blk] = block_bneck_s.get(blk, 0.0) + phi

        now += phi
        alp_time += len({design.task_pe[t] for t in running}) * phi
        traffic_bytes += sum(
            min(state[t].rd + state[t].wr, (rates[t].read_bw + rates[t].write_bw) * phi)
            for t in running
        )
        for t in running:
            if state[t].done() or remain[t] <= phi + _EPS:
                # numerical guard: a task whose Eq.-5 time equals phi retires
                state[t].ops = state[t].rd = state[t].wr = 0.0
                completed.add(t)
                finish_s[t] = now
                kind = bottleneck_of(tdg.tasks[t], rates[t])
                task_bneck[t] = kind
                task_bneck_block[t] = binding_block(design, t, rates[t], kind)

    # ---- PPA rollup -----------------------------------------------------
    energy_j = energy_pj * 1e-12 + total_leakage_w(design, db) * now
    power_w = energy_j / now if now > 0 else 0.0
    area = total_area_mm2(design, tdg, db)
    mem_cap = mem_capacities(design, tdg)

    wl_latency: Dict[str, float] = {}
    for t, f in finish_s.items():
        # un-namespaced tasks (single-workload graphs) roll up to the graph name
        w = workload_of(t) if "." in t else tdg.name
        wl_latency[w] = max(wl_latency.get(w, 0.0), f)

    return SimResult(
        latency_s=now,
        workload_latency_s=wl_latency,
        energy_j=energy_j,
        power_w=power_w,
        area_mm2=area,
        n_phases=n_phases,
        bottleneck_s=bneck_s,
        task_bottleneck=task_bneck,
        task_finish_s=finish_s,
        mem_capacity_bytes=mem_cap,
        task_bottleneck_block=task_bneck_block,
        task_energy_j={t: e * 1e-12 for t, e in task_energy_pj.items()},
        block_bottleneck_s=block_bneck_s,
        avg_accel_parallelism=alp_time / now if now > 0 else 1.0,
        total_traffic_bytes=traffic_bytes,
    )
