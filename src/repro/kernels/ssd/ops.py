"""jit'd wrapper for the SSD chunk-scan kernel, signature-compatible with
``ref.ssd_reference`` (so models/mamba2.py can swap implementations via
RunFlags). Interpret mode for CPU validation; Mosaic on TPU."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import ssd_chunk_scan


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_mat: jax.Array,
    c_mat: jax.Array,
    chunk: int = 128,
    interpret: bool = False,
):
    return ssd_chunk_scan(
        x, dt, a, b_mat, c_mat, chunk=chunk, interpret=interpret
    )
