"""Exploration heuristic (paper §3.3–3.4, Algorithm 1).

Simulated annealing is the base search; FARSI augments its neighbour
generation with architectural reasoning. A neighbour is produced by choosing
the 5-tuple (Metric, Direction, Task, Block, Move):

  metric    — the one farthest from budget (co-design: changes per iteration)
  direction — +1 buy performance / −1 return it
  task      — highest distance contribution (critical-path duration for
              latency, dynamic energy for power)
  block     — the task's bottleneck block (Eq. 5 attribution)
  move      — Algorithm 1 reasoning + development-cost precedence
              (join > migrate > fork > swap > fork_swap), sampled
              probabilistically by precedence weight

Awareness ladder (paper Fig. 9b): ``sa`` picks all five at random;
``task`` adds bottleneck-driven task selection; ``task_block`` adds block
selection; ``farsi`` adds Algorithm-1 move selection + precedence.

If no neighbour improves, the failed (task, block) target goes on a short
taboo list so the next iteration targets "the task/block with the next
highest distance" (§3.4), and classic SA temperature occasionally accepts a
worse design.
"""
from __future__ import annotations

import dataclasses
import math
import random
import time
from typing import Dict, Generator, List, Optional, Tuple

from .backend import Candidate, SimHandle, SimulatorBackend, make_backend
from .blocks import BlockKind
from .budgets import Budget, Distance, distance
from .codesign import CodesignLedger, FocusRecord
from .database import HardwareDatabase
from .design import Design
from .moves import MOVE_KINDS, MOVE_PRECEDENCE, MoveDelta, MoveSpec, apply_move
from .phase_sim import SimResult
from .tdg import TaskGraph, workload_of

AWARENESS_LEVELS = ("sa", "task", "task_block", "farsi")


@dataclasses.dataclass
class _Sel:
    """One dispatched iteration's selection context (the 5-tuple choices a
    resolution needs back after its batch was scored — possibly one full
    iteration later, when the batch was dispatched speculatively)."""

    it: int
    metric: str
    task: str
    block: str
    bneck: str
    neighbors: List["Candidate"]


@dataclasses.dataclass
class ExplorerConfig:
    awareness: str = "farsi"
    neighbors_per_iter: int = 4
    max_iterations: int = 1500
    seed: int = 0
    temperature0: float = 0.05
    temp_decay: float = 0.997
    alpha_met: float = 0.05
    dev_cost_aware: bool = True
    codesign: bool = True  # False => fixate focus until the focused metric is met
    taboo_ttl: int = 5
    backend: str = "python"  # SimulatorBackend registry name (backend.BACKENDS)
    # two-deep speculative dispatch pipeline: generate + encode batch i+1
    # (assuming batch i is rejected) while the device scores batch i.
    #   None  — auto: on async backends, speculate ADAPTIVELY (only while a
    #           running estimate says rejection is the likely outcome — in
    #           accept-heavy phases a speculative batch is almost always
    #           thrown away, so speculating there is pure overhead);
    #   True  — always speculate (the stall-guard / identity-test mode);
    #   False — off.
    # Every mode produces the same accepted-move sequence under a fixed
    # seed — speculation rolls its rng/taboo state back on a miss.
    pipeline: Optional[bool] = None


@dataclasses.dataclass
class ExplorationResult:
    best_design: Design
    best_result: SimResult
    best_distance: Distance
    converged: bool
    iterations: int
    n_sims: int  # committed evaluations (mis-speculated batches excluded)
    wall_s: float
    history: List[dict]
    ledger: CodesignLedger
    backend_name: str = "python"
    sim_wall_s: float = 0.0  # time inside backend.evaluate for this run
    pipelined: bool = False  # ran with the speculative dispatch pipeline
    n_spec_hits: int = 0  # speculative batches that became the next iteration
    n_sims_wasted: int = 0  # speculated evaluations discarded on accept


def _task_duration(result: SimResult, tdg: TaskGraph, t: str) -> float:
    start = max((result.task_finish_s[p] for p in tdg.parents[t]), default=0.0)
    return result.task_finish_s[t] - start


def _block_has_parallel_tasks(design: Design, tdg: TaskGraph, block: str) -> bool:
    kind = design.blocks[block].kind
    if kind == BlockKind.PE:
        hosted = design.tasks_on_pe(block)
    elif kind == BlockKind.MEM:
        hosted = design.buffers_on_mem(block)
    else:
        hosted = design.tasks_via_noc(block)
    for i, a in enumerate(hosted):
        par = set(tdg.parallel_tasks_of(a))
        if par & set(hosted[i + 1:]):
            return True
    return False


def _task_parallel_other_blocks(design: Design, tdg: TaskGraph, t: str) -> bool:
    mine = design.task_pe[t]
    return any(design.task_pe[p] != mine for p in tdg.parallel_tasks_of(t))


class Explorer:
    def __init__(
        self,
        tdg: TaskGraph,
        db: HardwareDatabase,
        budget: Budget,
        config: ExplorerConfig = ExplorerConfig(),
        backend: Optional[SimulatorBackend] = None,
    ) -> None:
        self.tdg = tdg
        self.db = db
        self.budget = budget
        self.cfg = config
        assert config.awareness in AWARENESS_LEVELS
        self.rng = random.Random(config.seed)
        self.backend = backend or make_backend(config.backend, tdg, db)
        self.n_sims = 0  # committed designs this run submitted (backend stats
        # aggregate across sharers AND count mis-speculated batches; this
        # stays per-exploration — and per-commit — under Campaign)
        self.n_sims_wasted = 0  # speculated evaluations discarded on accept
        self.n_spec_hits = 0
        if config.pipeline is None:  # auto: needs an asynchronous dispatch
            self._pipeline = (
                "adaptive" if getattr(self.backend, "async_dispatch", False) else "off"
            )
        else:
            self._pipeline = "always" if config.pipeline else "off"
        self._p_rej = 0.0  # EW estimate of the rejection rate (adaptive gate)
        self._taboo: Dict[Tuple[str, str], int] = {}
        self._sticky_focus: Optional[str] = None  # codesign-off fixation

    # ---- 5-tuple selection ----------------------------------------------
    def _select_metric(self, dist: Distance) -> str:
        if self.cfg.awareness == "sa":
            return self.rng.choice(("latency", "power", "area"))
        if not self.cfg.codesign:
            # fixation ablation: stick to one metric until it meets budget
            if self._sticky_focus and dist.per_metric[self._sticky_focus] > 0:
                return self._sticky_focus
            unmet = [m for m, d in dist.per_metric.items() if d > 0]
            self._sticky_focus = unmet[0] if unmet else "latency"
            return self._sticky_focus
        return dist.farthest_metric()

    def _select_task(
        self, design: Design, metric: str, dist: Distance, result: SimResult
    ) -> str:
        tasks = list(self.tdg.tasks)
        if self.cfg.awareness == "sa":
            return self.rng.choice(tasks)
        # domain/architecture awareness: rank by contribution to the metric
        if metric == "latency":
            wl = max(
                dist.per_workload_latency,
                key=lambda w: dist.per_workload_latency[w],
            )
            pool = [t for t in tasks if workload_of(t) == wl] or tasks
            ranked = sorted(
                pool, key=lambda t: _task_duration(result, self.tdg, t), reverse=True
            )
        elif metric == "power":
            ranked = sorted(
                tasks, key=lambda t: result.task_energy_j.get(t, 0.0), reverse=True
            )
        else:  # area: tasks whose buffers sit on the largest memories first
            # (capacity is keyed by *memory* name — resolve through the task's
            # mapped memory; own write bytes break ties within one memory)
            ranked = sorted(
                tasks,
                key=lambda t: (
                    result.mem_capacity_bytes.get(design.task_mem.get(t, ""), 0.0),
                    self.tdg.tasks[t].write_bytes,
                ),
                reverse=True,
            )
        for t in ranked:
            if not any(k[0] == t for k in self._taboo):
                return t
        return ranked[0]

    def _select_block(self, design: Design, metric: str, task: str, result: SimResult) -> str:
        if self.cfg.awareness in ("sa", "task"):
            return self.rng.choice(list(design.blocks))
        if metric in ("power", "area"):
            # dead hardware first: an idle block is pure leakage/area, and
            # join removes it for free (the cheapest possible move)
            for n, b in design.blocks.items():
                if b.kind == BlockKind.PE and not design.tasks_on_pe(n):
                    return n
                if b.kind == BlockKind.MEM and not design.buffers_on_mem(n):
                    return n
        if metric == "area":
            return max(design.blocks, key=lambda b: self.db.block_area_mm2(design.blocks[b]))
        blk = result.task_bottleneck_block.get(task)
        if blk in design.blocks:
            return blk
        return design.task_pe[task]

    def _select_moves(self, design: Design, metric: str, task: str, block: str) -> List[str]:
        """Algorithm 1, steps I + II."""
        if self.cfg.awareness != "farsi":
            moves = list(MOVE_KINDS)
            self.rng.shuffle(moves)
            return moves
        if metric == "latency":
            if _block_has_parallel_tasks(design, self.tdg, block):
                allowed = ["migrate", "fork"]
            else:
                allowed = ["swap", "fork_swap"]
        elif metric == "power":
            if _task_parallel_other_blocks(design, self.tdg, task):
                if not _block_has_parallel_tasks(design, self.tdg, block):
                    allowed = ["migrate"]
                else:
                    allowed = ["join"]
            else:
                allowed = ["swap", "fork_swap"]
        else:  # area
            if design.blocks[block].kind == BlockKind.PE:
                allowed = ["join", "swap"]
            else:
                allowed = ["migrate", "join", "swap"]
        # step II/III: precedence-weighted probabilistic ordering
        if self.cfg.dev_cost_aware:
            weights = [MOVE_PRECEDENCE[m] for m in allowed]
        else:
            weights = [1.0] * len(allowed)
        ordered: List[str] = []
        pool, w = list(allowed), list(weights)
        while pool:
            pick = self.rng.choices(range(len(pool)), weights=w)[0]
            ordered.append(pool.pop(pick))
            w.pop(pick)
        # graceful fallback to the rest of the move set
        ordered += [m for m in MOVE_KINDS if m not in ordered]
        return ordered

    # ---- neighbour generation --------------------------------------------
    def _make_neighbors(
        self, design: Design, metric: str, task: str, block: str, moves: List[str],
        bottleneck: str, n: int,
    ) -> List[Candidate]:
        """Up to ``n`` *distinct* neighbours: one per move of the precedence-
        ordered list (candidate generation in SA, §3.4).

        Clone-free: each move is trialled in place on ``design`` (checkpoint
        → apply, recording its encoding delta → rollback), and the neighbour
        is shipped to the backend as a lightweight :class:`Candidate` — the
        paper's Fig.-8b design-duplication hot-spot never runs. Only the
        accepted candidate is ever materialized (``Candidate.accept``)."""
        direction = +1 if metric == "latency" else -1
        out: List[Candidate] = []
        ck = design.checkpoint()
        for move in moves:
            if len(out) >= n:
                break
            delta = MoveDelta()
            ok = apply_move(
                design, self.tdg, move, block, task, direction, bottleneck,
                metric, self.rng, delta,
            )
            design.restore(ck)
            if ok:
                spec = MoveSpec(move, block, task, direction, bottleneck, metric)
                out.append(
                    Candidate(
                        base=design, spec=spec, delta=delta,
                        budget=self.budget, alpha=self.cfg.alpha_met,
                    )
                )
        return out

    # ---- main loop ---------------------------------------------------------
    def run_steps(
        self, initial: Optional[Design] = None
    ) -> Generator[List[Candidate], List[SimHandle], ExplorationResult]:
        """Coroutine form of the search: yields each iteration's candidate
        batch (lightweight :class:`Candidate` records sharing the current
        design — no clones) and is resumed (``gen.send``) with the matching
        :class:`SimHandle` list. The winner is picked from the handles'
        fitness column (device-computed on the JAX backend); only that one
        handle is decoded into a full ``SimResult``, and only on acceptance
        is its move materialized onto the current design.

        With ``pipeline`` on (auto-enabled on async backends) the coroutine
        runs a TWO-DEEP SPECULATIVE PIPELINE: after receiving batch *i*'s
        (lazy) handles it does NOT touch them — it first speculates that
        batch *i* will be *rejected* (the steady-state outcome of a cooling
        anneal), generates + yields batch *i+1* under that assumption, and
        only then forces batch *i*'s one ``(B,)`` fitness pull. The driver
        encodes and dispatches batch *i+1* while the device is still scoring
        batch *i*, so host work hides behind device compute. On a miss (the
        move was accepted) the speculated rng/taboo/focus state is rolled
        back and batch *i+1* is regenerated from the true state — the
        accepted-move sequence is therefore IDENTICAL to the unpipelined
        coroutine under a fixed seed (asserted in tests); the only cost is
        the discarded device batch, accounted in ``n_sims_wasted``.

        ``run()`` drives it against ``self.backend``; `Campaign` drives many
        explorers' generators in lockstep so one dispatch prices the pending
        neighbours of *all* live explorations (speculative or not). The
        ``StopIteration`` value is the :class:`ExplorationResult`."""
        t0 = time.perf_counter()
        cur = initial or Design.base(self.tdg)
        adopt = getattr(self.backend, "adopt_encoding", None)
        self.n_sims += 1
        (h0,) = yield [Candidate.of_design(cur, self.budget, self.cfg.alpha_met)]
        cur_res = h0.result()
        cur_dist = distance(cur_res, self.budget)
        if adopt is not None:
            adopt(h0)
        # best keeps a stable-name snapshot: cur mutates in place hereafter.
        # The snapshot CLONE is deferred (best_stale) until right after the
        # next dispatch is submitted, so its dict-copy cost hides behind the
        # device scoring that batch — cur cannot mutate again before then.
        best_design, best_res, best_dist = cur.clone(rename=False), cur_res, cur_dist
        best_stale = False
        history: List[dict] = []
        ledger = CodesignLedger()
        max_it = self.cfg.max_iterations

        def select_from(it: int) -> Optional[_Sel]:
            """The head of one serial iteration, from the CURRENT search
            state: taboo decrement → 5-tuple selection → neighbour
            generation; iterations yielding no neighbours are taboo'd and
            skipped exactly as the serial loop's ``continue`` did. Returns
            None once the iteration budget is spent or the search converged
            (convergence only moves on accept, so a reject-speculated call
            sees the truth)."""
            while it < max_it and not cur_dist.converged():
                self._taboo = {k: v - 1 for k, v in self._taboo.items() if v > 1}
                metric = self._select_metric(cur_dist)
                task = self._select_task(cur, metric, cur_dist, cur_res)
                block = self._select_block(cur, metric, task, cur_res)
                bneck = cur_res.task_bottleneck.get(task, "pe")
                moves = self._select_moves(cur, metric, task, block)
                neighbors = self._make_neighbors(
                    cur, metric, task, block, moves, bneck, self.cfg.neighbors_per_iter
                )
                if neighbors:
                    return _Sel(it, metric, task, block, bneck, neighbors)
                self._taboo[(task, block)] = self.cfg.taboo_ttl
                it += 1
            return None

        def resolve(sel: _Sel, handles: List[SimHandle], u: float) -> bool:
            """Rank batch ``sel`` from its fitness column (the one host pull
            that forces the dispatch) and run the SA accept test with the
            pre-drawn uniform ``u`` — directly on that column: the backend's
            fitness IS Eq.-7 (device-computed on JAX, `budgets.distance` on
            Python), so a rejected iteration never decodes anything. Only an
            accepted winner is decoded into the ``SimResult`` the next
            selection reasons over. Commits the accept-path state change;
            the reject-path taboo add is the caller's (it is part of the
            speculated continuation)."""
            nonlocal cur_res, cur_dist, best_design, best_res, best_dist, best_stale
            assert len(handles) == len(sel.neighbors)
            # stable argmin preserves the precedence order on ties
            fits = [h.fitness for h in handles]
            j = min(range(len(fits)), key=fits.__getitem__)
            cand, move = sel.neighbors[j], sel.neighbors[j].spec.move
            d_before = cur_dist.fitness(self.cfg.alpha_met)
            d_after = fits[j]
            temp = self.cfg.temperature0 * self.cfg.temp_decay**sel.it
            accept = d_after < d_before or (
                temp > 0 and u < math.exp(-(d_after - d_before) / max(temp, 1e-9))
            )
            dist_after = None
            if accept:
                res = handles[j].result()  # lazy: only the winner pays decode
                dist_after = distance(res, self.budget)
            ledger.log(
                FocusRecord(
                    iteration=sel.it,
                    metric=sel.metric,
                    workload=workload_of(sel.task),
                    comm_comp="comp" if sel.bneck == "pe" else "comm",
                    move=move,
                    distance_before=cur_dist.city_block(),
                    distance_after=dist_after.city_block() if accept else cur_dist.city_block(),
                )
            )
            if accept:
                cand.accept(self.tdg)  # materialize the move onto cur
                if adopt is not None:
                    adopt(handles[j])  # cur's encoding == the winner's row
                cur_res, cur_dist = res, dist_after
                if cur_dist.city_block() < best_dist.city_block():
                    best_res, best_dist, best_stale = cur_res, cur_dist, True
            history.append(
                {
                    "iteration": sel.it,
                    "n_sims": self.n_sims,
                    "distance": best_dist.city_block(),
                    "fitness": best_dist.fitness(self.cfg.alpha_met),
                    "metric": sel.metric,
                    "move": move,
                    "accepted": accept,
                    "wall_s": time.perf_counter() - t0,
                }
            )
            return accept

        mode = self._pipeline
        sel = select_from(0)
        if sel is not None:
            self.n_sims += len(sel.neighbors)
            handles = yield sel.neighbors
        while sel is not None:
            # the SA accept draw: consumed unconditionally and BEFORE the
            # next iteration's selection draws, so the rng stream is the
            # same whether that selection happens now (speculation) or
            # after resolution (serial)
            u = self.rng.random()

            # ---- speculate REJECT: select + dispatch batch i+1 while the
            # device is still scoring batch i. The adaptive gate only
            # speculates when rejection is the likely outcome — a wasted
            # speculative batch costs real encode + device time, so in
            # accept-heavy (early, improving) phases the serial path wins.
            speculate = mode == "always" or (mode == "adaptive" and self._p_rej >= 0.5)
            spec = spec_handles = None
            if speculate:
                ck = (self.rng.getstate(), dict(self._taboo), self._sticky_focus)
                self._taboo[(sel.task, sel.block)] = self.cfg.taboo_ttl
                spec = select_from(sel.it + 1)
                if spec is not None:
                    spec_handles = yield spec.neighbors  # in flight behind batch i

            accepted = resolve(sel, handles, u)  # first host pull forces batch i
            self._p_rej = 0.75 * self._p_rej + (0.0 if accepted else 0.25)
            if speculate and not accepted:
                # hit: batch i+1 was encoded while batch i was scored and is
                # (likely) already scored itself — commit the speculation
                if spec is None:
                    break
                self.n_spec_hits += 1
                self.n_sims += len(spec.neighbors)
                sel, handles = spec, spec_handles
                continue
            if speculate:
                # miss: the accepted move invalidated the speculated state —
                # roll back rng/taboo/focus and regenerate from the truth
                self.rng.setstate(ck[0])
                self._taboo, self._sticky_focus = ck[1], ck[2]
                if spec is not None:
                    self.n_sims_wasted += len(spec.neighbors)
            elif not accepted:
                self._taboo[(sel.task, sel.block)] = self.cfg.taboo_ttl
            sel = select_from(sel.it + 1)
            if sel is None:
                break
            self.n_sims += len(sel.neighbors)
            handles = yield sel.neighbors
            if best_stale:  # deferred snapshot: hides behind the dispatch
                best_design, best_stale = cur.clone(rename=False), False

        if best_stale:
            best_design = cur.clone(rename=False)
        return ExplorationResult(
            best_design=best_design,
            best_result=best_res,
            best_distance=best_dist,
            converged=best_dist.converged(),
            iterations=len(history),
            n_sims=self.n_sims,
            wall_s=time.perf_counter() - t0,
            history=history,
            ledger=ledger,
            backend_name=self.backend.name,
            pipelined=self._pipeline != "off",
            n_spec_hits=self.n_spec_hits,
            n_sims_wasted=self.n_sims_wasted,
        )

    def run(self, initial: Optional[Design] = None) -> ExplorationResult:
        """Drive :meth:`run_steps` against ``self.backend`` — exactly one
        ``backend.evaluate_candidates`` call per search iteration (plus one
        for the initial design, plus any mis-speculated batches when the
        pipeline is on). Drains abandoned speculative dispatches on exit."""
        gen = self.run_steps(initial)
        sim_wall = 0.0
        try:
            pending = next(gen)
            while True:
                t0 = time.perf_counter()
                handles = self.backend.evaluate_candidates(pending)
                sim_wall += time.perf_counter() - t0
                pending = gen.send(handles)
        except StopIteration as stop:
            flush = getattr(self.backend, "flush", None)
            if flush is not None:
                flush()
            result: ExplorationResult = stop.value
            result.sim_wall_s = sim_wall
            return result
