"""Fault-tolerant checkpointing.

Layout:  <dir>/step_00000420/
            meta.json            — step, treedef, shapes/dtypes, data state
            arr_<flatkey>.npy    — one file per leaf

Guarantees:
  * atomic commit — writes land in ``.tmp-step_N`` and are os.rename()'d into
    place, so a crash mid-save can never yield a half checkpoint that
    ``latest_step`` would pick up;
  * keep-N retention (oldest complete checkpoints pruned after commit);
  * async mode — leaves are device_get'd synchronously (cheap) and written by
    a background thread, overlapping serialization with the next train steps;
  * elastic restore — leaves are re-placed with *target* shardings, so a
    checkpoint written on one mesh restores onto any other mesh/topology
    (runtime/elastic.py wires this to recovery-time mesh shrinking).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten(tree) -> List[tuple]:
    return jax.tree_util.tree_flatten_with_path(tree)[0]


def _key_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return ".".join(parts)


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        steps = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict] = None) -> None:
        """Snapshot ``state`` at ``step``. Device transfer happens here
        (synchronously — the arrays are consistent); file IO may be async."""
        self.wait()  # one outstanding async save at a time
        leaves = _flatten(state)
        host_leaves = [(p, np.asarray(jax.device_get(v))) for p, v in leaves]
        meta = {
            "step": step,
            "extra": extra or {},
            "leaves": [
                {"key": _key_str(p), "shape": list(v.shape), "dtype": str(v.dtype)}
                for p, v in host_leaves
            ],
        }

        def write():
            tmp = os.path.join(self.dir, f".tmp-step_{step:08d}")
            final = os.path.join(self.dir, f"step_{step:08d}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp)
            for p, v in host_leaves:
                np.save(os.path.join(tmp, f"arr_{_key_str(p)}.npy"), v)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)  # atomic commit
            self._prune()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _prune(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(
        self,
        target: Any,
        step: Optional[int] = None,
        shardings: Any = None,
    ) -> tuple:
        """Restore into the structure of ``target`` (a pytree of arrays or
        ShapeDtypeStructs). If ``shardings`` (matching pytree of Shardings) is
        given, leaves are placed with them — this is the elastic-resharding
        path: the checkpoint is topology-free numpy, placement is the
        caller's current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)

        leaves, treedef = jax.tree_util.tree_flatten_with_path(target)
        shard_leaves = (
            jax.tree_util.tree_flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
        )
        out = []
        for (path, tgt), shd in zip(leaves, shard_leaves):
            arr = np.load(os.path.join(d, f"arr_{_key_str(path)}.npy"))
            assert tuple(arr.shape) == tuple(tgt.shape), (path, arr.shape, tgt.shape)
            if shd is not None:
                out.append(jax.device_put(arr.astype(tgt.dtype), shd))
            else:
                out.append(jax.numpy.asarray(arr, dtype=tgt.dtype))
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(target), out
        )
        return state, meta
