"""Generic decoder stack: instantiates every assigned architecture from its
``ModelConfig`` (DESIGN.md §4).

Layers are grouped into a repeating *cycle* (Jamba's 8-layer Mamba/attention
pattern, or a single layer for uniform stacks) and the stack is a
``lax.scan`` over cycles — keeping HLO size and compile time independent of
depth (88–94-layer configs) and making remat policies uniform.

Three execution modes share one block implementation:
  train    — full sequence, no cache (logits + MoE aux loss)
  prefill  — full sequence, emits per-layer cache (KV / SSM state)
  decode   — one token against the cache (serve_step)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.act import constrain
from .layers import (
    apply_mrope,
    apply_rope,
    attention_blockwise,
    attention_decode,
    attention_full,
    mlp_apply,
    mlp_init,
    rms_norm,
)
from .mamba2 import (
    mamba2_apply,
    mamba2_cache_init,
    mamba2_decode,
    mamba2_init,
)
from .moe import moe_apply, moe_init


@dataclasses.dataclass(frozen=True)
class RunFlags:
    """Per-call execution knobs (the FARSI-tunable 'swap' dimension)."""

    attn_impl: str = "auto"  # "auto" | "full" | "blockwise" | "kernel"
    q_block: int = 512
    kv_block: int = 1024
    remat: str = "none"  # "none" | "full" | "dots"
    ssd_chunk: int = 64
    moe_impl: str = "dense"  # "dense" | "shard_map" (EP local-dispatch)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _attn_init(key: jax.Array, cfg: ModelConfig, dtype) -> dict:
    d, h, k_, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)  # repro: noqa[f64-promote]: cfg dims are static Python ints
    s_out = 1.0 / math.sqrt(h * dh)  # repro: noqa[f64-promote]: cfg dims are static Python ints
    p = {
        "wq": (jax.random.normal(keys[0], (d, h * dh)) * s_in).astype(dtype),
        "wk": (jax.random.normal(keys[1], (d, k_ * dh)) * s_in).astype(dtype),
        "wv": (jax.random.normal(keys[2], (d, k_ * dh)) * s_in).astype(dtype),
        "wo": (jax.random.normal(keys[3], (h * dh, d)) * s_out).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), dtype)
        p["k_norm"] = jnp.zeros((dh,), dtype)
    return p


def _pos_init(key: jax.Array, cfg: ModelConfig, pos: int, dtype) -> dict:
    kind = cfg.block_kinds[pos]
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {"norm1": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "attn":
        p["mixer"] = _attn_init(k1, cfg, dtype)
    else:
        p["mixer"] = mamba2_init(k1, cfg, dtype)
    mk = cfg.mlp_kind_at(pos)
    if mk == "dense":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype, cfg.mlp_kind)
    elif mk == "moe":
        p["norm2"] = jnp.zeros((cfg.d_model,), dtype)
        p["mlp"] = moe_init(k2, cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, cfg.cycle_len + 2)
    layers = []
    for pos in range(cfg.cycle_len):
        cycle_keys = jax.random.split(keys[pos], cfg.n_cycles)
        layers.append(jax.vmap(lambda k, p=pos: _pos_init(k, cfg, p, dtype))(cycle_keys))
    params: Dict[str, Any] = {
        "layers": layers,
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if cfg.input_mode == "tokens":
        params["embed"] = (
            jax.random.normal(keys[-2], (cfg.vocab_size, cfg.d_model)) * 0.02
        ).astype(dtype)
    if not (cfg.tie_embeddings and cfg.input_mode == "tokens"):
        params["lm_head"] = (
            jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab_size))
            / math.sqrt(cfg.d_model)
        ).astype(dtype)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _attn_seq(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    flags: RunFlags,
    positions: jax.Array,
    mrope_positions: Optional[jax.Array],
    want_cache: bool,
):
    b, s, _ = x.shape
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, kh, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, kh, dh)
    q = constrain(q, ("batch", "seq", "act_heads", None))
    k = constrain(k, ("batch", "seq", "act_kv_heads", "act_kv_dim"))
    v = constrain(v, ("batch", "seq", "act_kv_heads", "act_kv_dim"))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.rope_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        mp = (
            mrope_positions
            if mrope_positions is not None
            else jnp.broadcast_to(positions[None], (3, b, s))
        )
        q = apply_mrope(q, mp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mp, cfg.rope_theta, cfg.mrope_sections)

    impl = flags.attn_impl
    if impl == "auto":
        impl = "full" if s <= 1024 else "blockwise"
    if impl == "kernel":
        from ..kernels.flash_attention.ops import flash_attention

        out = flash_attention(q, k, v, causal=True)
    elif impl == "blockwise":
        from .flash_ref import flash_attention_ref

        qb = min(flags.q_block, s)
        kb = min(flags.kv_block, s)
        out = flash_attention_ref(q, k, v, True, qb, kb)
    else:
        out = attention_full(q, k, v, causal=True)
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * dh), p["wo"])
    cache = {"k": k, "v": v} if want_cache else None
    return y, cache


def _attn_decode(
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache: dict,
    cur_index: jax.Array,
    mrope_positions: Optional[jax.Array],
):
    b, s, _ = x.shape  # s == 1
    h, kh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(b, s, kh, dh)
    v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(b, s, kh, dh)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    positions = jnp.broadcast_to(cur_index[None, None], (b, 1)).astype(jnp.int32)
    if cfg.rope_kind == "rope":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    elif cfg.rope_kind == "mrope":
        mp = (
            mrope_positions
            if mrope_positions is not None
            else jnp.broadcast_to(positions[None], (3, b, 1))
        )
        q = apply_mrope(q, mp, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, mp, cfg.rope_theta, cfg.mrope_sections)
    if "k_scale" in cache:  # int8 KV cache (per-token, per-head absmax)
        def quantize(x_):
            scale = jnp.max(jnp.abs(x_.astype(jnp.float32)), axis=-1) / 127.0
            scale = jnp.maximum(scale, 1e-8)
            q_ = jnp.round(x_.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
            return q_, scale.astype(jnp.bfloat16)

        kq, ks = quantize(k)
        vq, vs = quantize(v)
        new_cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, cur_index, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, cur_index, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, cur_index, 0)),
            "v_scale": jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, cur_index, 0)),
        }
        k_cache = (
            new_cache["k"].astype(jnp.bfloat16)
            * new_cache["k_scale"].astype(jnp.bfloat16)[..., None]
        )
        v_cache = (
            new_cache["v"].astype(jnp.bfloat16)
            * new_cache["v_scale"].astype(jnp.bfloat16)[..., None]
        )
    else:
        new_cache = {
            "k": jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cur_index, 0, 0)
            ),
            "v": jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cur_index, 0, 0)
            ),
        }
        k_cache, v_cache = new_cache["k"], new_cache["v"]
    out = attention_decode(q, k_cache, v_cache, cur_index)
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, s, h * dh), p["wo"])
    return y, new_cache


def _block_seq(
    pos: int,
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    flags: RunFlags,
    positions: jax.Array,
    mrope_positions,
    want_cache: bool,
):
    kind = cfg.block_kinds[pos]
    normed = rms_norm(x, p["norm1"], cfg.norm_eps)
    # Megatron-SP boundary: the residual stream is sequence-sharded over the
    # model axis (seq_res rule); block inputs re-gather to full sequence here
    # (lowers to all-gather), and the residual add below reduce-scatters back.
    normed = constrain(normed, ("batch", None, "act_embed"))
    cache = None
    if kind == "attn":
        h, cache = _attn_seq(p["mixer"], normed, cfg, flags, positions, mrope_positions, want_cache)
    else:
        from ..kernels.ssd.ref import ssd_reference

        h = mamba2_apply(
            p["mixer"], normed, cfg, ssd_fn=partial(ssd_reference, chunk=min(flags.ssd_chunk, x.shape[1]))
        )
        if want_cache:
            # sequence-mode cache: rebuild recurrent state for decode handoff
            cache = _mamba_prefill_cache(p["mixer"], normed, cfg, flags)
    x = x + h
    aux = jnp.zeros((), jnp.float32)
    mk = cfg.mlp_kind_at(pos)
    if mk == "dense":
        h2 = constrain(rms_norm(x, p["norm2"], cfg.norm_eps), ("batch", None, "act_embed"))
        x = x + mlp_apply(p["mlp"], h2, cfg.mlp_kind)
    elif mk == "moe":
        from ..sharding.act import current_context

        ctx = current_context()
        if (
            flags.moe_impl == "shard_map"
            and ctx is not None
            and cfg.n_experts % ctx[1].shape.get("model", 1) == 0
        ):
            from .moe_shard_map import moe_apply_shard_map

            rules, mesh = ctx
            h2 = rms_norm(x, p["norm2"], cfg.norm_eps)
            y, aux = moe_apply_shard_map(p["mlp"], h2, cfg, mesh, rules)
        else:
            h2 = constrain(rms_norm(x, p["norm2"], cfg.norm_eps), ("batch", None, "act_embed"))
            y, aux = moe_apply(p["mlp"], h2, cfg)
        x = x + y
    return x, cache, aux


def _mamba_prefill_cache(p: dict, h: jax.Array, cfg: ModelConfig, flags: RunFlags) -> dict:
    """Recompute the (conv window, final SSM state) after a prefill pass."""
    from .mamba2 import _dims, _split
    from ..kernels.ssd.ref import ssd_reference

    d_in, nh, n, conv_dim = _dims(cfg)
    b, s, _ = h.shape
    zxbcdt = jnp.einsum("bsd,de->bse", h, p["in_proj"])
    _, xbc_raw, dt = _split(cfg, zxbcdt)
    from .mamba2 import _causal_conv

    xbc = jax.nn.silu(
        _causal_conv(xbc_raw, p["conv_w"], p["conv_b"]).astype(jnp.float32)
    ).astype(h.dtype)
    xs = xbc[..., :d_in].reshape(b, s, nh, cfg.ssm_head_dim)
    b_mat = xbc[..., d_in : d_in + n]
    c_mat = xbc[..., d_in + n :]
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    _, h_final = ssd_reference(
        xs, dt_sp, a, b_mat, c_mat, chunk=min(flags.ssd_chunk, s)
    )
    w = cfg.ssm_conv_width
    return {"conv": xbc_raw[:, s - (w - 1) :, :], "ssm": h_final}


def _block_decode(
    pos: int,
    p: dict,
    x: jax.Array,
    cfg: ModelConfig,
    cache: dict,
    cur_index: jax.Array,
    mrope_positions,
):
    kind = cfg.block_kinds[pos]
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        h, new_cache = _attn_decode(p["mixer"], h, cfg, cache, cur_index, mrope_positions)
    else:
        h, new_cache = mamba2_decode(p["mixer"], h, cache, cfg)
    x = x + h
    mk = cfg.mlp_kind_at(pos)
    if mk == "dense":
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.mlp_kind)
    elif mk == "moe":
        y, _ = moe_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        x = x + y
    return x, new_cache


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def cast_params(params, compute_dtype):
    """Mixed-precision policy: matrices compute in bf16; the MoE router and
    the fp32 SSM scalars (a_log, dt_bias, d_skip) and all 1-D norm scales
    keep full precision."""

    def cast(path, a):
        name = str(path[-1]) if path else ""
        if "router" in name:
            return a
        if a.ndim >= 2 and a.dtype == jnp.float32:
            return a.astype(compute_dtype)
        return a

    return jax.tree_util.tree_map_with_path(cast, params)


def _embed(params: dict, cfg: ModelConfig, batch: Dict[str, jax.Array], compute_dtype):
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["embeds"]
    x = x.astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    return x


def _head(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and "embed" in params:
        w = params["embed"].T.astype(x.dtype)
    else:
        w = params["lm_head"].astype(x.dtype)
    # bf16 matmul with fp32 accumulation — logits feed the fp32 CE loss
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    return constrain(logits, ("batch", "seq", "act_vocab"))


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------
def forward(
    params: dict,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    flags: RunFlags = RunFlags(),
    compute_dtype=jnp.bfloat16,
    want_cache: bool = False,
):
    """Sequence-mode forward: returns (logits fp32, aux, cache|None)."""
    params = cast_params(params, compute_dtype)
    x = _embed(params, cfg, batch, compute_dtype)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    mrope_positions = batch.get("mrope_positions")

    x = constrain(x, ("batch", "seq_res", "act_embed"))

    def cycle_body(carry, cycle_params):
        x, aux = carry
        caches = []
        for pos in range(cfg.cycle_len):
            x, cache, a = _block_seq(
                pos, cycle_params[pos], x, cfg, flags, positions, mrope_positions, want_cache
            )
            x = constrain(x, ("batch", "seq_res", "act_embed"))
            aux = aux + a
            caches.append(cache)
        out = tuple(caches) if want_cache else None
        return (x, aux), out

    body = cycle_body
    if flags.remat == "full":
        body = jax.checkpoint(cycle_body)
    elif flags.remat == "dots":
        body = jax.checkpoint(
            cycle_body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    logits = _head(params, cfg, x).astype(jnp.float32)
    return logits, aux, caches


def forward_hidden(
    params: dict,
    cfg: ModelConfig,
    batch: Dict[str, jax.Array],
    flags: RunFlags = RunFlags(),
    compute_dtype=jnp.bfloat16,
):
    """Forward without the LM head: returns (hidden (B,S,D) post-final-norm
    pre-head, aux). The training loss streams the head over sequence chunks
    (train/step.py) so full fp32 logits never materialize."""
    params = cast_params(params, compute_dtype)
    x = _embed(params, cfg, batch, compute_dtype)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    mrope_positions = batch.get("mrope_positions")
    x = constrain(x, ("batch", "seq_res", "act_embed"))

    def cycle_body(carry, cycle_params):
        x, aux = carry
        for pos in range(cfg.cycle_len):
            x, _, a = _block_seq(
                pos, cycle_params[pos], x, cfg, flags, positions, mrope_positions, False
            )
            x = constrain(x, ("batch", "seq_res", "act_embed"))
            aux = aux + a
        return (x, aux), None

    body = cycle_body
    if flags.remat == "full":
        body = jax.checkpoint(cycle_body)
    elif flags.remat == "dots":
        body = jax.checkpoint(
            cycle_body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def head_matrix(params: dict, cfg: ModelConfig, compute_dtype=jnp.bfloat16):
    params = cast_params(params, compute_dtype)
    if cfg.tie_embeddings and "embed" in params:
        return params["embed"].T.astype(compute_dtype)
    return params["lm_head"].astype(compute_dtype)


def init_cache(
    cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16, kv_quant: str = "none"
):
    """Decode cache pytree: per cycle position, stacked over cycles.
    ``kv_quant='int8'`` stores KV as int8 with a per-(token, head) absmax
    scale — halving both the cache footprint and the decode HBM-read term
    (the dominant roofline term of every decode cell)."""
    caches = []
    for pos, kind in enumerate(cfg.block_kinds):
        if kind == "attn":
            shape = (cfg.n_cycles, batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
            if kv_quant == "int8":
                sshape = shape[:-1]
                caches.append(
                    {
                        "k": jnp.zeros(shape, jnp.int8),
                        "v": jnp.zeros(shape, jnp.int8),
                        "k_scale": jnp.zeros(sshape, jnp.bfloat16),
                        "v_scale": jnp.zeros(sshape, jnp.bfloat16),
                    }
                )
                continue
            caches.append({"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)})
        else:
            c = mamba2_cache_init(cfg, batch, dtype)
            caches.append(
                jax.tree.map(lambda a: jnp.zeros((cfg.n_cycles,) + a.shape, a.dtype), c)
            )
    return tuple(caches)


def decode_step(
    params: dict,
    cfg: ModelConfig,
    cache,
    batch: Dict[str, jax.Array],
    cur_index: jax.Array,
    flags: RunFlags = RunFlags(),
    compute_dtype=jnp.bfloat16,
):
    """serve_step: one new token against the cache. Returns (logits, cache)."""
    params = cast_params(params, compute_dtype)
    x = _embed(params, cfg, batch, compute_dtype)
    mrope_positions = batch.get("mrope_positions")

    def cycle_body(x, inp):
        cycle_params, cycle_cache = inp
        new_caches = []
        for pos in range(cfg.cycle_len):
            x, nc = _block_decode(
                pos, cycle_params[pos], x, cfg, cycle_cache[pos], cur_index, mrope_positions
            )
            new_caches.append(nc)
        return x, tuple(new_caches)

    x, new_cache = jax.lax.scan(cycle_body, x, (params["layers"], cache))
    logits = _head(params, cfg, x).astype(jnp.float32)
    return logits, new_cache
