"""AdamW with global-norm clipping and warmup+cosine schedule (pure pytree
implementation — optimizer state shards exactly like the parameters)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.peak_lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda a: jnp.zeros(a.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params), "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(
    grads, state: Dict[str, Any], params, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    count = state["count"] + 1
    lr = schedule(count, cfg)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, state["v"], grads)
    bc1 = 1 - cfg.b1**count.astype(jnp.float32)
    bc2 = 1 - cfg.b2**count.astype(jnp.float32)

    def upd(p, m_, v_):
        step_ = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)
        return (p.astype(jnp.float32) - lr * (step_ + cfg.weight_decay * p.astype(jnp.float32))).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, {"m": m, "v": v, "count": count}, {"lr": lr, "grad_norm": gnorm}
