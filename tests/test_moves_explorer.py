"""Move symmetry/legality (paper §3.3) and explorer behaviour (§3.4, §5.2)."""
import random

import pytest
from _optional_hypothesis import given, settings, st

from repro.core import (
    Design,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    ar_complex,
    calibrated_budget,
    distance,
    edge_detection,
    simulate,
)
from repro.core.blocks import BlockKind
from repro.core.moves import (
    MOVE_KINDS,
    apply_fork,
    apply_join,
    apply_migrate,
    apply_move,
    apply_swap,
)


def _design_and_graph():
    g = edge_detection()
    return Design.base(g), g


def _check_invariants(d: Design, g) -> None:
    """Every task mapped to an existing PE/MEM; every route resolvable."""
    for t in g.tasks:
        assert d.task_pe[t] in d.blocks
        assert d.task_mem[t] in d.blocks
        assert d.blocks[d.task_pe[t]].kind == BlockKind.PE
        assert d.blocks[d.task_mem[t]].kind == BlockKind.MEM
        assert len(d.route(t)) >= 1
    for name in d.attached_noc.values():
        assert name in d.blocks


def test_swap_symmetry():
    d, g = _design_and_graph()
    pe = d.pes()[0]
    before = d.blocks[pe].signature()
    assert apply_swap(d, g, pe, +1)
    assert d.blocks[pe].signature() != before
    assert apply_swap(d, g, pe, -1)
    assert d.blocks[pe].signature() == before


def test_fork_then_join_restores_count():
    d, g = _design_and_graph()
    n0 = d.block_counts()["pe"]
    assert apply_fork(d, g, d.pes()[0], task_name="grad_x")
    assert d.block_counts()["pe"] == n0 + 1
    new_pe = d.task_pe["grad_x"]
    assert apply_join(d, g, new_pe)
    assert d.block_counts()["pe"] == n0
    _check_invariants(d, g)


def test_fork_requires_splittable_load():
    """Fork must never orphan a single-task block (the zombie-PE bug)."""
    d, g = _design_and_graph()
    assert apply_fork(d, g, d.pes()[0], task_name="grad_x")
    solo_pe = d.task_pe["grad_x"]
    assert len(d.tasks_on_pe(solo_pe)) == 1
    assert not apply_fork(d, g, solo_pe, task_name="grad_x")


def test_fork_anchor_task_refused_and_mover_set_exact():
    """Regression: fork with task_name == hosted[0] (the anchor) used to
    silently migrate a *different* task via the `or hosted[1:2]` fallback —
    it must refuse instead, and an applicable targeted fork must move
    EXACTLY the requested task (nothing else)."""
    d, g = _design_and_graph()
    pe = d.pes()[0]
    hosted = d.tasks_on_pe(pe)
    assert len(hosted) >= 3
    before = dict(d.task_pe)
    # anchor request: inapplicable, and the design must be untouched
    assert not apply_fork(d, g, pe, task_name=hosted[0])
    assert d.task_pe == before and d.block_counts()["pe"] == 1
    # targeted request: exactly the requested task moves
    assert apply_fork(d, g, pe, task_name=hosted[1])
    moved = [t for t in before if d.task_pe[t] != before[t]]
    assert moved == [hosted[1]]
    # untargeted request: the anchor stays, half the rest moves over
    d2, _ = _design_and_graph()
    pe2 = d2.pes()[0]
    hosted2 = d2.tasks_on_pe(pe2)
    before2 = dict(d2.task_pe)
    assert apply_fork(d2, g, pe2, task_name=None)
    moved2 = {t for t in before2 if d2.task_pe[t] != before2[t]}
    assert moved2 == set(hosted2[1::2]) and hosted2[0] not in moved2


def test_noc_fork_join_record_encodable_deltas():
    """NoC fork/join record chain + attachment edits (not topology=True):
    the delta names the inserted/removed NoC, its chain anchor, and every
    re-homed block — the prerequisite for device-priced topology moves."""
    from repro.core.blocks import BlockKind
    from repro.core.moves import MoveDelta

    d, g = _design_and_graph()
    from repro.core.blocks import make_gpp, make_mem

    d.add_block(make_gpp(), attach_to=d.noc_chain[0])
    d.add_block(make_mem(), attach_to=d.noc_chain[0])
    noc0 = d.noc_chain[0]
    delta = MoveDelta()
    assert apply_fork(d, g, noc0, delta=delta)
    assert not delta.topology
    assert len(delta.added) == 1 and delta.added[0].kind == BlockKind.NOC
    new = delta.added[0].name
    assert delta.noc_after == noc0 and d.noc_chain == [noc0, new]
    # every block the fork re-homed is recorded, with its new NoC
    rehomed = {b for b, n in d.attached_noc.items() if n == new}
    assert rehomed and delta.attached == {b: new for b in rehomed}

    delta2 = MoveDelta()
    assert apply_join(d, g, new, delta=delta2)
    assert not delta2.topology
    assert delta2.removed == [new]
    assert delta2.attached == {b: noc0 for b in rehomed}
    assert d.noc_chain == [noc0]


def test_join_last_block_fails():
    d, g = _design_and_graph()
    assert not apply_join(d, g, d.pes()[0])  # only PE
    assert not apply_join(d, g, d.mems()[0])  # only MEM
    assert not apply_join(d, g, d.nocs()[0])  # only NoC


def test_migrate_moves_task_and_buffer():
    d, g = _design_and_graph()
    apply_fork(d, g, d.pes()[0], task_name="grad_x")
    src = d.task_pe["grad_y"]
    assert apply_migrate(d, g, "grad_y", bottleneck="pe")
    assert d.task_pe["grad_y"] != src
    # buffer migrate needs a second memory
    from repro.core.blocks import make_mem

    d.add_block(make_mem("sram"), attach_to=d.noc_chain[0])
    src_m = d.task_mem["grad_y"]
    assert apply_migrate(d, g, "grad_y", bottleneck="mem")
    assert d.task_mem["grad_y"] != src_m
    _check_invariants(d, g)


def test_noc_fork_splits_attachments():
    d, g = _design_and_graph()
    from repro.core.blocks import make_gpp, make_mem

    d.add_block(make_gpp(), attach_to=d.noc_chain[0])
    d.add_block(make_mem(), attach_to=d.noc_chain[0])
    assert apply_fork(d, g, d.noc_chain[0])
    assert len(d.noc_chain) == 2
    _check_invariants(d, g)


@given(st.lists(st.tuples(st.sampled_from(MOVE_KINDS), st.integers(0, 10**6)), max_size=25))
@settings(max_examples=20, deadline=None)
def test_random_move_sequences_keep_invariants(moves):
    """Any sequence of (possibly failing) moves leaves a simulatable design."""
    db = HardwareDatabase()
    g = edge_detection()
    d = Design.base(g)
    rng = random.Random(0)
    tasks = sorted(g.tasks)
    for kind, seed in moves:
        r = random.Random(seed)
        block = r.choice(list(d.blocks))
        task = r.choice(tasks)
        apply_move(
            d, g, kind, block, task, r.choice([-1, 1]),
            r.choice(["pe", "mem", "noc"]), r.choice(["latency", "power", "area"]), rng,
        )
        _check_invariants(d, g)
    simulate(d, g, db)  # must still simulate


# ---------------------------------------------------------------------------
# explorer
# ---------------------------------------------------------------------------
def test_farsi_converges_on_ar_complex():
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    ex = Explorer(g, db, bud, ExplorerConfig(awareness="farsi", max_iterations=500, seed=1))
    res = ex.run()
    assert res.converged, res.best_distance.per_metric
    # development-cost sanity: no more blocks than tasks + a few
    counts = res.best_design.block_counts()
    assert counts["pe"] <= len(g.tasks) + 4


def test_awareness_ordering():
    """§5.2: naive SA must be far behind FARSI at equal iteration budget."""
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    dists = {}
    for level in ("farsi", "sa"):
        ex = Explorer(g, db, bud, ExplorerConfig(awareness=level, max_iterations=250, seed=3))
        res = ex.run()
        dists[level] = res.best_distance.city_block()
    assert dists["farsi"] < dists["sa"]


def test_codesign_ledger_populates():
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    ex = Explorer(g, db, bud, ExplorerConfig(max_iterations=60, seed=0))
    res = ex.run()
    summary = res.ledger.summary()
    assert set(summary) == {"metric", "workload", "comm_comp", "opt_level"}
    assert res.ledger.move_histogram()


def test_budget_relaxation_reduces_complexity():
    """§6.1 mechanism: a 4× relaxed budget must not need a more complex
    system (block count monotonicity in expectation)."""
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    counts = {}
    for scale in (1.0, 4.0):
        ex = Explorer(g, db, bud.scaled(scale), ExplorerConfig(max_iterations=400, seed=5))
        res = ex.run()
        c = res.best_design.block_counts()
        counts[scale] = c["pe"] + c["mem"] + c["noc"]
    assert counts[4.0] <= counts[1.0]
