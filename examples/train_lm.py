"""End-to-end training driver: synthetic data → sharded train state →
jit'd train step (remat + grad accumulation) → checkpoints + supervisor
(fault-tolerant) → loss curve.

Presets scale from CI-friendly to the 100M-param reference run:

  PYTHONPATH=src python examples/train_lm.py --preset 2m --steps 200
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300   # real HW

On this CPU container the 2m preset runs in ~2 minutes; the 100m preset is
the deliverable configuration for a TPU host (same code path).
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import for_model
from repro.models.model import RunFlags
from repro.optim.adamw import AdamWConfig
from repro.runtime.health import Supervisor
from repro.train.step import init_train_state, make_train_step

PRESETS = {
    "tiny": dict(n_layers=2, d_model=64, d_ff=256, n_heads=4, n_kv_heads=2, vocab=512,
                 batch=4, seq=64),
    "2m": dict(n_layers=4, d_model=128, d_ff=512, n_heads=4, n_kv_heads=2, vocab=2048,
               batch=8, seq=128),
    "20m": dict(n_layers=8, d_model=256, d_ff=1024, n_heads=8, n_kv_heads=4, vocab=8192,
                batch=8, seq=256),
    "100m": dict(n_layers=12, d_model=768, d_ff=2048, n_heads=12, n_kv_heads=4, vocab=32768,
                 batch=32, seq=512),
}


def make_config(p) -> ModelConfig:
    return ModelConfig(
        name="train-lm",
        n_layers=p["n_layers"],
        d_model=p["d_model"],
        vocab_size=p["vocab"],
        n_heads=p["n_heads"],
        n_kv_heads=p["n_kv_heads"],
        head_dim=p["d_model"] // p["n_heads"],
        d_ff=p["d_ff"],
        rope_kind="rope",
        tie_embeddings=True,
        block_kinds=("attn",),
        mlp_kinds=("dense",),
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="2m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = make_config(p)
    n_params = cfg.param_counts()["total"]
    print(f"preset={args.preset}: {n_params/1e6:.1f}M params, "
          f"{p['batch']}×{p['seq']} tokens/step, devices={jax.device_count()}")

    data = for_model(cfg, seq_len=p["seq"], global_batch=p["batch"], seed=0)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(
        make_train_step(cfg, RunFlags(attn_impl="auto", remat="none"), opt,
                        microbatches=args.microbatches)
    )

    ckpt = CheckpointManager(args.ckpt_dir, keep_n=2, async_save=True)
    sup = Supervisor(ckpt, data, save_every=args.save_every)
    losses = []
    t0 = time.perf_counter()

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % 10 == 0 or step == 1:
            dt = time.perf_counter() - t0
            tps = step * p["batch"] * p["seq"] / dt
            print(f"step {step:4d}  loss={losses[-1]:.4f}  lr={float(m['lr']):.2e} "
                  f"gnorm={float(m['grad_norm']):.2f}  {tps:,.0f} tok/s")

    state = sup.run(state, step_fn, args.steps, restore_fn=lambda: ckpt.restore(state),
                    on_metrics=on_metrics)
    print(f"\nfinal: loss {losses[0]:.3f} → {losses[-1]:.3f} over {args.steps} steps "
          f"({time.perf_counter()-t0:.0f}s); stragglers flagged: {len(sup.monitor.flagged)}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
