"""Fused Pallas phase-sim kernel ≡ the vmap'd XLA oracle ≡ the Python
simulator — across pow2 batch buckets and both paper workload scales, with
interpret mode forced so CPU tier-1 exercises the REAL kernel path (grid,
block specs, VMEM scratch, padded-task masking), not just the oracle."""
import numpy as np
import pytest

from repro.core import (
    HardwareDatabase,
    PythonBackend,
    ar_complex,
    audio,
    calibrated_budget,
    make_backend,
    random_single_noc_designs,
)
from repro.core.phase_sim_jax import EncodedWorkload, encode_batch, fill_budget

KERNEL_REL_TOL = 1e-5  # acceptance bar: Pallas vs ref parity
# every output the kernel must reproduce (bit-compatible math, f32 rounding)
_CHECK_KEYS = (
    "latency_s", "finish_s", "bneck_code", "bneck_kind_s", "alp_time_s",
    "traffic_bytes", "n_phases", "wl_latency_s", "energy_j", "power_w",
    "area_mm2", "fitness", "all_done",
)


@pytest.mark.parametrize("graph_fn", [audio, ar_complex])
@pytest.mark.parametrize(
    "batch", [1, 8, pytest.param(64, marks=pytest.mark.slow)]
)
def test_kernel_matches_ref_oracle(graph_fn, batch):
    """Interpret-mode kernel vs the pure-jnp oracle, every output column,
    ≤ 1e-5 relative — including the Eq.-7 fitness the explorer ranks by."""
    import jax

    from repro.kernels.phase_sim import phase_sim, phase_sim_ref

    db = HardwareDatabase()
    g = graph_fn()
    enc = EncodedWorkload.of(g)
    designs = random_single_noc_designs(g, batch, seed=batch + 1)
    bud = calibrated_budget(db)
    rows = encode_batch(designs, g, db, enc)
    for j in range(batch):
        fill_budget(rows, j, enc, bud.latency_s, bud.power_w, bud.area_mm2, 0.05)
    ref = jax.jit(lambda r: phase_sim_ref(enc, r))(rows)
    got = jax.jit(lambda r: phase_sim(enc, r, interpret=True))(rows)
    assert set(_CHECK_KEYS) <= set(got)
    for k in _CHECK_KEYS:
        a = np.asarray(ref[k], np.float64)
        b = np.asarray(got[k], np.float64)
        assert a.shape == b.shape, k
        rel = np.max(np.abs(a - b) / np.maximum(np.abs(a), 1e-12)) if a.size else 0.0
        assert rel <= KERNEL_REL_TOL, (k, rel)
    # integer outputs keep integer dtypes through the packed scal block
    assert np.asarray(got["bneck_code"]).dtype == np.int32
    assert np.asarray(got["n_phases"]).dtype == np.int32
    assert np.asarray(got["all_done"]).dtype == bool


@pytest.mark.parametrize("graph_fn", [audio, ar_complex])
def test_pallas_backend_matches_python(graph_fn, monkeypatch):
    """The registered "pallas" backend (kernel forced through interpret mode
    on CPU) prices designs identically to the scalar Python simulator."""
    db = HardwareDatabase()
    g = graph_fn()
    designs = random_single_noc_designs(g, 8, seed=13)
    jb = make_backend("pallas", g, db)
    assert jb.name == "jax_pallas"
    got = jb.evaluate(designs)
    ref = PythonBackend(g, db).evaluate(designs)
    for i, (a, b) in enumerate(zip(ref, got)):
        assert abs(a.latency_s - b.latency_s) / a.latency_s < 1e-4, i
        for t in a.task_finish_s:
            r = max(a.task_finish_s[t], 1e-12)
            assert abs(a.task_finish_s[t] - b.task_finish_s[t]) / r < 1e-4, (i, t)
        assert a.task_bottleneck == b.task_bottleneck, i
        assert abs(a.power_w - b.power_w) / a.power_w < 1e-3, i
        assert abs(a.area_mm2 - b.area_mm2) / a.area_mm2 < 1e-6, i


def test_kernel_env_var_forces_kernel_path(monkeypatch):
    """REPRO_PHASE_SIM_KERNEL=1 flips the default backend onto the kernel."""
    from repro.core import JaxBatchedBackend

    db = HardwareDatabase()
    g = audio()
    monkeypatch.setenv("REPRO_PHASE_SIM_KERNEL", "1")
    assert JaxBatchedBackend(g, db).name == "jax_pallas"
    monkeypatch.setenv("REPRO_PHASE_SIM_KERNEL", "0")
    assert JaxBatchedBackend(g, db).name == "jax"
