"""Event-driven reference simulator — the Synopsys Platform Architect stand-in
(paper §4).

PA's "approximately timed" mode advances on *transactions* and fixed time
intervals. We model each task as a three-stage pipeline (read-burst → compute
→ write-burst) over its chunks, re-arbitrating contention at **every** stage-
completion event: this captures the intra-phase congestion transients that the
phase-driven model deliberately averages away (§4: "we do not model
intermittent congestion ... and rather assume constant congestion for a
phase"), which is exactly where the two simulators diverge.

Granularity is ``burst_bytes`` per transaction (the paper sets PA's interval
to 10 µs ≈ 1000–10000 block cycles); ``max_chunks`` caps event counts for very
fine bursts. Each transaction additionally pays a protocol *header*
(``NOC_HEADER_BYTES`` per burst per hop) — transaction-level overhead the
analytical Gables rates deliberately do not model, which is what gives the
phase simulator a real (small, burst-size-dependent) error against this
reference: small-burst, communication-heavy tasks err most, matching the
paper's observation that buses show the highest fidelity sensitivity (§4).
The phase simulator's accuracy/speedup numbers in EXPERIMENTS.md are measured
against this reference.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

from .database import HardwareDatabase
from .design import Design
from .gables import RouteContext
from .phase_sim import SimResult
from .ppa import mem_capacities, total_area_mm2, total_leakage_w
from .tdg import TaskGraph, workload_of

_EPS = 1e-12
_STAGES = ("read", "compute", "write")
NOC_HEADER_BYTES = 8.0  # per burst per transaction (protocol overhead)


@dataclasses.dataclass
class _PipeState:
    n_chunks: int
    per_chunk: Dict[str, float]  # work per chunk per stage
    done_chunks: Dict[str, int]
    stage_remaining: Dict[str, float]  # remaining work in the in-flight chunk

    @staticmethod
    def of(task, max_chunks: int) -> "_PipeState":
        n = int(max(1.0, min(task.read_bytes / max(task.burst_bytes, 1.0), max_chunks)))
        # transaction header: each burst carries protocol bytes the analytic
        # model ignores (scaled so the cap on n preserves total overhead)
        n_true = max(task.read_bytes / max(task.burst_bytes, 1.0), 1.0)
        hdr = NOC_HEADER_BYTES * n_true / n
        per = {
            "read": task.read_bytes / n + hdr,
            "compute": task.work_ops / n,
            "write": task.write_bytes / n + hdr,
        }
        return _PipeState(
            n_chunks=n,
            per_chunk=per,
            done_chunks={s: 0 for s in _STAGES},
            stage_remaining={s: 0.0 for s in _STAGES},
        )

    def stage_active(self, stage: str) -> bool:
        i = _STAGES.index(stage)
        if self.done_chunks[stage] >= self.n_chunks:
            return False
        if self.stage_remaining[stage] > _EPS:
            return True
        # can a new chunk enter this stage? (upstream must be ahead)
        if i == 0:
            return True
        return self.done_chunks[_STAGES[i - 1]] > self.done_chunks[stage]

    def ensure_inflight(self) -> None:
        for s in _STAGES:
            if self.stage_active(s) and self.stage_remaining[s] <= _EPS:
                self.stage_remaining[s] = self.per_chunk[s]

    def complete(self) -> bool:
        return all(self.done_chunks[s] >= self.n_chunks for s in _STAGES)


def _stage_rates(
    design: Design,
    tdg: TaskGraph,
    pipes: Dict[str, _PipeState],
    running: List[str],
    db: HardwareDatabase,
    ctx: RouteContext,
) -> Dict[str, Dict[str, float]]:
    """Rates for the *currently active* stage instances only — this is the
    transaction-level re-arbitration."""
    active = {
        t: [s for s in _STAGES if pipes[t].stage_active(s)] for t in running
    }
    # PE contention: equal share among tasks actively computing on the PE
    pe_users: Dict[str, int] = {}
    for t in running:
        if "compute" in active[t]:
            pe = design.task_pe[t]
            pe_users[pe] = pe_users.get(pe, 0) + 1
    # Mem contention per direction: burst-proportional among active users
    mem_burst: Dict[tuple, float] = {}
    for t in running:
        mem = design.task_mem[t]
        b = tdg.tasks[t].burst_bytes
        if "read" in active[t]:
            mem_burst[(mem, "read")] = mem_burst.get((mem, "read"), 0.0) + b
        if "write" in active[t]:
            mem_burst[(mem, "write")] = mem_burst.get((mem, "write"), 0.0) + b
    # NoC: striped links, burst-proportional within link, per direction
    noc_users: Dict[str, List[str]] = {}
    for t in sorted(running):
        for noc_name in ctx.route(t):
            noc_users.setdefault(noc_name, []).append(t)
    noc_link_burst: Dict[tuple, float] = {}
    link_of: Dict[tuple, int] = {}
    for noc_name, users in noc_users.items():
        n_links = design.blocks[noc_name].n_links
        for i, t in enumerate(users):
            link = i % n_links
            link_of[(t, noc_name)] = link
            b = tdg.tasks[t].burst_bytes
            for d in ("read", "write"):
                if d in active[t]:
                    key = (noc_name, link, d)
                    noc_link_burst[key] = noc_link_burst.get(key, 0.0) + b

    rates: Dict[str, Dict[str, float]] = {}
    for t in running:
        task = tdg.tasks[t]
        pe = design.blocks[design.task_pe[t]]
        mem = design.blocks[design.task_mem[t]]
        r: Dict[str, float] = {}
        if "compute" in active[t]:
            p = db.pe_peak_ops(pe) / pe_users[pe.name]
            if pe.subtype == "acc" and pe.hardened_for == t:
                p *= db.a_peak(t, task.llp, pe.unroll)
            r["compute"] = p
        for d in ("read", "write"):
            if d in active[t]:
                share = task.burst_bytes / mem_burst[(mem.name, d)]
                bw = mem.peak_bandwidth(db) * share
                for noc_name in ctx.route(t):
                    noc = design.blocks[noc_name]
                    link = link_of[(t, noc_name)]
                    tot = noc_link_burst[(noc_name, link, d)]
                    bw = min(bw, noc.peak_bandwidth(db) * (task.burst_bytes / tot))
                r[d] = bw
        rates[t] = r
    return rates


def simulate_events(
    design: Design,
    tdg: TaskGraph,
    db: HardwareDatabase,
    max_chunks: int = 256,
    max_events: int = 5_000_000,
) -> SimResult:
    pipes = {t: _PipeState.of(task, max_chunks) for t, task in tdg.tasks.items()}
    completed: set = set()
    finish_s: Dict[str, float] = {}
    energy_pj = 0.0
    now = 0.0
    n_events = 0
    bneck_s = {"pe": 0.0, "mem": 0.0, "noc": 0.0}
    ctx = RouteContext(design, tdg)

    while len(completed) < len(tdg.tasks):
        running = [
            t
            for t in tdg.tasks
            if t not in completed and all(p in completed for p in tdg.parents[t])
        ]
        assert running, "deadlock"
        for t in running:
            pipes[t].ensure_inflight()
        rates = _stage_rates(design, tdg, pipes, running, db, ctx)

        # next event = earliest in-flight stage completion
        dt = float("inf")
        for t in running:
            for s, rate in rates[t].items():
                rem = pipes[t].stage_remaining[s]
                if rem > _EPS and rate > 0:
                    dt = min(dt, rem / rate)
        assert dt < float("inf"), "no active stage"
        dt = max(dt, _EPS)
        n_events += 1
        if n_events > max_events:
            raise RuntimeError("event simulation exceeded max_events")

        for t in running:
            task = tdg.tasks[t]
            pe = design.blocks[design.task_pe[t]]
            mem = design.blocks[design.task_mem[t]]
            hops = ctx.hops[t]
            slowest, slow_s = 0.0, "pe"
            for s, rate in rates[t].items():
                rem = pipes[t].stage_remaining[s]
                if rem <= _EPS:
                    continue
                d = min(rem, rate * dt)
                pipes[t].stage_remaining[s] = rem - d
                if pipes[t].stage_remaining[s] <= _EPS * max(1.0, rem):
                    pipes[t].stage_remaining[s] = 0.0
                    pipes[t].done_chunks[s] += 1
                if s == "compute":
                    energy_pj += db.compute_energy_pj(pe, d)
                else:
                    energy_pj += db.mem_energy_pj(mem, d)
                    energy_pj += db.noc_energy_pj(d * hops)
                t_need = rem / rate
                if t_need > slowest:
                    slowest, slow_s = t_need, s
            bneck_s["pe" if slow_s == "compute" else "mem"] += dt

        now += dt
        for t in running:
            if pipes[t].complete():
                completed.add(t)
                finish_s[t] = now

    energy_j = energy_pj * 1e-12 + total_leakage_w(design, db) * now
    wl_latency: Dict[str, float] = {}
    for t, f in finish_s.items():
        w = workload_of(t) if "." in t else tdg.name
        wl_latency[w] = max(wl_latency.get(w, 0.0), f)
    return SimResult(
        latency_s=now,
        workload_latency_s=wl_latency,
        energy_j=energy_j,
        power_w=energy_j / now if now else 0.0,
        area_mm2=total_area_mm2(design, tdg, db),
        n_phases=n_events,
        bottleneck_s=bneck_s,
        task_bottleneck={},
        task_finish_s=finish_s,
        mem_capacity_bytes=mem_capacities(design, tdg),
    )
