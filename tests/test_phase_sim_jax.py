"""Vectorized phase simulator ≡ the Python reference (single-NoC regime)."""
import random

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Design, HardwareDatabase, ar_complex, edge_detection, simulate
from repro.core.blocks import make_accelerator, make_gpp, make_mem
from repro.core.phase_sim_jax import EncodedWorkload, encode_batch, simulate_batch


def _random_single_noc_designs(g, n, seed=0):
    rng = random.Random(seed)
    designs = []
    for _ in range(n):
        d = Design.base(g)
        noc = d.noc_chain[0]
        tasks = sorted(g.tasks)
        for _ in range(rng.randint(0, 6)):
            if rng.random() < 0.6:
                t = rng.choice(tasks)
                b = d.add_block(make_accelerator(t, rng.choice((100, 400, 800))), attach_to=noc)
                b.unroll = rng.choice((1, 8, 64))
                d.task_pe[t] = b.name
            else:
                d.add_block(make_mem(rng.choice(("dram", "sram")), rng.choice((100, 800)),
                                     rng.choice((32, 256))), attach_to=noc)
        mems = d.mems()
        for t in tasks:
            d.task_mem[t] = rng.choice(mems)
        d.blocks[noc].n_links = rng.choice((1, 2, 4))
        designs.append(d)
    return designs


@pytest.mark.parametrize("graph_fn", [edge_detection, ar_complex])
def test_vectorized_matches_python(graph_fn):
    db = HardwareDatabase()
    g = graph_fn()
    enc = EncodedWorkload.of(g)
    designs = _random_single_noc_designs(g, 8, seed=3)
    batch = encode_batch(designs, g, db, enc)
    out = jax.jit(lambda *a: simulate_batch(enc, *a))(*batch)
    assert bool(out["all_done"].all())
    for i, d in enumerate(designs):
        ref = simulate(d, g, db)
        got = float(out["latency_s"][i])
        assert abs(got - ref.latency_s) / ref.latency_s < 1e-3, (i, got, ref.latency_s)
        # per-task finish times agree too
        for j, name in enumerate(enc.names):
            a, b = float(out["finish_s"][i, j]), ref.task_finish_s[name]
            assert abs(a - b) / max(b, 1e-12) < 1e-3


def test_batch_throughput_smoke():
    """One jit'd call evaluates a whole neighbour batch (the Fig-8 answer)."""
    db = HardwareDatabase()
    g = edge_detection()
    enc = EncodedWorkload.of(g)
    designs = _random_single_noc_designs(g, 32, seed=9)
    batch = encode_batch(designs, g, db, enc)
    out = jax.jit(lambda *a: simulate_batch(enc, *a))(*batch)
    assert out["latency_s"].shape == (32,)
    assert bool(jnp.isfinite(out["latency_s"]).all())
