"""In-loop re-simulation for the device-resident explorer.

The fused accept loop (``repro.core.device_explore``) mutates R chain
encodings per iteration and needs their ``fitness`` plus the per-slot
bottleneck telemetry columns (``pe_bneck_s``/``mem_bneck_s``) back *inside*
the same ``lax.scan`` step — no host round trip. The chains ARE the batch
axis: every scan iteration prices an (R,)-rows dict, which is exactly the
contract of the batched simulator, so the device loop routes through the
fused Pallas kernel (``ops.phase_sim``) when the backend runs with the
kernel enabled and through the XLA reference (``simulate_batch``)
otherwise. Both return the same output dict, which keeps the scan body
layout-agnostic: the carry never stores kernel-specific packing.

Mixed mapping+allocation chains price through the SAME call: allocation
moves are shape-preserving over capacity-padded slot inventories, so a
fork/join/swap/NoC-attach step still hands this function an (R,)-rows dict
— the per-slot coefficient columns are (R, cap) wide with
``pe_active``/``mem_active`` masks pricing inactive slots as absent (zero
leak/area contribution, pad-neutral rates). Nothing here distinguishes a
mapping-only step from a mixed one; the move semantics live entirely in
the carry mutations upstream.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

from ...core.phase_sim_jax import EncodedWorkload, simulate_batch
from .ops import phase_sim

__all__ = ["resimulate_chains"]


def resimulate_chains(  # repro: traced
    enc: EncodedWorkload,
    rows: Dict[str, jnp.ndarray],
    *,
    use_kernel: bool = False,
    interpret: bool = False,
) -> Dict[str, jnp.ndarray]:
    """Price the R mutated chain encodings of one accept-loop iteration.

    ``rows`` is a batched rows dict with the chain axis leading (R designs,
    one per chain). Traced inside the chain scan body, so it must stay a
    pure function of its array inputs — it is, both branches are.
    """
    if use_kernel:
        return phase_sim(enc, rows, interpret=interpret)
    return simulate_batch(enc, rows)
