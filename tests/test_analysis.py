"""repro.analysis acceptance pins.

Every pass must (a) exit clean on the shipped tree and (b) demonstrably
fail on a seeded mutation of the exact bug class it was built for:
reordering a SCAL_COLS entry and narrowing the ChainCarry taboo column
(the PR-9 desync) must trip the contract checker, and a ``float()`` host
sync injected into the fused chain scan must trip the lint. The lint
rules are pinned per-rule with trigger / no-trigger fixture snippets so a
rule that rots (stops firing, or starts firing on the legal idiom) fails
here, not in review.
"""
import json
import os
import subprocess
import sys

from repro.analysis.contracts import (
    CARRY_PREFIX,
    check_chain_carry,
    check_move_codes,
    check_policy_registry,
    check_rollup_anchors,
    check_scal_cols,
    dispatch_mv_names,
    kernel_rollup_sources,
    kernel_rollup_width,
    parse_md_tables,
    state_tuple_fields,
)
from repro.analysis.findings import Finding
from repro.analysis.lint import (
    apply_baseline,
    lint_source,
    write_baseline,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEVEXP = os.path.join(REPO, "src", "repro", "core", "device_explore.py")


def _live(findings, rule=None):
    return [
        f for f in findings
        if f.live and (rule is None or f.rule == rule)
    ]


# ---------------------------------------------------------------------------
# lint rules: one trigger and one no-trigger snippet each
# ---------------------------------------------------------------------------
def test_lint_host_sync_float_in_jitted_fn():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x) + 1\n"
    )
    assert _live(lint_source(src), "host-sync")


def test_lint_host_sync_item_and_asarray():
    src = (
        "import jax, numpy as np\n"
        "def step(c, x):\n"
        "    v = c.item()\n"
        "    w = np.asarray(x)\n"
        "    return c, w\n"
        "def run(xs):\n"
        "    import jax.lax as lax\n"
        "    return lax.scan(step, 0, xs)\n"
    )
    hits = _live(lint_source(src), "host-sync")
    assert len(hits) == 2, hits


def test_lint_host_sync_not_outside_traced_scope():
    src = (
        "def host_only(x):\n"
        "    return float(x)\n"
    )
    assert not _live(lint_source(src))


def test_lint_host_sync_float_on_literal_ok():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x * float('inf')\n"
    )
    assert not _live(lint_source(src), "host-sync")


def test_lint_tracer_branch_flags_jnp_call_test():
    src = (
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    if jnp.sum(x) > 0:\n"
        "        return x\n"
        "    return -x\n"
    )
    assert _live(lint_source(src), "tracer-branch")


def test_lint_tracer_branch_static_config_ok():
    # static-config branches and dtype comparisons are the shipped idiom
    # (backend.packed() selects columns by dtype at trace time)
    src = (
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x, n_noc=1, menu='farsi'):\n"
        "    if n_noc == 1:\n"
        "        x = x + 1\n"
        "    if menu in ('farsi', 'telemetry'):\n"
        "        x = x * 2\n"
        "    y = x if x.dtype == jnp.float32 else x.astype(jnp.float32)\n"
        "    return y\n"
    )
    assert not _live(lint_source(src))


def test_lint_f64_promote_math_call():
    src = (
        "import jax, math\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return math.exp(x)\n"
    )
    assert _live(lint_source(src), "f64-promote")


def test_lint_f64_promote_dtype_kw():
    src = (
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return jnp.zeros((3,), dtype='float64') + x\n"
    )
    assert _live(lint_source(src), "f64-promote")


def test_lint_mutable_closure_append():
    src = (
        "import jax\n"
        "acc = []\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    acc.append(x)\n"
        "    return x\n"
    )
    assert _live(lint_source(src), "mutable-closure")


def test_lint_mutable_closure_local_list_ok():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    acc = []\n"
        "    acc.append(x)\n"
        "    return x\n"
    )
    assert not _live(lint_source(src), "mutable-closure")


def test_lint_mutable_closure_pallas_ref_write_ok():
    # `o_ref[...] = acc` on a closed-over Ref is THE Pallas output idiom
    src = (
        "def kernel(x_ref, o_ref):\n"
        "    def body(i):\n"
        "        o_ref[i] = x_ref[i] * 2\n"
        "    import jax.lax as lax\n"
        "    lax.fori_loop(0, 4, lambda i, _: body(i), None)\n"
        "def call(x):\n"
        "    import jax.experimental.pallas as pl\n"
        "    return pl.pallas_call(kernel)(x)\n"
    )
    assert not _live(lint_source(src), "mutable-closure")


def test_lint_traced_marker_comment():
    # cross-module entry points carry `# repro: traced` — no visible jit
    src = (
        "def hot(x):  # repro: traced\n"
        "    return float(x)\n"
    )
    assert _live(lint_source(src), "host-sync")


def test_lint_vmap_lambda_marks_callee():
    # the shipped simulate_batch shape: vmap over a lambda that calls a
    # same-module def — the callee must inherit the traced scope
    src = (
        "import jax\n"
        "def simulate_one(row):\n"
        "    return float(row)\n"
        "def simulate_batch(rows):\n"
        "    return jax.vmap(lambda r: simulate_one(r))(rows)\n"
    )
    assert _live(lint_source(src), "host-sync")


# ---------------------------------------------------------------------------
# suppression + baseline machinery
# ---------------------------------------------------------------------------
def test_noqa_suppresses_with_reason():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x)  # repro: noqa[host-sync]: proven static here\n"
    )
    fs = lint_source(src)
    assert not _live(fs)
    assert any(f.suppressed for f in fs)


def test_noqa_without_reason_is_its_own_finding():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x)  # repro: noqa[host-sync]\n"
    )
    assert _live(lint_source(src), "noqa-reason")


def test_noqa_wrong_rule_does_not_suppress():
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x)  # repro: noqa[f64-promote]: wrong rule\n"
    )
    assert _live(lint_source(src), "host-sync")


def test_baseline_roundtrip(tmp_path):
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return float(x)\n"
    )
    findings = lint_source(src, path="src/repro/x.py")
    p = tmp_path / "baseline.json"
    write_baseline(findings, str(p))
    base = {
        k: v for k, v in json.loads(p.read_text())["findings"].items()
    }
    after = apply_baseline(lint_source(src, path="src/repro/x.py"), base)
    assert not _live(after)
    assert any(f.baselined for f in after)
    # a NEW finding in the same file stays live
    src2 = src + "\n@jax.jit\ndef g(y):\n    return float(y)\n"
    after2 = apply_baseline(lint_source(src2, path="src/repro/x.py"), base)
    assert _live(after2)


def test_shipped_baseline_is_empty_for_core():
    """Satellite pin: the frozen lint debt must stay empty for
    src/repro/core/ (it is empty for the whole tree today)."""
    with open(os.path.join(
        REPO, "src", "repro", "analysis", "baseline.json"
    )) as fh:
        frozen = json.load(fh)["findings"]
    assert not {
        k: v for k, v in frozen.items() if k.startswith("src/repro/core/")
    }, frozen


# ---------------------------------------------------------------------------
# contracts: pure checks on deliberately-desynced inputs
# ---------------------------------------------------------------------------
_COLS = (
    "latency_s", "energy_j", "power_w", "area_mm2", "fitness",
    "alp_time_s", "traffic_bytes", "n_phases", "all_done",
    "kind_pe_s", "kind_mem_s", "kind_noc_s", "top_bneck_pe", "top_bneck_mem",
)


def test_contract_scal_cols_clean():
    assert not check_scal_cols(_COLS, _COLS, _COLS[:9], 14, 14)


def test_contract_scal_cols_reorder_trips():
    """Acceptance mutation 1: swapping two SCAL_COLS entries on one side
    must produce a finding."""
    swapped = list(_COLS)
    swapped[4], swapped[5] = swapped[5], swapped[4]
    assert check_scal_cols(_COLS, tuple(swapped), _COLS[:9], 14, 14)


def test_contract_scal_cols_single_source_reorder_trips():
    """Acceptance mutation 1, hardened: because every module imports the
    schema from core.scal_layout, a reorder of the single source passes
    the name-diff tautologically — the rollup ANCHORS must catch it
    against the kernel's positional stack."""
    with open(os.path.join(
        REPO, "src", "repro", "kernels", "phase_sim", "kernel.py"
    ), encoding="utf-8") as fh:
        rollup = kernel_rollup_sources(fh.read())
    assert rollup is not None and len(rollup) == 14
    assert not check_rollup_anchors(_COLS, rollup)  # shipped order holds
    swapped = list(_COLS)
    swapped[0], swapped[1] = swapped[1], swapped[0]  # latency_s ↔ energy_j
    assert check_rollup_anchors(tuple(swapped), rollup)
    swapped2 = list(_COLS)
    swapped2[4], swapped2[6] = swapped2[6], swapped2[4]  # fitness ↔ traffic
    assert check_rollup_anchors(tuple(swapped2), rollup)


def test_contract_scal_cols_width_drift_trips():
    assert check_scal_cols(_COLS, _COLS, _COLS[:9], 13, 14)
    assert check_scal_cols(_COLS, _COLS, _COLS[:9], 14, 13)


def test_contract_chain_carry_taboo_narrowed_trips():
    """Acceptance mutation 2 (the PR-9 regression shape): a taboo column
    one row narrower than the move table must produce a finding."""
    fields = CARRY_PREFIX + ("pe_active",)
    ok = check_chain_carry(fields, 120, 120, {"pe_active": 8}, 8, {}, 4)
    assert not ok
    bad = check_chain_carry(fields, 119, 120, {"pe_active": 8}, 8, {}, 4)
    assert bad and any("PR-9" in m for m in bad)


def test_contract_chain_carry_prefix_order_trips():
    fields = ("task_mem", "task_pe") + CARRY_PREFIX[2:]
    assert check_chain_carry(fields, 10, 10, {}, 4, {}, 4)


def test_contract_chain_carry_state_coverage_trips():
    fields = CARRY_PREFIX + ("pe_active", "accel")
    ok = check_chain_carry(
        fields, 10, 10, {}, 4, {}, 4,
        state_fields=("task_pe", "task_mem", "pe_active", "accel"),
    )
    assert not ok
    bad = check_chain_carry(
        fields, 10, 10, {}, 4, {}, 4,
        state_fields=("task_pe", "task_mem", "pe_active"),  # accel dropped
    )
    assert bad and any("accel" in m for m in bad)


def test_contract_move_codes_clean_and_trips():
    codes = {
        "MV_MIG_PE": 0, "MV_MIG_MEM": 1, "MV_FORK_PE": 2, "MV_FORK_MEM": 3,
    }
    assert not check_move_codes(codes, 4, list(codes))
    # sparse enumeration
    assert check_move_codes({**codes, "MV_FORK_MEM": 5}, 4, list(codes))
    # parity convention
    assert check_move_codes(
        {"MV_MIG_PE": 1, "MV_MIG_MEM": 0, "MV_FORK_PE": 2, "MV_FORK_MEM": 3},
        4, list(codes),
    )
    # precedence table too short
    assert check_move_codes(codes, 3, list(codes))
    # dispatch forgets a kind
    assert check_move_codes(codes, 4, ["MV_MIG_PE", "MV_MIG_MEM"])


def test_contract_policy_registry_trips():
    menus = ("naive_sa", "telemetry", "farsi")
    pm = {"naive_sa": "naive_sa", "farsi": "farsi"}
    docs = dict(pm)
    assert not check_policy_registry(pm, menus, docs, list(pm))
    # unknown menu on the class
    assert check_policy_registry(
        {**pm, "farsi": "bogus"}, menus, docs, list(pm)
    )
    # doc disagrees with the class
    assert check_policy_registry(
        pm, menus, {**docs, "farsi": "telemetry"}, list(pm)
    )
    # doc table missing a registered policy
    assert check_policy_registry(
        pm, menus, {"naive_sa": "naive_sa"}, ["naive_sa"]
    )


def test_md_table_parser():
    text = (
        "prose\n\n"
        "| name | selection |\n|---|---|\n| `a` | x |\n| `b` / `c` | y |\n"
        "\nmore prose\n"
    )
    tables = parse_md_tables(text)
    assert len(tables) == 1
    assert tables[0][0] == ["name", "selection"]
    assert len(tables[0]) == 3


# ---------------------------------------------------------------------------
# contracts bound to the real tree
# ---------------------------------------------------------------------------
def test_contracts_hold_on_shipped_tree():
    from repro.analysis.contracts import run_contracts

    findings = run_contracts()
    assert not findings, "\n".join(f.render() for f in findings)


def test_real_taboo_desync_is_caught():
    """PR-9 regression fixture against the REAL fresh_carry: a carry whose
    taboo column is narrower than the real move table must be flagged by
    the same pure check the contract runs."""
    import numpy as np

    from repro.analysis.contracts import _carry_fixture
    from repro.core.device_explore import ChainCarry, MoveTable

    runner, d, ed, cap_pe, cap_mem = _carry_fixture()
    table = MoveTable.of(
        ed, runner.enc, alloc=True, cap_pe=cap_pe, cap_mem=cap_mem
    )
    carry = runner.fresh_carry(
        d, ed, r=2, seed=0, cap_pe=cap_pe, cap_mem=cap_mem, alloc=True
    )
    assert int(carry.taboo.shape[1]) == table.n_moves  # shipped tree holds
    narrowed = carry._replace(taboo=np.asarray(carry.taboo)[:, :-1])
    msgs = check_chain_carry(
        ChainCarry._fields, int(narrowed.taboo.shape[1]), table.n_moves,
        {}, cap_pe, {}, cap_mem,
    )
    assert msgs and any("PR-9" in m for m in msgs)


def test_real_dispatch_and_state_extractors_bind():
    with open(DEVEXP, encoding="utf-8") as fh:
        src = fh.read()
    assert len(dispatch_mv_names(src)) == 10
    state = state_tuple_fields(src)
    assert state is not None and len(state) == 20


def test_kernel_rollup_width_binds():
    with open(os.path.join(
        REPO, "src", "repro", "kernels", "phase_sim", "kernel.py"
    ), encoding="utf-8") as fh:
        assert kernel_rollup_width(fh.read()) == 14


# ---------------------------------------------------------------------------
# acceptance mutation 3: float() injected into the fused chain scan
# ---------------------------------------------------------------------------
def test_fused_block_source_lints_clean():
    with open(DEVEXP, encoding="utf-8") as fh:
        src = fh.read()
    assert not _live(lint_source(src, path="src/repro/core/device_explore.py"))


def test_injected_host_sync_in_chain_scan_is_caught():
    """Textually seed a `float(...)` host sync into the fused block's
    accept step (the `t_it = ...` temperature line inside the scanned
    step) and assert the lint flags it — the scan body is three lexical
    levels below the jit, so this pins the whole scope-propagation
    chain."""
    with open(DEVEXP, encoding="utf-8") as fh:
        src = fh.read()
    needle = "def block(carry, it0, row0, kind, arg, dest):"
    assert needle in src
    mutated = src.replace(
        needle,
        needle + "\n            _leak = float(it0)", 1,
    )
    hits = _live(
        lint_source(mutated, path="src/repro/core/device_explore.py"),
        "host-sync",
    )
    assert hits and any("float" in f.message for f in hits)


# ---------------------------------------------------------------------------
# jaxpr audit
# ---------------------------------------------------------------------------
def test_jaxpr_audit_flags_callback():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import audit_jaxpr

    def leaky(x):
        return jax.pure_callback(
            lambda v: v, jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )

    jx = jax.make_jaxpr(leaky)(jnp.zeros((3,), jnp.float32))
    assert audit_jaxpr("leaky", jx, "x.py")


def test_jaxpr_audit_require_missing():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import audit_jaxpr

    jx = jax.make_jaxpr(lambda x: x + 1)(jnp.zeros((3,), jnp.float32))
    assert audit_jaxpr("plain", jx, "x.py", require=("pallas_call",))


def test_jaxpr_audit_recurses_into_scan():
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import collect_primitives

    def f(xs):
        return jax.lax.scan(lambda c, x: (c + jnp.sin(x), c), 0.0, xs)

    prims = collect_primitives(jax.make_jaxpr(f)(jnp.zeros(4)))
    assert "sin" in prims  # lives inside the scan body's sub-jaxpr


def test_jaxpr_audit_clean_on_shipped_entry_points():
    from repro.analysis.jaxpr_audit import run_jaxpr_audit

    findings = run_jaxpr_audit()
    assert not findings, "\n".join(f.render() for f in findings)


def test_bucket_grid_within_bound():
    from repro.analysis.jaxpr_audit import run_jaxpr_audit

    assert not run_jaxpr_audit(entries=["buckets"])


# ---------------------------------------------------------------------------
# CLI gate (the tier-1 wire-in)
# ---------------------------------------------------------------------------
def test_cli_strict_exits_zero_on_shipped_tree():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict"],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=300,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 live finding(s)" in out.stderr, out.stderr


def test_finding_key_survives_line_drift():
    f1 = Finding("lint", "host-sync", "m", "p.py", 10, source="x = float(y)")
    f2 = Finding("lint", "host-sync", "m", "p.py", 99, source="x = float(y)")
    assert f1.key() == f2.key()
