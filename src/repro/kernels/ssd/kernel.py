"""Pallas TPU kernel for the Mamba-2 SSD chunk scan.

Grid: (batch, ssm_heads, num_chunks) — chunks innermost, so the (P, N) SSM
state lives in VMEM scratch and carries across chunk iterations (the
inter-chunk recurrence), while each chunk's intra-block work is three
MXU matmuls: C·Bᵀ (Q×Q decay-masked "attention"), its product with dt·x, and
the state outer-product update. This is the state-space-duality mapping that
makes SSMs MXU-shaped — per DESIGN.md, the reason we adapt Mamba to SSD form
on TPU rather than porting the GPU selective-scan.

VMEM working set per step at (Q=128, P=64, N=128): ~0.4 MB — far under
budget; Q is the tunable block knob.

Exponents are ≤ 0 by construction (A < 0, dt > 0), so the fp32 exp/cumsum
chain cannot overflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref,  # (1, Q, 1, P)
    dt_ref,  # (1, Q, 1)
    a_ref,  # (1,)
    b_ref,  # (1, Q, N)
    c_ref,  # (1, Q, N)
    y_ref,  # (1, Q, 1, P)
    hout_ref,  # (1, 1, P, N)
    h_ref,  # VMEM scratch (P, N) f32
    *,
    nc: int,
):
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    a = a_ref[0].astype(jnp.float32)  # scalar
    bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    cm = c_ref[0].astype(jnp.float32)  # (Q, N)

    da = dt * a  # (Q,) ≤ 0
    cum = jnp.cumsum(da)  # (Q,)
    xdt = x * dt[:, None]  # (Q, P)

    # intra-chunk: masked decay attention
    q = x.shape[0]
    li = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask = li >= lj
    diff = jnp.where(mask, cum[:, None] - cum[None, :], 0.0)  # avoid exp(+big)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(
        cm, bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    y = jax.lax.dot_general(
        cb * decay, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # inter-chunk: contribution of the carried state
    h_prev = h_ref[...]  # (P, N)
    y = y + jax.lax.dot_general(
        cm, h_prev, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]

    # state update: h ← h·exp(Σda) + Σ_j exp(cum_Q − cum_j)·(dt_j x_j) ⊗ B_j
    decay_to_end = jnp.exp(cum[-1] - cum)  # (Q,)
    h_ref[...] = h_prev * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xdt * decay_to_end[:, None],
        bm,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(c_idx == nc - 1)
    def _emit_state():
        hout_ref[0, 0, :, :] = h_ref[...]


def ssd_chunk_scan(
    x: jax.Array,  # (B, S, H, P)
    dt: jax.Array,  # (B, S, H)
    a: jax.Array,  # (H,)
    b_mat: jax.Array,  # (B, S, N)
    c_mat: jax.Array,  # (B, S, N)
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    kernel = functools.partial(_ssd_kernel, nc=nc)
    y, h_final = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b_, h_, c: (b_, c, h_)),
            pl.BlockSpec((1,), lambda b_, h_, c: (h_,)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, h_, c: (b_, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda b_, h_, c: (b_, c, h_, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b_, h_, c: (b_, h_, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b_mat, c_mat)
    return y, h_final
