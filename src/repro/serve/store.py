"""Content-addressed design-evaluation cache (the serve layer's database).

``core/database.py`` hashes task *names* into stable units
(``_stable_unit``); this module generalizes the idiom to whole evaluations:
the cache key is a SHA-256 digest over the **content** of a candidate's
flat :class:`~repro.core.phase_sim_jax.EncodedDesign` leaves (every array
the device row is filled from, plus ``noc_pj``), the workload's encoded
tensors, and the Eq.-7 budget/alpha the dispatch would score against. Two
candidates with identical digests produce bit-identical device rows, so the
second one can be served from the first one's memoized output row without a
dispatch — across sessions, across users, across time.

The store deliberately knows nothing about JAX or the backend beyond two
duck-typed facts:

  * a *pending* entry holds ``(batch, j)`` where ``batch.host()`` yields the
    dispatch's host-side column dict (the backend registers every dispatched
    row right after submission — nothing is forced early);
  * a *materialized* entry is that dict sliced to one row (leading axis kept
    at 1 so a cached row quacks exactly like a one-row batch).

Entries materialize lazily on first hit — the producing batch has almost
always been consumed by then (any handle read forces it), so materialization
is a few row copies, after which the batch reference is dropped and the
entry is compact. Eviction is LRU under a configurable ``capacity`` bound,
with one carve-out: a pending entry whose producing dispatch is still in
flight is pinned (evicting it would lose the row or force the dispatch
early), so the store may transiently overshoot ``capacity`` until those
batches are consumed.

Hit/miss/bypass accounting lives twice on purpose: per backend in
``BackendStats`` (``n_cache_hits``/``n_cache_misses``/``n_cache_bypass``)
and aggregated here across every backend sharing the store — the service's
fleet-level hit rate.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, Optional, Tuple

import numpy as np

# EncodedDesign leaves that fill a device row (phase_sim_jax.ENCODED_FIELDS
# plus the noc_pj scalar fill_row writes separately). Imported lazily so the
# store stays importable without pulling jax at module-import time.
_FIELDS: Optional[Tuple[str, ...]] = None


def _fields() -> Tuple[str, ...]:
    global _FIELDS
    if _FIELDS is None:
        from ..core.phase_sim_jax import ENCODED_FIELDS

        _FIELDS = tuple(ENCODED_FIELDS) + ("noc_pj",)
    return _FIELDS


@dataclasses.dataclass
class StoreStats:
    """Fleet-level cache accounting (across every backend sharing the store).

    ``hits`` counts both store hits (served from a memoized row of an
    earlier dispatch) and same-dispatch aliases (two sessions submitting the
    identical candidate in one scheduler tick share one device row);
    ``misses`` counts rows actually dispatched and registered; ``bypasses``
    counts candidates that skipped the cache entirely (scalar-fallback
    pricing has no device row to memoize). ``evictions`` counts LRU drops."""

    hits: int = 0
    misses: int = 0
    bypasses: int = 0
    evictions: int = 0

    def hit_rate(self) -> float:
        """Hits over cacheable lookups (bypasses excluded)."""
        n = self.hits + self.misses
        return self.hits / n if n else 0.0


class _Entry:
    """One cached evaluation: pending ``(batch, j)`` until first hit, then a
    compact one-row column dict."""

    __slots__ = ("batch", "j", "row")

    def __init__(self, batch, j: int) -> None:
        self.batch = batch
        self.j = j
        self.row: Optional[Dict[str, np.ndarray]] = None

    def materialize(self) -> Dict[str, np.ndarray]:
        if self.row is None:
            host = self.batch.host()
            j = self.j
            # keep the leading axis at length 1: a cached row is shaped like
            # a one-row batch, so the backend's handle machinery reads it
            # through the exact same code path as a fresh dispatch
            self.row = {k: np.ascontiguousarray(v[j:j + 1]) for k, v in host.items()}
            self.batch = None  # drop the producing batch; entry is compact
        return self.row


class DesignStore:
    """LRU content-addressed map: evaluation digest → memoized device row.

    One store may back any number of backends/workloads concurrently — the
    workload digest is part of every key, so entries never collide across
    task graphs. Thread-unsafe by design (the service is a single-threaded
    tick loop)."""

    def __init__(self, capacity: int = 1 << 16) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.stats = StoreStats()
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()

    def __len__(self) -> int:
        return len(self._entries)

    # ---- digests ---------------------------------------------------------
    @staticmethod
    def workload_digest(enc) -> bytes:
        """Content digest of an ``EncodedWorkload``: the static per-task
        tensors plus the task/workload name order (names pin the row layout
        the finish/bneck columns are decoded through)."""
        h = hashlib.sha256(b"workload")
        for name in ("work_ops", "read_bytes", "write_bytes", "burst", "llp",
                     "parent_mask", "wl_id"):
            arr = np.asarray(getattr(enc, name))
            h.update(name.encode())
            h.update(str(arr.shape).encode())
            h.update(arr.tobytes())
        h.update("\x00".join(enc.names).encode())
        h.update("\x00".join(enc.wl_names).encode())
        return h.digest()

    @staticmethod
    def budget_digest(budget, alpha: float) -> bytes:
        """Digest of the Eq.-7 scoring inputs a dispatch row carries
        (``fill_budget``): per-workload latency budgets, power/area rails,
        and the dampening alpha. ``None`` (neutral scoring) is its own key."""
        h = hashlib.sha256(b"budget")
        if budget is None:
            h.update(b"none")
        else:
            for w in sorted(budget.latency_s):
                h.update(w.encode())
                h.update(np.float64(budget.latency_s[w]).tobytes())
            h.update(np.float64(budget.power_w).tobytes())
            h.update(np.float64(budget.area_mm2).tobytes())
        h.update(np.float64(alpha).tobytes())
        return h.digest()

    @staticmethod
    def key_of(ed, wl_digest: bytes, budget_digest: bytes) -> bytes:
        """The content address of one evaluation: every EncodedDesign leaf
        the device row is filled from. Block *names* (the slot dicts) are
        deliberately excluded — two designs that differ only in naming
        price identically, and name resolution happens at decode time
        against the consumer's own design."""
        h = hashlib.sha256(wl_digest)
        h.update(budget_digest)
        for f in _fields():
            arr = np.asarray(getattr(ed, f))
            h.update(f.encode())
            h.update(str(arr.shape).encode())
            h.update(arr.tobytes())
        return h.digest()

    # ---- cache operations ------------------------------------------------
    def lookup(self, key: bytes) -> Optional[Dict[str, np.ndarray]]:
        """The memoized one-row column dict for ``key``, or None. A hit
        refreshes LRU recency and is counted; misses are only counted when
        the backend registers the dispatched row (``insert``)."""
        entry = self._entries.get(key)
        if entry is None:
            return None
        self._entries.move_to_end(key)
        self.stats.hits += 1
        row = entry.materialize()
        self._evict()  # entries pinned at insert time may be evictable now
        return row

    def insert(self, key: bytes, batch, j: int) -> None:
        """Register row ``j`` of a just-submitted dispatch under ``key``
        (counted as the miss that produced it). Nothing is forced: the entry
        stays pending until its first hit materializes it."""
        self.stats.misses += 1
        self._entries[key] = _Entry(batch, j)
        self._entries.move_to_end(key)
        self._evict()

    def _evict(self) -> None:
        """LRU eviction down to ``capacity`` — but a pending entry whose
        producing batch is still in flight is PINNED: evicting it here would
        either silently lose the row (the hazard this fixes) or force the
        just-submitted non-blocking dispatch early (destroying the pipeline
        the backend exists for). Pinned entries let the store overshoot
        capacity transiently; the overshoot drains on the next ``insert`` or
        materializing ``lookup`` after the batch is consumed, since a
        consumed batch's entries evict normally."""
        excess = len(self._entries) - self.capacity
        if excess <= 0:
            return
        for key, entry in list(self._entries.items()):  # LRU → MRU
            if entry.row is None and not getattr(entry.batch, "consumed", True):
                continue  # pinned: source dispatch still in flight
            del self._entries[key]
            self.stats.evictions += 1
            excess -= 1
            if excess <= 0:
                return

    def note_alias_hit(self) -> None:
        """Count a same-dispatch alias: a duplicate candidate inside one
        batch shares the first occurrence's device row (no store entry is
        involved, but it is a dedupe all the same)."""
        self.stats.hits += 1

    def note_bypass(self) -> None:
        self.stats.bypasses += 1
