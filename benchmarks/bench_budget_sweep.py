"""Paper Figs. 14–15 (§6.1 case study): budget relaxation vs system
complexity. Sweep budgets ×1/×2/×4 and report block counts, memory size,
NoC frequency, and heterogeneity (coefficient of variation) of the converged
designs — FARSI must spend relaxed budgets on *simpler* systems."""
from __future__ import annotations

import statistics
from typing import List

from repro.core import Explorer, ExplorerConfig, HardwareDatabase, ar_complex, calibrated_budget
from repro.core.blocks import BlockKind

from .common import Row

SEEDS = (1, 2)
SCALES = (1.0, 2.0, 4.0)


def run() -> List[Row]:
    db = HardwareDatabase()
    g = ar_complex()
    base = calibrated_budget(db)
    rows: List[Row] = []
    for scale in SCALES:
        pes, nocs, mems, mem_bytes, noc_freqs = [], [], [], [], []
        cv_links, cv_mem, cv_freq, alps, traffic = [], [], [], [], []
        for seed in SEEDS:
            res = Explorer(
                g, db, base.scaled(scale), ExplorerConfig(max_iterations=500, seed=seed)
            ).run()
            d = res.best_design
            c = d.block_counts()
            pes.append(c["pe"])
            nocs.append(c["noc"])
            mems.append(c["mem"])
            mem_bytes.append(sum(res.best_result.mem_capacity_bytes.values()))
            noc_freqs.append(
                statistics.mean(d.blocks[n].freq_mhz for n in d.nocs())
            )
            cv_links.append(d.heterogeneity_cv(BlockKind.NOC, "n_links"))
            cv_mem.append(d.heterogeneity_cv(BlockKind.MEM, "width_bytes"))
            cv_freq.append(d.heterogeneity_cv(BlockKind.NOC, "freq_mhz"))
            alps.append(res.best_result.avg_accel_parallelism)
            traffic.append(res.best_result.total_traffic_bytes)
        rows.append(
            (
                f"fig14.budget_{scale:g}x",
                0.0,
                f"pe={statistics.mean(pes):.1f} noc={statistics.mean(nocs):.1f} "
                f"mem={statistics.mean(mems):.1f} mem_bytes={statistics.mean(mem_bytes):.2e} "
                f"noc_freq={statistics.mean(noc_freqs):.0f}MHz",
            )
        )
        rows.append(
            (
                f"fig15.heterogeneity_{scale:g}x",
                0.0,
                f"cv_noc_links={statistics.mean(cv_links):.2f} "
                f"cv_mem_width={statistics.mean(cv_mem):.2f} "
                f"cv_noc_freq={statistics.mean(cv_freq):.2f}",
            )
        )
        # Fig 16: system dynamics — tighter budgets need more accelerator-
        # level parallelism and move more traffic
        rows.append(
            (
                f"fig16.dynamics_{scale:g}x",
                0.0,
                f"alp={statistics.mean(alps):.2f} "
                f"traffic_bytes={statistics.mean(traffic):.2e}",
            )
        )
    return rows
