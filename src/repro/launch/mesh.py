"""Production mesh definitions.

Functions (not module-level constants) so importing this module never touches
jax device state. Single pod = 16×16 = 256 chips ("data", "model"); multi-pod
= 2×16×16 = 512 chips with the leading "pod" axis spanning the (slower)
inter-pod links — batch shards over ("pod", "data") so cross-pod traffic is
gradient reduction only.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host has (tests / examples): (n_devices/model, model)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
