"""Paper Fig. 9: convergence of the DSE.

9a — simulator agility's impact: the same heuristic with the phase-driven
simulator vs the event-driven reference as its inner loop (the paper
extrapolates PA; we actually run both and extrapolate per-sim cost).
9b — architecture awareness: SA / Task-aware / Task&Block-aware / FARSI
distance-vs-iteration, averaged over seeds.

The seed × awareness grid runs as one `Campaign`: every live exploration's
neighbour batch is cross-batched into a shared dispatch stream instead of
3 × 4 independent simulate() loops.
"""
from __future__ import annotations

import statistics
import time
from typing import List

from repro.core import (
    AWARENESS_LEVELS,
    Campaign,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    ar_complex,
    calibrated_budget,
    simulate_events,
)

from .common import Row

SEEDS = (1, 2, 3)
MAX_ITERS = 600


def run() -> List[Row]:
    db = HardwareDatabase()
    g = ar_complex()
    bud = calibrated_budget(db)
    rows: List[Row] = []

    # --- 9b: awareness ladder, one campaign over the whole grid ---------
    camp = Campaign.sweep(
        db,
        {g.name: g},
        bud,
        seeds=SEEDS,
        awareness=AWARENESS_LEVELS,
        max_iterations=MAX_ITERS,
    )
    cres = camp.run()
    per_level = {}
    for level in AWARENESS_LEVELS:
        runs = [cres.runs[f"{g.name}.{level}.s{s}"] for s in SEEDS]
        iters = [r.iterations if r.converged else MAX_ITERS for r in runs]
        per_level[level] = statistics.mean(iters)
        rows.append(
            (
                # per-run wall is campaign-wide under lockstep execution; the
                # attributed share of shared dispatches is the per-level cost
                f"fig9b.{level}",
                statistics.mean([r.sim_wall_s for r in runs]) * 1e6,
                f"iters_avg={statistics.mean(iters):.0f} "
                f"dist_avg={statistics.mean([r.best_distance.city_block() for r in runs]):.3f} "
                f"converged={sum(r.converged for r in runs)}/{len(SEEDS)} "
                f"blocks_avg={statistics.mean([sum(r.best_design.block_counts().values()) for r in runs]):.1f}",
            )
        )
    if per_level["farsi"] > 0:
        rows.append(
            (
                "fig9b.speedup_vs_sa",
                0.0,
                f"sa/farsi={per_level['sa']/per_level['farsi']:.1f}x "
                f"task/farsi={per_level['task']/per_level['farsi']:.1f}x "
                f"task_block/farsi={per_level['task_block']/per_level['farsi']:.1f}x",
            )
        )
    stats = cres.backend_stats[g.name]
    rows.append(
        (
            "fig9b.campaign",
            cres.wall_s * 1e6,
            f"runs={int(cres.aggregate['n_runs'])} sims={stats.n_sims} "
            f"dispatches={stats.n_dispatches} "
            f"sims_per_dispatch={stats.n_sims/max(stats.n_dispatches,1):.1f}",
        )
    )

    # --- 9a: simulator agility -------------------------------------------
    ex = Explorer(g, db, bud, ExplorerConfig(max_iterations=MAX_ITERS, seed=1))
    res = ex.run()
    phase_wall = res.wall_s
    n_sims = res.n_sims
    # measured per-sim cost of the reference simulator on the final design
    t0 = time.perf_counter()
    simulate_events(res.best_design, g, db, max_chunks=128)
    event_per_sim = time.perf_counter() - t0
    est_event_wall = event_per_sim * n_sims
    rows.append(
        (
            "fig9a.convergence_time",
            phase_wall * 1e6,
            f"farsi_sim={phase_wall:.1f}s est_with_event_sim={est_event_wall:.0f}s "
            f"ratio={est_event_wall/max(phase_wall,1e-9):.0f}x sims={n_sims}",
        )
    )
    return rows
