"""Training-loop semantics: loss goes down, microbatch equivalence, chunked
CE correctness, prefill→decode consistency with the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import reduced_config
from repro.data.pipeline import for_model
from repro.launch.serve import extend_cache, generate
from repro.models.model import RunFlags, forward, init_params
from repro.optim.adamw import AdamWConfig
from repro.train.step import (
    chunked_ce_loss,
    cross_entropy,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)


def test_loss_decreases_qwen3():
    cfg = reduced_config("qwen3-1.7b")
    data = for_model(cfg, seq_len=32, global_batch=8, seed=0)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(
        make_train_step(cfg, RunFlags(attn_impl="full"), AdamWConfig(peak_lr=3e-3, warmup_steps=5, total_steps=60))
    )
    losses = []
    for _ in range(40):
        batch = jax.tree.map(jnp.asarray, data.next_batch())
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[::8]


def test_microbatch_equivalence():
    """grad accumulation over k microbatches == single big batch (same loss
    metric and near-identical params after one step)."""
    cfg = reduced_config("qwen3-1.7b")
    data = for_model(cfg, seq_len=32, global_batch=8, seed=1)
    batch = jax.tree.map(jnp.asarray, data.next_batch())
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0)
    s0 = init_train_state(cfg, jax.random.PRNGKey(1))
    s1, m1 = jax.jit(make_train_step(cfg, RunFlags(attn_impl="full"), opt, microbatches=1))(s0, batch)
    s4, m4 = jax.jit(make_train_step(cfg, RunFlags(attn_impl="full"), opt, microbatches=4))(s0, batch)
    # losses agree to bf16 rounding; each param moves by ≤ lr·(1+wd) per
    # entry, so the two updates differ by at most ~2 step sizes (AdamW's
    # sqrt(v) normalization can flip near-zero grads between groupings)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-3
    step_bound = 2.1 * opt.peak_lr * (1 + opt.weight_decay)
    p1 = jax.tree.leaves(s1["params"])
    p4 = jax.tree.leaves(s4["params"])
    for a, b in zip(p1, p4):
        assert float(jnp.abs(a - b).max()) <= step_bound


def test_chunked_ce_matches_plain():
    key = jax.random.PRNGKey(3)
    b, s, d, v = 2, 32, 16, 64
    hidden = jax.random.normal(key, (b, s, d), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(4), (d, v), jnp.float32)
    labels = jax.random.randint(key, (b, s), 0, v)
    plain = cross_entropy(jnp.einsum("bsd,dv->bsv", hidden, w), labels)
    chunked = chunked_ce_loss(hidden, w, labels, n_chunks=4)
    np.testing.assert_allclose(plain, chunked, rtol=1e-6)
    # grads agree too
    g1 = jax.grad(lambda h: cross_entropy(jnp.einsum("bsd,dv->bsv", h, w), labels))(hidden)
    g2 = jax.grad(lambda h: chunked_ce_loss(h, w, labels, n_chunks=4))(hidden)
    np.testing.assert_allclose(g1, g2, atol=1e-6, rtol=1e-4)


@pytest.mark.parametrize("name", ["qwen3-1.7b", "jamba-v0.1-52b", "mamba2-370m"])
def test_prefill_decode_matches_forward(name):
    """Teacher-forced decode over the cache must reproduce the full-sequence
    forward logits (attention + SSM state handoff correctness).

    MoE capacity dropping is batch-coupled (position-in-expert is a cumsum
    over the flat token axis), so exact equality needs a dropless capacity
    factor — serving deployments use dropless dispatch for the same reason."""
    import dataclasses

    cfg = dataclasses.replace(reduced_config(name), capacity_factor=8.0)
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    s_total, s_prompt = 24, 16
    toks = jax.random.randint(key, (2, s_total), 0, cfg.vocab_size)

    flags = RunFlags(attn_impl="full", ssd_chunk=8)
    full_logits, _, _ = forward(params, cfg, {"tokens": toks}, flags, compute_dtype=jnp.float32)

    # prefill in fp32 for a tight comparison
    p_logits, _, cache = forward(
        params, cfg, {"tokens": toks[:, :s_prompt]}, flags, compute_dtype=jnp.float32, want_cache=True
    )
    logits_last = p_logits[:, -1]
    cache = extend_cache(cfg, cache, s_total)
    np.testing.assert_allclose(
        logits_last, full_logits[:, s_prompt - 1], atol=2e-2, rtol=1e-2
    )
    from repro.models.model import decode_step as model_decode

    for i in range(s_prompt, s_total):
        logits, cache = model_decode(
            params, cfg, cache, {"tokens": toks[:, i : i + 1]}, jnp.int32(i), flags,
            compute_dtype=jnp.float32,
        )
        np.testing.assert_allclose(
            logits[:, 0], full_logits[:, i], atol=5e-2, rtol=2e-2
        )


def test_generate_runs():
    cfg = reduced_config("qwen3-1.7b")
    params = init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    out, _ = generate(params, cfg, {"tokens": toks}, n_tokens=5, flags=RunFlags(attn_impl="full", ssd_chunk=8))
    assert out.shape == (2, 5)
    assert bool((out >= 0).all() and (out < cfg.vocab_size).all())
