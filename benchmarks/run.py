"""Benchmark harness — one bench per paper table/figure plus the §Roofline
table. Prints ``name,us_per_call,derived`` CSV per row.

  table4b   simulator accuracy + speedup vs the event-driven reference
  fig8      DSE time breakdown (design duplication hot-spot)
  fig9      convergence: simulator agility (9a) + awareness ladder (9b)
  fig10     co-design rates, contributions, ON/OFF ablation
  fig12/13  domain awareness (boundedness + parallelism exploitation)
  fig14/15  budget relaxation vs system complexity/heterogeneity
  fig17     divide-and-conquer suboptimality
  roofline  all (arch × shape) baseline roofline terms
  simbackend scalar-Python vs batched-JAX backend throughput, Pallas
             kernel-vs-ref dispatch, explorer iteration rate incl. the
             device-resident fused (R, K) chain blocks, heuristic-policy
             convergence comparison + synthetic-scenario policy sweep
             (also writes BENCH_simbackend.json for trajectory tracking)

After a full (non ``--smoke``) run, every ``benchmarks/BENCH_*.json`` is
mirrored to the repo root, where the perf-trajectory tracker looks for it.
"""
from __future__ import annotations

import argparse
import glob
import os
import shutil
import time

from . import (
    bench_budget_sweep,
    bench_codesign,
    bench_convergence,
    bench_divide_conquer,
    bench_domain,
    bench_generation,
    bench_roofline,
    bench_sim_validation,
    bench_simbackend,
)
from .common import emit

BENCHES = {
    "table4b": bench_sim_validation,
    "fig8": bench_generation,
    "fig9": bench_convergence,
    "fig10": bench_codesign,
    "fig12_13": bench_domain,
    "fig14_15": bench_budget_sweep,
    "fig17": bench_divide_conquer,
    "roofline": bench_roofline,
    "simbackend": bench_simbackend,
}


def _mirror_bench_json() -> None:
    """Copy every benchmarks/BENCH_*.json next to the repo root: the perf-
    trajectory tracker only reads root-level BENCH_*.json, so numbers that
    live solely inside benchmarks/ are invisible to it.

    Each mirror is written atomically (tmp + rename into the destination
    directory, so the rename never crosses filesystems): a run that dies
    mid-write can leave a stale root mirror, but never a torn one that the
    tracker would half-parse as a regression."""
    bench_dir = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(bench_dir)
    for src in sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json"))):
        dst = os.path.join(root, os.path.basename(src))
        tmp = dst + ".tmp"
        shutil.copyfile(src, tmp)
        os.replace(tmp, dst)
        print(f"mirror,{os.path.basename(src)},0.0,copied to repo root", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", choices=sorted(BENCHES), default=None)
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="perf-regression guard: runs the repro.analysis layout "
        "contracts first (non-zero exit on any cross-file desync), then a "
        "tiny simbackend run that *asserts* the "
        "JAX neighbour-eval path beats the Python path, both agree on the "
        "winner, multi-NoC batches dispatch at ≥0.5x the single-NoC "
        "throughput with zero fallbacks, the Pallas kernel matches the ref "
        "path ≤1e-5, the fused device loop sustains ≥2x the host-driven "
        "loop at R=16 (n_compiles ≤ 4, n_fallback == 0, R=1 parity), the "
        "mixed mapping+allocation block does the same on the widened move "
        "table (R=1 parity, ≥2x at R=16, n_compiles ≤ 6, n_fallback == 0), "
        "the root BENCH-json mirror is byte-identical to its source, and "
        "FarsiPolicy converges in ≤ NaiveSA's iterations on audio — "
        "non-zero exit on regression; invoked by tier-1",
    )
    args = ap.parse_args()
    if args.smoke:
        t0 = time.perf_counter()
        # layout contracts first: a desynced scal schema or taboo width
        # makes every perf number below meaningless, so fail before
        # timing anything (repro.analysis also runs standalone in tier-1)
        from repro.analysis.contracts import run_contracts

        contract_findings = run_contracts()
        if contract_findings:
            for f in contract_findings:
                print(f"contracts.ERROR,0.0,{f.render()}", flush=True)
            raise SystemExit("layout contracts violated — see above")
        print("contracts.ok,0.0,all layout contracts hold", flush=True)
        emit(bench_simbackend.run(smoke=True))  # raises on regression
        print(f"smoke.wall,{(time.perf_counter()-t0)*1e6:.0f},bench wall time", flush=True)
        return
    names = args.only or list(BENCHES)
    print("name,us_per_call,derived")
    for name in names:
        t0 = time.perf_counter()
        try:
            rows = BENCHES[name].run()
        except Exception as e:  # keep the harness running; report the failure
            print(f"{name}.ERROR,0.0,{type(e).__name__}: {e}", flush=True)
            continue
        emit(rows)
        print(f"{name}.wall,{(time.perf_counter()-t0)*1e6:.0f},bench wall time", flush=True)
    _mirror_bench_json()


if __name__ == "__main__":
    main()
