"""Serve layer: content-addressed DesignStore semantics (bit-identical hits,
LRU eviction), continuous-batching determinism (mid-flight joins), DseService
multi-session runs, Campaign-through-scheduler equivalence, and chain-batched
session ticks."""
import numpy as np
import pytest

from repro.core import (
    Campaign,
    Explorer,
    ExplorerConfig,
    HardwareDatabase,
    JaxBatchedBackend,
    audio,
    calibrated_budget,
    edge_detection,
    random_single_noc_designs,
)
from repro.core.backend import Candidate
from repro.serve import DesignStore, DseService


@pytest.fixture(scope="module")
def db():
    return HardwareDatabase()


@pytest.fixture(scope="module")
def g(db):
    return edge_detection()


@pytest.fixture(scope="module")
def bud(db):
    return calibrated_budget(db)


def _force(handles):
    for h in handles:
        h.fitness  # one stacked device_get per batch


# ---- DesignStore ---------------------------------------------------------
def test_cache_hit_bit_identical(db, g, bud):
    """A store hit serves the memoized row of an earlier identical dispatch:
    fitness and PPA scalars are bit-identical floats, and no device rows are
    dispatched for a fully-hitting batch."""
    # seed 3 yields six content-DISTINCT designs (random designs can collide
    # in encoded content, which would — correctly — alias within the batch)
    designs = random_single_noc_designs(g, 6, seed=3)
    store = DesignStore()
    jb = JaxBatchedBackend(g, db)
    jb.attach_store(store)

    first = jb.evaluate_candidates([Candidate.of_design(d, bud) for d in designs])
    _force(first)
    assert store.stats.misses == 6 and store.stats.hits == 0

    again = jb.evaluate_candidates([Candidate.of_design(d, bud) for d in designs])
    s = jb.stats()
    assert s.n_cache_hits == 6 and store.stats.hits == 6
    assert store.stats.misses == 6  # nothing new dispatched
    for a, b in zip(first, again):
        assert b.fitness == a.fitness  # bit-identical, not approx
        assert b.scalars() == a.scalars()


def test_within_batch_alias_dedupes(db, g, bud):
    """Two identical candidates inside ONE dispatch share a single device
    row (the store has no entry yet at lookup time — the batch-local alias
    map is what dedupes co-batched replicas)."""
    d = random_single_noc_designs(g, 1, seed=2)[0]
    store = DesignStore()
    jb = JaxBatchedBackend(g, db)
    jb.attach_store(store)
    got = jb.evaluate_candidates([Candidate.of_design(d, bud) for _ in range(3)])
    assert store.stats.misses == 1 and store.stats.hits == 2
    assert got[1].fitness == got[0].fitness == got[2].fitness


def test_store_eviction_respects_capacity(db, g, bud):
    """LRU eviction under a configurable capacity bound — deferred past the
    producing dispatch: entries whose batch is still in flight are pinned
    (see test_store_eviction_pins_pending_entries), so eviction lands on the
    first insert/lookup after the batch is consumed."""
    with pytest.raises(ValueError):
        DesignStore(capacity=0)
    designs = random_single_noc_designs(g, 8, seed=3)
    store = DesignStore(capacity=4)
    jb = JaxBatchedBackend(g, db)
    jb.attach_store(store)
    _force(jb.evaluate_candidates([Candidate.of_design(d, bud) for d in designs]))
    # all 8 entries were registered while their dispatch was in flight —
    # pinned, so the store transiently overshoots capacity with 0 evictions
    assert len(store) == 8 and store.stats.evictions == 0
    assert store.stats.misses == 8
    # the batch is consumed now; the first lookups both hit the 4 survivors
    # (most recently inserted) and drain the overshoot down to capacity
    again = jb.evaluate_candidates(
        [Candidate.of_design(d, bud) for d in designs[4:]]
    )
    assert store.stats.hits == 4
    assert jb.stats().n_cache_hits == 4
    assert len(store) == 4 and store.stats.evictions == 4
    _force(again)


def test_store_eviction_pins_pending_entries(db, g, bud):
    """Regression for the eviction hazard: an LRU entry whose (batch, j)
    source is still PENDING used to be evictable before materialization —
    losing the row (or, if materialized eagerly, forcing the just-submitted
    non-blocking dispatch). Pinned pending entries must survive capacity
    pressure and still serve bit-identical hits once their batch lands."""
    designs = random_single_noc_designs(g, 4, seed=5)
    store = DesignStore(capacity=2)
    jb = JaxBatchedBackend(g, db)
    jb.attach_store(store)
    first = jb.evaluate_candidates([Candidate.of_design(d, bud) for d in designs])
    # nothing forced yet: every entry is pending on the in-flight batch, so
    # nothing may be evicted — the overshoot is the fix working
    assert len(store) == 4 and store.stats.evictions == 0
    _force(first)  # batch consumed; entries are now evictable
    # every registered row must still be servable, bit-identically
    again = jb.evaluate_candidates(
        [Candidate.of_design(d, bud) for d in designs[2:]]
    )
    assert store.stats.hits == 2  # the 2 MRU entries hit...
    assert len(store) == 2  # ...and the overshoot drained to capacity
    assert store.stats.evictions == 2
    for a, b in zip(first[2:], again):
        assert b.fitness == a.fitness
        assert b.scalars() == a.scalars()


def test_key_excludes_block_names(db, g, bud):
    """Pure content addressing: renaming a block changes no array leaf, so
    the digest — and therefore the cached row — is shared."""
    from repro.core.phase_sim_jax import EncodedDesign, EncodedWorkload

    d = random_single_noc_designs(g, 1, seed=4)[0]
    enc = EncodedWorkload.of(g)
    ed = EncodedDesign.of(d, g, db, enc)
    wl = DesignStore.workload_digest(enc)
    bd = DesignStore.budget_digest(bud, 0.05)
    k1 = DesignStore.key_of(ed, wl, bd)
    d.rename_block(d.pes()[0], "totally_new_name")
    k2 = DesignStore.key_of(EncodedDesign.of(d, g, db, enc), wl, bd)
    assert k1 == k2
    # ...while a different budget (scoring input) must not collide
    assert DesignStore.budget_digest(None, 0.05) != bd


# ---- continuous batching -------------------------------------------------
def test_midflight_join_matches_solo(db, g, bud):
    """A session admitted mid-flight — co-batched with a stranger already
    several ticks in — walks the exact accepted-move sequence (and final
    distance) of the same config run alone: per-row results are independent
    of batch composition, and cache hits are bit-identical."""
    cfg = dict(seed=5, max_iterations=30, backend="jax")
    solo = Explorer(g, db, bud, ExplorerConfig(**cfg)).run()
    solo_seq = [(h["move"], h["accepted"]) for h in solo.history]

    svc = DseService(db, backend="jax")
    svc.submit("warm", g, bud, ExplorerConfig(seed=11, max_iterations=45, backend="jax"))
    for _ in range(6):
        svc.step()  # the stranger is mid-flight when the joiner arrives
    joiner = svc.submit("joiner", g, bud, ExplorerConfig(**cfg))
    svc.run()
    got = joiner.result
    assert [(h["move"], h["accepted"]) for h in got.history] == solo_seq
    assert got.best_distance.city_block() == solo.best_distance.city_block()
    assert got.iterations == solo.iterations


def test_best_event_stream(db, g, bud):
    """Streaming contract: every committed best-so-far improvement fires one
    event, strictly improving; the final result is at least as good as the
    last streamed event."""
    svc = DseService(db, backend="jax")
    h = svc.submit("s", g, bud, ExplorerConfig(seed=1, max_iterations=25, backend="jax"))
    svc.run()
    assert h.done and len(h.events) >= 1
    dists = [e.distance for e in h.events]
    assert all(b < a for a, b in zip(dists, dists[1:]))
    assert h.result.best_distance.city_block() <= dists[-1] + 1e-12
    e = h.events[-1]
    assert e.session == "s" and e.latency_s > 0 and e.area_mm2 > 0


def test_64_session_repeated_scenario_serve(db):
    """The acceptance-criterion run: 64 sessions over a repeated-scenario mix
    (16 distinct policy×seed configs × 4 replicas) complete on one service
    with cache hit-rate > 0.3 and zero scalar fallbacks."""
    g = audio()
    bud = calibrated_budget(db)
    svc = DseService(db, backend="jax")
    handles = []
    for rep in range(16):
        for i, pol in enumerate(("farsi", "naive_sa", "bottleneck", "locality")):
            handles.append(svc.submit(
                f"r{rep}.{pol}",
                g, bud,
                ExplorerConfig(seed=rep % 4, policy=pol, max_iterations=12,
                               backend="jax"),
            ))
    stats = svc.run()
    assert stats.n_done == 64 and all(h.done for h in handles)
    assert stats.n_fallback == 0
    assert stats.cache_hit_rate > 0.3
    assert stats.latency_percentile(95) >= stats.latency_percentile(50) > 0
    # replica sessions (same policy, same seed) converge identically —
    # bit-identical cache hits never perturb a session's own search
    a, b = handles[0].result, handles[16].result  # r0.farsi / r4.farsi, seed 0
    assert a.best_distance.city_block() == b.best_distance.city_block()


def test_duplicate_session_name_rejected(db, g, bud):
    svc = DseService(db, backend="jax")
    svc.submit("same", g, bud, ExplorerConfig(seed=0, max_iterations=5, backend="jax"))
    with pytest.raises(ValueError):
        svc.submit("same", g, bud, ExplorerConfig(seed=1, max_iterations=5, backend="jax"))
    svc.run()


# ---- session-level fault isolation ---------------------------------------
def test_coroutine_death_is_quarantined(db, g, bud):
    """Satellite fix: an exception escaping one session's coroutine used to
    propagate out of step() and abort the whole tick. It must instead fail
    exactly that session — error recorded on the handle, FAILED state,
    result raising SessionFailed — while every co-batched session runs to
    completion through the same ticks."""
    from repro.serve import SessionFailed

    svc = DseService(db, backend="jax")
    doomed = svc.submit(
        "doomed", g, bud, ExplorerConfig(seed=3, max_iterations=15, backend="jax")
    )
    healthy = svc.submit(
        "healthy", g, bud, ExplorerConfig(seed=4, max_iterations=15, backend="jax")
    )
    svc.step()  # let both sessions get a couple of committed ticks in
    svc.step()

    boom = RuntimeError("policy blew up mid-iteration")
    sess = svc._sessions["doomed"]

    def explode(*a, **k):
        raise boom

    sess.explorer.policy.select_focus = explode
    stats = svc.run()  # must not raise

    assert doomed.failed and doomed.error is boom
    assert doomed.state == "failed"
    with pytest.raises(SessionFailed):
        doomed.result
    assert healthy.done and not healthy.failed
    assert healthy.result.iterations > 0
    assert stats.n_failed == 1 and stats.n_done == 1
    assert svc.failures() == {"doomed": boom}


# ---- Campaign as a scheduler client --------------------------------------
def test_campaign_equivalent_with_and_without_store(db, g, bud):
    """Campaign.run() through the scheduler: attaching the evaluation cache
    changes no run outcome (same converged runs, same iteration counts, same
    distances) — it only removes duplicate device rows — and the aggregate
    carries the cache counters."""
    def grid(store):
        camp = Campaign(db, backend="jax", store=store)
        for s in range(3):
            camp.add(f"ed.s{s}", g, bud,
                     ExplorerConfig(seed=s, max_iterations=20, backend="jax"))
        return camp.run()

    plain = grid(None)
    cached = grid(DesignStore())
    assert plain.aggregate["cache_hits_total"] == 0
    assert cached.aggregate["cache_hits_total"] > 0
    assert 0.0 < cached.aggregate["cache_hit_rate"] <= 1.0
    for name in plain.runs:
        a, b = plain.runs[name], cached.runs[name]
        assert a.iterations == b.iterations
        assert a.converged == b.converged
        assert a.best_distance.city_block() == b.best_distance.city_block()
    assert plain.converged_runs() == cached.converged_runs()


# ---- chain-batched sessions ----------------------------------------------
def test_chain_batched_session_coexists_with_host_sessions(db, g, bud):
    """A session whose config opts into device chain blocks (``chain_r > 0``)
    rides the same service as ordinary host-loop sessions: its ChainRequests
    are dispatched as fused blocks (never joining the shared candidate pack),
    both sessions complete, and the chain session's result carries the
    chain-population metadata."""
    svc = DseService(db, backend="jax")
    chain = svc.submit(
        "chain", g, bud,
        ExplorerConfig(policy="device_sa", seed=3, max_iterations=32,
                       chain_r=8, chain_k=16, backend="jax"),
    )
    host = svc.submit(
        "host", g, bud,
        ExplorerConfig(seed=4, max_iterations=15, backend="jax"),
    )
    stats = svc.run()
    assert stats.n_done == 2 and stats.n_failed == 0
    assert chain.done and host.done
    res = chain.result
    assert res.chained and res.chain_r == 8
    assert res.iterations == 32
    assert not host.result.chained
    # streamed improvements from chain blocks carry fitness (scalar PPA
    # columns stay on device until the final decode)
    assert all(np.isfinite(e.fitness) for e in chain.events)
